"""Long-context decode with sub-quadratic mixers (xLSTM / Jamba).

The long_500k shape runs only on the SSM/hybrid archs: their inter-token
state is O(1), so decoding with a huge "context" costs the same per token
as a short one — demonstrated here at small scale by decoding after
contexts of increasing length and showing flat per-token cost, plus the
staged executor splitting the model when its weights "don't fit".

    PYTHONPATH=src python examples/long_context.py --arch xlstm-1.3b
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS
from repro.models import decode_step, forward, init_cache, init_params
from repro.runtime.reconfigure import StagedExecutor


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-1.3b",
                    choices=["xlstm-1.3b", "jamba-v0.1-52b"])
    args = ap.parse_args()
    cfg = ARCHS[args.arch].reduced()
    assert cfg.is_subquadratic
    params = init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    B = 1

    dec = jax.jit(lambda p, c, t, pos: decode_step(p, cfg, t, pos, c))
    print(f"{cfg.name}: per-token decode cost vs context length "
          f"(O(1) state => flat)")
    for ctx in (64, 256, 1024):
        cache = init_cache(cfg, B, ctx + 8, dtype=jnp.float32)
        toks = jax.random.randint(jax.random.PRNGKey(1), (B, ctx), 0,
                                  cfg.vocab)
        _, cache, _ = forward(params, cfg, toks, cache=cache)
        tok = toks[:, :1]
        # warmup + timed decode steps
        logits, cache = dec(params, cache, tok, jnp.asarray([ctx]))
        t0 = time.perf_counter()
        n = 8
        for i in range(n):
            logits, cache = dec(params, cache, tok,
                                jnp.asarray([ctx + 1 + i]))
        logits.block_until_ready()
        dt = (time.perf_counter() - t0) / n * 1e3
        print(f"  ctx={ctx:5d}: {dt:6.1f} ms/token")

    print("\nstaged execution (weights held on host, Eq. 5 accounting):")
    ex = StagedExecutor(cfg, params, n_stages=min(3, cfg.n_groups))
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, 32), 0, cfg.vocab)
    ex.forward_logits(toks)
    eq5 = ex.eq5_latency(batch=1)
    print(f"  stages={eq5['n_stages']} compute={eq5['compute_s'] * 1e3:.0f}ms "
          f"reconfig={eq5['reconfig_s'] * 1e3:.0f}ms "
          f"boundary_compression={eq5['boundary_compression']:.2f}")


if __name__ == "__main__":
    main()
