"""End-to-end serving driver (SMOF is an inference toolflow).

Serves a reduced-config model with batched requests through the continuous-
batching engine: prefill per request, lockstep decode over slots, KV pages
evicted to host in BFP8 when requests finish (the paper's activation
eviction at the HBM<->host level).

    PYTHONPATH=src python examples/serve_batched.py --arch yi-6b \
        --requests 6 --max-new 12

Passing ``--model`` switches to the streaming-graph serving path instead:
an ``EXEC_MODELS`` graph is compiled through ``repro.compile`` (the same
``--channel``/``--channel-gbps`` knobs as quickstart, docs/MEMORY.md) and
frames are served through ``Compiled.serve``; the summary prints the
off-chip per-stream bandwidth table and prefetch deadline misses:

    PYTHONPATH=src python examples/serve_batched.py --model unet_exec \
        --channel weighted-fair --channel-gbps 0.5 --onchip-kbits 300
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import add_compile_args, spec_from_args
from repro.configs import ARCHS
from repro.core import EXEC_MODELS
from repro.models import init_params
from repro.serving.engine import ServingEngine


def serve_graph(args) -> None:
    """Serve a compiled streaming graph and report the channel split."""
    import repro
    from repro.core.resources import get_device

    spec = spec_from_args(args, microbatches=4)
    if args.onchip_kbits is not None:
        spec = dataclasses.replace(spec, device=dataclasses.replace(
            get_device(args.device), onchip_bits=args.onchip_kbits * 1e3))
    c = repro.compile(spec)
    srv = c.serve()
    x = np.zeros(c.input_shape(), np.float32)
    t0 = time.time()
    for _ in range(args.requests):
        srv.submit(x)
    srv.flush()
    dt = time.time() - t0
    st = srv.stats
    print(f"served {st.frames_out}/{st.frames_in} frames of {args.model} "
          f"({c.mode}, {args.device}) in {dt:.2f}s "
          f"({st.frames_out / dt:.1f} fps)")

    mem = getattr(getattr(c.executor, "report", None), "memory", None)
    if mem is None:
        print("\nno off-chip channel model attached "
              "(pass --channel / --channel-gbps, see docs/MEMORY.md)")
        return
    arb = mem.arbitration
    print(f"\noff-chip channel ({mem.config.policy}, "
          f"{mem.channel.gbps:g} Gbps, utilization {arb.utilization:.0%}):")
    print(f"  {'stream':<28} {'kind':<20} {'demand':>9} {'granted':>9}  ok")
    for r in mem.stream_table():
        print(f"  {r['name']:<28} {r['kind']:<20} "
              f"{r['demand_gbps']:>7.2f}G {r['granted_gbps']:>7.2f}G"
              f"  {'yes' if r['satisfied'] else 'NO'}")
    misses = mem.prefetch.deadline_misses
    print(f"  prefetch deadline misses: {misses}"
          + (f" {mem.prefetch.misses_by_stage()}" if misses else ""))
    print(f"  contended Eq.6: {mem.eq6_contended_cycles:g} cycles "
          f"(uncontended {mem.eq6_cycles:g})")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b", choices=sorted(ARCHS))
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--batch-slots", type=int, default=3)
    ap.add_argument("--evict", action="store_true", default=True)
    # --model flips to the streaming-graph path; brings --device/--mode/
    # --channel/--channel-gbps along (docs/MEMORY.md)
    add_compile_args(ap, default_model=None, default_mode="pipelined",
                     models=EXEC_MODELS, modes=("staged", "pipelined"))
    ap.add_argument("--onchip-kbits", type=float, default=None,
                    help="graph path: shrink the on-chip view so the DSE "
                         "evicts/streams (as in quickstart)")
    args = ap.parse_args()

    if args.model is not None:
        serve_graph(args)
        return

    cfg = ARCHS[args.arch].reduced()
    print(f"serving {cfg.name} ({cfg.n_layers}L d={cfg.d_model}) "
          f"with {args.batch_slots} decode slots")
    params = init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    eng = ServingEngine(cfg, params, max_batch=args.batch_slots, s_max=128,
                        evict_to_host=args.evict)

    rng = np.random.default_rng(0)
    reqs = []
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab, size=8 + 4 * (i % 3))
        reqs.append(eng.submit(prompt, max_new_tokens=args.max_new))

    t0 = time.time()
    eng.run_until_drained()
    dt = time.time() - t0

    st = eng.stats
    print(f"\ndrained in {dt:.2f}s")
    print(f"  prefills      : {st.prefills}")
    print(f"  decode steps  : {st.decode_steps} "
          f"(continuous batching: {st.generated} tokens through "
          f"{args.batch_slots} slots)")
    print(f"  tokens/s      : {st.generated / dt:.1f}")
    if st.evicted_pages:
        print(f"  evicted pages : {st.evicted_pages} "
              f"({st.evicted_bytes_raw / 1e6:.2f} MB -> "
              f"{st.evicted_bytes_compressed / 1e6:.2f} MB, "
              f"ratio {st.evicted_bytes_compressed / st.evicted_bytes_raw:.2f}"
              f" — paper Eq. 2's c_bar)")
    for r in reqs[:3]:
        print(f"  req {r.rid}: {len(r.out_tokens)} tokens "
              f"{r.out_tokens[:8]}...")


if __name__ == "__main__":
    main()
