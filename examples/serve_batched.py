"""End-to-end serving driver (SMOF is an inference toolflow).

Serves a reduced-config model with batched requests through the continuous-
batching engine: prefill per request, lockstep decode over slots, KV pages
evicted to host in BFP8 when requests finish (the paper's activation
eviction at the HBM<->host level).

    PYTHONPATH=src python examples/serve_batched.py --arch yi-6b \
        --requests 6 --max-new 12
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.models import init_params
from repro.serving.engine import ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b", choices=sorted(ARCHS))
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--batch-slots", type=int, default=3)
    ap.add_argument("--evict", action="store_true", default=True)
    args = ap.parse_args()

    cfg = ARCHS[args.arch].reduced()
    print(f"serving {cfg.name} ({cfg.n_layers}L d={cfg.d_model}) "
          f"with {args.batch_slots} decode slots")
    params = init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    eng = ServingEngine(cfg, params, max_batch=args.batch_slots, s_max=128,
                        evict_to_host=args.evict)

    rng = np.random.default_rng(0)
    reqs = []
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab, size=8 + 4 * (i % 3))
        reqs.append(eng.submit(prompt, max_new_tokens=args.max_new))

    t0 = time.time()
    eng.run_until_drained()
    dt = time.time() - t0

    st = eng.stats
    print(f"\ndrained in {dt:.2f}s")
    print(f"  prefills      : {st.prefills}")
    print(f"  decode steps  : {st.decode_steps} "
          f"(continuous batching: {st.generated} tokens through "
          f"{args.batch_slots} slots)")
    print(f"  tokens/s      : {st.generated / dt:.1f}")
    if st.evicted_pages:
        print(f"  evicted pages : {st.evicted_pages} "
              f"({st.evicted_bytes_raw / 1e6:.2f} MB -> "
              f"{st.evicted_bytes_compressed / 1e6:.2f} MB, "
              f"ratio {st.evicted_bytes_compressed / st.evicted_bytes_raw:.2f}"
              f" — paper Eq. 2's c_bar)")
    for r in reqs[:3]:
        print(f"  req {r.rid}: {len(r.out_tokens)} tokens "
              f"{r.out_tokens[:8]}...")


if __name__ == "__main__":
    main()
