"""Train a small LM with the fault-tolerant loop: checkpoints, deterministic
data resume, straggler monitoring — then kill and resume to prove restart.

    PYTHONPATH=src python examples/train_small.py --steps 60 --arch yi-6b
"""
import argparse
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.store import CheckpointStore
from repro.configs import ARCHS
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.models import init_params, lm_loss
from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state
from repro.runtime.fault import FaultConfig, FaultTolerantLoop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b", choices=sorted(ARCHS))
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = ARCHS[args.arch].reduced()
    opt_cfg = AdamWConfig(lr=3e-3, warmup_steps=10, total_steps=args.steps,
                          weight_decay=0.01)
    data = TokenPipeline(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                    global_batch=args.batch))
    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="smof_ckpt_")
    store = CheckpointStore(ckpt_dir, keep_last=2)

    params = init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    opt = init_opt_state(params, opt_cfg)
    losses = []

    @jax.jit
    def train_step(state, batch):
        params, opt = state
        loss, grads = jax.value_and_grad(
            lambda p: lm_loss(p, cfg, batch["tokens"], batch["labels"]))(params)
        params, opt, metrics = adamw_update(params, grads, opt, opt_cfg)
        return (params, opt), loss

    def step_fn(state, batch):
        new_state, loss = train_step(state, jax.tree.map(jnp.asarray, batch))
        losses.append(float(loss))
        return new_state

    loop = FaultTolerantLoop(step_fn, store,
                             FaultConfig(checkpoint_every=20))
    half = args.steps // 2
    print(f"training {cfg.name}: {args.steps} steps "
          f"(batch {args.batch} x seq {args.seq}), ckpts -> {ckpt_dir}")
    state = loop.run((params, opt), data.batch_at, start_step=0,
                     num_steps=half)
    print(f"  phase 1: loss {losses[0]:.3f} -> {losses[-1]:.3f}; "
          f"simulating node failure + restart...")

    # "restart": a fresh loop restores the newest checkpoint and resumes the
    # deterministic data stream at the right step
    loop2 = FaultTolerantLoop(step_fn, store, FaultConfig(checkpoint_every=20))
    state2, next_step = loop2.try_restore((params, opt))
    print(f"  restored at step {next_step}")
    loop2.run(state2, data.batch_at, start_step=next_step,
              num_steps=args.steps - next_step)
    print(f"  phase 2: final loss {losses[-1]:.3f} "
          f"(start {losses[0]:.3f}; ln V = {np.log(cfg.vocab):.3f})")
    print(f"  events: { [e['kind'] for e in loop.events + loop2.events] }")
    assert losses[-1] < losses[0], "loss should decrease"
    if args.ckpt_dir is None:
        shutil.rmtree(ckpt_dir, ignore_errors=True)


if __name__ == "__main__":
    main()
