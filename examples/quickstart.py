"""Quickstart: run the SMOF DSE on a paper model and print the design.

    PYTHONPATH=src python examples/quickstart.py [--device u200] [--batch 1]
    PYTHONPATH=src python examples/quickstart.py --model unet_exec --execute

Reproduces the paper's Fig. 4 design point (UNet on U200: ~21 fps, single
partition, weights mostly on-chip) and shows the decision vector the DSE
produced — which edges were evicted, which layers fragmented.  Models are
looked up through the one registry (``repro.core.get_model``): paper-scale
cost-model graphs (``unet``, ``yolov8n``, ...) are costed only, while the
``*_exec`` graphs (``unet_exec``, ``yolo_head_exec``, ``x3d_exec``) can
additionally be *executed* with ``--execute`` — the plan is lowered to a
real JAX pipeline and its off-chip traffic report printed.
"""
import argparse

from repro.core import (DSEConfig, EXEC_MODELS, PAPER_MODELS, exec_input_shape,
                        get_device, get_model, plan_from_dse, run_dse)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--device", default="u200")
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--model", default="unet",
                    help=f"one of: {', '.join(sorted({**EXEC_MODELS, **PAPER_MODELS}))}")
    ap.add_argument("--execute", action="store_true",
                    help="lower the plan to a JAX pipeline and run it "
                         "(needs a *_exec model)")
    args = ap.parse_args()

    dev = get_device(args.device)
    g = get_model(args.model)()
    print(f"{args.model}: {g.total_macs() / 1e9:.1f} GMACs, "
          f"{g.total_weight_words() / 1e6:.1f} M params, "
          f"{g.g.number_of_nodes()} vertices")
    res = run_dse(g, dev, DSEConfig(batch=args.batch,
                                    cut_kinds=("conv", "pool"),
                                    codecs=("none", "rle"), word_bits=8))
    s = res.summary()
    print(f"\nDSE result on {dev.name} (paper Fig. 4 for unet/u200: "
          f"21 fps / 47 ms):")
    print(f"  throughput : {s['throughput_fps']:.2f} fps")
    print(f"  latency    : {s['latency_s'] * 1e3:.1f} ms")
    print(f"  partitions : {s['n_partitions']}")
    print(f"  evictions  : {s['n_evicted_edges']} edges")
    print(f"  fragmented : {s['n_fragmented']} layers "
          f"(mean m={s['mean_frag_ratio']:.2f})")
    for e in res.partitioning.graph.edges():
        if e.evicted:
            print(f"    evicted: {e.src} -> {e.dst}  codec={e.codec}")
    plan = plan_from_dse(args.model, dev.name, res)
    print(f"\nExecutionPlan: {plan.n_stages} stage(s), "
          f"{len(plan.layers)} layers; est {plan.est_throughput_fps:.2f} fps")

    if args.execute:
        if args.model not in EXEC_MODELS:
            raise SystemExit(f"--execute needs a *_exec model, not "
                             f"{args.model!r} (see EXEC_MODELS)")
        import jax
        import jax.numpy as jnp
        from repro.runtime.executor import lower_plan
        low = lower_plan(g, plan)
        x = jax.random.normal(jax.random.PRNGKey(0), exec_input_shape(g),
                              jnp.float32)
        y = low(x)
        print(f"\nexecuted: output shape {tuple(y.shape)}")
        print(f"off-chip traffic: {low.report.summary()}")


if __name__ == "__main__":
    main()
