"""Quickstart: run the SMOF DSE on the paper's UNet and print the design.

    PYTHONPATH=src python examples/quickstart.py [--device u200] [--batch 1]

Reproduces the paper's Fig. 4 design point (UNet on U200: ~21 fps, single
partition, weights mostly on-chip) and shows the decision vector the DSE
produced — which edges were evicted, which layers fragmented.
"""
import argparse

from repro.core import (DSEConfig, build_unet, get_device, plan_from_dse,
                        run_dse)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--device", default="u200")
    ap.add_argument("--batch", type=int, default=1)
    args = ap.parse_args()

    dev = get_device(args.device)
    g = build_unet()
    print(f"UNet: {g.total_macs() / 1e9:.1f} GMACs, "
          f"{g.total_weight_words() / 1e6:.1f} M params, "
          f"{g.g.number_of_nodes()} vertices")
    res = run_dse(g, dev, DSEConfig(batch=args.batch,
                                    cut_kinds=("conv", "pool"),
                                    codecs=("none", "rle"), word_bits=8))
    s = res.summary()
    print(f"\nDSE result on {dev.name} (paper Fig. 4: 21 fps / 47 ms):")
    print(f"  throughput : {s['throughput_fps']:.2f} fps")
    print(f"  latency    : {s['latency_s'] * 1e3:.1f} ms")
    print(f"  partitions : {s['n_partitions']}")
    print(f"  evictions  : {s['n_evicted_edges']} edges")
    print(f"  fragmented : {s['n_fragmented']} layers "
          f"(mean m={s['mean_frag_ratio']:.2f})")
    for e in res.partitioning.graph.edges():
        if e.evicted:
            print(f"    evicted: {e.src} -> {e.dst}  codec={e.codec}")
    plan = plan_from_dse("unet", dev.name, res)
    print(f"\nExecutionPlan: {plan.n_stages} stage(s), "
          f"{len(plan.layers)} layers; est {plan.est_throughput_fps:.2f} fps")


if __name__ == "__main__":
    main()
