"""Quickstart: the one compile façade, from model name to running design.

    PYTHONPATH=src python examples/quickstart.py [--device u200] [--batch 1]
    PYTHONPATH=src python examples/quickstart.py --model unet_exec \
        --mode pipelined --execute [--save unet.smof.json]

Everything goes through ``repro.compile``: ``CompileSpec`` names the model
(one registry: ``EXEC_MODELS`` for executable graphs, ``PAPER_MODELS`` for
paper-scale cost-model graphs), the device, the plan strategy and the
execution mode; the returned ``Compiled`` artifact runs, reports, and
saves itself.  Reproduces the paper's Fig. 4 design point (UNet on U200:
~21 fps, single partition, weights mostly on-chip) and shows the decision
vector the DSE produced — which edges were evicted, which layers
fragmented.  Paper-scale models are costed only; the ``*_exec`` models can
additionally be *executed* with ``--execute``.
"""
import argparse
import dataclasses

import repro
from repro.api import add_compile_args, spec_from_args
from repro.core import DSEConfig, EXEC_MODELS, get_model


def main() -> None:
    ap = argparse.ArgumentParser()
    add_compile_args(ap, default_model="unet", default_mode="staged")
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--execute", action="store_true",
                    help="lower the plan and run it (needs a *_exec model)")
    ap.add_argument("--save", default=None, metavar="PATH",
                    help="with --execute: save the Compiled artifact")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="with --execute: run once traced and write a "
                         "Chrome trace-event JSON (open in Perfetto)")
    ap.add_argument("--onchip-kbits", type=float, default=None,
                    help="shrink the device's on-chip memory view to this "
                         "many kilobits — forces the DSE into eviction/"
                         "fragmentation, the streaming story the exec "
                         "models are otherwise too small to trigger")
    args = ap.parse_args()

    spec = spec_from_args(
        args, strategy="dse",
        dse=DSEConfig(batch=args.batch, cut_kinds=("conv", "pool"),
                      codecs=("none", "rle"), word_bits=8))
    if args.onchip_kbits is not None:
        from repro.core.resources import get_device
        spec = dataclasses.replace(spec, device=dataclasses.replace(
            get_device(args.device), onchip_bits=args.onchip_kbits * 1e3))
    g = get_model(args.model)()
    print(f"{args.model}: {g.total_macs() / 1e9:.1f} GMACs, "
          f"{g.total_weight_words() / 1e6:.1f} M params, "
          f"{g.g.number_of_nodes()} vertices")

    # the search half of the façade works for every model — executable or
    # costed-only — and the plan carries the whole decision vector
    # (mode="reference" is plan-free, so the design print uses "staged")
    plan_spec = (spec if spec.mode != "reference"
                 else dataclasses.replace(spec, mode="staged"))
    plan, _ = repro.build_plan(plan_spec, g)
    fragged = [lp for lp in plan.layers.values()
               if lp.weight_static_fraction < 1.0]
    print(f"\nDSE result on {args.device} (paper Fig. 4 for unet/u200: "
          f"21 fps / 47 ms):")
    print(f"  throughput : {plan.est_throughput_fps:.2f} fps")
    print(f"  latency    : {plan.est_latency_s * 1e3:.1f} ms")
    print(f"  partitions : {plan.n_stages}")
    print(f"  evictions  : {sum(1 for s in plan.streams if s.evicted)} edges")
    if fragged:
        mean_m = sum(1.0 - lp.weight_static_fraction
                     for lp in fragged) / len(fragged)
        print(f"  fragmented : {len(fragged)} layers (mean m={mean_m:.2f})")
    else:
        print("  fragmented : 0 layers")
    for s in plan.streams:
        if s.evicted:
            print(f"    evicted: {s.src} -> {s.dst}  codec={s.codec}")
    print(f"\nExecutionPlan: {plan.n_stages} stage(s), "
          f"{len(plan.layers)} layers; est {plan.est_throughput_fps:.2f} fps; "
          f"provenance {plan.provenance}")

    if args.execute:
        if args.model not in EXEC_MODELS:
            raise SystemExit(f"--execute needs a *_exec model, not "
                             f"{args.model!r} (see EXEC_MODELS)")
        import jax
        import jax.numpy as jnp
        # same spec, same plan — just lowered per --mode this time
        compiled = repro.compile(dataclasses.replace(
            spec, model=g, strategy="manual-plan", plan=plan))
        x = jax.random.normal(jax.random.PRNGKey(0), compiled.input_shape(),
                              jnp.float32)
        y = compiled.run(x)
        print(f"\nexecuted ({compiled.mode}): output shape {tuple(y.shape)}")
        # --channel attaches the off-chip memory model (docs/MEMORY.md):
        # show how the arbiter divided the port and whether every weight
        # prefetch made its stage-start deadline
        mem = getattr(getattr(compiled.executor, "report", None),
                      "memory", None)
        if mem is not None:
            arb = mem.arbitration
            print(f"\noff-chip channel ({mem.config.policy}, "
                  f"{mem.channel.gbps:g} Gbps, "
                  f"utilization {arb.utilization:.0%}):")
            print(f"  {'stream':<28} {'kind':<20} {'demand':>9} "
                  f"{'granted':>9}  ok")
            for r in mem.stream_table():
                print(f"  {r['name']:<28} {r['kind']:<20} "
                      f"{r['demand_gbps']:>7.2f}G {r['granted_gbps']:>7.2f}G"
                      f"  {'yes' if r['satisfied'] else 'NO'}")
            misses = mem.prefetch.deadline_misses
            print(f"  prefetch deadline misses: {misses}"
                  + (f" {mem.prefetch.misses_by_stage()}" if misses else ""))
            print(f"  contended Eq.6: {mem.eq6_contended_cycles:g} cycles "
                  f"(uncontended {mem.eq6_cycles:g}); "
                  f"stalls/tick {mem.total_stall_cycles:g} cycles")
        if args.trace:
            _, mc = compiled.trace(x, path=args.trace)
            print(f"trace written: {args.trace}")
            if mc is not None:
                print(f"model check: ok={mc.ok} "
                      f"ticks={mc.ticks_measured}/{mc.ticks_predicted} "
                      f"steady={mc.steady_measured}/{mc.steady_predicted} "
                      f"max_stage_rel_err={mc.max_stage_rel_err}")
        print(f"unified report: {compiled.report()}")
        if args.save:
            print(f"saved artifact: {compiled.save(args.save)} "
                  f"(reload with repro.Compiled.load)")


if __name__ == "__main__":
    main()
