"""Paper-faithful reproduction run: Tables III-V + Figs 6-8 in one shot.

    PYTHONPATH=src python examples/fpga_repro.py
"""
import sys

sys.path.insert(0, ".")  # allow running from repo root

from benchmarks import (fig6_ablation, fig7_compression, fig8_variability,
                        table3_models, table4_partitioning, table5_throughput)


def main() -> None:
    print("name,us_per_call,derived")
    print("# --- Table III: model characteristics ---")
    table3_models.run()
    print("# --- Table IV: partitioning vs batch (UNet3D) ---")
    table4_partitioning.run()
    print("# --- Fig 6: off-chip streaming ablation ---")
    fig6_ablation.run()
    print("# --- Fig 7: compression schemes ---")
    fig7_compression.run()
    print("# --- Fig 8: compression-ratio variability ---")
    fig8_variability.run()
    print("# --- Table V: cross-work comparison points ---")
    table5_throughput.run()


if __name__ == "__main__":
    main()
