"""Paper-faithful reproduction run: Tables III-V + Figs 6-8 in one shot.

    PYTHONPATH=src python examples/fpga_repro.py                # full sweep
    PYTHONPATH=src python examples/fpga_repro.py --model unet_exec \
        --device u200 --mode pipelined                          # one design

With no ``--model`` the full paper sweep runs as before.  With a model the
script compiles exactly one design point through the ``repro.compile``
façade — the same ``--model/--device/--mode`` flags as
``examples/quickstart.py``, with choices sourced from the
``EXEC_MODELS``/``PAPER_MODELS`` registries.
"""
import argparse
import sys

sys.path.insert(0, ".")  # allow running from repo root


def run_sweep() -> None:
    from benchmarks import (fig6_ablation, fig7_compression, fig8_variability,
                            table3_models, table4_partitioning,
                            table5_throughput)
    print("name,us_per_call,derived")
    print("# --- Table III: model characteristics ---")
    table3_models.run()
    print("# --- Table IV: partitioning vs batch (UNet3D) ---")
    table4_partitioning.run()
    print("# --- Fig 6: off-chip streaming ablation ---")
    fig6_ablation.run()
    print("# --- Fig 7: compression schemes ---")
    fig7_compression.run()
    print("# --- Fig 8: compression-ratio variability ---")
    fig8_variability.run()
    print("# --- Table V: cross-work comparison points ---")
    table5_throughput.run()


def run_one(args) -> None:
    import repro
    from repro.api import spec_from_args
    from repro.core import EXEC_MODELS

    spec = spec_from_args(args)
    if args.model in EXEC_MODELS:
        import jax
        import jax.numpy as jnp
        compiled = repro.compile(spec)
        x = jax.random.normal(jax.random.PRNGKey(0), compiled.input_shape(),
                              jnp.float32)
        y = compiled.run(x)
        print(f"{args.model} on {args.device} ({compiled.mode}): "
              f"output shape {tuple(y.shape)}")
        print(f"report: {compiled.report()}")
    else:
        # paper-scale models are costed, not executed: plan only
        # (mode="reference" is plan-free, so cost it as "staged")
        import dataclasses
        if spec.mode == "reference":
            spec = dataclasses.replace(spec, mode="staged")
        plan, _ = repro.build_plan(spec)
        print(f"{args.model} on {args.device}: {plan.n_stages} stage(s), "
              f"{sum(1 for s in plan.streams if s.evicted)} evicted edges, "
              f"est {plan.est_throughput_fps:.2f} fps / "
              f"{plan.est_latency_s * 1e3:.1f} ms")


def main() -> None:
    from repro.api import add_compile_args

    ap = argparse.ArgumentParser()
    add_compile_args(ap, default_model=None)
    args = ap.parse_args()
    if args.model:
        run_one(args)
    else:
        run_sweep()


if __name__ == "__main__":
    main()
