"""§E2E — execute a DSE-chosen plan as a real JAX pipeline.

Closes the loop the analytical benchmarks leave open: Algorithm 1 picks an
eviction/fragmentation plan for a skip-connection-heavy graph on a
memory-limited device view, the runtime lowers it, and we report *executed*
throughput next to the Eq. 5/6 estimates — for both executors:

* ``sequential`` — ``runtime/executor.lower_plan``: one frame at a time,
  stages back to back (the Eq. 5 regime);
* ``pipelined``  — ``runtime/streamer.lower_plan_pipelined``: stages
  overlap over a stream of microbatches, spills double-buffered (the Eq. 6
  regime).  Enabled with ``--pipelined``.

Both land in one artifact with a shared row schema (CSV on stdout via
``common.emit``; JSON rows with ``--json PATH``):

  executor        "sequential" | "pipelined"
  model, codecs   workload + allowed eviction codecs
  n_stages        stages in the DSE plan
  microbatches    stream length B (1 for sequential)
  fps_executed    measured frames/s (steady state, best of N)
  fps_eq5         1 / sum_j(L_j)   — sequential-schedule estimate
  fps_eq6         1 / max_j(L_j)   — pipelined-schedule estimate
  rel_err         max relative deviation vs the dense reference
  offchip_kbits   per-frame off-chip spill traffic (Spill/StreamReport)
  channel_policy  off-chip arbitration policy of the pipelined compile
                  ("none" when no channel model is attached)
  fps_contended_eq6
                  fps_eq6 scaled by the contended-Eq.6 slowdown of the
                  ``repro.memory`` channel model (== fps_eq6 when the
                  channel is uncontended or absent; 0 when a stream is
                  starved outright)
  prefetch_deadline_misses
                  weight-prefetch slots that miss their stage-start
                  deadline under the arbitrated bandwidth

``L_j`` are per-stage wall-clock latencies measured stage-by-stage
(``streamer.measured_stage_latencies``) so fps_eq5/fps_eq6 bracket the two
schedules in the same units as fps_executed: sequential should track
fps_eq5, pipelined should land nearer fps_eq6 (the ISSUE 2 acceptance).

Every search + lowering below goes through the one compile façade
(``repro.api``): ``CompileSpec(strategy="dse"|"autotune"|"manual-plan",
mode="reference"|"staged"|"pipelined")`` -> ``Compiled`` — the benchmark
measures exactly what ``repro.compile`` hands users.

``--autotune`` runs the closed loop instead (``repro.optim.autotune``): the
default DSE plan seeds an SA search whose every candidate is *executed*
through the pipelined streamer, and the candidate trajectory lands as
``autotune/...`` CSV rows (schema ``AUTOTUNE_SCHEMA``) plus a JSON artifact
(``--autotune-json``) with per-candidate predicted-vs-measured fps and the
latency-model calibration report.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import CompileSpec, build_plan, compile as smof_compile
from repro.core import DSEConfig, EXEC_MODELS
from repro.core.resources import Device
from repro.memory import POLICIES, ChannelConfig
from repro.optim.autotune import AutotuneConfig
from repro.runtime.streamer import (eq5_sequential_time, eq6_pipeline_time,
                                    measured_stage_latencies)

from .common import emit, timeit

# A deliberately memory-starved streaming-device view: small enough that
# the exec graphs cannot hold their skip buffers + weights on-chip, so
# Algorithm 1 is forced into eviction and fragmentation.
TINY_STREAM = Device("tiny_stream", compute_units=4096,
                     onchip_bits=300_000, offchip_gbps=64.0,
                     freq_mhz=500.0, reconfig_s=0.0)

# All three paper topologies in executable form, via the one registry
# (core.builders.EXEC_MODELS); input shapes come from the graphs' own
# exec specs, not a parallel table.
MODEL_NAMES = tuple(EXEC_MODELS)

# Two plan flavours per (model, codecs):
#   ("output",)       one stage -> the DSE is forced into eviction and
#                     fragmentation (the paper's spill story; pipelined
#                     execution degenerates to a batched scan);
#   ("pool", "conv")  multi-stage -> stage-boundary spills and something
#                     for the pipeline to actually overlap (the Eq. 6 story).
CUT_VARIANTS = (("output",), ("pool", "conv"))

ROW_SCHEMA = ("executor", "model", "codecs", "kernel_mode", "n_stages",
              "microbatches", "fps_executed", "fps_eq5", "fps_eq6", "rel_err",
              "offchip_kbits", "evicted", "fragged", "channel_policy",
              "fps_contended_eq6", "prefetch_deadline_misses")


def _row(executor: str, model: str, codecs: tuple, plan, report,
         fps_executed: float, fps_eq5: float, fps_eq6: float,
         rel_err: float, microbatches: int, mem=None,
         kernel_mode: str = "auto") -> dict:
    # contended-Eq.6 estimate: fps_eq6 (measured-latency units) scaled by
    # the memory model's analytic contention slowdown; a starved stream
    # (infinite contended cycles) predicts zero throughput
    fps_cont = fps_eq6
    misses = 0
    policy = "none"
    if mem is not None:
        policy = mem.config.policy
        cont = mem.eq6_contended_cycles
        fps_cont = (fps_eq6 * mem.eq6_cycles / cont
                    if (cont > 0 and cont != float("inf")) else 0.0)
        misses = mem.prefetch.deadline_misses
    return {
        "executor": executor,
        "model": model,
        "codecs": "+".join(codecs),
        "kernel_mode": kernel_mode,
        "n_stages": plan.n_stages,
        "microbatches": microbatches,
        "fps_executed": fps_executed,
        "fps_eq5": fps_eq5,
        "fps_eq6": fps_eq6,
        "rel_err": rel_err,
        "offchip_kbits": report.total_offchip_bits / 1e3,
        "evicted": sum(1 for s in plan.streams if s.evicted),
        "fragged": sum(1 for lp in plan.layers.values()
                       if lp.weight_static_fraction < 1.0),
        "channel_policy": policy,
        "fps_contended_eq6": fps_cont,
        "prefetch_deadline_misses": misses,
    }


def _derived(r: dict, schema: tuple, exclude: tuple) -> str:
    """key=value derived-metrics string shared by every CSV row family."""
    return " ".join(
        f"{k}={r[k]:.4g}" if isinstance(r[k], float) else f"{k}={r[k]}"
        for k in schema if k not in exclude)


def _emit_row(r: dict, us_per_call: float) -> None:
    emit(f"e2e/{r['model']}_{r['codecs']}_s{r['n_stages']}_{r['executor']}"
         f"_{r['kernel_mode']}",
         us_per_call, _derived(r, ROW_SCHEMA, ("model", "codecs")))


SEED = 0  # all bench inputs derive from PRNGKey(SEED); stamped in the JSON


def run(smoke: bool = False, pipelined: bool = False,
        microbatches: int = 8, json_path: str | None = None,
        trace_path: str | None = None,
        channel: str | None = "weighted-fair",
        kernel_modes: tuple[str, ...] = ("auto",)) -> list[dict]:
    rows: list[dict] = []
    model_check = None
    np.random.seed(SEED)  # nothing below should draw host randomness, but
    #                       pin it anyway so rows are bit-reproducible
    names = MODEL_NAMES[:1] if smoke else MODEL_NAMES
    repeats = 3 if smoke else 5
    for name in names:
        # everything below goes through the one compile façade: the dense
        # reference is codec/kernel-mode independent, so it is compiled
        # once per model (reference dispatch is the numerical target)
        ref = smof_compile(CompileSpec(model=name, device=TINY_STREAM,
                                       mode="reference"))
        in_shape = ref.input_shape()
        x = jax.random.normal(jax.random.PRNGKey(SEED), in_shape,
                              jnp.float32)
        yr = ref.run(x).block_until_ready()
        for codecs, cut_kinds, km in (
                (c, k, km) for c in (("none",), ("none", "bfp8"))
                for k in CUT_VARIANTS for km in kernel_modes):
            staged = smof_compile(CompileSpec(
                model=name, device=TINY_STREAM, strategy="dse", mode="staged",
                kernel_mode=km,
                dse=DSEConfig(batch=1, codecs=codecs, word_bits=16,
                              cut_kinds=cut_kinds)))
            plan, low = staged.plan, staged.executor
            yl = staged.run(x).block_until_ready()
            rel = float(jnp.abs(yl - yr).max() / jnp.abs(yr).max())

            B = microbatches
            # same plan, pipelined — no re-search, just a re-lowering;
            # the channel model arbitrates the plan's off-chip traffic
            piped = smof_compile(dataclasses.replace(
                staged.spec, mode="pipelined", strategy="manual-plan",
                plan=plan, microbatches=B,
                channel=(ChannelConfig(policy=channel) if channel else None)))
            sx = piped.executor
            mem = sx.report.memory
            lat = measured_stage_latencies(sx, x)  # compiles stage fns only
            fps_eq5 = 1.0 / eq5_sequential_time(lat)
            fps_eq6 = 1.0 / eq6_pipeline_time(lat)

            us_seq = timeit(lambda: low(x).block_until_ready(),
                            repeats=repeats, warmup=1)
            rows.append(_row("sequential", name, codecs, plan, low.report,
                             1e6 / us_seq, fps_eq5, fps_eq6, rel, 1,
                             kernel_mode=km))
            _emit_row(rows[-1], us_seq)

            if pipelined:
                xs = jnp.broadcast_to(x, (B,) + in_shape)
                us_stream = timeit(lambda: sx(xs).block_until_ready(),
                                   repeats=repeats, warmup=1)
                us_frame = us_stream / B
                ys = np.asarray(sx(xs))
                rel_p = float(np.abs(ys[0] - np.asarray(yr)).max()
                              / np.abs(np.asarray(yr)).max())
                rows.append(_row("pipelined", name, codecs, plan, sx.report,
                                 1e6 / us_frame, fps_eq5, fps_eq6, rel_p, B,
                                 mem=mem, kernel_mode=km))
                _emit_row(rows[-1], us_frame)

                # --trace: narrate the first multi-stage pipelined config
                # (per-tick spans + ModelCheck) into a Chrome trace file
                if (trace_path and model_check is None
                        and plan.n_stages > 1):
                    _, mc = piped.trace(x, path=trace_path)
                    model_check = mc.summary()
                    emit(f"e2e/{name}_{'+'.join(codecs)}"
                         f"_s{plan.n_stages}_trace",
                         us_frame,
                         f"ok={mc.ok} ticks={mc.ticks_measured} "
                         f"steady={mc.steady_measured} "
                         f"max_rel_err={mc.max_stage_rel_err:.4g} "
                         f"bottleneck={mc.bottleneck_predicted}")

    if json_path:
        from .baseline import git_sha
        with open(json_path, "w") as f:
            json.dump({"schema": list(ROW_SCHEMA), "rows": rows,
                       "model_check": model_check,
                       "generated_unix": time.time(),
                       "git_sha": git_sha(), "seed": SEED,
                       "backend": jax.default_backend()}, f, indent=1)
    return rows


# =============================================================================
# Closed-loop autotune mode (--autotune)
# =============================================================================

# the per-candidate trajectory row schema ("model" + AutotuneResult
# .trajectory_rows()); one CSV line per candidate under autotune/<model>/
AUTOTUNE_SCHEMA = ("model", "candidate", "move", "accepted", "best_so_far",
                   "n_stages", "evicted", "fragged", "fps_measured",
                   "fps_eq6_pre", "fps_eq6_cal", "bottleneck_stage")

# smoke = the ISSUE 3 acceptance pair: UNet + the hardest memory-wall case
AUTOTUNE_SMOKE_MODELS = ("unet_exec", "x3d_exec")


def run_autotune(smoke: bool = False, microbatches: int = 8,
                 candidates: int | None = None,
                 json_path: str | None = None) -> dict:
    """Run the measured-in-the-loop autotuner per model; emit the candidate
    trajectory as CSV rows and (optionally) one JSON artifact."""
    names = AUTOTUNE_SMOKE_MODELS if smoke else MODEL_NAMES
    cfg = AutotuneConfig(
        n_candidates=candidates or (8 if smoke else 16),
        microbatches=microbatches,
        repeats=2 if smoke else 3,
        kernel_mode="auto")
    out = {"schema": list(AUTOTUNE_SCHEMA), "rows": [], "summaries": {}}
    for name in names:
        # the search half of the façade only: the autotuner already lowered
        # and measured every candidate, so compiling (= re-lowering) the
        # winner here would be pure wasted jit time
        _, res = build_plan(CompileSpec(
            model=name, device=TINY_STREAM, strategy="autotune",
            mode="pipelined", autotune_cfg=cfg, microbatches=microbatches))
        for r in res.trajectory_rows():
            row = {"model": name, **r}
            out["rows"].append(row)
            emit(f"autotune/{name}/cand{row['candidate']}",
                 1e6 / max(row["fps_measured"], 1e-30),
                 _derived(row, AUTOTUNE_SCHEMA, ("model", "candidate")))
        s = res.summary()
        out["summaries"][name] = s
        emit(f"autotune/{name}/best", 1e6 / max(res.best_fps, 1e-30),
             f"baseline_fps={res.baseline_fps:.4g} "
             f"best_fps={res.best_fps:.4g} speedup={s['speedup']:.4g} "
             f"pre_err={res.calibration.pre_err:.4g} "
             f"post_err={res.calibration.post_err:.4g} "
             f"calibrated={res.calibration.improved}")
    if json_path:
        out["generated_unix"] = time.time()
        out["backend"] = jax.default_backend()
        with open(json_path, "w") as f:
            json.dump(out, f, indent=1)
    return out


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(prog="benchmarks.e2e_executor")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--pipelined", action="store_true",
                    help="also run the pipelined streaming executor")
    ap.add_argument("--microbatches", type=int, default=8)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write rows as a JSON artifact")
    ap.add_argument("--autotune", action="store_true",
                    help="run the closed-loop autotuner instead of the "
                         "fixed DSE-plan sweep")
    ap.add_argument("--candidates", type=int, default=None,
                    help="autotune candidate budget (default 8 smoke / 16)")
    ap.add_argument("--autotune-json", default=None, metavar="PATH",
                    help="write the autotune trajectory as a JSON artifact")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="with --pipelined: write a Chrome trace (per-tick "
                         "spans + ModelCheck) of the first multi-stage "
                         "config; open in Perfetto / chrome://tracing")
    ap.add_argument("--channel", default="weighted-fair",
                    choices=list(POLICIES) + ["none"],
                    help="off-chip channel arbitration policy for the "
                         "pipelined compile ('none' disables the model)")
    ap.add_argument("--kernel-mode", default="auto",
                    choices=("auto", "pallas", "reference", "both"),
                    help="kernel dispatch for the measured compiles; "
                         "'both' emits comparable reference and pallas "
                         "rows per bench point (default auto)")
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    if args.autotune:
        run_autotune(smoke=args.smoke, microbatches=args.microbatches,
                     candidates=args.candidates,
                     json_path=args.autotune_json)
        return
    run(smoke=args.smoke, pipelined=args.pipelined,
        microbatches=args.microbatches, json_path=args.json,
        trace_path=args.trace if args.pipelined else None,
        channel=None if args.channel == "none" else args.channel,
        kernel_modes=(("reference", "pallas") if args.kernel_mode == "both"
                      else (args.kernel_mode,)))


if __name__ == "__main__":
    main()
