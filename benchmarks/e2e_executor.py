"""§E2E — execute a DSE-chosen plan as a real JAX pipeline.

Closes the loop the analytical benchmarks leave open: Algorithm 1 picks an
eviction/fragmentation plan for a skip-connection-heavy graph on a
memory-limited device view, ``runtime/executor.py`` lowers it to a jitted
streaming pipeline, and we report the *executed* throughput next to the
Eq. 5/6 analytical estimates — plus the numerical distance between the
lowered pipeline and the dense un-evicted reference (zero for lossless
plans, ~8-bit codec error when the DSE chose BFP8).

Derived fields per row:
  exec_fps       executed frames/s (jitted, steady-state median)
  est_fps        Eq. 6 analytical estimate from the DSE
  est_lat_ms     Eq. 5 analytical latency estimate
  rel_err        max relative deviation of the executed plan vs. reference
  evicted/frag   plan decision counts
  offchip_kbits  per-frame off-chip spill traffic (SpillReport)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import (DSEConfig, build_unet_exec, build_yolo_head_exec,
                        plan_from_dse, run_dse)
from repro.core.resources import Device
from repro.runtime.executor import lower_plan, reference_pipeline

from .common import emit, timeit

# A deliberately memory-starved streaming-device view: small enough that
# unet_exec/yolo_head_exec cannot hold their skip buffers + weights
# on-chip, so Algorithm 1 is forced into eviction and fragmentation.
TINY_STREAM = Device("tiny_stream", compute_units=4096,
                     onchip_bits=300_000, offchip_gbps=64.0,
                     freq_mhz=500.0, reconfig_s=0.0)

MODELS = {
    "unet_exec": (build_unet_exec, (64, 32)),
    "yolo_head_exec": (build_yolo_head_exec, (64, 32)),
}


def run(smoke: bool = False) -> dict:
    out = {}
    models = dict(list(MODELS.items())[:1]) if smoke else MODELS
    for name, (build, in_shape) in models.items():
        # the DSE only mutates graph design state it resets on entry, and
        # the dense reference is codec-independent: build/lower both once
        g = build()
        ref = reference_pipeline(g)
        x = jax.random.normal(jax.random.PRNGKey(0), in_shape, jnp.float32)
        yr = ref(x).block_until_ready()
        for codecs in (("none",), ("none", "bfp8")):
            res = run_dse(g, TINY_STREAM,
                          DSEConfig(batch=1, codecs=codecs, word_bits=16,
                                    cut_kinds=("output",)))
            plan = plan_from_dse(name, TINY_STREAM.name, res)
            low = lower_plan(g, plan)
            yl = low(x).block_until_ready()
            rel = float(jnp.abs(yl - yr).max() / jnp.abs(yr).max())
            us = timeit(lambda: low(x).block_until_ready(),
                        repeats=3 if smoke else 5, warmup=1)
            exec_fps = 1e6 / us
            n_ev = sum(1 for s in plan.streams if s.evicted)
            n_fr = sum(1 for lp in plan.layers.values()
                       if lp.weight_static_fraction < 1.0)
            tag = "+".join(codecs)
            out[(name, tag)] = exec_fps
            emit(f"e2e/{name}_{tag}", us,
                 f"exec_fps={exec_fps:.1f} est_fps={res.throughput_fps:.1f} "
                 f"est_lat_ms={res.latency_s * 1e3:.4f} rel_err={rel:.2e} "
                 f"evicted={n_ev} fragged={n_fr} "
                 f"offchip_kbits={low.report.total_offchip_bits / 1e3:.1f}")
    return out


if __name__ == "__main__":
    run()
