"""Table III — characteristics of the evaluated CNN models.

Validates our graph reconstructions (core/builders.py) against the paper's
MAC / parameter / conv-layer counts.
"""
from __future__ import annotations

from repro.core import PAPER_MODELS, TABLE3

from .common import emit, timeit


def run() -> None:
    for name, build in PAPER_MODELS.items():
        us = timeit(lambda b=build: b(), repeats=1)
        g = build()
        macs = g.total_macs() / 1e9
        params = g.total_weight_words() / 1e6
        convs = sum(1 for v in g.vertices()
                    if v.kind in ("conv", "dwconv", "deconv"))
        ref = TABLE3[name]
        emit(f"table3/{name}", us,
             f"macs={macs:.2f}G ref={ref['macs_g']}G "
             f"dev={100 * (macs / ref['macs_g'] - 1):+.1f}% "
             f"params={params:.2f}M ref={ref['params_m']}M "
             f"convs={convs} ref={ref['convs']}")


if __name__ == "__main__":
    run()
