"""§Roofline — three-term roofline per (arch x shape x mesh) from the
compiled dry-run artifacts.

  compute    = HLO_dot_FLOPs_per_device / peak_FLOPs          [s]
  memory     = HBM_traffic_per_device   / HBM_bw              [s]
  collective = collective_operand_bytes_per_device / link_bw  [s]

FLOPs and collective bytes come from the trip-count-aware HLO analysis
(launch/hlo_analysis.py) — XLA's cost_analysis counts while bodies once, so
scan-over-layers models would otherwise be understated by ~n_layers.
HBM traffic uses the dot-operand/result proxy (weights + activations of
every matmul, trip-aware) plus the argument residents once per step.

MODEL_FLOPS: 6*N*D (train) / 2*N*D (prefill) / 2*N*B (decode, per token),
with N = active params for MoE.  The MODEL/HLO ratio flags remat and
dispatch overheads.
"""
from __future__ import annotations

import json
import pathlib

from repro.configs import ARCHS, SHAPES
from repro.core.resources import (HBM_GBPS, ICI_GBPS_PER_LINK,
                                  PEAK_FLOPS_BF16)

from .common import emit

PEAK = PEAK_FLOPS_BF16
HBM_BPS = HBM_GBPS / 8 * 1e9
ICI_BPS = ICI_GBPS_PER_LINK / 8 * 1e9


def model_flops(arch: str, shape_name: str) -> float:
    """Global analytic step FLOPs (6ND / 2ND + attention)."""
    cfg = ARCHS[arch]
    sh = SHAPES[shape_name]
    n = cfg.param_counts()["active"]
    B, S = sh.global_batch, sh.seq_len
    if sh.kind == "train":
        tok = B * S
        base = 6 * n * tok
        attn = 12 * B * S * S * cfg.n_heads * cfg.hd * _attn_layers(cfg) / 2
    elif sh.kind == "prefill":
        tok = B * S
        base = 2 * n * tok
        attn = 4 * B * S * S * cfg.n_heads * cfg.hd * _attn_layers(cfg) / 2
    else:                                      # decode: one token vs cache S
        tok = B
        base = 2 * n * tok
        attn = 4 * B * S * cfg.n_heads * cfg.hd * _attn_layers(cfg)
    return base + attn


def _attn_layers(cfg) -> int:
    return sum(1 for i in range(cfg.n_layers) if cfg.layer_kind(i) == "attn")


def cell_terms(rec: dict) -> dict | None:
    if "memory" not in rec:
        return None
    chips = rec["n_devices"]
    ta = rec.get("trip_aware", {})
    flops_dev = ta.get("flops_dot", 0.0)
    coll_dev = ta.get("collectives", {}).get("total_bytes", 0.0)
    # HBM traffic: trip-aware dot bytes + one pass over resident arguments
    hbm_dev = ta.get("dot_bytes", 0.0) + rec["memory"].get(
        "argument_size_in_bytes", 0)
    t_compute = flops_dev / PEAK
    t_memory = hbm_dev / HBM_BPS
    t_coll = coll_dev / ICI_BPS
    mf = model_flops(rec["arch"], rec["shape"])
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll,
             "model_flops": mf, "hlo_flops_global": flops_dev * chips,
             "useful_ratio": mf / max(flops_dev * chips, 1.0),
             "bound": max(
                 (("compute", t_compute), ("memory", t_memory),
                  ("collective", t_coll)), key=lambda kv: kv[1])[0]}
    dom = max(t_compute, t_memory, t_coll)
    terms["roofline_fraction"] = t_compute / dom if dom > 0 else 0.0
    terms["step_lower_bound_s"] = dom
    return terms


def load(results_dir: str = "results/dryrun") -> list[dict]:
    recs = []
    for f in sorted(pathlib.Path(results_dir).glob("*.json")):
        recs.append(json.loads(f.read_text()))
    return recs


def run(results_dir: str = "results/dryrun",
        out_md: str = "results/roofline.md") -> list[dict]:
    rows = []
    lines = ["| arch | shape | mesh | compute_s | memory_s | collective_s | "
             "bound | MODEL/HLO | roofline_frac |",
             "|---|---|---|---|---|---|---|---|---|"]
    for rec in load(results_dir):
        tag = f"{rec['arch']}/{rec['shape']}/{rec['mesh']}"
        if "skipped" in rec:
            lines.append(f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} "
                         f"| — | — | — | skipped | — | — |")
            continue
        t = cell_terms(rec)
        if t is None:
            continue
        rows.append({**rec, **t})
        emit(f"roofline/{tag}", 0.0,
             f"compute={t['compute_s']:.4f}s memory={t['memory_s']:.4f}s "
             f"coll={t['collective_s']:.4f}s bound={t['bound']} "
             f"useful={t['useful_ratio']:.2f} "
             f"frac={t['roofline_fraction']:.2f}")
        lines.append(
            f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} "
            f"| {t['compute_s']:.4f} | {t['memory_s']:.4f} "
            f"| {t['collective_s']:.4f} | {t['bound']} "
            f"| {t['useful_ratio']:.2f} | {t['roofline_fraction']:.2f} |")
    out = pathlib.Path(out_md)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text("\n".join(lines) + "\n")
    # optimised (§Perf) runs, when present, reported next to the baseline
    opt_dir = pathlib.Path("results/dryrun_opt")
    if results_dir == "results/dryrun" and opt_dir.exists():
        for rec in load(str(opt_dir)):
            t = cell_terms(rec)
            if t is None:
                continue
            tag = f"{rec['arch']}/{rec['shape']}/{rec['mesh']}"
            emit(f"roofline_opt/{tag}", 0.0,
                 f"compute={t['compute_s']:.4f}s memory={t['memory_s']:.4f}s "
                 f"coll={t['collective_s']:.4f}s bound={t['bound']} "
                 f"useful={t['useful_ratio']:.2f}")
    return rows


if __name__ == "__main__":
    run()
