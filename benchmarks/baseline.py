"""Bench baseline gate: write / compare ``BENCH_*.json`` with tolerances.

The e2e benchmark (``benchmarks/e2e_executor.py``) emits one row per
(executor, model, codecs, plan) point.  This module turns those rows into
a committed **baseline artifact** and a CI **regression gate**:

    python -m benchmarks.run --smoke --pipelined --baseline BENCH_smoke.json
    python -m benchmarks.run --smoke --pipelined --check-baseline BENCH_smoke.json

``--baseline`` snapshots the current rows (stamped with git SHA +
timestamp, so trajectory entries are attributable); ``--check-baseline``
re-runs the bench and compares row-by-row under per-metric tolerances,
exiting non-zero on any violation — that is what makes a silent
throughput regression fail CI.

Tolerance policy (``TOLERANCES``): deterministic plan metrics
(``n_stages``, ``evicted``, ``fragged``, ``microbatches``) must match
exactly and ``offchip_kbits`` within 1% — those only move when the code
changes what the toolflow *decides*, which is exactly what the gate
should catch.  Hardware-dependent metrics are gated loosely:
``fps_executed`` fails only when it drops below ``1 - rel_drop`` of the
baseline (CI runners are shared and noisy; a 2x collapse is a real
regression, 20% jitter is not), and ``rel_err`` may not grow past double
the baseline plus a small absolute floor.
"""
from __future__ import annotations

import json
import pathlib
import subprocess
import time

BASELINE_KIND = "smof-bench-baseline"
BASELINE_SCHEMA_VERSION = 1

# metric -> rule; exactly one of:
#   {"exact": True}                       value must match the baseline
#   {"rel": r}                            |measured - base| <= r * |base|
#   {"rel_drop": r}                       measured >= (1 - r) * base
#                                         (one-sided: only drops fail)
#   {"max_growth": g, "abs_floor": a}     measured <= base * g + a
TOLERANCES: dict[str, dict] = {
    "n_stages": {"exact": True},
    "microbatches": {"exact": True},
    "evicted": {"exact": True},
    "fragged": {"exact": True},
    "offchip_kbits": {"rel": 0.01},
    "fps_executed": {"rel_drop": 0.60},
    "fps_eq5": {"rel_drop": 0.60},
    "fps_eq6": {"rel_drop": 0.60},
    "rel_err": {"max_growth": 2.0, "abs_floor": 1e-4},
    # off-chip channel model columns (repro.memory): the arbitration
    # policy and the analytic prefetch-deadline verdicts are fully
    # deterministic; the contended-Eq.6 estimate inherits fps_eq6's
    # measured-latency noise so it gates on large drops only
    "channel_policy": {"exact": True},
    "prefetch_deadline_misses": {"exact": True},
    "fps_contended_eq6": {"rel_drop": 0.60},
    # kernel dispatch of the row's compiles: the reference and pallas
    # paths are bit-exact against each other, so rel_err gates identically
    # per mode, but the fps columns are only comparable mode-to-mode
    "kernel_mode": {"exact": True},
}


def row_key(row: dict) -> str:
    """Stable identity of one bench point across runs.  ``kernel_mode``
    defaults to "auto" so rows written before the per-kernel-mode sweep
    keep their identity."""
    return (f"{row['executor']}/{row['model']}/{row['codecs']}"
            f"/s{row['n_stages']}/{row.get('kernel_mode', 'auto')}")


def git_sha(default: str = "unknown") -> str:
    """The repo's HEAD SHA, or ``default`` outside a git checkout."""
    try:
        out = subprocess.run(["git", "rev-parse", "HEAD"],
                             capture_output=True, text=True, timeout=10)
        sha = out.stdout.strip()
        return sha if out.returncode == 0 and sha else default
    except OSError:
        return default


def write_baseline(rows: list[dict], path, *, note: str = "") -> pathlib.Path:
    """Snapshot bench rows as a committed baseline artifact."""
    path = pathlib.Path(path)
    payload = {
        "kind": BASELINE_KIND,
        "schema_version": BASELINE_SCHEMA_VERSION,
        "git_sha": git_sha(),
        "generated_unix": time.time(),
        "note": note,
        "tolerances": TOLERANCES,
        "rows": {row_key(r): r for r in rows},
    }
    path.write_text(json.dumps(payload, indent=1, sort_keys=True))
    return path


def _check_metric(metric: str, measured, base, rule: dict) -> str | None:
    """One metric under one rule; returns a failure message or ``None``."""
    if rule.get("exact"):
        if measured != base:
            return f"{metric}: {measured!r} != baseline {base!r} (exact)"
        return None
    measured, base = float(measured), float(base)
    if "rel" in rule:
        if abs(measured - base) > rule["rel"] * abs(base):
            return (f"{metric}: {measured:.6g} deviates from baseline "
                    f"{base:.6g} by more than {rule['rel']:.0%}")
    if "rel_drop" in rule:
        floor = (1.0 - rule["rel_drop"]) * base
        if measured < floor:
            return (f"{metric}: {measured:.6g} dropped below "
                    f"{floor:.6g} ({1 - rule['rel_drop']:.0%} of baseline "
                    f"{base:.6g})")
    if "max_growth" in rule:
        ceil = base * rule["max_growth"] + rule.get("abs_floor", 0.0)
        if measured > ceil:
            return (f"{metric}: {measured:.6g} grew past {ceil:.6g} "
                    f"(baseline {base:.6g})")
    return None


def check_baseline(rows: list[dict], path) -> tuple[list[str], list[str]]:
    """Compare bench rows against a baseline artifact.

    Returns ``(failures, notes)``: ``failures`` is empty iff the run is
    within tolerance of the baseline (missing rows are failures — a bench
    point silently disappearing is a regression too; *new* rows are
    reported as notes, they gate nothing until committed).
    """
    d = json.loads(pathlib.Path(path).read_text())
    if d.get("kind") != BASELINE_KIND:
        raise ValueError(f"{path}: not a {BASELINE_KIND} artifact")
    base_rows: dict[str, dict] = d["rows"]
    tolerances = {**TOLERANCES, **d.get("tolerances", {})}
    measured = {row_key(r): r for r in rows}

    failures: list[str] = []
    notes: list[str] = [f"baseline {path} @ {d.get('git_sha', 'unknown')}"]
    for key, base in sorted(base_rows.items()):
        got = measured.get(key)
        if got is None:
            failures.append(f"{key}: present in baseline but not measured")
            continue
        for metric, rule in tolerances.items():
            if metric not in base or metric not in got:
                continue
            msg = _check_metric(metric, got[metric], base[metric], rule)
            if msg is not None:
                failures.append(f"{key}: {msg}")
        notes.append(f"{key}: fps_executed {got.get('fps_executed', 0):.4g} "
                     f"vs baseline {base.get('fps_executed', 0):.4g}")
    for key in sorted(set(measured) - set(base_rows)):
        notes.append(f"{key}: new row (not in baseline, not gated)")
    return failures, notes
