"""Fig. 6 — off-chip streaming ablation: baseline / activations-only /
weights-only / both, on UNet and UNet3D.

Paper: both mechanisms together give up to 1.3x (UNet) and 1.1x (UNet3D);
gains are larger at small batch sizes.  Metric: GMACs/s = model MACs x fps.
"""
from __future__ import annotations

from repro.core import DSEConfig, ZCU102, build_unet, build_unet3d, run_dse

from .common import emit, timeit

STRATEGIES = {
    "baseline": dict(allow_eviction=False, allow_fragmentation=False),
    "act_only": dict(allow_eviction=True, allow_fragmentation=False),
    "wgt_only": dict(allow_eviction=False, allow_fragmentation=True),
    "both": dict(allow_eviction=True, allow_fragmentation=True),
}


def run(batch: int = 1) -> dict:
    out = {}
    for model_name, build in (("unet", build_unet), ("unet3d", build_unet3d)):
        for strat, flags in STRATEGIES.items():
            g = build()
            res = None

            def go():
                nonlocal res
                res = run_dse(g, ZCU102, DSEConfig(
                    batch=batch, cut_kinds=("conv", "pool"), word_bits=8,
                    codecs=("none",), **flags))

            us = timeit(go, repeats=1, warmup=0)
            gmacs = g.total_macs() / 1e9 * res.throughput_fps
            out[(model_name, strat)] = gmacs
            emit(f"fig6/{model_name}_{strat}_b{batch}", us,
                 f"gmacs_per_s={gmacs:.1f} fps={res.throughput_fps:.2f} "
                 f"parts={res.partitioning.n} "
                 f"evicted={sum(1 for e in res.partitioning.graph.edges() if e.evicted)} "
                 f"fragged={sum(1 for v in res.partitioning.graph.vertices() if v.frag_ratio > 0)}")
    return out


if __name__ == "__main__":
    run()
