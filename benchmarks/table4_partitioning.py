"""Table IV — model partitioning + device reconfiguration vs batch size.

Paper result (UNet3D): larger batches amortise the reconfiguration time
(31.2% of batch latency at b=1 down to 1.1% at b=64).  We reproduce the
trend with the DSE on a constrained device.
"""
from __future__ import annotations

from repro.core import DSEConfig, ZCU102, build_unet3d, run_dse
from repro.core.partition import subgraph_cost

from .common import emit, timeit

PAPER = {1: (4, 31.16), 4: (5, 11.95), 16: (6, 4.29), 64: (6, 1.11)}


def run() -> None:
    for batch, (ref_parts, ref_pct) in PAPER.items():
        g = build_unet3d()
        res = None

        def go():
            nonlocal res
            res = run_dse(g, ZCU102, DSEConfig(
                batch=batch, cut_kinds=("conv", "pool"), word_bits=8))

        us = timeit(go, repeats=1, warmup=0)
        n = res.partitioning.n
        f = ZCU102.cycles_per_s
        compute_s = sum(
            (batch * subgraph_cost(res.partitioning, i).ii_cycles
             + subgraph_cost(res.partitioning, i).depth_cycles) / f
            for i in range(n))
        reconf_s = n * ZCU102.reconfig_s if n > 1 else 0.0
        total = compute_s + reconf_s
        pct = 100 * reconf_s / total if total else 0.0
        emit(f"table4/unet3d_b{batch}", us,
             f"parts={n} ref={ref_parts} reconf_pct={pct:.1f} "
             f"ref_pct={ref_pct} latency_s={total:.2f}")


if __name__ == "__main__":
    run()
