"""Table V — accelerator comparison: SMOF designs across the four paper
workloads + their devices, vs the paper's reported numbers.

Columns reproduced: fps, GOP/s, GOP/s/DSP (the paper's device-agnostic
metric).  We report our DSE's estimates next to the paper's design points.
"""
from __future__ import annotations

from repro.core import (DSEConfig, PAPER_MODELS, U200, VCU118, VCU1525,
                        ZCU102, run_dse)

from .common import emit, timeit

# (model, device, batch) -> paper (fps, gops, gops_per_dsp)
PAPER_POINTS = {
    ("unet", U200, 1): (21.21, 2758, 0.45),
    ("unet", VCU1525, 1): (16.96, 2206, 0.36),
    ("yolov8n", VCU118, 16): (184.27, 808, 0.16),
    ("x3d_m", ZCU102, 16): (27.08, 171, 0.18),
    ("unet3d", U200, 4): (1.75, 1595, 0.28),
}


def run() -> dict:
    out = {}
    for (model, dev, batch), (ref_fps, ref_gops, ref_gpd) in \
            PAPER_POINTS.items():
        g = PAPER_MODELS[model]()
        res = None

        def go():
            nonlocal res
            res = run_dse(g, dev, DSEConfig(
                batch=batch, cut_kinds=("conv", "pool"), word_bits=8,
                codecs=("none", "rle")))

        us = timeit(go, repeats=1, warmup=0)
        fps = res.throughput_fps
        gops = 2 * g.total_macs() / 1e9 * fps
        gpd = gops / (dev.compute_units / 2)       # DSPs (packing=2)
        out[(model, dev.name)] = (fps, gops, gpd)
        emit(f"table5/{model}_{dev.name}_b{batch}", us,
             f"fps={fps:.2f} ref={ref_fps} gops={gops:.0f} ref={ref_gops} "
             f"gops_per_dsp={gpd:.2f} ref={ref_gpd} "
             f"parts={res.partitioning.n}")
    return out


if __name__ == "__main__":
    run()
