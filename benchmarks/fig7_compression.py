"""Fig. 7 — off-chip streaming compression schemes (none / Huffman / RLE).

Paper: RLE is the best choice for UNet (up to 2.21x MACs/s vs no encoding);
UNet3D sees no gain because the design becomes LUT-bound and Huffman's
decoder overhead actually hurts.  The LUT costs per codec are modelled in
core/compression.CODEC_LUT_COST.
"""
from __future__ import annotations

from repro.core import DSEConfig, ZCU102, build_unet, build_unet3d, run_dse

from .common import emit, timeit

SCHEMES = {"none": ("none",), "huffman": ("none", "huffman"),
           "rle": ("none", "rle")}


def run(batch: int = 1) -> dict:
    out = {}
    for model_name, build in (("unet", build_unet), ("unet3d", build_unet3d)):
        for scheme, codecs in SCHEMES.items():
            g = build()
            res = None

            def go():
                nonlocal res
                res = run_dse(g, ZCU102, DSEConfig(
                    batch=batch, cut_kinds=("conv", "pool"), word_bits=8,
                    codecs=codecs))

            us = timeit(go, repeats=1, warmup=0)
            gmacs = g.total_macs() / 1e9 * res.throughput_fps
            out[(model_name, scheme)] = gmacs
            used = {e.codec for e in res.partitioning.graph.edges()
                    if e.evicted}
            used |= {v.meta.get("frag_codec") for v in
                     res.partitioning.graph.vertices() if v.frag_ratio > 0}
            emit(f"fig7/{model_name}_{scheme}_b{batch}", us,
                 f"gmacs_per_s={gmacs:.1f} fps={res.throughput_fps:.2f} "
                 f"codecs_used={sorted(c for c in used if c and c != 'none')}")
    return out


if __name__ == "__main__":
    run()
