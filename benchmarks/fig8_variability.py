"""Fig. 8 — runtime variability of the activation compression ratio.

The DSE budgets bandwidth with the calibration-average ratio ``c_bar``; at
runtime a hard-to-compress input needs more.  While the device has leftover
bandwidth the curve plateaus; past that, stalls scale throughput by
budget/required (the U200 plateaus until ~140% in the paper).
"""
from __future__ import annotations

from repro.core import DSEConfig, U200, ZCU102, build_unet, run_dse
from repro.core.eviction import eviction_bw_words
from repro.core.partition import subgraph_cost

from .common import emit, timeit


def degraded_fps(res, dev, batch, ratio_scale: float, word_bits: int = 8):
    """Throughput when evicted-activation streams need ratio_scale x the
    predicted bandwidth."""
    p = res.partitioning
    budget = dev.words_per_cycle_offchip(word_bits)
    f = dev.cycles_per_s
    total = 0.0
    for i in range(p.n):
        c = subgraph_cost(p, i)
        sg = p.graph.subgraph(p.parts[i])
        evict_bw = eviction_bw_words(sg)
        fixed_bw = c.bw_words_per_cycle - evict_bw
        required = fixed_bw + evict_bw * ratio_scale
        stall = max(1.0, required / budget)
        total += (batch * c.ii_cycles * stall + c.depth_cycles) / f
    if p.n > 1:
        total += p.n * dev.reconfig_s
    return batch / total


def run() -> dict:
    """Our compute-bound ZCU102/U200 designs have large bandwidth headroom
    (the paper's U200 design used 37% of its DDR BW), so to expose the
    Fig. 8 phenomenon we also sweep bandwidth-constrained variants whose
    DDR budget is sized to the design's predicted use x a small margin —
    matching the paper's operating point."""
    import dataclasses

    out = {}
    for base_dev, margin in ((U200, 1.15), (ZCU102, 1.4)):
        g = build_unet()
        res = None

        def go():
            nonlocal res
            res = run_dse(g, base_dev, DSEConfig(
                batch=1, cut_kinds=("conv", "pool"), word_bits=8,
                codecs=("none", "rle")))

        us = timeit(go, repeats=1, warmup=0)
        # size a constrained device to the design's actual bandwidth use
        used = max(subgraph_cost(res.partitioning, i).bw_words_per_cycle
                   for i in range(res.partitioning.n))
        used = max(used, 1e-3)
        gbps = used * margin * 8 * base_dev.cycles_per_s / 1e9
        dev = dataclasses.replace(base_dev, offchip_gbps=gbps,
                                  name=base_dev.name + "_bwlim")
        base = res.throughput_fps
        curve = []
        for pct in (100, 120, 140, 160, 200, 300):
            fps = degraded_fps(res, dev, 1, pct / 100.0)
            curve.append((pct, fps))
            out[(dev.name, pct)] = fps
        flat = " ".join(f"{p}%:{f:.2f}" for p, f in curve)
        emit(f"fig8/{dev.name}", us,
             f"base_fps={base:.2f} margin={margin} curve=[{flat}]")
    return out


if __name__ == "__main__":
    run()
