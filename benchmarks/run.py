"""Benchmark driver — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines.  The roofline section reads
the dry-run artifacts in results/dryrun (run launch/dryrun.py first; the
checked-in results are used if present).
"""
from __future__ import annotations

import sys


def main() -> None:
    from . import (fig6_ablation, fig7_compression, fig8_variability,
                   kernels_bench, roofline, table3_models,
                   table4_partitioning, table5_throughput)
    print("name,us_per_call,derived")
    table3_models.run()
    table4_partitioning.run()
    fig6_ablation.run()
    fig7_compression.run()
    fig8_variability.run()
    table5_throughput.run()
    kernels_bench.run()
    try:
        roofline.run()
    except FileNotFoundError:
        print("roofline,0,skipped (run `python -m repro.launch.dryrun --all` first)",
              file=sys.stderr)


if __name__ == "__main__":
    main()
