"""Benchmark driver — one function per paper table/figure, plus the
end-to-end executor benchmark.

CSV output schema (one line per benchmark point, written to stdout):

    name,us_per_call,derived

  name          ``<section>/<point>`` — section matches the paper artefact
                (``table3``, ``table4``, ``table5``, ``fig6``, ``fig7``,
                ``fig8``, ``kernels``, ``roofline``), ``e2e`` for the
                executed-pipeline benchmark, or ``autotune`` for the
                closed-loop candidate trajectory (``--autotune``).
  us_per_call   median wall-clock microseconds of the timed callable
                (DSE solve, kernel invocation, or jitted pipeline step;
                0 where the point is analytic only).
  derived       space-separated ``key=value`` metrics specific to the
                point (fps, GMACs/s, compression ratios, rel_err, ...).

The first line is the literal header ``name,us_per_call,derived``; all
diagnostics go to stderr, so stdout is directly machine-readable.

Modes:
    python -m benchmarks.run            # full sweep
    python -m benchmarks.run --smoke    # CI-sized subset (CPU-friendly)
    python -m benchmarks.run --smoke --pipelined --e2e-json out.json
                                        # sequential vs pipelined executor
                                        # rows in one JSON artifact (CI)
    python -m benchmarks.run --smoke --autotune --autotune-json tune.json
                                        # + the closed-loop autotuner's
                                        # candidate trajectory (autotune/...
                                        # rows, schema in e2e_executor.py)
    python -m benchmarks.run --smoke --pipelined --baseline BENCH_smoke.json
                                        # snapshot e2e rows as a committed
                                        # baseline (git SHA + timestamp)
    python -m benchmarks.run --smoke --pipelined \
                             --check-baseline BENCH_smoke.json
                                        # regression gate: exits 1 if any
                                        # row breaks the per-metric
                                        # tolerances (benchmarks/baseline.py)

The roofline section reads the dry-run artifacts in results/dryrun (run
``python -m repro.launch.dryrun --all`` first; checked-in results are used
if present) — see README.md § "Benchmarks" for the full workflow.
"""
from __future__ import annotations

import argparse
import sys


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(prog="benchmarks.run",
                                 description="SMOF benchmark driver")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized subset (table3 + e2e) instead of the "
                         "full sweep")
    ap.add_argument("--pipelined", action="store_true",
                    help="also run the pipelined streaming executor in the "
                         "e2e section")
    ap.add_argument("--microbatches", type=int, default=8,
                    help="stream length B for the pipelined executor")
    ap.add_argument("--e2e-json", default=None, metavar="PATH",
                    help="write the e2e rows as a JSON artifact")
    ap.add_argument("--autotune", action="store_true",
                    help="also run the closed-loop autotuner in the e2e "
                         "section (candidate-trajectory rows)")
    ap.add_argument("--autotune-json", default=None, metavar="PATH",
                    help="write the autotune trajectory as a JSON artifact")
    ap.add_argument("--baseline", default=None, metavar="PATH",
                    help="write the e2e rows as a baseline artifact "
                         "(BENCH_*.json, stamped with git SHA + timestamp)")
    ap.add_argument("--check-baseline", default=None, metavar="PATH",
                    help="compare the e2e rows against a committed baseline "
                         "under per-metric tolerances; exit 1 on regression")
    ap.add_argument("--kernel-mode", default="auto",
                    choices=("auto", "pallas", "reference", "both"),
                    help="kernel dispatch for the e2e compiles; 'both' "
                         "emits comparable reference and pallas rows per "
                         "bench point (default auto)")
    args = ap.parse_args(argv)
    smoke = args.smoke
    from . import (baseline, e2e_executor, fig6_ablation, fig7_compression,
                   fig8_variability, kernels_bench, roofline, table3_models,
                   table4_partitioning, table5_throughput)
    print("name,us_per_call,derived")
    table3_models.run()
    e2e_rows = e2e_executor.run(
        smoke=smoke, pipelined=args.pipelined,
        microbatches=args.microbatches, json_path=args.e2e_json,
        kernel_modes=(("reference", "pallas") if args.kernel_mode == "both"
                      else (args.kernel_mode,)))
    if args.baseline:
        p = baseline.write_baseline(e2e_rows, args.baseline,
                                    note="smoke" if smoke else "full")
        print(f"baseline: wrote {len(e2e_rows)} rows -> {p}", file=sys.stderr)
    if args.check_baseline:
        failures, notes = baseline.check_baseline(e2e_rows,
                                                  args.check_baseline)
        for line in notes:
            print(f"baseline: {line}", file=sys.stderr)
        if failures:
            for line in failures:
                print(f"baseline REGRESSION: {line}", file=sys.stderr)
            raise SystemExit(1)
        print("baseline: all rows within tolerance", file=sys.stderr)
    if args.autotune:
        e2e_executor.run_autotune(smoke=smoke,
                                  microbatches=args.microbatches,
                                  json_path=args.autotune_json)
    if smoke:
        return
    table4_partitioning.run()
    fig6_ablation.run()
    fig7_compression.run()
    fig8_variability.run()
    table5_throughput.run()
    kernels_bench.run()
    try:
        roofline.run()
    except FileNotFoundError:
        print("roofline,0,skipped (needs results/dryrun artifacts: run "
              "`python -m repro.launch.dryrun --all` first — see README.md "
              "§ Benchmarks)",
              file=sys.stderr)


if __name__ == "__main__":
    main()
