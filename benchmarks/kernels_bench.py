"""Kernel-level microbenchmarks.

Wall-clock on this container measures the jnp reference implementations
(XLA:CPU); the Pallas kernels themselves are validated in interpret mode
(tests/) and characterised here by their *structural* roofline: VMEM
working set and the HBM-traffic saving of the fragmentation static region.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.streamed_matmul import vmem_bytes

from .common import emit, timeit


def run() -> None:
    key = jax.random.PRNGKey(0)

    # streamed matmul ref throughput + fragmentation traffic model
    M, K, N = 256, 4096, 4096
    x = jax.random.normal(key, (M, K), jnp.float32)
    for frac in (0.0, 0.5, 1.0):
        ks = max(int(K * frac) // 128 * 128, 128)
        ks = min(ks, K - 128)
        ws = jax.random.normal(key, (ks, N), jnp.float32)
        wd = jax.random.normal(key, (K - ks, N), jnp.float32)
        f = jax.jit(lambda a, b, c: ref.streamed_matmul_ref(a, b, c))
        f(x, ws, wd).block_until_ready()
        us = timeit(lambda: f(x, ws, wd).block_until_ready())
        nm = M // 128
        traffic_full = nm * K * N * 2                  # every panel re-read
        traffic_frag = (ks * N + nm * (K - ks) * N) * 2
        emit(f"kernel/streamed_matmul_static{frac:.1f}", us,
             f"flops={2 * M * K * N / 1e9:.2f}G "
             f"hbm_traffic_saving={1 - traffic_frag / traffic_full:.2f} "
             f"vmem_claim_mb={vmem_bytes(ks, N, 128, 128, 128) / 2 ** 20:.1f}")

    # flash attention ref
    q, k, v = (jax.random.normal(kk, (1, 1024, 4, 64), jnp.float32)
               for kk in jax.random.split(key, 3))
    fa = jax.jit(lambda a, b, c: ref.flash_attention_ref(a, b, c, causal=True))
    fa(q, k, v).block_until_ready()
    us = timeit(lambda: fa(q, k, v).block_until_ready())
    emit("kernel/flash_attention_ref_1k", us,
         f"flops={4 * 1024 * 1024 * 4 * 64 / 2 / 1e9:.2f}G")

    # bfp8 codec
    xx = jax.random.normal(key, (1024, 1024), jnp.float32)
    qf = jax.jit(lambda a: ref.bfp8_quant_ref(a))
    qf(xx)[0].block_until_ready()
    us = timeit(lambda: qf(xx)[0].block_until_ready())
    emit("kernel/bfp8_quant_ref_1M", us,
         f"ratio={(8 + 8 / 32) / 16:.3f} throughput_gbps="
         f"{xx.size * 4 / (us / 1e6) / 1e9:.1f}")


if __name__ == "__main__":
    run()
