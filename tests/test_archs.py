"""Per-architecture smoke tests (reduced configs, CPU).

For every assigned arch: one forward/train step runs, output shapes are
correct, and nothing is NaN.  For one arch per mixer family we additionally
check the *decode-equivalence invariant*: stepwise decode with caches must
reproduce the full-sequence forward logits (this exercises the KV cache,
the mamba state update, and the mLSTM chunkwise<->recurrent equivalence).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, cell_applicable, get_arch
from repro.models import (decode_step, forward, init_cache, init_params,
                          lm_loss, param_count, project_logits)

ALL = sorted(ARCHS)


def _inputs(cfg, key, B=2, S=32):
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    kw = {}
    if cfg.is_encdec:
        kw["enc_frames"] = jax.random.normal(key, (B, cfg.enc_frames, cfg.d_model))
    if cfg.vlm_patches:
        kw["patch_embeds"] = jax.random.normal(key, (B, cfg.vlm_patches, cfg.d_model))
    return toks, kw


@pytest.mark.parametrize("name", ALL)
def test_forward_shapes_and_finite(name):
    cfg = ARCHS[name].reduced()
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg, dtype=jnp.float32)
    toks, kw = _inputs(cfg, key)
    x, _, aux = forward(params, cfg, toks, **kw)
    assert x.shape == (2, 32, cfg.d_model)
    assert bool(jnp.isfinite(x).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("name", ALL)
def test_train_step_no_nans(name):
    cfg = ARCHS[name].reduced()
    key = jax.random.PRNGKey(1)
    params = init_params(key, cfg, dtype=jnp.float32)
    toks, kw = _inputs(cfg, key)
    loss, grads = jax.value_and_grad(
        lambda p: lm_loss(p, cfg, toks, toks, **kw))(params)
    assert bool(jnp.isfinite(loss))
    assert np.isclose(float(loss), np.log(cfg.vocab), rtol=0.25)  # random init
    for path, g in jax.tree_util.tree_leaves_with_path(grads):
        assert bool(jnp.isfinite(g).all()), f"NaN grad at {path}"


@pytest.mark.parametrize("name", ALL)
def test_remat_matches_no_remat(name):
    cfg = ARCHS[name].reduced()
    key = jax.random.PRNGKey(2)
    params = init_params(key, cfg, dtype=jnp.float32)
    toks, kw = _inputs(cfg, key)
    l0 = lm_loss(params, cfg, toks, toks, remat="none", **kw)
    l1 = lm_loss(params, cfg, toks, toks, remat="full", **kw)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-5)


# one representative per mixer family (attn / moe / mamba-hybrid / xlstm / encdec)
DECODE_ARCHS = ["yi-6b", "olmoe-1b-7b", "jamba-v0.1-52b", "xlstm-1.3b",
                "whisper-large-v3", "qwen2-vl-72b"]


@pytest.mark.parametrize("name", DECODE_ARCHS)
def test_decode_matches_full_forward(name):
    """Prefill S-1 tokens, decode the next ones stepwise; logits must match
    the full-sequence forward at every decoded position."""
    cfg = ARCHS[name].reduced()
    key = jax.random.PRNGKey(3)
    params = init_params(key, cfg, dtype=jnp.float32)
    B, S, prefill_len = 2, 16, 12
    toks, kw = _inputs(cfg, key, B=B, S=S)
    if cfg.vlm_patches:
        # keep the patch-embed region inside the prefill window
        kw["patch_embeds"] = kw["patch_embeds"][:, :8]

    # reference: full forward logits at each position
    x_full, _, _ = forward(params, cfg, toks, **kw)
    ref_logits = project_logits(params, cfg, x_full)          # (B, S, V)

    # prefill then stepwise decode
    cache = init_cache(cfg, B, S, dtype=jnp.float32)
    _, cache, _ = forward(params, cfg, toks[:, :prefill_len], cache=cache, **kw)
    for t in range(prefill_len, S):
        logits, cache = decode_step(params, cfg, toks[:, t:t + 1],
                                    jnp.full((B,), t, jnp.int32), cache)
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(ref_logits[:, t]),
            rtol=2e-3, atol=2e-3)


def test_param_counts_match_config_formula():
    """init_params agrees with ArchConfig.param_counts on reduced configs."""
    for name in ALL:
        cfg = ARCHS[name].reduced()
        params = init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
        actual = param_count(params)
        predicted = cfg.param_counts()["total"]
        # formula ignores norms/biases/router details: allow 12%
        assert abs(actual - predicted) / predicted < 0.15, (
            name, actual, predicted)


def test_cell_applicability():
    long = SHAPES["long_500k"]
    runs = {n for n in ALL if cell_applicable(ARCHS[n], long)[0]}
    assert runs == {"xlstm-1.3b", "jamba-v0.1-52b"}
    for n in ALL:
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            assert cell_applicable(ARCHS[n], SHAPES[s])[0]


def test_get_arch_unknown():
    with pytest.raises(KeyError):
        get_arch("nonexistent-model")
