"""Executable X3D coverage: the third paper topology (ISSUE 3).

Contract: ``build_x3d_exec`` emits a graph the *same* executors run with no
special cases — temporal depthwise convs (``dwconv``), SE branches (global
``pool`` + broadcast ``mul``) and the temporal-feature-bank long skip all
lower through ``apply_vertex``; lossless plans match the dense reference
exactly, BFP8 spill edges carry bounded codec error, and the pipelined
streamer reproduces the sequential executor per microbatch bit-for-bit.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (DSEConfig, EXEC_MODELS, build_x3d_exec,
                        exec_input_shape, get_model, plan_from_dse, run_dse)
from repro.core.plan import ExecutionPlan, LayerPlan, StreamPlan
from repro.core.resources import Device
from repro.runtime.executor import (_dwconv, _pool, _upsample, lower_plan,
                                    reference_pipeline)
from repro.runtime.streamer import lower_plan_pipelined

TINY = Device("tiny", compute_units=4096, onchip_bits=300_000,
              offchip_gbps=64.0, freq_mhz=500.0, reconfig_s=0.0)


def _dse_plan(g, codecs=("none",), cut_kinds=("pool", "conv")):
    res = run_dse(g, TINY, DSEConfig(batch=1, codecs=codecs, word_bits=16,
                                     cut_kinds=cut_kinds))
    return plan_from_dse(g.name, TINY.name, res)


def _staged_bfp8_plan(g, n_stages=2, depth_thresh=2048.0):
    """Equal-thirds staging; every deep edge evicted through BFP8."""
    g.compute_buffer_depths()
    topo = g.topo()
    stage = {n: min(i * n_stages // len(topo), n_stages - 1)
             for i, n in enumerate(topo)}
    layers = {v.name: LayerPlan(name=v.name, stage=stage[v.name])
              for v in g.vertices()}
    streams = [StreamPlan(e.src, e.dst,
                          evicted=e.buffer_depth > depth_thresh,
                          codec="bfp8" if e.buffer_depth > depth_thresh
                          else "none")
               for e in g.edges()]
    return ExecutionPlan(model=g.name, device="tiny", n_stages=n_stages,
                         layers=layers, streams=streams, topo_order=topo)


# =============================================================================
# Registry (the one lookup helper)
# =============================================================================

class TestRegistry:
    def test_x3d_exec_registered(self):
        assert EXEC_MODELS["x3d_exec"] is build_x3d_exec
        assert get_model("x3d_exec") is build_x3d_exec

    def test_unknown_model_lists_known_names(self):
        with pytest.raises(KeyError, match="x3d_exec"):
            get_model("not_a_model")

    def test_exec_input_shape(self):
        g = build_x3d_exec(positions=32, cin=32)
        assert exec_input_shape(g) == (32, 32)

    def test_paper_graph_has_no_exec_shape(self):
        from repro.core import build_unet
        with pytest.raises(ValueError, match="exec"):
            exec_input_shape(build_unet())


# =============================================================================
# New op kinds
# =============================================================================

class TestOps:
    def test_dwconv_matches_manual_temporal_mix(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (8, 4), jnp.float32)
        w = jax.random.normal(jax.random.PRNGKey(1), (3, 4), jnp.float32)
        y = _dwconv(x, w)
        xp = np.pad(np.asarray(x), ((1, 1), (0, 0)))
        want = np.stack([sum(np.asarray(w)[k] * xp[i + k] for k in range(3))
                         for i in range(8)])
        np.testing.assert_allclose(np.asarray(y), want, rtol=1e-5, atol=1e-6)

    def test_global_pool_and_broadcast_mul(self):
        x = jnp.arange(12.0).reshape(6, 2)
        g = _pool(x, 1)                          # SE global pool: m -> 1
        np.testing.assert_allclose(np.asarray(g), np.asarray(x).mean(0)[None])
        np.testing.assert_allclose(np.asarray(x * g),        # (1,c) broadcast
                                   np.asarray(x) * np.asarray(g))

    def test_pool_upsample_general_factors(self):
        x = jnp.arange(16.0).reshape(8, 2)
        np.testing.assert_allclose(np.asarray(_pool(x, 2)),
                                   np.asarray(x).reshape(2, 4, 2).mean(1))
        assert _upsample(x, 24).shape == (24, 2)
        with pytest.raises(ValueError):
            _pool(x, 3)


# =============================================================================
# Parity (the ISSUE 3 test satellite)
# =============================================================================

class TestParity:
    def test_lossless_dse_plan_matches_reference(self):
        g = build_x3d_exec()
        plan = _dse_plan(g)
        assert any(s.evicted for s in plan.streams) or any(
            lp.weight_static_fraction < 1.0 for lp in plan.layers.values()), \
            "tiny device should force eviction or fragmentation"
        # strip codecs: lossless eviction must be numerically invisible
        for s in plan.streams:
            if s.evicted:
                s.codec = "none"
        x = jax.random.normal(jax.random.PRNGKey(0), exec_input_shape(g),
                              jnp.float32)
        ref = reference_pipeline(g)
        low = lower_plan(g, plan, kernel_mode="reference")
        np.testing.assert_allclose(np.asarray(low(x)), np.asarray(ref(x)),
                                   rtol=1e-5, atol=1e-5)

    def test_bfp8_spill_edges_bounded_error(self):
        g = build_x3d_exec()
        plan = _staged_bfp8_plan(g)
        assert any(s.evicted for s in plan.streams)
        x = jax.random.normal(jax.random.PRNGKey(1), exec_input_shape(g),
                              jnp.float32)
        ref = reference_pipeline(g)
        low = lower_plan(g, plan, kernel_mode="reference")
        rel = float(jnp.abs(low(x) - ref(x)).max() / jnp.abs(ref(x)).max())
        assert 0.0 < rel < 0.2, rel             # codec ran, error bounded

    def test_pipelined_matches_sequential_with_bfp8(self):
        """Per microbatch, the streamer == the sequential executor on the
        same BFP8-evicted multi-stage plan (codec error included)."""
        g = build_x3d_exec()
        plan = _staged_bfp8_plan(g)
        B = 4
        low = lower_plan(g, plan, kernel_mode="reference")
        sx = lower_plan_pipelined(g, plan, microbatches=B,
                                  kernel_mode="reference")
        xs = jax.random.normal(jax.random.PRNGKey(2),
                               (B,) + exec_input_shape(g), jnp.float32)
        want = np.stack([np.asarray(low(xs[b])) for b in range(B)])
        np.testing.assert_allclose(np.asarray(sx(xs)), want,
                                   rtol=1e-5, atol=1e-6)
        assert sx.report.spills == low.report.spills

    def test_pipelined_dse_plan_parity(self):
        g = build_x3d_exec()
        plan = _dse_plan(g, codecs=("none", "bfp8"))
        B = 4
        low = lower_plan(g, plan, kernel_mode="reference")
        sx = lower_plan_pipelined(g, plan, microbatches=B,
                                  kernel_mode="reference")
        xs = jax.random.normal(jax.random.PRNGKey(3),
                               (B,) + exec_input_shape(g), jnp.float32)
        want = np.stack([np.asarray(low(xs[b])) for b in range(B)])
        np.testing.assert_allclose(np.asarray(sx(xs)), want,
                                   rtol=1e-5, atol=1e-6)

    def test_long_temporal_skip_creates_deep_buffers(self):
        """The stem->fusion feature-bank skip must be a deep-buffer edge —
        the topology property eviction attacks (paper §III-A)."""
        g = build_x3d_exec()
        g.compute_buffer_depths()
        concat = next(v.name for v in g.vertices()
                      if v.kind == "concat" and "concat" in v.name
                      and any(g.vertex(p).kind == "pool"
                              for p in g.predecessors(v.name)))
        depths = [g.edge(p, concat).buffer_depth
                  for p in g.predecessors(concat)]
        assert max(depths) > 10 * min(depths)
