"""SLO evaluation + flight recorder tests (ISSUE 7 tentpole).

The contract under test:

* :class:`SloEvaluator` scores the four objectives (Eq. 6 fps roofline,
  latency quantiles, Eq. 1 stall ratio, spill bandwidth vs the device
  budget) over a rolling window, each banding pass/warn/breach, and
  skips objectives without data or targets;
* a breach fires every ``on_breach`` callback;
* :class:`FlightRecorder` is a bounded-ring ``TraceRecorder`` whose
  dumps are valid Chrome traces, triggered by SLO breaches and failed
  ModelChecks;
* the acceptance path: an artificially throttled serving run is flagged
  as a breach and the flight recorder dumps a valid trace for it.
"""
import json

import numpy as np
import pytest

from repro.core import build_unet_exec
from repro.core.plan import ExecutionPlan, LayerPlan, StreamPlan
from repro.obs import (BREACH, PASS, WARN, FlightRecorder, SloConfig,
                       SloEvaluator, validate_chrome_trace)


class _FixedLatency:
    """A stub quantile(q) provider."""

    def __init__(self, p50, p99):
        self._q = {0.50: p50, 0.99: p99}

    def quantile(self, q):
        return self._q[q]


def _stub_clock(step=1.0):
    state = [0.0]

    def clock():
        state[0] += step
        return state[0]

    return clock


# =============================================================================
# SloConfig + evaluator scoring
# =============================================================================

class TestSloConfig:
    def test_dict_roundtrip_ignores_unknown_keys(self):
        cfg = SloConfig(window=8, p99_target_s=0.25)
        d = cfg.to_dict()
        assert SloConfig.from_dict(d) == cfg
        assert SloConfig.from_dict(d | {"future": 1}) == cfg
        assert SloConfig.from_dict({}) == SloConfig()


class TestSloEvaluator:
    def test_no_data_no_targets_means_no_checks(self):
        ev = SloEvaluator()                       # nothing configured
        ev.observe(frames=10, seconds=1.0)
        rep = ev.evaluate()
        assert rep.checks == [] and rep.verdict == PASS and rep.ok

    def test_negative_observation_rejected(self):
        ev = SloEvaluator()
        with pytest.raises(ValueError, match="negative"):
            ev.observe(frames=-1, seconds=1.0)
        with pytest.raises(ValueError, match="negative"):
            ev.observe(frames=1, seconds=-1.0)

    @pytest.mark.parametrize("fps,verdict", [
        (90.0, PASS),        # 0.9 of roofline
        (30.0, WARN),        # 0.3: below warn fraction 0.5
        (10.0, BREACH),      # 0.1: below breach fraction 0.25
    ])
    def test_fps_vs_roofline_bands(self, fps, verdict):
        ev = SloEvaluator(roofline_fps=100.0)
        ev.observe(frames=fps, seconds=1.0)
        (check,) = ev.evaluate().checks
        assert check.objective == "fps" and check.verdict == verdict
        assert check.measured == pytest.approx(fps)
        assert check.target == pytest.approx(25.0)   # breach floor in fps

    def test_latency_quantile_bands(self):
        cfg = SloConfig(p50_target_s=0.1, p99_target_s=1.0)
        ev = SloEvaluator(cfg, latency=_FixedLatency(p50=0.09, p99=1.5))
        ev.observe(frames=1, seconds=1.0)
        by_name = {c.objective: c for c in ev.evaluate().checks}
        assert by_name["latency_p50"].verdict == WARN    # > 0.8 * target
        assert by_name["latency_p99"].verdict == BREACH  # > target

    def test_stall_ratio_bands_and_skip_without_ops(self):
        ev = SloEvaluator()
        ev.observe(frames=1, seconds=1.0)                 # no queue ops
        assert ev.evaluate().checks == []
        ev.observe(frames=1, seconds=1.0, stalls=20, queue_ops=100)
        (check,) = ev.evaluate().checks
        assert check.objective == "stall_ratio"
        assert check.measured == pytest.approx(0.2)       # 20%: breach
        assert check.verdict == BREACH

    def test_spill_bw_vs_device_budget(self):
        ev = SloEvaluator(bw_gbps=64.0)
        # 5 GB in 1s = 40 Gbps = 0.625 of budget -> warn band; without
        # per-direction bytes the split checks see an even 20/20 Gbps
        # against half-device (32 Gbps) budgets -> same 0.625 warn band
        ev.observe(frames=1, seconds=1.0, spill_bytes=5e9)
        by_name = {c.objective: c for c in ev.evaluate().checks}
        assert set(by_name) == {"spill_bw", "spill_bw_evict",
                                "spill_bw_restore"}
        assert by_name["spill_bw"].measured == pytest.approx(40.0)
        assert by_name["spill_bw"].verdict == WARN
        for name in ("spill_bw_evict", "spill_bw_restore"):
            assert by_name[name].measured == pytest.approx(20.0)
            assert by_name[name].verdict == WARN
            assert "half-device" in by_name[name].detail

    def test_spill_bw_split_uses_arbiter_budgets(self):
        # granted budgets from the arbiter skew the per-direction verdicts
        ev = SloEvaluator(bw_gbps=64.0,
                          stream_budgets={"activation-evict": 60.0,
                                          "activation-restore": 4.0})
        ev.observe(frames=1, seconds=1.0, evict_bytes=2.5e9,
                   restore_bytes=2.5e9)
        by_name = {c.objective: c for c in ev.evaluate().checks}
        assert by_name["spill_bw_evict"].verdict == PASS    # 20/60 Gbps
        assert by_name["spill_bw_restore"].verdict == BREACH  # 20/4 Gbps
        assert "arbiter-granted" in by_name["spill_bw_evict"].detail

    def test_rolling_window_evicts_old_samples(self):
        ev = SloEvaluator(SloConfig(window=4), roofline_fps=100.0)
        ev.observe(frames=1, seconds=1.0)                 # 1 fps: breach...
        for _ in range(4):
            ev.observe(frames=90, seconds=1.0)            # ...pushed out
        rep = ev.evaluate()
        assert rep.window["samples"] == 4
        assert rep.verdict == PASS

    def test_report_verdict_is_worst_and_breach_fires_callbacks(self):
        cfg = SloConfig(p50_target_s=1.0)
        ev = SloEvaluator(cfg, roofline_fps=100.0,
                          latency=_FixedLatency(p50=0.1, p99=0.1))
        fired = []
        ev.on_breach.append(fired.append)
        ev.observe(frames=90, seconds=1.0)
        rep = ev.evaluate()                               # all pass
        assert rep.ok and fired == [] and ev.last_report is rep
        for _ in range(64):
            ev.observe(frames=1, seconds=1.0)             # throttle hard
        rep = ev.evaluate()
        assert rep.verdict == BREACH and not rep.ok
        assert [c.objective for c in rep.breaches()] == ["fps"]
        assert fired == [rep]
        assert rep.summary()["checks"][0]["detail"].startswith("0.01")


# =============================================================================
# Flight recorder
# =============================================================================

class TestFlightRecorder:
    def test_ring_is_bounded_and_keeps_newest(self):
        rec = FlightRecorder(capacity=8, clock=_stub_clock())
        for i in range(50):
            rec.add_span(f"tick{i}", float(i), 1.0, track="pipeline")
        assert len(rec._events) == 8
        assert [e["name"] for e in rec._events] == \
            [f"tick{i}" for i in range(42, 50)]

    def test_capacity_must_be_positive(self):
        with pytest.raises(ValueError, match="capacity"):
            FlightRecorder(capacity=0)

    def test_dump_requires_a_path(self):
        rec = FlightRecorder(capacity=4, clock=_stub_clock())
        rec.instant("x")
        with pytest.raises(ValueError, match="no dump path"):
            rec.dump()

    def test_manual_dump_is_a_valid_chrome_trace(self, tmp_path):
        rec = FlightRecorder(capacity=16, path=tmp_path / "f.json",
                             clock=_stub_clock())
        rec.add_span("tick", 0.0, 1.0, track="pipeline")
        p = rec.dump(reason="operator")
        stats = validate_chrome_trace(json.loads(p.read_text()))
        assert stats["spans"] == 1 and stats["instants"] == 1
        assert rec.dumps == [(p, "operator")]

    def test_slo_pass_does_not_dump_breach_does(self, tmp_path):
        rec = FlightRecorder(capacity=16, path=tmp_path / "f.json",
                             clock=_stub_clock())
        rec.add_span("tick", 0.0, 1.0, track="pipeline")
        ev = SloEvaluator(roofline_fps=100.0)
        ev.on_breach.append(rec.on_slo_report)
        ev.observe(frames=90, seconds=1.0)
        assert ev.evaluate().ok and rec.dumps == []
        ev = SloEvaluator(roofline_fps=100.0)     # fresh window, throttled
        ev.on_breach.append(rec.on_slo_report)
        ev.observe(frames=1, seconds=1.0)
        assert not ev.evaluate().ok
        (path, reason) = rec.dumps[0]
        assert reason == "slo_breach:fps"
        data = json.loads(path.read_text())
        validate_chrome_trace(data)
        (inst,) = [e for e in data["traceEvents"]
                   if e["ph"] == "i" and e["name"] == "flight:dump"]
        assert inst["args"]["reason"] == "slo_breach:fps"

    def test_model_check_failure_dumps(self, tmp_path):
        class _BadCheck:
            ok = False
            ticks_ok = False
            queues_ok = True

        rec = FlightRecorder(capacity=4, path=tmp_path / "f.json",
                             clock=_stub_clock())
        rec.instant("stall", track="queues")
        assert rec.on_model_check(_BadCheck()) is not None
        assert rec.dumps[0][1] == "model_check:ticks"
        ok = type("OkCheck", (), {"ok": True})()
        assert rec.on_model_check(ok) is None and len(rec.dumps) == 1


# =============================================================================
# Acceptance: throttled serving run -> breach -> flight dump
# =============================================================================

class TestThrottledServing:
    def _server(self):
        from repro.serving.engine import GraphStreamServer
        g = build_unet_exec(positions=32, levels=2)
        g.compute_buffer_depths()
        topo = g.topo()
        plan = ExecutionPlan(
            model=g.name, device="tiny", n_stages=1,
            layers={n: LayerPlan(name=n, stage=0) for n in topo},
            streams=[StreamPlan(e.src, e.dst) for e in g.edges()],
            topo_order=topo)
        return GraphStreamServer(g, plan, microbatches=2,
                                 kernel_mode="reference")

    def test_throttled_run_breaches_and_dumps_flight_trace(self, tmp_path):
        srv = self._server()
        # a roofline far above anything a CPU run can deliver = an
        # artificially throttled run relative to the claimed Eq. 6 bound
        ev = srv.enable_slo(roofline_fps=1e12)
        flight = FlightRecorder(capacity=64, path=tmp_path / "flight.json")
        ev.on_breach.append(flight.on_slo_report)
        srv.flight = flight
        for _ in range(4):
            srv.submit(np.zeros((32, 32), np.float32))
        srv.flush()
        rep = ev.last_report
        assert rep is not None and not rep.ok
        assert "fps" in [c.objective for c in rep.breaches()]
        (path, reason) = flight.dumps[0]
        assert reason.startswith("slo_breach:")
        validate_chrome_trace(json.loads(path.read_text()))
        # the breach verdict also lands on the scrape surface
        snap = srv.metrics.snapshot()
        assert snap['smof_server_slo_evaluations_total{verdict="breach"}'] \
            >= 1.0
