"""Hypothesis property tests for system-level invariants of the SMOF core."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import Graph, U200, Vertex
from repro.core.partition import initial_partition, latency_s, merge
from repro.core.pipeline import (initiation_interval, pipeline_depth,
                                 vertex_delays)


def chain(n, macs, depths):
    g = Graph("c")
    g.add(Vertex("in", "input", in_words=64, out_words=64))
    prev = "in"
    for i in range(n):
        g.add(Vertex(f"v{i}", "conv", work_macs=float(macs[i]),
                     weight_words=100, in_words=64, out_words=64,
                     base_depth=float(depths[i]), max_par=16))
        g.connect(prev, f"v{i}")
        prev = f"v{i}"
    return g


@given(st.integers(1, 6),
       st.lists(st.integers(100, 100_000), min_size=6, max_size=6),
       st.lists(st.integers(1, 500), min_size=6, max_size=6))
@settings(max_examples=30, deadline=None)
def test_pipeline_depth_positive_and_bounded(n, macs, depths):
    g = chain(n, macs, depths)
    d = pipeline_depth(g)
    assert d > 0
    # upper bound: every vertex at its worst-case initiation rate
    rates = {v: max(1e-12, 64 / max(m + 10, 64))
             for v, m in zip([f"v{i}" for i in range(n)], macs)}
    assert np.isfinite(d)


@given(st.lists(st.integers(1_000, 1_000_000), min_size=4, max_size=4))
@settings(max_examples=25, deadline=None)
def test_parallelism_never_hurts_ii(macs):
    g = chain(4, macs, [10] * 4)
    ii_before = initiation_interval(g)
    for v in g.vertices():
        v.par = min(v.par * 4, v.max_par)
    assert initiation_interval(g) <= ii_before


@given(st.lists(st.integers(100, 50_000), min_size=5, max_size=5))
@settings(max_examples=25, deadline=None)
def test_delays_monotone_along_chain(macs):
    """Eq. 10: Delay accumulates — downstream >= upstream."""
    g = chain(5, macs, [5] * 5)
    d = vertex_delays(g)
    prev = d["in"]
    for i in range(5):
        assert d[f"v{i}"] >= prev
        prev = d[f"v{i}"]


@given(st.integers(2, 5), st.integers(1, 16))
@settings(max_examples=20, deadline=None)
def test_merge_preserves_vertex_set(n, batch):
    g = chain(n, [1000] * n, [10] * n)
    g.compute_buffer_depths()
    p = initial_partition(g, cut_kinds=None)
    all_v = set(g.g.nodes)
    while p.n > 1:
        p = merge(p, 0)
        assert set(v for part in p.parts for v in part) == all_v
        p.validate()


@given(st.integers(1, 64))
@settings(max_examples=15, deadline=None)
def test_latency_monotone_in_batch(batch):
    g = chain(3, [10_000, 5_000, 2_000], [10, 10, 10])
    g.compute_buffer_depths()
    p = initial_partition(g, cut_kinds=None)
    t1 = latency_s(p, U200, batch)
    t2 = latency_s(p, U200, batch + 1)
    assert t2 >= t1


# =============================================================================
# BFP8 codec properties — the padded path the streamer's queues exercise
# =============================================================================

def _bfp8_block_error_bound(x_flat: np.ndarray, block: int = 32) -> np.ndarray:
    """Per-element worst-case |err|: half the block scale.

    scale = 2^(ceil(log2 amax) - 6) <= amax * 2^-5, and |x| <= amax <= 2^exp
    means no mantissa clipping, so rounding error <= scale/2 <= amax/64."""
    pad = (-x_flat.size) % block
    fp = np.pad(x_flat, (0, pad)).reshape(-1, block)
    amax = np.abs(fp).max(axis=1)
    return np.repeat(amax / 64.0 + 1e-12, block)[: x_flat.size]


@given(st.integers(1, 6), st.integers(1, 97), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=30, deadline=None)
def test_bfp8_roundtrip_error_bound_and_shape_any_channels(rows, cols, seed):
    """encode->decode keeps the shape for ANY (rows, cols) — channel counts
    that are not multiples of the block included — and every element lands
    within the shared-exponent quantisation bound."""
    from repro.core.compression import bfp8_decode, bfp8_encode

    rng = np.random.default_rng(seed)
    x = (10.0 * rng.standard_normal((rows, cols))).astype(np.float32)
    enc = bfp8_encode(x, block=32)
    dec = bfp8_decode(enc)
    assert dec.shape == x.shape and dec.dtype == np.float32
    err = np.abs(dec - x).ravel()
    assert np.all(err <= _bfp8_block_error_bound(x.ravel()))


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=10, deadline=None)
def test_bfp8_all_zero_blocks_roundtrip_exactly(seed):
    rng = np.random.default_rng(seed)
    from repro.core.compression import bfp8_decode, bfp8_encode
    x = np.zeros((int(rng.integers(1, 5)), int(rng.integers(1, 70))),
                 np.float32)
    np.testing.assert_array_equal(bfp8_decode(bfp8_encode(x, block=32)), x)


@given(st.integers(1, 8), st.integers(1, 95), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=20, deadline=None)
def test_jax_padded_roundtrip_matches_numpy_codec(m, c, seed):
    """The in-pipeline jax round-trip (pad channels to the block, quantise
    row-blockwise) is shape-invariant for non-block-multiple channel counts
    and agrees with the numpy codec applied to the padded stripe — the
    exact path a streamer queue payload takes."""
    from repro.core.compression import bfp8_decode, bfp8_encode
    from repro.runtime.executor import _bfp8_roundtrip
    import jax.numpy as jnp

    rng = np.random.default_rng(seed)
    x = rng.standard_normal((m, c)).astype(np.float32)
    got = np.asarray(_bfp8_roundtrip(jnp.asarray(x), use_pallas=False,
                                     interpret=True))
    assert got.shape == x.shape
    c_pad = ((c + 31) // 32) * 32
    xp = np.pad(x, ((0, 0), (0, c_pad - c)))
    want = bfp8_decode(bfp8_encode(xp, block=32))[:, :c]
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-7)
    # the padded path still honours the per-block error bound row by row
    for r in range(m):
        err = np.abs(got[r] - x[r])
        assert np.all(err <= _bfp8_block_error_bound(xp[r])[:c])


# =============================================================================
# ExecutionPlan serialisation — the compile façade's on-disk artifact
# =============================================================================

_CODECS = ("none", "rle", "huffman", "bfp8")


def _plan_from_draws(n_layers, stages, fracs, codec_ids, tp, extra):
    """Deterministically build a nested plan from integer draws."""
    from repro.core.plan import ExecutionPlan, LayerPlan, StreamPlan

    names = [f"v{i}" for i in range(n_layers)]
    cur = 0
    layers = {}
    for i, n in enumerate(names):
        cur = max(cur, stages[i % len(stages)])       # stages non-decreasing
        layers[n] = LayerPlan(
            name=n, stage=cur, tp_parallelism=1 + tp[i % len(tp)],
            weight_static_fraction=fracs[i % len(fracs)] / 8.0,
            weight_stream_codec=_CODECS[codec_ids[i % len(codec_ids)] % 4])
    streams = [StreamPlan(names[i], names[i + 1],
                          evicted=bool(codec_ids[i % len(codec_ids)] % 2),
                          codec=_CODECS[codec_ids[i % len(codec_ids)] % 4])
               for i in range(n_layers - 1)]
    return ExecutionPlan(
        model="prop", device="dev", n_stages=cur + 1, layers=layers,
        streams=streams, remat="none", microbatch=1 + extra,
        est_throughput_fps=extra / 7.0, est_latency_s=extra * 1e-3,
        topo_order=list(names),
        provenance={f"k{i}": i for i in range(extra)})


@given(st.integers(1, 9),
       st.lists(st.integers(0, 3), min_size=4, max_size=4),
       st.lists(st.integers(0, 8), min_size=4, max_size=4),
       st.lists(st.integers(0, 7), min_size=4, max_size=4),
       st.lists(st.integers(0, 3), min_size=4, max_size=4),
       st.integers(0, 5))
@settings(max_examples=30, deadline=None)
def test_plan_json_roundtrip_bit_exact(n_layers, stages, fracs, codec_ids,
                                       tp, extra):
    """to_json -> from_json round-trips nested LayerPlan/StreamPlan
    dataclasses bit-exactly: dataclass-equal AND byte-equal re-serialised."""
    from repro.core.plan import ExecutionPlan, PLAN_SCHEMA_VERSION

    plan = _plan_from_draws(n_layers, stages, fracs, codec_ids, tp, extra)
    s = plan.to_json()
    back = ExecutionPlan.from_json(s)
    assert back == plan                       # nested dataclass equality
    assert back.to_json() == s                # bit-exact on the wire
    assert back.dropped_keys == ()            # nothing migrated away
    assert back.schema_version == PLAN_SCHEMA_VERSION


@given(st.integers(1, 6), st.integers(1, 4))
@settings(max_examples=15, deadline=None)
def test_plan_unknown_keys_are_collected(n_layers, n_extra):
    """Forward-compat is observable: every key a newer writer added is in
    ``dropped_keys`` (per scope), and the known payload is untouched."""
    import json as _json

    from repro.core.plan import ExecutionPlan

    plan = _plan_from_draws(n_layers, [0, 1, 1, 2], [8] * 4, [0] * 4,
                            [0] * 4, 0)
    d = _json.loads(plan.to_json())
    lname = next(iter(d["layers"]))
    expect = set()
    for i in range(n_extra):
        d[f"new{i}"] = i
        expect.add(f"plan.new{i}")
    d["layers"][lname]["new_layer_knob"] = 1
    expect.add(f"layers[{lname}].new_layer_knob")
    if d["streams"]:
        d["streams"][0]["new_stream_knob"] = 2
        expect.add("streams[0].new_stream_knob")
    back = ExecutionPlan.from_json(_json.dumps(d))
    assert set(back.dropped_keys) == expect
    assert back.layers == plan.layers
    assert back.streams == plan.streams


@given(st.integers(1, 6), st.integers(1, 5))
@settings(max_examples=15, deadline=None)
def test_plan_future_schema_version_roundtrips_clean(n_layers, bump):
    """A plan written by a *future* toolflow (higher schema_version plus
    keys this version has never heard of) still loads: the migration is
    recorded in provenance, the unknown keys are collected, and — the
    forward-compat contract — re-serialising does NOT reintroduce them,
    so a second load sees a clean current-version artifact."""
    import json as _json

    from repro.core.plan import ExecutionPlan, PLAN_SCHEMA_VERSION

    plan = _plan_from_draws(n_layers, [0, 0, 1, 1], [8] * 4, [0] * 4,
                            [0] * 4, 0)
    d = _json.loads(plan.to_json())
    d["schema_version"] = PLAN_SCHEMA_VERSION + bump
    d["spill_priority"] = [1, 2, 3]                  # "future" plan knob
    lname = next(iter(d["layers"]))
    d["layers"][lname]["vector_lanes"] = 8           # "future" layer knob
    back = ExecutionPlan.from_json(_json.dumps(d))
    assert back.schema_version == PLAN_SCHEMA_VERSION
    assert (back.provenance["migrated_from_schema_version"]
            == PLAN_SCHEMA_VERSION + bump)
    assert "plan.spill_priority" in back.dropped_keys
    assert f"layers[{lname}].vector_lanes" in back.dropped_keys
    s2 = back.to_json()
    assert "spill_priority" not in s2 and "vector_lanes" not in s2
    again = ExecutionPlan.from_json(s2)
    assert again.dropped_keys == ()                  # second load is clean
    assert again.layers == plan.layers


def test_plan_future_schema_keys_are_logged(caplog):
    """The forward-compat shim is observable: dropping keys logs one
    warning naming every dropped key."""
    import json as _json
    import logging

    from repro.core.plan import ExecutionPlan

    plan = _plan_from_draws(2, [0] * 4, [8] * 4, [0] * 4, [0] * 4, 0)
    d = _json.loads(plan.to_json())
    d["from_the_future"] = True
    with caplog.at_level(logging.WARNING, logger="repro.core.plan"):
        ExecutionPlan.from_json(_json.dumps(d))
    assert any("plan.from_the_future" in r.getMessage()
               for r in caplog.records)


def test_from_json_rejects_backwards_stage_crossing():
    """A plan whose stream runs from a later stage to an earlier one is
    unschedulable; from_json must fail with the typed validation error,
    not hand the plan to the lowering to crash on."""
    import json as _json

    import pytest

    from repro.core.plan import ExecutionPlan, PlanValidationError

    plan = _plan_from_draws(3, [0, 1, 2, 2], [8] * 4, [0] * 4, [0] * 4, 0)
    d = _json.loads(plan.to_json())
    d["layers"]["v0"]["stage"] = 2                   # v0 -> v1 now 2 -> ?
    d["layers"]["v1"]["stage"] = 0                   # ... -> 0: backwards
    with pytest.raises(PlanValidationError, match="backwards"):
        ExecutionPlan.from_json(_json.dumps(d))


def test_from_json_rejects_malformed_scalars():
    """Out-of-range stages, fractions and microbatch counts all fail
    validation with every problem named in one error."""
    import json as _json

    import pytest

    from repro.core.plan import ExecutionPlan, PlanValidationError

    plan = _plan_from_draws(2, [0] * 4, [8] * 4, [0] * 4, [0] * 4, 0)
    d = _json.loads(plan.to_json())
    d["layers"]["v0"]["stage"] = 99
    d["layers"]["v1"]["weight_static_fraction"] = 1.5
    d["microbatch"] = 0
    with pytest.raises(PlanValidationError) as ei:
        ExecutionPlan.from_json(_json.dumps(d))
    msg = str(ei.value)
    assert "v0" in msg and "weight_static_fraction" in msg
    assert "microbatch" in msg


# =============================================================================
# Streaming telemetry invariants (ISSUE 6) — random plans driven purely
# through the schedule walk: build_schedule + queue_specs/build_queues +
# StreamTracer, no jit anywhere.
# =============================================================================

import dataclasses as _dc


@_dc.dataclass(frozen=True)
class _Spill:
    """Duck-typed SpillRecord: what StreamTracer/emit_spill_counters read."""
    src: str
    dst: str
    codec: str
    offchip_bits: int


def _random_staged_chain(n, n_stages, chans, skip_draws, stage_draws):
    """A chain with forward skip edges plus a non-decreasing random stage
    assignment — every edge is same-stage or forward-crossing, like any
    valid plan the DSE can emit."""
    g = chain(n, [1000] * n, [10] * n)
    for i, d in enumerate(skip_draws[: max(0, n - 2)]):
        if d:                                      # forward skip v_i -> v_j
            j = i + 2 + d % max(1, n - i - 2)
            if j < n and not g.g.has_edge(f"v{i}", f"v{j}"):
                g.connect(f"v{i}", f"v{j}")
    topo = g.topo()
    steps = [stage_draws[i % len(stage_draws)] % 2 for i in range(len(topo))]
    stage, stage_of = 0, {}
    for name, inc in zip(topo, steps):
        stage = min(n_stages - 1, stage + inc)
        stage_of[name] = stage
    out_shape = {name: (1 + chans[i % len(chans)] % 8,
                        1 + chans[(i + 1) % len(chans)])
                 for i, name in enumerate(topo)}
    return g, stage_of, out_shape


@given(st.integers(2, 6), st.integers(1, 4), st.integers(1, 8),
       st.lists(st.integers(0, 5), min_size=4, max_size=4),
       st.lists(st.integers(0, 63), min_size=4, max_size=4),
       st.lists(st.integers(0, 99), min_size=6, max_size=6))
@settings(max_examples=40, deadline=None)
def test_queue_high_water_bounded_by_eq1_capacity(n, n_stages, B, skips,
                                                  chans, stages):
    """Eq. 1 queue sizing holds on random plans: walking the full 1F1B
    schedule through the bounded rings never exceeds any ring's capacity,
    never stalls, and drains every ring completely."""
    from repro.obs import StreamTracer
    from repro.runtime.streamer import (build_queues, build_schedule,
                                        queue_specs)

    g, stage_of, out_shape = _random_staged_chain(n, n_stages, chans,
                                                  skips, stages)
    specs = queue_specs(g, stage_of, out_shape)
    queues = build_queues(specs)
    sched = build_schedule(max(stage_of.values()) + 1, B)
    acct = StreamTracer(schedule=sched, queues=queues,
                        stage_of=stage_of).run_model()
    assert acct["ticks_run"] == sched.ticks
    for e, s in specs.items():
        st_ = acct["queues"][f"{e[0]}->{e[1]}"]
        assert st_["high_water"] <= s.capacity      # the Eq. 1 bound
        assert st_["high_water"] == min(B, s.delay)  # shift-register depth
        assert st_["push_stalls"] == 0 and st_["pop_stalls"] == 0
        assert st_["occupancy"] == 0                # fully drained


@given(st.integers(2, 6), st.integers(1, 4), st.integers(1, 8),
       st.lists(st.integers(0, 5), min_size=4, max_size=4),
       st.lists(st.integers(0, 63), min_size=4, max_size=4),
       st.lists(st.integers(0, 99), min_size=6, max_size=6),
       st.lists(st.integers(1, 10_000), min_size=5, max_size=5),
       st.lists(st.integers(0, 1), min_size=5, max_size=5))
@settings(max_examples=40, deadline=None)
def test_spill_bytes_conserved_on_random_plans(n, n_stages, B, skips, chans,
                                               stages, sizes, codecs):
    """Every byte evicted off-chip is restored: over any complete 1F1B
    run, ``bytes_evicted == bytes_restored`` per spilled edge (and BFP8
    encode count == decode count) — each endpoint stage is active for
    exactly ``B`` ticks, regardless of plan shape."""
    from repro.obs import StreamTracer, TraceRecorder
    from repro.runtime.streamer import build_schedule

    g, stage_of, _ = _random_staged_chain(n, n_stages, chans, skips, stages)
    names = list(stage_of)
    records = []
    for i, (bits, is_bfp8) in enumerate(zip(sizes, codecs)):
        src = names[i % len(names)]
        dst = names[(i * 3 + 1) % len(names)]
        records.append(_Spill(src=src, dst=dst,
                              codec="bfp8" if is_bfp8 else "none",
                              offchip_bits=8 * bits))
    rec = TraceRecorder(clock=lambda: 0.0)
    sched = build_schedule(max(stage_of.values()) + 1, B)
    StreamTracer(rec, sched, stage_of=stage_of,
                 spill_records=records).run_model()
    per_edge_bytes = {}
    for r in records:
        per_edge_bytes.setdefault(f"{r.src}->{r.dst}", 0)
        per_edge_bytes[f"{r.src}->{r.dst}"] += B * (r.offchip_bits // 8)
    for edge, want in per_edge_bytes.items():
        assert rec.totals[f"spill:{edge}:bytes_evicted"] == want
        assert rec.totals[f"spill:{edge}:bytes_restored"] == want
    for k, v in rec.totals.items():
        if k.startswith("bfp8:") and k.endswith(":encodes"):
            assert v == rec.totals[k.replace(":encodes", ":decodes")]


@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 8))
@settings(max_examples=15, deadline=None)
def test_buffer_depths_nonnegative_any_dag(seed, width):
    """Random DAGs: buffer depths are always >= the double-buffer floor."""
    rng = np.random.default_rng(seed)
    g = Graph("r")
    g.add(Vertex("in", "input", in_words=32, out_words=32))
    names = ["in"]
    for i in range(width):
        v = g.add(Vertex(f"n{i}", "conv",
                         work_macs=float(rng.integers(100, 10_000)),
                         weight_words=10, in_words=32, out_words=32,
                         base_depth=float(rng.integers(1, 100)), max_par=8))
        for parent in rng.choice(names, size=min(2, len(names)),
                                 replace=False):
            g.connect(str(parent), v.name)
        names.append(v.name)
    g.compute_buffer_depths()
    for e in g.edges():
        assert e.buffer_depth >= 2.0


@given(st.lists(st.floats(1e-9, 100.0, allow_nan=False,
                          allow_infinity=False),
                min_size=1, max_size=64),
       st.lists(st.floats(0.0, 1.0, allow_nan=False, allow_infinity=False),
                min_size=2, max_size=8))
@settings(max_examples=40, deadline=None)
def test_latency_histogram_quantile_monotone_and_bounded(values, qs):
    """The serving-layer quantile estimator (ISSUE 7): for any recorded
    sample set, ``quantile(q)`` is monotone non-decreasing in q and every
    estimate lies within [min recorded, max recorded] — the log2-bucket
    upper-edge answer is conservative but never escapes the data."""
    from repro.obs import LatencyHistogram
    h = LatencyHistogram()
    for v in values:
        h.record(v)
    lo, hi = min(values), max(values)
    estimates = [h.quantile(q) for q in sorted(qs)]
    assert estimates == sorted(estimates)
    for est in estimates:
        assert lo <= est <= hi
    s = h.summary()
    assert s["min_s"] == lo and s["max_s"] == hi
    assert s["p50_s"] <= s["p95_s"] <= s["p99_s"] <= hi


# -----------------------------------------------------------------------------
# off-chip channel arbitration (ISSUE 9: repro.memory)
# -----------------------------------------------------------------------------

def _arbitrate(policy, bits, weights=None, gbps=8.0, tick=1024.0):
    """One allocation round over len(bits) eviction streams."""
    from repro.memory import ChannelArbiter, ChannelConfig, OffChipChannel
    ch = OffChipChannel(gbps, freq_mhz=250.0)
    kw = {}
    if weights is not None:
        kw = dict(evict_weight=weights[0], restore_weight=weights[1],
                  weight_fetch_weight=weights[2])
    arb = ChannelArbiter(ch, ChannelConfig(policy=policy, **kw))
    kinds = ("activation-evict", "activation-restore", "weight-fetch")
    for i, b in enumerate(bits):
        arb.register(f"s{i}", kinds[i % 3], stage=i % 4, bits_per_frame=b)
    return arb.allocate(tick)


@given(st.sampled_from(("round-robin", "fixed-priority", "weighted-fair")),
       st.lists(st.integers(0, 5_000_000), min_size=1, max_size=12),
       st.floats(0.1, 64.0, allow_nan=False, allow_infinity=False))
@settings(max_examples=40, deadline=None)
def test_arbiter_work_conserving_and_capacity_bounded(policy, bits, gbps):
    """Every policy (a) never grants past the channel's capacity, (b)
    never grants a stream more than it demands, and (c) is
    work-conserving: while unmet demand remains, the channel is fully
    granted (up to burst-quantisation epsilon)."""
    rep = _arbitrate(policy, bits, gbps=gbps)
    cap = rep.capacity_bits_per_cycle
    eps = 1e-9 * max(1.0, cap)
    assert rep.total_granted_rate <= cap + eps
    for s in rep.streams:
        assert 0.0 <= s.granted_rate <= s.demand_rate + eps
    if rep.total_demand_rate > cap + eps:        # oversubscribed
        assert rep.total_granted_rate >= cap - eps
        assert not rep.feasible
    else:                                        # everyone satisfied
        assert abs(rep.total_granted_rate - rep.total_demand_rate) <= eps
        assert rep.feasible


@given(st.lists(st.integers(1_000, 5_000_000), min_size=3, max_size=9),
       st.floats(0.25, 4.0, allow_nan=False, allow_infinity=False),
       st.floats(1.5, 8.0, allow_nan=False, allow_infinity=False))
@settings(max_examples=40, deadline=None)
def test_weighted_fair_grant_monotone_in_weight(bits, w0, factor):
    """Raising one stream kind's weight (all else fixed) never shrinks
    that kind's aggregate weighted-fair grant."""
    lo = _arbitrate("weighted-fair", bits, weights=(w0, 1.0, 1.0),
                    gbps=0.5)                    # scarce: weights matter
    hi = _arbitrate("weighted-fair", bits, weights=(w0 * factor, 1.0, 1.0),
                    gbps=0.5)
    got_lo = sum(s.granted_rate for s in lo.streams
                 if s.kind == "activation-evict")
    got_hi = sum(s.granted_rate for s in hi.streams
                 if s.kind == "activation-evict")
    assert got_hi >= got_lo - 1e-9


@given(st.lists(st.integers(1_000, 5_000_000), min_size=3, max_size=9))
@settings(max_examples=30, deadline=None)
def test_fixed_priority_starves_low_before_high(bits):
    """Under fixed-priority on a scarce channel, a higher-priority kind
    is never less satisfied than a lower-priority one (priority order:
    weight-fetch > activation-restore > activation-evict)."""
    rep = _arbitrate("fixed-priority", bits, gbps=0.25)
    frac = {}
    for kind in ("weight-fetch", "activation-restore", "activation-evict"):
        ss = [s for s in rep.streams if s.kind == kind and s.demand_rate > 0]
        if ss:
            frac[kind] = (sum(s.granted_rate for s in ss)
                          / sum(s.demand_rate for s in ss))
    order = [k for k in ("weight-fetch", "activation-restore",
                         "activation-evict") if k in frac]
    for hi_k, lo_k in zip(order, order[1:]):
        assert frac[hi_k] >= frac[lo_k] - 1e-9


# =============================================================================
# Streaming-conv fused-codec properties (ISSUE 10) — the fused BFP8
# boundary codec is *defined* to be the unfused three-op pipeline, and
# tile sizes are pure performance knobs.  Hypothesis searches the shape /
# tile / seed space for any counterexample.
# =============================================================================

def _sc_case(m, c, cout, seed):
    import jax
    import jax.numpy as jnp
    key = jax.random.PRNGKey(seed)
    kx, kw = jax.random.split(key)
    x = jax.random.normal(kx, (m, c), jnp.float32)
    w = jax.random.normal(kw, (c, cout), jnp.float32) / np.sqrt(c)
    return x, w


def _sc_encode_ref(y, block=32):
    import jax.numpy as jnp
    from repro.kernels import ref as kref
    c = y.shape[1]
    cp = ((c + block - 1) // block) * block
    return kref.bfp8_quant_ref(jnp.pad(y, ((0, 0), (0, cp - c))),
                               block=block)


@given(st.integers(1, 70), st.integers(1, 70), st.integers(1, 48),
       st.integers(0, 2 ** 31 - 1))
@settings(max_examples=20, deadline=None)
def test_fused_conv_codec_equals_unfused_pipeline(m, c, cout, seed):
    """decode->conv->encode fused inside one pallas_call is *bitwise* the
    three-dispatch pipeline, for ANY shape: same activation, same quant
    blocks, same payload bytes."""
    import jax
    from repro.kernels import ref as kref
    from repro.kernels import streaming_conv as SC

    x, w = _sc_case(m, c, cout, seed)
    payload = _sc_encode_ref(x)
    y_f, pay_f = SC.conv2d(None, w, payload=payload, encode=True,
                           interpret=True)

    def unfused(payload):
        xe = kref.bfp8_dequant_ref(*payload, block=32)[:, :c]
        y = kref.conv2d_ref(xe, w)
        return y, _sc_encode_ref(y)
    y_u, pay_u = jax.jit(unfused)(payload)
    np.testing.assert_array_equal(np.asarray(y_f), np.asarray(y_u))
    np.testing.assert_array_equal(np.asarray(pay_f[0]), np.asarray(pay_u[0]))
    np.testing.assert_array_equal(np.asarray(pay_f[1]), np.asarray(pay_u[1]))


@given(st.integers(1, 70), st.integers(1, 70), st.integers(1, 48),
       st.integers(1, 160), st.integers(1, 160),
       st.integers(0, 2 ** 31 - 1))
@settings(max_examples=20, deadline=None)
def test_conv_tile_size_independence(m, c, cout, bm, bc, seed):
    """Any (bm, bc) draw — dividing the axes or not, bigger than them or
    not — produces bit-identical results to the default tiling."""
    from repro.kernels import streaming_conv as SC

    x, w = _sc_case(m, c, cout, seed)
    base = SC.conv2d(x, w, interpret=True)
    tiled = SC.conv2d(x, w, bm=bm, bc=bc, interpret=True)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(tiled))


@given(st.integers(1, 60), st.integers(1, 60), st.integers(1, 128),
       st.integers(0, 2 ** 31 - 1))
@settings(max_examples=15, deadline=None)
def test_dwconv_tile_size_independence(m, c, bm, seed):
    """The halo-read dwconv grid: any row-block size, same bits (tap sums
    are evaluated per output row — tiling cannot reassociate them)."""
    import jax
    import jax.numpy as jnp
    from repro.kernels import streaming_conv as SC

    key = jax.random.PRNGKey(seed)
    kx, kw = jax.random.split(key)
    x = jax.random.normal(kx, (m, c), jnp.float32)
    w = jax.random.normal(kw, (3, c), jnp.float32)
    base = SC.dwconv(x, w, interpret=True)
    tiled = SC.dwconv(x, w, bm=bm, interpret=True)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(tiled))
