"""Launcher-level tests: input_specs contract, mesh construction, CLI smoke."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES
from repro.launch.mesh import make_host_mesh

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


class TestInputSpecs:
    def _specs(self, arch, shape):
        from repro.runtime.steps import input_specs
        mesh = make_host_mesh()
        return input_specs(ARCHS[arch], SHAPES[shape], mesh)

    def test_train_specs_structure(self):
        s = self._specs("yi-6b", "train_4k")
        assert set(s) == {"params", "opt_state", "batch"}
        assert s["batch"]["tokens"].shape == (256, 4096)
        assert s["batch"]["tokens"].dtype == jnp.int32

    def test_prefill_specs_structure(self):
        s = self._specs("glm4-9b", "prefill_32k")
        assert set(s) == {"params", "cache", "batch"}
        assert s["batch"]["tokens"].shape == (32, 32768)

    def test_decode_specs_structure(self):
        s = self._specs("granite-8b", "decode_32k")
        assert set(s) == {"params", "cache", "token", "pos"}
        assert s["token"].shape == (128, 1)
        assert s["pos"].shape == (128,)
        # cache sequence length equals the shape's seq_len
        k = s["cache"]["pos_0"]["k"]
        assert k.shape[2] == 32768

    def test_encdec_gets_frames(self):
        s = self._specs("whisper-large-v3", "prefill_32k")
        assert "enc_frames" in s["batch"]
        assert s["batch"]["enc_frames"].shape == (32, 1500, 1280)

    def test_vlm_gets_patches(self):
        s = self._specs("qwen2-vl-72b", "train_4k")
        assert "patch_embeds" in s["batch"]
        assert s["batch"]["patch_embeds"].shape == (256, 1024, 8192)

    def test_no_allocation(self):
        """input_specs are pure ShapeDtypeStructs — zero device memory."""
        s = self._specs("yi-6b", "decode_32k")
        for leaf in jax.tree.leaves(s):
            assert isinstance(leaf, jax.ShapeDtypeStruct)


class TestMesh:
    def test_host_mesh(self):
        mesh = make_host_mesh()
        assert mesh.shape == {"data": 1, "model": 1}

    def test_production_mesh_shapes_via_subprocess(self):
        """512 placeholder devices; must run in its own process because jax
        locks the device count on first init."""
        env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"),
                   XLA_FLAGS="--xla_force_host_platform_device_count=512")
        code = (
            "from repro.launch.mesh import make_production_mesh\n"
            "m1 = make_production_mesh()\n"
            "assert dict(m1.shape) == {'data': 16, 'model': 16}, m1.shape\n"
            "m2 = make_production_mesh(multi_pod=True)\n"
            "assert dict(m2.shape) == {'pod': 2, 'data': 16, 'model': 16}\n"
            "print('OK')\n")
        out = subprocess.run([sys.executable, "-c", code], env=env,
                             capture_output=True, text=True, timeout=300)
        assert out.returncode == 0 and "OK" in out.stdout, out.stderr[-2000:]


class TestCLISmoke:
    def test_train_cli(self, tmp_path):
        env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
        out = subprocess.run(
            [sys.executable, "-m", "repro.launch.train", "--arch", "yi-6b",
             "--smoke", "--steps", "4", "--batch", "2", "--seq", "32",
             "--ckpt-dir", str(tmp_path)],
            env=env, capture_output=True, text=True, timeout=500)
        assert out.returncode == 0, out.stderr[-2000:]
        assert "steps, loss" in out.stdout

    def test_serve_cli(self):
        env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
        out = subprocess.run(
            [sys.executable, "-m", "repro.launch.serve", "--arch", "yi-6b",
             "--requests", "2", "--max-new", "3", "--s-max", "64"],
            env=env, capture_output=True, text=True, timeout=500)
        assert out.returncode == 0, out.stderr[-2000:]
        assert "tokens/s" in out.stdout
