"""Closed-loop autotuner tests (ISSUE 3 tentpole).

The measurement hooks are injectable, so the whole SA loop runs under a
deterministic stub clock: measured frame (tick) times are the analytic
Eq. 6 cycles scaled by a fixed ``s_per_cycle`` the device's nominal
frequency does NOT predict.  That pins down

* determinism — same seed, same stub => identical trajectory and winner;
* the acceptance floor — the winner's measured fps is never below the
  seed (default DSE) plan's, because the seed is candidate 0;
* calibration — the fitted scale recovers the stub exactly, so the
  post-calibration Eq. 6 prediction error collapses while the nominal
  (pre-calibration) error stays at ``|log(nominal / stub)|``.
"""
import math

import pytest

from repro.core import build_unet_exec, build_x3d_exec
from repro.core.resources import Device
from repro.optim.autotune import (AutotuneConfig, AutotuneResult,
                                  CalibrationReport, autotune,
                                  calibrated_latency_hook,
                                  measure_pipelined_fps)

TINY = Device("tiny", compute_units=4096, onchip_bits=300_000,
              offchip_gbps=64.0, freq_mhz=500.0, reconfig_s=0.0)

# stub wall clock: 7ns per analytic cycle (nominal 500 MHz would be 2ns,
# so pre-calibration predictions are off by exactly log(3.5))
STUB_S_PER_CYCLE = 7e-9


def _stub_fps(sx, xs):
    return 1.0 / (max(sx.report.stage_latency) * STUB_S_PER_CYCLE)


def _stub_stages(sx, x):
    return [l * STUB_S_PER_CYCLE for l in sx.report.stage_latency]


def _tune(g, **kw):
    cfg = AutotuneConfig(n_candidates=kw.pop("n_candidates", 6),
                         microbatches=4, kernel_mode="reference",
                         seed=kw.pop("seed", 0), **kw)
    return autotune(g, TINY, cfg,
                    measure_fps=_stub_fps, measure_stages=_stub_stages)


class TestAutotune:
    def test_seed_is_candidate_zero_and_floor(self):
        res = _tune(build_unet_exec())
        assert res.trajectory[0].move == "seed"
        assert res.trajectory[0].accepted
        assert res.baseline_fps == res.trajectory[0].fps_measured
        assert res.best_fps >= res.baseline_fps
        assert isinstance(res, AutotuneResult)

    def test_deterministic_under_fixed_seed(self):
        g1, g2 = build_unet_exec(), build_unet_exec()
        r1, r2 = _tune(g1, seed=3), _tune(g2, seed=3)
        assert r1.trajectory_rows() == r2.trajectory_rows()
        assert r1.best_plan.to_json() == r2.best_plan.to_json()
        assert r1.calibration.s_per_cycle == r2.calibration.s_per_cycle

    def test_different_seeds_explore_differently(self):
        g = build_unet_exec()
        moves = lambda r: [c.move for c in r.trajectory[1:]]
        assert moves(_tune(g, seed=0)) != moves(_tune(g, seed=11))

    def test_moves_mutate_the_genome(self):
        res = _tune(build_unet_exec(), n_candidates=8)
        sigs = {(r.n_stages, r.n_evicted, r.n_fragged)
                for r in res.trajectory}
        assert len(sigs) > 1                       # SA really moved
        assert len(res.trajectory) == 8

    def test_calibration_recovers_stub_scale(self):
        res = _tune(build_unet_exec())
        cal = res.calibration
        assert isinstance(cal, CalibrationReport)
        assert cal.s_per_cycle == pytest.approx(STUB_S_PER_CYCLE, rel=1e-9)
        assert cal.pre_err == pytest.approx(
            abs(math.log((1 / 500e6) / STUB_S_PER_CYCLE)), rel=1e-6)
        assert cal.post_err < 1e-9 < cal.pre_err   # strictly smaller
        assert cal.improved

    def test_predicted_vs_measured_per_candidate(self):
        res = _tune(build_unet_exec())
        for r in res.trajectory:
            assert r.fps_eq6_pre > 0 and r.fps_eq6_cal > 0
            # with the stub clock, the calibrated Eq. 6 prediction IS the
            # measurement; the nominal one is off by the fixed factor
            assert r.fps_eq6_cal == pytest.approx(r.fps_measured, rel=1e-9)
            assert r.fps_eq6_pre == pytest.approx(
                r.fps_measured * STUB_S_PER_CYCLE * 500e6, rel=1e-9)

    def test_x3d_smoke(self):
        res = _tune(build_x3d_exec(), n_candidates=4)
        assert res.model == "x3d_exec"
        assert res.best_fps >= res.baseline_fps
        assert res.calibration.improved
        rows = res.trajectory_rows()
        assert rows and all(
            set(rows[0]) == set(r) for r in rows)  # uniform row schema

    def test_calibrated_hook_plugs_into_stage_latencies(self):
        from repro.runtime.streamer import stage_latencies
        g = build_unet_exec()
        res = _tune(g)
        hook = calibrated_latency_hook(res.calibration.s_per_cycle)
        lat_s = stage_latencies(g, res.best_plan, hook=hook)
        lat_cyc = stage_latencies(g, res.best_plan)
        for s, c in zip(lat_s, lat_cyc):
            assert s == pytest.approx(c * res.calibration.s_per_cycle)

    def test_result_json_roundtrips(self):
        import json
        res = _tune(build_unet_exec(), n_candidates=4)
        d = json.loads(res.to_json())
        assert set(d) == {"summary", "trajectory", "best_plan"}
        assert d["summary"]["best_fps"] >= d["summary"]["baseline_fps"]
        from repro.core.plan import ExecutionPlan
        back = ExecutionPlan.from_json(json.dumps(d["best_plan"]))
        assert back.n_stages == res.best_plan.n_stages

    def test_default_measure_is_wall_clock(self):
        """The real measurement path still runs (one tiny candidate)."""
        import jax.numpy as jnp
        from repro.core import exec_input_shape
        from repro.core.plan import ExecutionPlan, LayerPlan, StreamPlan
        from repro.runtime.streamer import lower_plan_pipelined
        g = build_unet_exec(positions=32, levels=2)
        topo = g.topo()
        plan = ExecutionPlan(
            model=g.name, device="tiny", n_stages=1,
            layers={n: LayerPlan(name=n) for n in topo},
            streams=[StreamPlan(e.src, e.dst) for e in g.edges()],
            topo_order=topo)
        sx = lower_plan_pipelined(g, plan, microbatches=2,
                                  kernel_mode="reference")
        xs = jnp.zeros((2,) + exec_input_shape(g), jnp.float32)
        fps = measure_pipelined_fps(sx, xs, repeats=1, warmup=1)
        assert fps > 0


class TestServingIntegration:
    def test_graph_stream_server_serves_autotuned_plan(self):
        import numpy as np
        from repro.serving.engine import GraphStreamServer
        from repro.core import exec_input_shape
        g = build_unet_exec(positions=32, levels=2)
        cfg = AutotuneConfig(n_candidates=3, microbatches=2,
                             kernel_mode="reference")
        # route the server's search through the stub clock for test speed
        result = autotune(g, TINY, cfg, measure_fps=_stub_fps,
                          measure_stages=_stub_stages)
        srv = GraphStreamServer(g, result.best_plan,
                                microbatches=cfg.microbatches,
                                kernel_mode="reference")
        srv.autotune_result = result
        t0 = srv.submit(np.zeros(exec_input_shape(g), np.float32))
        t1 = srv.submit(np.ones(exec_input_shape(g), np.float32))
        out = srv.flush()
        assert set(out) == {t0, t1}
        assert srv.autotune_result.best_fps >= srv.autotune_result.baseline_fps

    def test_autotuned_classmethod(self):
        from repro.serving.engine import GraphStreamServer
        g = build_unet_exec(positions=32, levels=2)
        cfg = AutotuneConfig(n_candidates=2, microbatches=2, repeats=1,
                             warmup=1, kernel_mode="reference")
        srv = GraphStreamServer.autotuned(g, TINY, autotune_cfg=cfg,
                                          kernel_mode="reference")
        assert srv.autotune_result is not None
        assert srv.microbatches == 2
        assert srv.executor.plan is srv.autotune_result.best_plan
