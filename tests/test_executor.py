"""Executable lowering tests: DSE plan -> JAX pipeline.

The contract under test (docs/ARCHITECTURE.md):
* lossless plans execute numerically identical to the dense reference,
  no matter how aggressively the DSE evicted/fragmented/partitioned;
* BFP8-evicted streams really round-trip through the codec, and their
  off-chip traffic accounting is bit-exact against the compile-time
  c_bar = (8 + 8/block) / word_bits;
* fragmented weights dispatch to the Pallas streamed_matmul with the
  plan's static/dynamic split and stay numerically invisible.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (DSEConfig, build_unet_exec, build_yolo_head_exec,
                        plan_from_dse, run_dse)
from repro.core.compression import bfp8_decode, bfp8_encode, bfp8_ratio
from repro.core.plan import ExecutionPlan, LayerPlan, StreamPlan
from repro.core.resources import Device
from repro.runtime.executor import (LoweredPipeline, SpillReport,
                                    _bfp8_roundtrip, init_params, lower_plan,
                                    reference_pipeline)

TINY = Device("tiny", compute_units=4096, onchip_bits=300_000,
              offchip_gbps=64.0, freq_mhz=500.0, reconfig_s=0.0)


def _dse_plan(g, codecs=("none",), cut_kinds=("output",), dev=TINY):
    res = run_dse(g, dev, DSEConfig(batch=1, codecs=codecs, word_bits=16,
                                    cut_kinds=cut_kinds))
    return plan_from_dse(g.name, dev.name, res), res


class TestParity:
    def test_lossless_plan_matches_reference_unet(self):
        """Acceptance: DSE-chosen evicted/fragmented plan == dense baseline."""
        g = build_unet_exec()
        plan, _ = _dse_plan(g)
        assert any(s.evicted for s in plan.streams), "device should force eviction"
        assert any(lp.weight_static_fraction < 1.0
                   for lp in plan.layers.values()), "should force fragmentation"
        x = jax.random.normal(jax.random.PRNGKey(0), (64, 32), jnp.float32)
        ref = reference_pipeline(g)
        low = lower_plan(g, plan, kernel_mode="reference")
        np.testing.assert_allclose(np.asarray(low(x)), np.asarray(ref(x)),
                                   rtol=1e-5, atol=1e-5)

    def test_lossless_plan_matches_reference_yolo_head(self):
        g = build_yolo_head_exec()
        plan, _ = _dse_plan(g)
        x = jax.random.normal(jax.random.PRNGKey(1), (64, 32), jnp.float32)
        ref = reference_pipeline(g)
        low = lower_plan(g, plan, kernel_mode="reference")
        np.testing.assert_allclose(np.asarray(low(x)), np.asarray(ref(x)),
                                   rtol=1e-5, atol=1e-5)

    def test_multi_stage_plan_matches_reference(self):
        """Stage-boundary off-chip hops stay numerically invisible."""
        g = build_unet_exec()
        plan, _ = _dse_plan(g, cut_kinds=("pool", "conv"))
        assert plan.n_stages > 1
        x = jax.random.normal(jax.random.PRNGKey(2), (64, 32), jnp.float32)
        ref = reference_pipeline(g)
        low = lower_plan(g, plan, kernel_mode="reference")
        np.testing.assert_allclose(np.asarray(low(x)), np.asarray(ref(x)),
                                   rtol=1e-5, atol=1e-5)
        assert any(s.reason == "stage_boundary" for s in low.report.spills)

    def test_pallas_dispatch_matches_reference(self):
        """Fragmented layers through the real streamed_matmul kernel.

        The graph must contain layers with cin > 128, or the padded wrapper
        legitimately falls back to a plain dot (nothing to stream) and the
        kernel never runs — yolo_head_exec's neck convs reach cin=192.
        Every weighty layer is force-fragmented at m=0.5 so dispatch does
        not depend on what the DSE happens to choose.
        """
        from unittest import mock

        from repro.kernels import streamed_matmul as sm
        from repro.runtime.executor import WEIGHT_KINDS

        g = build_yolo_head_exec()
        layers = {}
        for v in g.vertices():
            f = 0.5 if v.kind in WEIGHT_KINDS else 1.0
            layers[v.name] = LayerPlan(name=v.name, weight_static_fraction=f)
        streams = [StreamPlan(e.src, e.dst) for e in g.edges()]
        plan = ExecutionPlan(model=g.name, device="tiny", n_stages=1,
                             layers=layers, streams=streams)
        x = jax.random.normal(jax.random.PRNGKey(3), (64, 32), jnp.float32)
        ref = reference_pipeline(g)
        real_kernel = sm.streamed_matmul
        with mock.patch.object(sm, "streamed_matmul",
                               side_effect=real_kernel) as spy:
            low = lower_plan(g, plan, kernel_mode="pallas")
            y = low(x)
        assert spy.call_count > 0, "no layer dispatched to the Pallas kernel"
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref(x)),
                                   rtol=2e-4, atol=2e-4)


class TestBFP8Eviction:
    def _plan_with_bfp8_skip(self, g):
        """Hand-written plan: evict every >1-consumer skip edge with BFP8."""
        layers = {v.name: LayerPlan(name=v.name) for v in g.vertices()}
        streams = []
        for e in g.edges():
            evict = e.buffer_depth > 4096.0
            streams.append(StreamPlan(e.src, e.dst, evicted=evict,
                                      codec="bfp8" if evict else "none"))
        assert any(s.evicted for s in streams)
        return ExecutionPlan(model=g.name, device="tiny", n_stages=1,
                             layers=layers, streams=streams)

    def test_roundtrip_ratio_matches_compile_time_constant(self):
        """Satellite acceptance: spill bits / raw bits == 8.25/16 exactly."""
        g = build_unet_exec()
        g.compute_buffer_depths()
        plan = self._plan_with_bfp8_skip(g)
        low = lower_plan(g, plan, kernel_mode="reference")
        evicted = [s for s in low.report.spills if s.reason == "evicted"]
        assert evicted
        for s in evicted:
            assert s.exact
            assert s.ratio == bfp8_ratio(16, block=32) == (8 + 8 / 32) / 16

    def test_bfp8_error_small_and_nonzero(self):
        """The codec really runs: output differs, but only by ~8-bit error."""
        g = build_unet_exec()
        g.compute_buffer_depths()
        plan = self._plan_with_bfp8_skip(g)
        x = jax.random.normal(jax.random.PRNGKey(4), (64, 32), jnp.float32)
        ref = reference_pipeline(g)
        low = lower_plan(g, plan, kernel_mode="reference")
        yr, yl = np.asarray(ref(x)), np.asarray(low(x))
        rel = np.abs(yl - yr).max() / np.abs(yr).max()
        assert 0.0 < rel < 0.15, rel

    def test_jax_roundtrip_matches_numpy_codec(self):
        """The in-pipeline codec and core.compression agree on real data."""
        rng = np.random.default_rng(5)
        x = rng.normal(size=(16, 64)).astype(np.float32)
        want = bfp8_decode(bfp8_encode(x, block=32))
        got = np.asarray(_bfp8_roundtrip(jnp.asarray(x), use_pallas=False,
                                         interpret=True))
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-7)

    def test_pallas_and_reference_codec_agree(self):
        x = jax.random.normal(jax.random.PRNGKey(6), (8, 96), jnp.float32)
        a = _bfp8_roundtrip(x, use_pallas=True, interpret=True)
        b = _bfp8_roundtrip(x, use_pallas=False, interpret=True)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7)


class TestReport:
    def test_spill_report_totals(self):
        g = build_unet_exec()
        plan, res = _dse_plan(g, codecs=("none", "bfp8"))
        low = lower_plan(g, plan, kernel_mode="reference")
        r = low.report
        assert isinstance(r, SpillReport)
        s = r.summary()
        assert s["total_offchip_bits"] == (s["spill_offchip_bits"]
                                          + s["streamed_weight_bits"])
        # every evicted stream in the plan is accounted for
        n_evicted = sum(1 for st in plan.streams if st.evicted)
        assert sum(1 for sp in r.spills if sp.reason == "evicted") == n_evicted

    def test_static_plus_streamed_is_total_weight_bits(self):
        g = build_unet_exec()
        plan, _ = _dse_plan(g)
        low = lower_plan(g, plan, kernel_mode="reference")
        total = sum(int(v.weight_words) * v.weight_bits for v in g.vertices())
        r = low.report
        assert r.static_weight_bits + r.streamed_weight_bits == total


class TestLoweringErrors:
    def test_non_exec_graph_rejected(self):
        from repro.core import build_unet
        with pytest.raises(ValueError, match="exec"):
            reference_pipeline(build_unet())

    def test_unknown_codec_rejected(self):
        g = build_unet_exec()
        layers = {v.name: LayerPlan(name=v.name) for v in g.vertices()}
        streams = [StreamPlan(e.src, e.dst, evicted=True, codec="lzw")
                   for e in g.edges()]
        plan = ExecutionPlan(model=g.name, device="tiny", n_stages=1,
                             layers=layers, streams=streams)
        with pytest.raises(ValueError, match="codec"):
            lower_plan(g, plan)

    def test_params_deterministic(self):
        g = build_unet_exec(positions=32, levels=2)
        p1, p2 = init_params(g, seed=3), init_params(g, seed=3)
        assert set(p1) == set(p2)
        for k in p1:
            np.testing.assert_array_equal(np.asarray(p1[k]),
                                          np.asarray(p2[k]))

    def test_lowered_pipeline_callable(self):
        g = build_unet_exec(positions=32, levels=2)
        ref = reference_pipeline(g)
        assert isinstance(ref, LoweredPipeline)
        x = jnp.zeros((32, 32), jnp.float32)
        assert ref(x).shape == (32 * 32,)
