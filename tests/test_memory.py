"""Off-chip memory channel subsystem tests (ISSUE 9: ``repro.memory``).

Three layers:

* unit — burst quantisation, ``ChannelConfig`` round-trip, and the
  weight prefetcher's deadline math under a stub tick clock;
* integration — every executable paper model compiled pipelined under a
  channel model satisfies the contention check's ordering chain
  (measured steady tick >= contended Eq. 6 >= uncontended Eq. 6, as
  *times*; equivalently fps measured <= contended <= uncontended) with
  per-kind arbitrated bytes conserved bit-exactly against the stream
  report's spill/weight accounting;
* search — the autotuner's bandwidth-infeasibility pruning never lets an
  oversubscribed candidate win, and the winner's measured fps never
  drops below the seed baseline.
"""
import math

import pytest

from repro.core import DSEConfig, EXEC_MODELS
from repro.core.resources import Device
from repro.memory import (ChannelConfig, MemoryModel, OffChipChannel,
                          PrefetchReport, prefetch_schedule)

# the benchmarks' memory-starved streaming device: small enough that the
# exec graphs are forced into eviction + fragmentation, so the channel
# actually has streams to arbitrate
TINY_STREAM = Device("tiny_stream", compute_units=4096,
                     onchip_bits=300_000, offchip_gbps=64.0,
                     freq_mhz=500.0, reconfig_s=0.0)

STUB_S_PER_CYCLE = 7e-9


# -----------------------------------------------------------------------------
# unit: channel + config
# -----------------------------------------------------------------------------

class TestChannel:
    def test_burst_quantisation_rounds_up_whole_bursts(self):
        ch = OffChipChannel(64.0, freq_mhz=500.0)   # 128 bits/cycle
        assert ch.bits_per_cycle == pytest.approx(128.0)
        burst = ch.burst_bits                       # DMA_FIFO_DEPTH words
        assert ch.n_bursts(0) == 0
        assert ch.n_bursts(1) == 1
        assert ch.n_bursts(burst) == 1
        assert ch.n_bursts(burst + 1) == 2
        assert ch.quantized_bits(burst + 1) == 2 * burst

    def test_transfer_cycles_inverse_in_rate_and_starved_is_inf(self):
        ch = OffChipChannel(64.0, freq_mhz=500.0)
        bits = 3 * ch.burst_bits
        assert ch.transfer_cycles(bits, 2.0) == \
            pytest.approx(ch.transfer_cycles(bits, 4.0) * 2.0)
        assert ch.transfer_cycles(bits, 0.0) == math.inf
        assert ch.transfer_cycles(0, 0.0) == 0.0    # nothing to move

    def test_config_round_trip_and_validation(self):
        cfg = ChannelConfig(policy="weighted-fair", gbps=8.0,
                            evict_weight=0.5, restore_weight=2.0)
        assert ChannelConfig.from_dict(cfg.to_dict()) == cfg
        # unknown keys are ignored (forward-compat artifacts)
        assert ChannelConfig.from_dict(
            {**cfg.to_dict(), "novel": 1}) == cfg
        with pytest.raises(ValueError):
            ChannelConfig(policy="fifo")
        with pytest.raises(ValueError):
            ChannelConfig(evict_weight=-1.0)


# -----------------------------------------------------------------------------
# unit: prefetcher deadline math (stub tick clock)
# -----------------------------------------------------------------------------

class TestPrefetch:
    CH = OffChipChannel(64.0, freq_mhz=500.0)

    def _sched(self, rates, tick=1000.0, microbatches=3):
        bits = {j: 4 * self.CH.burst_bits for j in rates}
        return prefetch_schedule(bits, rates, tick_cycles=tick,
                                 microbatches=microbatches, channel=self.CH)

    def test_warmup_slot_gets_cumulative_budget(self):
        rep = self._sched({0: 1.0, 2: 1.0}, tick=1000.0)
        by = {(s.stage, s.microbatch): s for s in rep.slots}
        # b=0 of stage j may prefetch during the whole fill ramp:
        # budget (j+1) ticks, deadline = first tick stage j runs (= j)
        assert by[(0, 0)].budget_cycles == pytest.approx(1000.0)
        assert by[(2, 0)].budget_cycles == pytest.approx(3000.0)
        assert by[(2, 0)].deadline_tick == 2
        # steady slots get exactly one tick
        assert by[(2, 1)].budget_cycles == pytest.approx(1000.0)
        assert by[(2, 1)].start_tick == 2 and by[(2, 1)].deadline_tick == 3

    def test_miss_iff_transfer_exceeds_budget(self):
        bits = 4 * self.CH.burst_bits               # transfer = bits/rate
        fast = bits / 999.0                         # fits in one tick
        slow = bits / 1001.0                        # misses steady budget
        ok = self._sched({0: fast}, tick=1000.0)
        assert ok.deadline_misses == 0
        assert min(s.slack_cycles for s in ok.slots) >= 0.0
        bad = self._sched({0: slow}, tick=1000.0)
        # every slot of stage 0 (incl. warmup b=0, whose budget is also
        # one tick at stage 0) misses by the same margin
        assert bad.deadline_misses == len(bad.slots)
        assert bad.misses_by_stage() == {0: len(bad.slots)}

    def test_starved_stage_misses_every_slot(self):
        rep = self._sched({0: 1.0, 1: 0.0}, tick=10_000.0, microbatches=2)
        assert all(s.missed for s in rep.slots if s.stage == 1)
        assert not any(s.missed for s in rep.slots if s.stage == 0)
        s = rep.summary()
        assert s["deadline_misses"] == rep.deadline_misses
        assert isinstance(rep, PrefetchReport)


# -----------------------------------------------------------------------------
# integration: every paper model under a channel model
# -----------------------------------------------------------------------------

@pytest.mark.parametrize("model", sorted(EXEC_MODELS))
@pytest.mark.parametrize("policy", ("round-robin", "weighted-fair"))
def test_contention_check_holds_for_exec_models(model, policy):
    """The ISSUE 9 acceptance chain on every executable paper topology:
    the stub-measured steady tick (contended Eq.6 scaled by k >= 1) sits
    above the contended bound, which sits above the uncontended bound —
    i.e. fps_measured <= fps_contended <= fps_uncontended — and every
    arbitrated byte is conserved bit-exactly vs the stream report."""
    import repro
    from repro.obs import check_contention

    c = repro.compile(repro.CompileSpec(
        model=model, device=TINY_STREAM, strategy="dse", mode="pipelined",
        microbatches=4, kernel_mode="reference",
        channel=ChannelConfig(policy=policy),
        dse=DSEConfig(batch=1, codecs=("none", "bfp8"), word_bits=16,
                      cut_kinds=("pool", "conv"))))
    rep = c.executor.report
    mem = rep.memory
    assert isinstance(mem, MemoryModel)
    assert mem.config.policy == policy

    # stub measurement: a real machine can only be slower than the model
    steady = mem.eq6_contended_cycles * STUB_S_PER_CYCLE * 1.5
    cc = check_contention(rep, s_per_cycle=STUB_S_PER_CYCLE,
                          steady_tick_seconds=steady)
    assert cc is not None and cc.ok, cc.violations()
    assert cc.bits_conserved
    # the fps chain, stated as times
    assert cc.eq6_contended_seconds >= cc.eq6_seconds - 1e-12
    assert cc.measured_within_bounds is True
    assert cc.summary()["ok"] is True

    # byte conservation is bit-exact against the stream report
    spill_bits = sum(int(r.offchip_bits) for r in rep.spills)
    by_kind = mem.arbitration.bits_by_kind()
    assert by_kind.get("activation-evict", 0) == spill_bits
    assert by_kind.get("activation-restore", 0) == spill_bits
    assert by_kind.get("weight-fetch", 0) == int(rep.streamed_weight_bits)

    # the report summary carries the channel block and stays JSON-able
    import json
    s = rep.summary()
    assert s["channel_policy"] == policy
    json.dumps(s)


def test_stream_report_without_channel_has_no_memory_model():
    import repro

    c = repro.compile(repro.CompileSpec(
        model="unet_exec", device=TINY_STREAM, strategy="dse",
        mode="pipelined", microbatches=4, kernel_mode="reference"))
    rep = c.executor.report
    assert rep.memory is None
    assert rep.channel_policy is None
    # contended estimators degrade to the uncontended ones
    assert rep.eq6_contended_time == rep.eq6_time
    from repro.obs import check_contention
    assert check_contention(rep) is None


# -----------------------------------------------------------------------------
# search: autotune bandwidth pruning
# -----------------------------------------------------------------------------

def _stub_fps(sx, xs):
    return 1.0 / (max(sx.report.stage_latency) * STUB_S_PER_CYCLE)


def _stub_stages(sx, x):
    return [l * STUB_S_PER_CYCLE for l in sx.report.stage_latency]


class TestAutotunePruning:
    def _tune(self, channel, n=4):
        from repro.core import build_unet_exec
        from repro.optim.autotune import AutotuneConfig, autotune
        cfg = AutotuneConfig(n_candidates=n, microbatches=4,
                             kernel_mode="reference", seed=0,
                             channel=channel)
        return autotune(build_unet_exec(), TINY_STREAM, cfg,
                        measure_fps=_stub_fps, measure_stages=_stub_stages)

    def test_generous_channel_keeps_candidates_feasible(self):
        res = self._tune(ChannelConfig(policy="weighted-fair", gbps=2000.0))
        assert all(r.feasible and not r.pruned for r in res.trajectory)
        assert all(r.eq6_contended_cycles >= r.eq6_cycles - 1e-9
                   for r in res.trajectory)
        assert res.best_fps >= res.baseline_fps

    def test_scarce_channel_prunes_everything_but_the_seed(self):
        res = self._tune(ChannelConfig(policy="round-robin", gbps=0.001))
        seed, rest = res.trajectory[0], res.trajectory[1:]
        assert seed.move == "seed" and not seed.pruned  # baseline anchor
        assert rest and all(r.pruned and r.fps_measured == 0.0
                            for r in rest)
        # a pruned candidate is never accepted, never best
        assert not any(r.accepted for r in rest)
        assert res.best_fps == res.baseline_fps

    def test_trajectory_rows_carry_channel_columns(self):
        res = self._tune(ChannelConfig(policy="weighted-fair", gbps=2000.0),
                         n=3)
        for row in res.trajectory_rows():
            assert {"eq6_contended_cycles", "feasible", "pruned"} <= set(row)
