"""Bench regression-gate tests (ISSUE 7 satellite): the write/compare
logic behind ``benchmarks/run.py --baseline`` / ``--check-baseline``."""
import json
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
from benchmarks.baseline import (check_baseline, git_sha,  # noqa: E402
                                 row_key, write_baseline)


def _row(**over):
    base = {"executor": "pipelined", "model": "unet_exec", "codecs": "none",
            "n_stages": 3, "microbatches": 8, "fps_executed": 1000.0,
            "fps_eq5": 800.0, "fps_eq6": 1200.0, "rel_err": 1e-6,
            "offchip_kbits": 512.0, "evicted": 2, "fragged": 1}
    base.update(over)
    return base


class TestBaselineGate:
    def test_write_stamps_provenance(self, tmp_path):
        p = write_baseline([_row()], tmp_path / "b.json", note="smoke")
        d = json.loads(p.read_text())
        assert d["kind"] == "smof-bench-baseline"
        assert d["git_sha"] == git_sha() != ""
        assert d["generated_unix"] > 0 and d["note"] == "smoke"
        assert row_key(_row()) in d["rows"]

    def test_identical_rows_pass(self, tmp_path):
        p = write_baseline([_row()], tmp_path / "b.json")
        failures, notes = check_baseline([_row()], p)
        assert failures == [] and len(notes) == 2

    def test_fps_drop_beyond_tolerance_fails(self, tmp_path):
        p = write_baseline([_row()], tmp_path / "b.json")
        # 35% of baseline: below the 40% floor -> regression
        failures, _ = check_baseline([_row(fps_executed=350.0)], p)
        assert any("fps_executed" in f and "dropped below" in f
                   for f in failures)
        # 50% of baseline: noisy but within the one-sided tolerance
        failures, _ = check_baseline([_row(fps_executed=500.0)], p)
        assert failures == []
        # fps *gains* never fail (one-sided gate)
        failures, _ = check_baseline([_row(fps_executed=9000.0)], p)
        assert failures == []

    def test_plan_shape_metrics_are_exact(self, tmp_path):
        p = write_baseline([_row()], tmp_path / "b.json")
        failures, _ = check_baseline([_row(n_stages=4)], p)
        # a changed stage count is both an exact-metric failure and a
        # missing row (n_stages is part of the row key)
        assert any("present in baseline but not measured" in f
                   for f in failures)
        failures, _ = check_baseline([_row(evicted=3)], p)
        assert any("evicted" in f and "exact" in f for f in failures)

    def test_offchip_and_rel_err_tolerances(self, tmp_path):
        p = write_baseline([_row()], tmp_path / "b.json")
        failures, _ = check_baseline([_row(offchip_kbits=512.0 * 1.005)], p)
        assert failures == []                       # within 1%
        failures, _ = check_baseline([_row(offchip_kbits=512.0 * 1.05)], p)
        assert any("offchip_kbits" in f for f in failures)
        failures, _ = check_baseline([_row(rel_err=0.01)], p)
        assert any("rel_err" in f and "grew past" in f for f in failures)

    def test_missing_row_fails_new_row_is_note(self, tmp_path):
        p = write_baseline([_row()], tmp_path / "b.json")
        failures, notes = check_baseline(
            [_row(model="x3d_exec")], p)            # renamed = gone + new
        assert any("not measured" in f for f in failures)
        assert any("new row" in n for n in notes)

    def test_wrong_artifact_kind_rejected(self, tmp_path):
        bad = tmp_path / "bad.json"
        bad.write_text(json.dumps({"rows": {}}))
        with pytest.raises(ValueError, match="smof-bench-baseline"):
            check_baseline([], bad)

    def test_committed_smoke_baseline_is_a_valid_artifact(self):
        committed = Path(__file__).resolve().parents[1] / "BENCH_smoke.json"
        d = json.loads(committed.read_text())
        assert d["kind"] == "smof-bench-baseline"
        # 2 codecs x 2 cuts x 2 kernel modes x 2 executors
        assert len(d["rows"]) == 16
        modes = set()
        for key, row in d["rows"].items():
            assert row_key(row) == key
            assert row["fps_executed"] > 0
            modes.add(row["kernel_mode"])
        assert modes == {"reference", "pallas"}
