"""Serving-engine KV-page evict -> restore path and the streaming front-end.

Covers the satellite gaps: BFP8 page round-trip numerics across the
HBM<->host boundary, slot refill after eviction (continuous batching), the
``resident_limit`` budget with its oldest-first eviction ordering, and the
``GraphStreamServer`` front-end that feeds the pipelined streamer.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.core import build_unet_exec
from repro.core.compression import bfp8_decode
from repro.core.plan import ExecutionPlan, LayerPlan, StreamPlan
from repro.models import init_params
from repro.runtime.executor import lower_plan
from repro.serving.engine import GraphStreamServer, ServingEngine


def _engine(**kw):
    cfg = ARCHS["yi-6b"].reduced()
    params = init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    return ServingEngine(cfg, params, max_batch=kw.pop("max_batch", 1),
                         s_max=64, **kw)


def _cache_page(eng, slot):
    return {
        "/".join(str(getattr(p, "key", p)) for p in path):
            np.asarray(leaf[:, slot], np.float32)
        for path, leaf in jax.tree_util.tree_leaves_with_path(eng.cache)
    }


def _zero_slot(eng, slot):
    eng.cache = jax.tree.map(lambda c: c.at[:, slot].set(0), eng.cache)


class TestKVEvictRestore:
    def test_bfp8_page_roundtrip_numerics(self):
        """Host-evicted pages decode back to ~the original KV values: small
        nonzero codec error, bounded by the 8-bit block quantisation."""
        eng = _engine(evict_to_host=True)
        r = eng.submit(np.arange(8), max_new_tokens=4)
        eng.run_until_drained()
        assert r.rid in eng.host_store
        # retiring does not clear the slot, so the cache still holds the
        # exact page the engine BFP8-encoded on its way out
        before = _cache_page(eng, 0)
        worst = 0.0
        for name, page in before.items():
            got = np.asarray(bfp8_decode(eng.host_store[r.rid][name]),
                             np.float32)
            denom = max(np.abs(page).max(), 1e-6)
            worst = max(worst, np.abs(got - page).max() / denom)
        assert 0.0 < worst < 0.05, worst

    def test_restore_after_host_eviction(self):
        """evict -> zero the slot -> restore: cache ~= original page."""
        eng = _engine(evict_to_host=True)
        r = eng.submit(np.arange(6), max_new_tokens=3)
        eng.run_until_drained()
        before = _cache_page(eng, 0)
        _zero_slot(eng, 0)
        eng.restore_request(r.rid, 0)
        assert r.rid not in eng.host_store        # pages came back
        after = _cache_page(eng, 0)
        for name, page in before.items():
            atol = 0.05 * max(np.abs(page).max(), 1e-6)
            np.testing.assert_allclose(after[name], page, rtol=0, atol=atol)
        assert eng.stats.restored_pages == len(before)

    def test_slot_refill_after_eviction(self):
        """Continuous batching: one slot serves many requests; every retired
        request's pages land on the host and the slot is reused."""
        eng = _engine(evict_to_host=True, max_batch=1)
        rs = [eng.submit(np.arange(4) + i, max_new_tokens=3)
              for i in range(3)]
        eng.run_until_drained()
        assert all(r.done for r in rs)
        assert eng.stats.prefills == 3            # 3 requests through 1 slot
        assert sorted(eng.host_store) == [r.rid for r in rs]

    def test_budget_exceeded_eviction_ordering(self):
        """resident_limit parks the newest retired page-sets in HBM; going
        over budget spills the OLDEST first (retirement order)."""
        eng = _engine(evict_to_host=True, max_batch=1, resident_limit=1)
        rs = [eng.submit(np.arange(4) + i, max_new_tokens=3)
              for i in range(3)]
        eng.run_until_drained()
        # newest stays resident, the two older crossed to host in order
        assert list(eng.resident_store) == [rs[2].rid]
        assert list(eng.host_store) == [rs[0].rid, rs[1].rid]

    def test_restore_from_resident_is_exact(self):
        eng = _engine(evict_to_host=True, resident_limit=4)
        r = eng.submit(np.arange(6), max_new_tokens=3)
        eng.run_until_drained()
        before = _cache_page(eng, 0)
        assert r.rid in eng.resident_store and r.rid not in eng.host_store
        _zero_slot(eng, 0)
        eng.restore_request(r.rid, 0)
        after = _cache_page(eng, 0)
        for name, page in before.items():
            np.testing.assert_array_equal(after[name], page)


class TestGraphStreamServer:
    def _plan(self, g, n_stages=2):
        topo = g.topo()
        stage = {n: min(i * n_stages // len(topo), n_stages - 1)
                 for i, n in enumerate(topo)}
        layers = {v.name: LayerPlan(name=v.name, stage=stage[v.name])
                  for v in g.vertices()}
        streams = [StreamPlan(e.src, e.dst) for e in g.edges()]
        return ExecutionPlan(model=g.name, device="tiny", n_stages=n_stages,
                             layers=layers, streams=streams, topo_order=topo)

    def test_flush_matches_sequential_executor(self):
        g = build_unet_exec(positions=32, levels=2)
        plan = self._plan(g)
        srv = GraphStreamServer(g, plan, microbatches=4,
                                kernel_mode="reference")
        low = lower_plan(g, plan, kernel_mode="reference")
        frames = [np.asarray(jax.random.normal(jax.random.PRNGKey(i),
                                               (32, 32), jnp.float32))
                  for i in range(6)]            # 1.5 streams -> padding
        tickets = [srv.submit(f) for f in frames]
        out = srv.flush()
        assert sorted(out) == tickets
        for t, f in zip(tickets, frames):
            np.testing.assert_allclose(out[t], np.asarray(low(jnp.asarray(f))),
                                       rtol=1e-5, atol=1e-5)
        assert srv.stats.streams_run == 2
        assert srv.stats.padded_frames == 2      # 6 frames into 2x4 slots
        assert srv.stats.frames_out == 6
        # delivered results are claimable by ticket, exactly once
        np.testing.assert_array_equal(srv.result(tickets[0]), out[tickets[0]])
        with pytest.raises(KeyError):
            srv.result(tickets[0])


class TestMetricsSurface:
    """ISSUE 7: both serving front-ends expose one registry-backed scrape
    surface; the legacy stats objects are live views of the same registry."""

    def test_engine_metrics_text_round_trips_and_matches_stats(self):
        from repro.obs import parse_metrics_text
        eng = _engine(evict_to_host=True)
        eng.submit(np.arange(8), max_new_tokens=4)
        eng.run_until_drained()
        fams = parse_metrics_text(eng.metrics_text())
        assert fams["smof_engine_prefills_total"]["samples"][
            "smof_engine_prefills_total"] == eng.stats.prefills == 1
        assert eng.stats.decode_steps > 0 and eng.stats.generated > 0
        assert eng.stats.evicted_pages > 0
        # BFP8 eviction compresses: compressed bytes < raw bytes, and both
        # land as one labeled family
        assert 0 < eng.stats.evicted_bytes_compressed \
            < eng.stats.evicted_bytes_raw
        kinds = fams["smof_engine_evicted_bytes_total"]["samples"]
        assert kinds['smof_engine_evicted_bytes_total{kind="raw"}'] \
            == eng.stats.evicted_bytes_raw
        # request latency is a real histogram family on the same surface
        assert fams["smof_engine_request_latency_seconds"]["type"] \
            == "histogram"
        # the legacy .latency attr and the registry read one histogram
        assert eng.latency.n == fams["smof_engine_request_latency_seconds"][
            "samples"]["smof_engine_request_latency_seconds_count"] == 1

    def test_engine_stats_report_is_the_registry_snapshot(self):
        eng = _engine()
        eng.submit(np.arange(4), max_new_tokens=2)
        eng.run_until_drained()
        rep = eng.stats.report()
        assert set(rep) <= set(eng.metrics.snapshot())
        assert all(k.startswith("smof_engine_") for k in rep)
        assert rep["smof_engine_prefills_total"] == 1.0
        assert "smof_engine_prefills_total" in repr(eng.stats)

    def test_stream_server_metrics_text_round_trips(self):
        from repro.obs import parse_metrics_text
        g = build_unet_exec(positions=32, levels=2)
        g.compute_buffer_depths()
        topo = g.topo()
        plan = ExecutionPlan(
            model=g.name, device="tiny", n_stages=1,
            layers={n: LayerPlan(name=n, stage=0) for n in topo},
            streams=[StreamPlan(e.src, e.dst) for e in g.edges()],
            topo_order=topo)
        srv = GraphStreamServer(g, plan, microbatches=4,
                                kernel_mode="reference")
        for i in range(6):
            srv.submit(np.zeros((32, 32), np.float32))
        srv.flush()
        fams = parse_metrics_text(srv.metrics_text())
        s = {k: v for f in fams.values() for k, v in f["samples"].items()}
        assert s["smof_server_frames_in_total"] == 6.0
        assert s["smof_server_frames_out_total"] == 6.0
        assert s["smof_server_streams_total"] == srv.stats.streams_run == 2.0
        assert s["smof_server_padded_frames_total"] == 2.0
        assert s["smof_server_frame_latency_seconds_count"] == 6.0
        assert srv.stats.report()["smof_server_frames_in_total"] == 6.0
