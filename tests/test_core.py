"""Unit tests for the SMOF core: graph IR, pipeline-depth model (Eq. 8-11),
eviction (Eq. 1-2), fragmentation (Eq. 3-4), partitioning (Eq. 5-6)."""
import pytest

from repro.core import (Graph, U200, Vertex,
                        build_unet, candidate_evictions,
                        candidate_fragmentations, apply_eviction,
                        apply_fragmentation, evaluate_eviction,
                        evaluate_fragmentation, initial_partition,
                        initiation_interval, initiation_rate, interval_prev,
                        latency_s, merge, Partitioning, pipeline_depth,
                        throughput_fps, vertex_delays)
from repro.core.eviction import DMA_DELAY_CYCLES, DMA_FIFO_DEPTH
from repro.core.fragmentation import weight_consumption_rate


def chain3() -> Graph:
    """input -> conv(a) -> conv(b), hand-checkable numbers."""
    g = Graph("chain3")
    g.add(Vertex("in", "input", in_words=100, out_words=100, base_depth=1))
    g.add(Vertex("a", "conv", work_macs=1000, weight_words=50,
                 in_words=100, out_words=200, base_depth=10, max_par=8))
    g.add(Vertex("b", "conv", work_macs=4000, weight_words=80,
                 in_words=200, out_words=100, base_depth=20, max_par=8))
    g.connect("in", "a")
    g.connect("a", "b")
    return g


def branchy() -> Graph:
    """A skip connection: in -> a -> (skip, long: b -> c) -> concat."""
    g = Graph("branchy")
    g.add(Vertex("in", "input", in_words=64, out_words=64))
    g.add(Vertex("a", "conv", work_macs=640, weight_words=16,
                 in_words=64, out_words=64, base_depth=8, max_par=4))
    g.add(Vertex("b", "conv", work_macs=64000, weight_words=32,
                 in_words=64, out_words=64, base_depth=512, max_par=4))
    g.add(Vertex("c", "conv", work_macs=64000, weight_words=32,
                 in_words=64, out_words=64, base_depth=512, max_par=4))
    g.add(Vertex("cat", "concat", in_words=128, out_words=128))
    g.connect("in", "a")
    g.connect("a", "b")
    g.connect("b", "c")
    g.connect("a", "cat")     # the skip
    g.connect("c", "cat")
    return g


class TestPipelineModel:
    def test_interval_prev_is_max_over_ancestors(self):
        g = chain3()
        # Eq. 8 for "a": only ancestor is "in": lambda=100, rho=1
        assert interval_prev(g, "a") == pytest.approx(100 + 1)
        # for "b": ancestor "a": lambda = max(1000, 200)/1 = 1000, rho = 10
        assert interval_prev(g, "b") == pytest.approx(1000 + 10)

    def test_initiation_rate(self):
        g = chain3()
        # Eq. 9: source vertex uses its standard input rate
        assert initiation_rate(g, "in") == pytest.approx(100 / 100)
        # "b": sigma_in / Interval_prev = 200 / 1010
        assert initiation_rate(g, "b") == pytest.approx(200 / 1010)

    def test_delay_accumulates_along_path(self):
        g = chain3()
        d = vertex_delays(g)
        assert d["in"] < d["a"] < d["b"]
        # Eq. 10 closed form for the chain
        r_in = 1.0
        r_a = 100 / (100 + 1)
        r_b = 200 / (1000 + 10)
        expect = 1 / r_in + 10 / r_a + 20 / r_b
        assert d["b"] == pytest.approx(expect)

    def test_pipeline_depth_is_max_delay(self):
        g = chain3()
        assert pipeline_depth(g) == pytest.approx(max(vertex_delays(g).values()))

    def test_parallelism_reduces_ii(self):
        g = chain3()
        ii0 = initiation_interval(g)
        g.vertex("b").par = 8
        assert initiation_interval(g) < ii0


class TestBufferDepths:
    def test_skip_edge_gets_deep_buffer(self):
        g = branchy()
        g.compute_buffer_depths()
        skip = g.edge("a", "cat")
        seq = g.edge("a", "b")
        assert skip.buffer_depth > seq.buffer_depth
        assert skip.buffer_depth > DMA_DELAY_CYCLES  # evictable

    def test_unet_deepest_buffers_are_the_long_skips(self):
        g = build_unet()
        g.compute_buffer_depths()
        deepest = max(g.edges(), key=lambda e: e.buffer_depth)
        assert g.vertex(deepest.dst).kind == "concat"


class TestEviction:
    def test_eq1_saving_and_constraint(self):
        g = branchy()
        g.compute_buffer_depths()
        opt = evaluate_eviction(g, "a", "cat")
        d_b = g.edge("a", "cat").buffer_depth
        assert opt.delta_depth_words == pytest.approx(d_b - 2 * DMA_FIFO_DEPTH)
        assert opt.feasible == (d_b > max(2 * DMA_FIFO_DEPTH, DMA_DELAY_CYCLES))

    def test_eq2_bandwidth(self):
        g = branchy()
        g.compute_buffer_depths()
        opt = evaluate_eviction(g, "a", "cat", codec="none", alpha=1.0)
        r = g.vertex("a").rate_out()
        assert opt.delta_bw_words_per_cycle == pytest.approx(r * 1.0 * 2.0)

    def test_shallow_edge_not_feasible(self):
        g = chain3()
        g.compute_buffer_depths()   # all shallow
        opts = candidate_evictions(g)
        assert opts == []           # nothing worth evicting

    def test_apply_eviction_shrinks_buffer(self):
        g = branchy()
        g.compute_buffer_depths()
        before = g.edge("a", "cat").buffer_depth
        opt = evaluate_eviction(g, "a", "cat")
        apply_eviction(g, opt)
        e = g.edge("a", "cat")
        assert e.evicted and e.buffer_depth == pytest.approx(2 * DMA_FIFO_DEPTH)
        assert e.buffer_depth < before


class TestFragmentation:
    def test_eq3_eq4(self):
        g = chain3()
        v = g.vertex("b")
        opt = evaluate_fragmentation(g, "b", ratio_step=0.25)
        assert opt.delta_depth_words == pytest.approx(0.25 * v.weight_words)
        r = weight_consumption_rate(v)
        assert opt.delta_bw_words_per_cycle == pytest.approx(0.25 * r * 1.0)

    def test_ratio_saturates_at_one(self):
        g = chain3()
        for _ in range(10):
            opt = evaluate_fragmentation(g, "b", ratio_step=0.3)
            if opt is None:
                break
            apply_fragmentation(g, opt)
        assert g.vertex("b").frag_ratio == pytest.approx(1.0)
        assert g.vertex("b").static_weight_bits() == pytest.approx(0.0)

    def test_weightless_vertex_has_no_option(self):
        g = chain3()
        assert evaluate_fragmentation(g, "in") is None

    def test_merit_ordering(self):
        g = chain3()
        opts = candidate_fragmentations(g)
        merits = [o.merit for o in opts]
        assert merits == sorted(merits, reverse=True)


class TestPartitioning:
    def test_initial_partition_is_fine_grained(self):
        g = build_unet()
        p = initial_partition(g, cut_kinds=("conv", "pool"))
        assert p.n > 10

    def test_dependency_violation_rejected(self):
        g = chain3()
        with pytest.raises(ValueError):
            Partitioning(g, [["b"], ["in", "a"]])

    def test_merge_reduces_reconfig_latency(self):
        g = chain3()
        g.compute_buffer_depths()
        p = initial_partition(g, cut_kinds=None)
        t_before = latency_s(p, U200, batch=1)
        merged = p
        while merged.n > 1:
            merged = merge(merged, 0)
        t_after = latency_s(merged, U200, batch=1)
        assert t_after < t_before       # reconfig overhead gone

    def test_eq6_throughput_matches_latency(self):
        g = chain3()
        g.compute_buffer_depths()
        p = initial_partition(g, cut_kinds=None)
        b = 4
        assert throughput_fps(p, U200, b) == pytest.approx(
            b / latency_s(p, U200, b))

    def test_boundary_words(self):
        g = chain3()
        p = Partitioning(g, [["in", "a"], ["b"]])
        w_in, w_out = p.boundary_words(1)
        assert w_in == pytest.approx(g.vertex("a").out_words)
        assert w_out == 0.0
