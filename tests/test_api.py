"""Compile façade tests (ISSUE 4 tentpole): ``CompileSpec`` -> ``Compiled``.

The acceptance contract: ``repro.compile`` succeeds for every registered
exec model x mode, its ``.run`` output is *bit-identical* to calling the
pre-façade lowering functions directly, and a ``Compiled.save``d artifact
reloads and runs — bit-identically — in a fresh process.
"""
import json
import os
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.api import CompileSpec, Compiled
from repro.core import (DSEConfig, EXEC_MODELS, build_unet_exec,
                        exec_input_shape, get_model, plan_from_dse, run_dse)
from repro.core.plan import PLAN_SCHEMA_VERSION
from repro.core.resources import Device

# the memory-starved streaming device view the e2e benchmark uses: forces
# the DSE into eviction + fragmentation on every exec graph
TINY = Device("tiny_stream", compute_units=4096, onchip_bits=300_000,
              offchip_gbps=64.0, freq_mhz=500.0, reconfig_s=0.0)
DSE_CFG = DSEConfig(batch=1, codecs=("none", "bfp8"), word_bits=16,
                    cut_kinds=("pool", "conv"))


def _spec(name, **kw):
    kw.setdefault("device", TINY)
    kw.setdefault("strategy", "dse")
    kw.setdefault("dse", DSE_CFG)
    kw.setdefault("kernel_mode", "reference")
    return CompileSpec(model=name, **kw)


def _input(compiled, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed),
                             compiled.input_shape(), jnp.float32)


class TestParity:
    """compile(mode=...) == the direct lowering path, bit for bit, for
    every model in EXEC_MODELS (the acceptance matrix)."""

    @pytest.mark.parametrize("name", sorted(EXEC_MODELS))
    def test_staged_matches_lower_plan(self, name):
        from repro.runtime.executor import lower_plan
        c = repro.compile(_spec(name, mode="staged"))
        g = get_model(name, EXEC_MODELS)()
        res = run_dse(g, TINY, DSE_CFG)
        plan = plan_from_dse(name, TINY.name, res)
        low = lower_plan(g, plan, kernel_mode="reference")
        x = _input(c)
        np.testing.assert_array_equal(np.asarray(c.run(x)),
                                      np.asarray(low(x)))

    @pytest.mark.parametrize("name", sorted(EXEC_MODELS))
    def test_pipelined_matches_lower_plan_pipelined(self, name):
        from repro.runtime.streamer import lower_plan_pipelined
        c = repro.compile(_spec(name, mode="pipelined", microbatches=2))
        g = get_model(name, EXEC_MODELS)()
        res = run_dse(g, TINY, DSE_CFG)
        plan = plan_from_dse(name, TINY.name, res)
        sx = lower_plan_pipelined(g, plan, microbatches=2,
                                  kernel_mode="reference")
        x = _input(c)
        xs = jnp.stack([x, 2.0 * x])
        np.testing.assert_array_equal(np.asarray(c.run(xs)),
                                      np.asarray(sx(xs)))

    def test_reference_matches_reference_pipeline(self):
        from repro.runtime.executor import reference_pipeline
        c = repro.compile(_spec("unet_exec", mode="reference"))
        x = _input(c)
        want = reference_pipeline(get_model("unet_exec", EXEC_MODELS)())(x)
        np.testing.assert_array_equal(np.asarray(c.run(x)),
                                      np.asarray(want))
        assert c.plan is None            # the baseline is plan-free

    def test_pipelined_single_frame_convenience(self):
        c = repro.compile(_spec("unet_exec", mode="pipelined",
                                microbatches=2))
        x = _input(c)
        y1 = c.run(x)                                   # (L,)
        ys = c.run(jnp.broadcast_to(x, (2,) + x.shape))  # (2, L)
        np.testing.assert_array_equal(np.asarray(y1), np.asarray(ys[0]))


class TestSpec:
    def test_unknown_mode_and_strategy_rejected(self):
        with pytest.raises(ValueError, match="mode"):
            repro.compile(_spec("unet_exec", mode="warp"))
        with pytest.raises(ValueError, match="strategy"):
            repro.compile(_spec("unet_exec", strategy="oracle"))

    def test_manual_plan_requires_plan(self):
        with pytest.raises(ValueError, match="manual-plan"):
            repro.compile(CompileSpec(model="unet_exec",
                                      strategy="manual-plan", mode="staged"))

    def test_unknown_model_lists_registry(self):
        with pytest.raises(KeyError, match="unet_exec"):
            repro.compile(_spec("resnet9000"))

    def test_use_pallas_shorthand(self):
        assert CompileSpec(model="m", use_pallas=True
                           ).resolved_kernel_mode() == "pallas"
        assert CompileSpec(model="m", use_pallas=False, kernel_mode="pallas"
                           ).resolved_kernel_mode() == "reference"
        assert CompileSpec(model="m", kernel_mode="auto"
                           ).resolved_kernel_mode() == "auto"
        c = repro.compile(_spec("unet_exec", mode="staged",
                                kernel_mode="auto", use_pallas=False))
        x = _input(c)
        want = repro.compile(_spec("unet_exec", mode="staged")).run(x)
        np.testing.assert_array_equal(np.asarray(c.run(x)), np.asarray(want))

    def test_graph_instance_model(self):
        g = build_unet_exec(positions=32, levels=2)
        c = repro.compile(_spec(g, mode="staged"))
        assert c.model == "unet_exec"
        assert c.input_shape() == exec_input_shape(g)


class TestProvenanceAndReport:
    def test_plan_provenance_stamped(self):
        c = repro.compile(_spec("unet_exec", mode="staged"))
        prov = c.plan.provenance
        assert prov["strategy"] == "dse"
        assert prov["device"] == "tiny_stream"
        assert prov["compiled_by"] == "repro.api.compile"
        assert c.plan.schema_version == PLAN_SCHEMA_VERSION

    def test_unified_report(self):
        c = repro.compile(_spec("unet_exec", mode="pipelined",
                                microbatches=2))
        rep = c.report()
        assert rep["model"] == "unet_exec"
        assert rep["mode"] == "pipelined"
        assert rep["strategy"] == "dse"
        assert rep["traffic"]["n_stages"] == c.plan.n_stages
        assert "total_offchip_bits" in rep["traffic"]
        assert rep["provenance"]["device"] == "tiny_stream"

    def test_autotune_strategy_provenance_and_report(self):
        from repro.optim.autotune import AutotuneConfig
        g = build_unet_exec(positions=32, levels=2)
        c = repro.compile(CompileSpec(
            model=g, device=TINY, strategy="autotune", mode="pipelined",
            autotune_cfg=AutotuneConfig(n_candidates=2, microbatches=2,
                                        repeats=1, warmup=1,
                                        kernel_mode="reference"),
            kernel_mode="reference"))
        assert c.autotune_result is not None
        prov = c.plan.provenance
        assert prov["strategy"] == "autotune"
        assert len(prov["autotune_digest"]) == 16
        assert prov["s_per_cycle"] > 0
        assert prov["best_fps"] >= prov["baseline_fps"]
        rep = c.report()
        assert rep["autotune"]["candidates"] == 2
        assert "calibration" in rep["autotune"]
        # the executor serves at the depth the search measured at
        assert c.executor.microbatches == 2
        # ...and a serve() with overrides keeps that depth unless the
        # caller explicitly changes it
        srv = c.serve(seed=0)                # any override forces re-lower
        assert srv.microbatches == 2


class TestServe:
    def test_serve_reuses_pipelined_executor(self):
        c = repro.compile(_spec("unet_exec", mode="pipelined",
                                microbatches=2))
        srv = c.serve()
        assert srv.executor is c.executor
        x = np.asarray(_input(c))
        t0, t1 = srv.submit(x), srv.submit(2.0 * x)
        out = srv.flush()
        np.testing.assert_array_equal(out[t0], np.asarray(c.run(x)))
        assert set(out) == {t0, t1}

    def test_serve_rejects_plan_free_reference(self):
        c = repro.compile(_spec("unet_exec", mode="reference"))
        with pytest.raises(ValueError, match="plan-free"):
            c.serve()

    def test_serve_relower_from_staged(self):
        c = repro.compile(_spec("unet_exec", mode="staged"))
        srv = c.serve(microbatches=2)
        assert srv.microbatches == 2
        assert srv.executor.plan is c.plan   # same decisions, re-lowered

    def test_stream_server_legacy_signature_still_works(self):
        from repro.serving.engine import GraphStreamServer
        c = repro.compile(_spec("unet_exec", mode="staged"))
        g = get_model("unet_exec", EXEC_MODELS)()
        srv = GraphStreamServer(g, c.plan, microbatches=2,
                                kernel_mode="reference")
        x = np.asarray(_input(c))
        t = srv.submit(x)
        np.testing.assert_array_equal(srv.flush()[t], np.asarray(c.run(x)))


class TestSaveLoad:
    def test_roundtrip_bit_identical_in_process(self, tmp_path):
        c = repro.compile(_spec("unet_exec", mode="staged"))
        path = c.save(tmp_path / "unet.smof.json")
        d = json.loads(path.read_text())
        assert d["artifact"] == "smof-compiled"
        assert d["plan_schema_version"] == PLAN_SCHEMA_VERSION
        assert d["plan"]["provenance"]["strategy"] == "dse"
        back = Compiled.load(path)
        assert back.spec.strategy == "manual-plan"   # decisions are baked in
        x = _input(c, seed=7)
        np.testing.assert_array_equal(np.asarray(back.run(x)),
                                      np.asarray(c.run(x)))

    def test_custom_graph_roundtrip(self, tmp_path):
        # the artifact embeds the graph, so non-default builder kwargs
        # (which the registry could not reproduce) reload exactly
        g = build_unet_exec(positions=32, levels=2)
        c = repro.compile(_spec(g, mode="pipelined", microbatches=2))
        back = Compiled.load(c.save(tmp_path / "small.smof.json"))
        assert back.input_shape() == exec_input_shape(g)
        x = _input(c, seed=3)
        np.testing.assert_array_equal(np.asarray(back.run(x)),
                                      np.asarray(c.run(x)))

    def test_newer_artifact_schema_rejected(self, tmp_path):
        c = repro.compile(_spec("unet_exec", mode="staged"))
        path = c.save(tmp_path / "a.json")
        d = json.loads(path.read_text())
        d["artifact_schema_version"] = 99
        path.write_text(json.dumps(d))
        with pytest.raises(ValueError, match="newer"):
            Compiled.load(path)
        path.write_text(json.dumps({"artifact": "other"}))
        with pytest.raises(ValueError, match="not a smof-compiled"):
            Compiled.load(path)

    def test_fresh_process_reload_bit_identical(self, tmp_path):
        """The acceptance criterion: a saved artifact reloads and runs in a
        *fresh process*, bit-identical (weights are seeded, the graph is
        embedded)."""
        g = build_unet_exec(positions=32, levels=2)
        c = repro.compile(_spec(g, mode="staged"))
        art = c.save(tmp_path / "fresh.smof.json")
        x = _input(c, seed=11)
        want = np.asarray(c.run(x))
        out = tmp_path / "y.npy"
        code = (
            "import numpy as np, jax, jax.numpy as jnp\n"
            "import repro\n"
            f"c = repro.Compiled.load({str(art)!r})\n"
            "x = jax.random.normal(jax.random.PRNGKey(11), c.input_shape(),"
            " jnp.float32)\n"
            f"np.save({str(out)!r}, np.asarray(c.run(x)))\n")
        src = pathlib.Path(__file__).resolve().parent.parent / "src"
        env = dict(os.environ,
                   PYTHONPATH=f"{src}{os.pathsep}"
                              f"{os.environ.get('PYTHONPATH', '')}",
                   JAX_PLATFORMS="cpu")
        subprocess.run([sys.executable, "-c", code], check=True, env=env,
                       timeout=600)
        np.testing.assert_array_equal(np.load(out), want)


class TestGraphSerialisation:
    def test_operand_order_preserved(self):
        """Multi-input ops consume operands in predecessor order; the
        structural dump must reproduce it (concat is order-sensitive)."""
        from repro.core.graph import Graph
        g = build_unet_exec(positions=32, levels=2)
        g2 = Graph.from_json_dict(g.to_json_dict())
        for n in g.topo():
            assert g.predecessors(n) == g2.predecessors(n)
        assert g2.to_json_dict() == g.to_json_dict()

    def test_design_state_included(self):
        g = build_unet_exec(positions=32, levels=2)
        run_dse(g, TINY, DSE_CFG)            # mutates eviction/frag state
        from repro.core.graph import Graph
        g2 = Graph.from_json_dict(g.to_json_dict())
        assert ([(e.src, e.dst, e.evicted, e.codec) for e in g.edges()]
                == [(e.src, e.dst, e.evicted, e.codec) for e in g2.edges()])


class TestPlanMigration:
    def test_unknown_keys_collected_not_silently_dropped(self):
        from repro.core.plan import ExecutionPlan
        c = repro.compile(_spec("unet_exec", mode="staged"))
        d = json.loads(c.plan.to_json())
        lname = next(iter(d["layers"]))
        d["from_the_future"] = 1
        d["layers"][lname]["future_knob"] = 2
        d["streams"][0]["future_flag"] = True
        back = ExecutionPlan.from_json(json.dumps(d))
        assert set(back.dropped_keys) == {
            "plan.from_the_future", f"layers[{lname}].future_knob",
            "streams[0].future_flag"}
        assert back.layers.keys() == c.plan.layers.keys()
        assert back.streams == c.plan.streams

    def test_v1_plans_migrate(self):
        from repro.core.plan import ExecutionPlan
        c = repro.compile(_spec("unet_exec", mode="staged"))
        d = json.loads(c.plan.to_json())
        del d["schema_version"]              # what a v1 writer produced
        del d["provenance"]
        back = ExecutionPlan.from_json(json.dumps(d))
        # migrated forward to the current shape, observably
        assert back.schema_version == PLAN_SCHEMA_VERSION
        assert back.provenance == {"migrated_from_schema_version": 1}
        assert back.dropped_keys == ()
        # re-serialising a migrated plan emits a current-schema payload
        again = ExecutionPlan.from_json(back.to_json())
        assert again.schema_version == PLAN_SCHEMA_VERSION
        assert again.to_json() == back.to_json()

    def test_save_load_save_strategy_stable(self, tmp_path):
        c = repro.compile(_spec("unet_exec", mode="staged"))
        p1 = c.save(tmp_path / "a.json")
        back = Compiled.load(p1)
        assert back.strategy == "dse"        # decision origin survives
        assert back.report()["strategy"] == "dse"
        p2 = back.save(tmp_path / "b.json")
        assert (json.loads(p2.read_text())["strategy"]
                == json.loads(p1.read_text())["strategy"] == "dse")
