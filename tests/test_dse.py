"""DSE (Algorithm 1) behaviour + invariants, incl. hypothesis properties."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (DSEConfig, Graph, U200, Vertex, ZCU102, build_unet,
                        pack_onchip, plan_from_dse, run_dse, ExecutionPlan)
from repro.core.dse import _snapshot, _restore
from repro.core.partition import subgraph_cost, fits


def random_chain(seed: int, n: int) -> Graph:
    rng = np.random.default_rng(seed)
    g = Graph(f"rand{seed}")
    g.add(Vertex("in", "input", in_words=256, out_words=256))
    prev = "in"
    for i in range(n):
        v = g.add(Vertex(f"c{i}", "conv",
                         work_macs=float(rng.integers(10_000, 5_000_000)),
                         weight_words=float(rng.integers(1_000, 500_000)),
                         in_words=256, out_words=256,
                         base_depth=float(rng.integers(1, 2000)),
                         max_par=64))
        g.connect(prev, v.name)
        prev = v.name
    return g


class TestDSE:
    def test_unet_u200_matches_paper_ballpark(self):
        """Paper Fig. 4: UNet on U200 = 21 fps, 47 ms, single partition."""
        res = run_dse(build_unet(), U200,
                      DSEConfig(batch=1, cut_kinds=("conv", "pool"), word_bits=8))
        assert res.feasible
        assert res.partitioning.n == 1
        assert 14.0 < res.throughput_fps < 28.0
        assert res.latency_s < 0.08

    def test_small_device_triggers_offchip(self):
        """ZCU102 cannot hold UNet weights on-chip -> fragmentation/eviction."""
        res = run_dse(build_unet(), ZCU102,
                      DSEConfig(batch=1, cut_kinds=("conv", "pool"), word_bits=8))
        assert res.feasible
        g = res.partitioning.graph
        used_offchip = (any(v.frag_ratio > 0 for v in g.vertices())
                        or any(e.evicted for e in g.edges())
                        or res.partitioning.n > 1)
        assert used_offchip

    def test_all_parts_feasible_after_dse(self):
        cfg = DSEConfig(batch=1, cut_kinds=("conv", "pool"), word_bits=8)
        res = run_dse(build_unet(), U200, cfg)
        for i in range(res.partitioning.n):
            c = subgraph_cost(res.partitioning, i)
            assert fits(c, U200, word_bits=8)

    def test_disabling_mechanisms_never_improves(self):
        """Fig. 6's premise: baseline <= eviction/fragmentation-enabled."""
        g1, g2 = build_unet(), build_unet()
        cfg_full = DSEConfig(batch=1, cut_kinds=("conv", "pool"), word_bits=8)
        cfg_base = DSEConfig(batch=1, cut_kinds=("conv", "pool"), word_bits=8,
                             allow_eviction=False, allow_fragmentation=False)
        full = run_dse(g1, ZCU102, cfg_full)
        base = run_dse(g2, ZCU102, cfg_base)
        assert full.throughput_fps >= base.throughput_fps * 0.999

    @given(st.integers(0, 6), st.integers(2, 8))
    @settings(max_examples=10, deadline=None)
    def test_constraints_hold_property(self, seed, n):
        g = random_chain(seed, n)
        cfg = DSEConfig(batch=1, word_bits=8)
        res = run_dse(g, ZCU102, cfg)
        if res.feasible:
            for i in range(res.partitioning.n):
                c = subgraph_cost(res.partitioning, i)
                assert c.compute_units <= ZCU102.compute_units
                assert c.bw_words_per_cycle <= ZCU102.words_per_cycle_offchip(8) * 1.001

    def test_history_records_passes(self):
        res = run_dse(build_unet(), U200,
                      DSEConfig(batch=1, cut_kinds=("conv", "pool"), word_bits=8))
        passes = {h.get("pass") for h in res.history}
        assert 1 in passes and 2 in passes and 5 in passes

    def test_batch_size_amortises_reconfig(self):
        """Table IV trend: reconfig contribution shrinks with batch size."""
        thr = {}
        for b in (1, 16):
            res = run_dse(build_unet(), ZCU102,
                          DSEConfig(batch=b, cut_kinds=("conv", "pool"), word_bits=8))
            thr[b] = res.throughput_fps
        assert thr[16] >= thr[1]


class TestSnapshot:
    def test_restore_undoes_mutation(self):
        g = build_unet()
        g.compute_buffer_depths()
        snap = _snapshot(g)
        for v in g.vertices():
            v.par = v.max_par
            v.frag_ratio = 0.5
        for e in g.edges():
            e.evicted = True
        _restore(g, snap)
        assert all(v.par == v.min_par and v.frag_ratio == 0.0 for v in g.vertices())
        assert not any(e.evicted for e in g.edges())


class TestPackOnchip:
    def test_balances_utilisation(self):
        out = pack_onchip(weight_bits=200e6, buffer_bits=80e6, dev=U200)
        assert out["feasible"]
        assert out["bram"] <= U200.bram18k and out["uram"] <= U200.uram

    def test_infeasible_when_too_big(self):
        out = pack_onchip(weight_bits=1e10, buffer_bits=1e9, dev=ZCU102)
        assert not out["feasible"]

    def test_no_uram_device(self):
        out = pack_onchip(weight_bits=10e6, buffer_bits=5e6, dev=ZCU102)
        assert out["uram"] == 0


class TestPlan:
    def test_plan_roundtrip(self):
        res = run_dse(build_unet(), U200,
                      DSEConfig(batch=1, cut_kinds=("conv", "pool"), word_bits=8))
        plan = plan_from_dse("unet", "u200", res)
        j = plan.to_json()
        back = ExecutionPlan.from_json(j)
        assert back.n_stages == plan.n_stages
        assert set(back.layers) == set(plan.layers)
        assert back.est_throughput_fps == pytest.approx(plan.est_throughput_fps)

    def test_stage_layers_partition(self):
        res = run_dse(build_unet(), U200,
                      DSEConfig(batch=1, cut_kinds=("conv", "pool"), word_bits=8))
        plan = plan_from_dse("unet", "u200", res)
        all_layers = set()
        for s in range(plan.n_stages):
            all_layers |= set(plan.stage_layers(s))
        assert all_layers == set(plan.layers)
