"""Differential conformance suite (see docs/TESTING.md).

Four layers of defence, all driven by ``repro.testing``:

* committed repro files under ``tests/repros/`` replay on every run — a
  fixed bug stays fixed;
* the serving front-end with ``resident_limit`` eviction is bit-exact vs
  ``Compiled.run`` across a generated population (host byte-store spills
  must not change results);
* a budget-limited fuzz smoke proves the generator/oracle loop is clean
  on the current tree;
* the harness self-test plants a known fault, and the fuzzer must catch
  it, shrink it, and emit a repro that replays to the same failure — a
  conformance suite that cannot catch a planted bug measures nothing.
"""
import json
import pathlib

import numpy as np
import pytest

from repro.testing import GenConfig, OracleViolation, random_case
from repro.testing.fuzz import replay, run_case

REPRO_DIR = pathlib.Path(__file__).parent / "repros"
REPRO_FILES = sorted(REPRO_DIR.glob("*.json"))

# Small population for test-time fuzzing: tiny graphs, shallow streams —
# same vocabulary and oracles as the CLI default, just faster cases.
SMALL = GenConfig(min_blocks=2, max_blocks=4, positions=(8, 16),
                  max_positions=32, channels=(8, 16, 32), max_stages=3,
                  max_microbatches=3)


# -----------------------------------------------------------------------------
# committed repros replay
# -----------------------------------------------------------------------------

@pytest.mark.parametrize("path", REPRO_FILES, ids=lambda p: p.stem)
def test_committed_repros_replay_clean(path):
    """Every committed repro re-executes its exact (graph, plan, seed)
    case through all oracles.  A bug repro is committed once its bug is
    fixed and must replay clean; a *planted-fault* repro (``inject_fault``
    set) replays with the fault re-injected and must keep failing on the
    same oracle — the lock that the harness still catches it."""
    d = json.loads(path.read_text())
    assert d["kind"] == "smof-fuzz-repro"
    assert d["oracle"]             # records what originally failed
    if d.get("inject_fault"):
        with pytest.raises(OracleViolation) as exc:
            replay(path)
        assert exc.value.oracle == d["oracle"]
    else:
        report = replay(path)      # raises OracleViolation on regression
        assert report.oracles      # all oracles ran


def test_repro_files_are_valid_format():
    for path in REPRO_FILES:
        d = json.loads(path.read_text())
        assert d["kind"] == "smof-fuzz-repro"
        assert d["version"] == 1
        assert {"graph", "plan", "seed"} <= set(d["case"])


# -----------------------------------------------------------------------------
# serving parity under resident_limit eviction (25 generated graphs)
# -----------------------------------------------------------------------------

def test_server_resident_limit_bit_exact_on_generated_population():
    """GraphStreamServer with ``resident_limit`` eviction returns
    bit-identical results to ``Compiled.run`` on 25 generated graphs:
    flushed results that spilled to the host byte store must restore
    exactly, across batch-padding boundaries."""
    import repro

    for i in range(25):
        case = random_case(3, i, SMALL)
        B = max(2, case.plan.microbatch)
        c = repro.compile(repro.CompileSpec(
            model=case.graph, device="u200", strategy="manual-plan",
            mode="pipelined", plan=case.plan, microbatches=B,
            kernel_mode="reference", placement="interleave",
            seed=case.seed))
        m, ch = case.input_shape
        rng = np.random.default_rng(case.seed)
        xs = rng.normal(size=(B, m, ch)).astype(np.float32)
        want = np.asarray(c.run(xs))

        srv = c.serve(resident_limit=1)
        tickets = [srv.submit(xs[b]) for b in range(B)]
        srv.flush()
        # resident_limit=1: all but the newest flushed result were evicted
        # to the host byte store before any claim
        for b, t in enumerate(tickets):
            got = srv.result(t)
            assert np.array_equal(got, want[b]), (
                f"case {case.label}: server result {b} differs from "
                f"Compiled.run after resident-limit eviction")


def test_server_resident_limit_evicts_and_restores_counters():
    """The eviction path actually exercises: counters move and results
    survive a restore round-trip."""
    import repro

    case = random_case(3, 0, SMALL)
    B = max(2, case.plan.microbatch)
    c = repro.compile(repro.CompileSpec(
        model=case.graph, device="u200", strategy="manual-plan",
        mode="pipelined", plan=case.plan, microbatches=B,
        kernel_mode="reference", placement="interleave", seed=case.seed))
    m, ch = case.input_shape
    xs = np.random.default_rng(0).normal(size=(B, m, ch)).astype(np.float32)
    srv = c.serve(resident_limit=1)      # keep only the newest resident
    tickets = [srv.submit(xs[b]) for b in range(B)]
    srv.flush()
    snap = c.metrics()
    evicted = sum(v for k, v in snap.items() if "evicted_results" in k)
    assert evicted == B - 1               # all but the newest spilled
    for t in tickets:
        srv.result(t)                     # claims restore without error
    snap = c.metrics()
    restored = sum(v for k, v in snap.items() if "restored_results" in k)
    assert restored == B - 1


# -----------------------------------------------------------------------------
# generator properties (no compiles: cheap, broad)
# -----------------------------------------------------------------------------

def test_generated_cases_are_structurally_valid():
    """Every generated (graph, plan) passes structural validation, the
    plan covers every vertex/edge, and (seed, index) is deterministic."""
    for i in range(20):
        case = random_case(11, i, SMALL)
        case.graph.validate()
        case.plan.validate()
        topo = case.graph.topo()
        assert set(case.plan.layers) == set(topo)
        assert set((s.src, s.dst) for s in case.plan.streams) == \
            set((e.src, e.dst) for e in case.graph.edges())
        again = random_case(11, i, SMALL)
        assert again.plan.to_json() == case.plan.to_json()
        assert (again.graph.to_json_dict() == case.graph.to_json_dict())


def test_facade_rejects_invalid_manual_plan():
    """The compile façade refuses a backwards-crossing manual plan with
    the typed error before any lowering starts."""
    import repro
    from repro.core.plan import PlanValidationError

    case = random_case(3, 1, SMALL)
    names = case.plan.ordered_layers()
    case.plan.n_stages = max(case.plan.n_stages, 2)
    case.plan.layers[names[0]].stage = 1   # source after its consumers
    for n in names[1:]:
        case.plan.layers[n].stage = 0
    with pytest.raises(PlanValidationError, match="backwards"):
        repro.compile(repro.CompileSpec(
            model=case.graph, device="u200", strategy="manual-plan",
            mode="staged", plan=case.plan, kernel_mode="reference"))


# -----------------------------------------------------------------------------
# fuzz smoke + harness self-test (planted fault must be caught)
# -----------------------------------------------------------------------------

def test_fuzz_smoke_clean_tree(tmp_path):
    """A small fuzz budget completes with zero violations and writes no
    repro files on the current tree."""
    from repro.testing.fuzz import main

    rc = main(["--budget", "2", "--seed", "5", "--out", str(tmp_path),
               "--max-blocks", "4", "--max-stages", "3",
               "--max-microbatches", "3"])
    assert rc == 0
    assert list(tmp_path.glob("*.json")) == []


def test_planted_fault_is_caught_shrunk_and_replayable(tmp_path):
    """End-to-end harness self-test: plant ``skip-bfp8-decode``, fuzz
    until it is caught, and verify the shrunk repro JSON replays to the
    SAME oracle failure.  (Calibrated: seed 0 index 0 of the default
    population carries an evicted BFP8 stage-crossing, the exact shape
    the fault corrupts.)"""
    from repro.testing.fuzz import main

    rc = main(["--budget", "1", "--seed", "0", "--out", str(tmp_path),
               "--inject-fault", "skip-bfp8-decode",
               "--max-shrink-runs", "6"])
    assert rc == 1                         # the planted fault MUST fail
    files = list(tmp_path.glob("*.json"))
    assert len(files) == 1
    d = json.loads(files[0].read_text())
    assert d["oracle"] in ("staged_vs_pipelined", "bfp8_bounded")
    assert d["inject_fault"] == "skip-bfp8-decode"
    assert d["shrunk"]["to_vertices"] <= d["shrunk"]["from_vertices"]
    with pytest.raises(OracleViolation) as ei:
        replay(files[0])                   # fault is recorded -> replays
    assert ei.value.oracle == d["oracle"]


def test_undersized_queue_fault_trips_modelcheck():
    """The Eq. 1 gate is live: shrinking every inter-stage ring to
    capacity 1 makes the traced walk stall and ``modelcheck`` fire.
    (Calibrated: seed 0 index 9 has a crossing with pipeline delay > 1.)"""
    case = random_case(0, 9, SMALL)
    v = run_case(case, "undersize-queues")
    assert v is not None and v.oracle == "modelcheck"
    assert run_case(case, None) is None    # same case is clean unfaulted


def test_oversubscribed_channel_fault_trips_contention_gate():
    """The channel-capacity gate is live: granting every stream its full
    demand (ignoring ``bits_per_cycle``) must trip the contention check
    on a case whose drawn channel is genuinely oversubscribed.
    (Calibrated: seed 0 index 1 of the default population draws a 1 Gbps
    fixed-priority channel over an off-chip demand that exceeds it.)"""
    case = random_case(0, 1, GenConfig())
    assert case.channel is not None        # the draw this test relies on
    v = run_case(case, "oversubscribe-channel")
    assert v is not None and v.oracle in ("modelcheck", "channel_model")
    assert "capacity" in str(v)
    assert run_case(case, None) is None    # same case is clean unfaulted
