"""Minimal deterministic stand-in for the ``hypothesis`` API surface the
test suite uses (``given``, ``settings``, ``strategies.integers/lists/
floats``).

Installed by ``conftest.py`` into ``sys.modules`` ONLY when the real
hypothesis package is unavailable (this repo's pinned container images do
not ship it).  The real package, when installed, always wins — this module
is never imported in that case.

Semantics: ``@given`` re-runs the test body over a fixed number of drawn
examples (``settings(max_examples=...)`` is honoured).  Draws are seeded
from the test function's qualified name, so runs are reproducible and
failures can be re-triggered locally.  The first example of every strategy
is its minimal element (empty list / lower bound / 0.0-ish), which covers
the boundary cases hypothesis's shrinker would otherwise find.
"""
from __future__ import annotations

import functools
import inspect
import math
import zlib

import numpy as np

__version__ = "0.0-fallback"

_DEFAULT_MAX_EXAMPLES = 30


class _Strategy:
    """Base strategy: subclasses implement draw(rng, minimal)."""

    def draw(self, rng: np.random.Generator, minimal: bool):
        raise NotImplementedError


class _Integers(_Strategy):
    def __init__(self, lo: int, hi: int):
        self.lo, self.hi = int(lo), int(hi)

    def draw(self, rng, minimal):
        if minimal:
            return self.lo
        return int(rng.integers(self.lo, self.hi + 1))


class _Floats(_Strategy):
    def __init__(self, lo: float, hi: float):
        self.lo, self.hi = float(lo), float(hi)

    def draw(self, rng, minimal):
        if minimal:
            return 0.0 if self.lo <= 0.0 <= self.hi else self.lo
        # mix uniform draws with boundary/special values
        specials = [self.lo, self.hi, 0.0, 1.0, -1.0, 2.0 ** -6, 2.0 ** 10]
        if rng.random() < 0.25:
            v = specials[int(rng.integers(len(specials)))]
            if self.lo <= v <= self.hi:
                return float(v)
        return float(rng.uniform(self.lo, self.hi))


class _SampledFrom(_Strategy):
    def __init__(self, elements):
        self.elements = list(elements)

    def draw(self, rng, minimal):
        if minimal:
            return self.elements[0]
        return self.elements[int(rng.integers(len(self.elements)))]


class _Lists(_Strategy):
    def __init__(self, elem: _Strategy, min_size: int, max_size: int):
        self.elem = elem
        self.min_size, self.max_size = int(min_size), int(max_size)

    def draw(self, rng, minimal):
        if minimal:
            return [self.elem.draw(rng, True) for _ in range(self.min_size)]
        n = int(rng.integers(self.min_size, self.max_size + 1))
        return [self.elem.draw(rng, False) for _ in range(n)]


class strategies:
    """Namespace mirroring ``hypothesis.strategies``."""

    @staticmethod
    def integers(min_value: int = -(2 ** 31), max_value: int = 2 ** 31 - 1):
        return _Integers(min_value, max_value)

    @staticmethod
    def floats(min_value: float = -math.inf, max_value: float = math.inf,
               allow_nan: bool = True, allow_infinity: bool = True,
               width: int = 64):
        lo = min_value if math.isfinite(min_value) else -1e30
        hi = max_value if math.isfinite(max_value) else 1e30
        return _Floats(lo, hi)

    @staticmethod
    def lists(elements: _Strategy, min_size: int = 0, max_size: int = 50,
              unique: bool = False):
        return _Lists(elements, min_size, max_size)

    @staticmethod
    def sampled_from(elements):
        return _SampledFrom(elements)


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None,
             **_ignored):
    """Decorator recording max_examples; consumed by @given in either
    decorator order."""
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn
    return deco


def given(*strats: _Strategy):
    def deco(fn):
        max_examples = getattr(fn, "_fallback_max_examples", None)

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):   # args = (self,) for methods
            n = (wrapper._fallback_max_examples if max_examples is None
                 else max_examples)
            seed0 = zlib.crc32(fn.__qualname__.encode())
            for i in range(n):
                rng = np.random.default_rng((seed0, i))
                drawn = [s.draw(rng, minimal=(i == 0)) for s in strats]
                try:
                    fn(*args, *drawn, **kwargs)
                except _Unsatisfied:
                    continue
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example (fallback shim, draw {i}): "
                        f"{fn.__qualname__}{tuple(drawn)!r}") from e

        wrapper._fallback_max_examples = _DEFAULT_MAX_EXAMPLES
        # hide the drawn parameters from pytest's fixture resolution (the
        # real hypothesis does the same: its wrapper takes no arguments)
        wrapper.__signature__ = inspect.Signature()
        if hasattr(wrapper, "__wrapped__"):
            del wrapper.__wrapped__
        # settings() applied ABOVE given() re-decorates the wrapper
        return wrapper
    return deco


class HealthCheck:
    all = staticmethod(lambda: [])


def assume(condition: bool) -> bool:
    """Weak form: treat a failed assumption as a vacuous pass."""
    if not condition:
        raise _Unsatisfied()
    return True


class _Unsatisfied(Exception):
    pass
