"""Test-suite bootstrap.

The property tests use ``hypothesis``; some pinned container images cannot
install it.  When the real package is importable it is used untouched —
otherwise ``_hypothesis_fallback`` (a tiny deterministic shim with the same
``given``/``settings``/``strategies`` surface) is aliased into
``sys.modules`` before any test module imports run, so the full suite
still collects and exercises every property with pseudo-random examples.
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))

try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))
    import _hypothesis_fallback

    sys.modules["hypothesis"] = _hypothesis_fallback
    sys.modules["hypothesis.strategies"] = _hypothesis_fallback.strategies
