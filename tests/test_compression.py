"""Codec tests: RLE / Huffman round-trips, BFP8 accuracy, ratio estimators.
Property-based (hypothesis) where the invariant is exact reconstruction."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import compression as C


class TestRLE:
    def test_roundtrip_simple(self):
        x = np.array([0, 0, 0, 5, 5, 1, 0, 0], dtype=np.int32)
        vals, runs = C.rle_encode(x)
        np.testing.assert_array_equal(C.rle_decode(vals, runs), x)

    @given(st.lists(st.integers(-128, 127), min_size=0, max_size=500))
    @settings(max_examples=60, deadline=None)
    def test_roundtrip_property(self, xs):
        x = np.asarray(xs, dtype=np.int32)
        vals, runs = C.rle_encode(x)
        np.testing.assert_array_equal(C.rle_decode(vals, runs), x)

    def test_max_run_respected(self):
        x = np.zeros(1000, dtype=np.int32)
        vals, runs = C.rle_encode(x, max_run=256)
        assert runs.max() <= 256
        np.testing.assert_array_equal(C.rle_decode(vals, runs), x)

    def test_sparse_compresses_dense_does_not(self):
        rng = np.random.default_rng(0)
        sparse = np.where(rng.random(4096) < 0.8, 0, rng.integers(1, 100, 4096))
        dense = rng.integers(-100, 100, 4096)
        assert C.rle_ratio(sparse, 8) < 1.0
        assert C.rle_ratio(dense, 8) > 1.0   # RLE hurts incompressible data


class TestHuffman:
    def test_roundtrip(self):
        rng = np.random.default_rng(1)
        x = rng.choice([0, 0, 0, 0, 1, 2, 3], size=200)
        code = C.huffman_build(dict(zip(*np.unique(x, return_counts=True))))
        payload, nbits = C.huffman_encode(x, code)
        out = C.huffman_decode(payload, nbits, code)
        np.testing.assert_array_equal(out, x)

    @given(st.lists(st.integers(0, 15), min_size=1, max_size=300))
    @settings(max_examples=40, deadline=None)
    def test_roundtrip_property(self, xs):
        x = np.asarray(xs)
        syms, counts = np.unique(x, return_counts=True)
        code = C.huffman_build(dict(zip(syms.tolist(), counts.tolist())))
        payload, nbits = C.huffman_encode(x, code)
        np.testing.assert_array_equal(C.huffman_decode(payload, nbits, code), x)

    def test_skewed_beats_uniform(self):
        rng = np.random.default_rng(2)
        skewed = rng.choice(16, p=[0.7] + [0.02] * 15, size=4096)
        uniform = rng.integers(0, 16, 4096)
        assert C.huffman_ratio(skewed, 8) < C.huffman_ratio(uniform, 8)

    def test_kraft_inequality(self):
        rng = np.random.default_rng(3)
        x = rng.integers(0, 64, 1000)
        syms, counts = np.unique(x, return_counts=True)
        code = C.huffman_build(dict(zip(syms.tolist(), counts.tolist())))
        kraft = sum(2.0 ** -ln for ln in code.lengths.values())
        assert kraft == pytest.approx(1.0)

    def test_prefix_free(self):
        rng = np.random.default_rng(4)
        x = rng.integers(0, 32, 500)
        syms, counts = np.unique(x, return_counts=True)
        code = C.huffman_build(dict(zip(syms.tolist(), counts.tolist())))
        bits = {format(c, f"0{l}b") for c, l in code.codes.values()}
        for a in bits:
            for b in bits:
                assert a == b or not b.startswith(a)


class TestBFP8:
    @given(st.lists(st.floats(-1e3, 1e3, allow_nan=False, width=32),
                    min_size=1, max_size=300))
    @settings(max_examples=40, deadline=None)
    def test_relative_error_bounded(self, xs):
        x = np.asarray(xs, dtype=np.float32)
        out = C.bfp8_decode(C.bfp8_encode(x, block=32))
        assert out.shape == x.shape
        # error bounded by half an lsb of the block scale
        blocks = np.pad(x, (0, (-x.size) % 32)).reshape(-1, 32)
        scales = 2.0 ** (np.ceil(np.log2(np.maximum(np.abs(blocks).max(1), 1e-38))) - 6)
        err = np.abs(np.pad(x, (0, (-x.size) % 32)).reshape(-1, 32) -
                     np.pad(out, (0, (-out.size) % 32)).reshape(-1, 32))
        assert (err <= scales[:, None] * 0.5 + 1e-30).all()

    def test_zeros_exact(self):
        x = np.zeros(100, dtype=np.float32)
        np.testing.assert_array_equal(C.bfp8_decode(C.bfp8_encode(x)), x)

    def test_shape_preserved(self):
        x = np.random.default_rng(5).normal(size=(7, 13)).astype(np.float32)
        assert C.bfp8_decode(C.bfp8_encode(x)).shape == (7, 13)

    def test_ratio_compile_time_known(self):
        assert C.bfp8_ratio(16, block=32) == pytest.approx((8 + 0.25) / 16)
        assert C.bfp8_ratio(8, block=32) > 1.0   # pointless on 8-bit words


class TestEstimator:
    def test_none_is_identity(self):
        assert C.estimate_ratio("none", 8) == 1.0

    def test_rle_improves_with_sparsity(self):
        lo = C.estimate_ratio("rle", 8, sparsity=0.2)
        hi = C.estimate_ratio("rle", 8, sparsity=0.9)
        assert hi < lo

    def test_measured_beats_analytic_on_real_sample(self):
        rng = np.random.default_rng(6)
        sample = np.where(rng.random(8192) < 0.7, 0.0, rng.normal(size=8192))
        measured = C.estimate_ratio("rle", 8, sample=sample)
        assert 0.0 < measured < 1.2

    def test_unknown_codec_raises(self):
        with pytest.raises(ValueError):
            C.estimate_ratio("lzw", 8)
