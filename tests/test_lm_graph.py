"""SMOF DSE on LM architectures — the paper's optimiser driving the TPU
runtime view (on-chip = HBM, off-chip = host DRAM)."""
import pytest

from repro.configs import ARCHS
from repro.core import DSEConfig, TPU_V5E_RUNTIME, plan_from_dse, run_dse
from repro.core.lm_graph import build_lm_graph


class TestLMGraphConstruction:
    @pytest.mark.parametrize("name", ["yi-6b", "grok-1-314b",
                                      "jamba-v0.1-52b", "xlstm-1.3b"])
    def test_weight_words_match_param_count(self, name):
        cfg = ARCHS[name]
        g = build_lm_graph(cfg, batch=4, seq=2048, kind="prefill")
        predicted = cfg.param_counts()["total"]
        got = g.total_weight_words()
        assert abs(got - predicted) / predicted < 0.12, (got, predicted)

    def test_moe_layers_present(self):
        g = build_lm_graph(ARCHS["olmoe-1b-7b"], batch=2, seq=512)
        kinds = {v.kind for v in g.vertices()}
        assert "router" in kinds and "expert" in kinds

    def test_hybrid_interleave(self):
        g = build_lm_graph(ARCHS["jamba-v0.1-52b"], batch=2, seq=512)
        attn = sum(1 for v in g.vertices() if v.kind == "attention")
        ssm = sum(1 for v in g.vertices() if v.kind == "ssm_scan")
        assert attn == 4 and ssm == 28          # 1:7 over 32 layers

    def test_decode_kv_cache_is_deep_buffer(self):
        cfg = ARCHS["yi-6b"]
        g = build_lm_graph(cfg, batch=8, seq=8192, kind="decode")
        deep = max(e.buffer_depth for e in g.edges())
        assert deep == pytest.approx(8 * 8192 * cfg.n_kv_heads * cfg.hd * 2)

    def test_acyclic_and_connected(self):
        g = build_lm_graph(ARCHS["glm4-9b"], batch=2, seq=256)
        order = g.topo()                         # raises if cyclic
        assert order[0] == "input" and order[-1] == "output"


class TestDSEOnLM:
    def test_big_model_triggers_offchip(self):
        """grok-1 (632 GB bf16 weights) vs one 16 GB chip: the DSE must use
        fragmentation (host weight streaming) and/or partitioning — the
        exact regime the paper built SMOF for."""
        import dataclasses
        cfg = dataclasses.replace(ARCHS["grok-1-314b"], n_layers=8)
        g = build_lm_graph(cfg, batch=1, seq=2048, kind="prefill")
        res = run_dse(g, TPU_V5E_RUNTIME,
                      DSEConfig(batch=1, word_bits=16, frag_step=0.25,
                                cut_kinds=("expert",), max_iters=20))
        used_offchip = (any(v.frag_ratio > 0 for v in g.vertices())
                        or res.partitioning.n > 1)
        assert used_offchip

    def test_small_model_stays_resident(self):
        """xlstm-1.3b (2.8 GB) fits one chip: no fragmentation needed."""
        import dataclasses
        cfg = dataclasses.replace(ARCHS["xlstm-1.3b"], n_layers=8)
        g = build_lm_graph(cfg, batch=1, seq=2048, kind="prefill")
        res = run_dse(g, TPU_V5E_RUNTIME,
                      DSEConfig(batch=1, word_bits=16,
                                cut_kinds=("ssm_scan",), max_iters=20))
        assert res.feasible
        assert res.partitioning.n == 1
        assert all(v.frag_ratio == 0 for v in g.vertices())

    def test_plan_projects_to_runtime_knobs(self):
        import dataclasses
        cfg = dataclasses.replace(ARCHS["yi-6b"], n_layers=8)
        g = build_lm_graph(cfg, batch=1, seq=1024, kind="prefill")
        res = run_dse(g, TPU_V5E_RUNTIME,
                      DSEConfig(batch=1, word_bits=16,
                                cut_kinds=("attention",), max_iters=20))
        plan = plan_from_dse(cfg.name, "tpu_v5e_runtime", res)
        assert plan.n_stages == res.partitioning.n
        for lp in plan.layers.values():
            assert 0.0 <= lp.weight_static_fraction <= 1.0
