"""Pipelined streaming executor tests.

The acceptance contract (ISSUE 2 / docs/ARCHITECTURE.md):
* per microbatch, the pipelined executor is numerically equivalent to the
  sequential ``lower_plan`` pipeline on the same plan — BFP8 codec error
  included identically in both (the same codec functions run in the same
  pad->quantise->dequantise->slice composition, only *when* changes);
* ``StreamReport`` spill bit-volumes are bit-exact against ``SpillReport``
  for the same plan;
* on a >=3-stage UNet exec graph with >=8 microbatches, measured
  steady-state throughput beats the sequential executor and lands closer
  to the Eq. 6 ``1/max_j(L_j)`` pipeline estimate than to the Eq. 5
  sequential sum (latencies measured per stage, same dispatch regime the
  sequential schedule pays).
"""
import math
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (DSEConfig, build_unet_exec, build_yolo_head_exec,
                        plan_from_dse, run_dse)
from repro.core.plan import ExecutionPlan, LayerPlan, StreamPlan
from repro.core.resources import Device
from repro.runtime.executor import lower_plan
from repro.runtime.streamer import (RingBuffer, StreamReport,
                                    build_queues, build_schedule,
                                    eq5_sequential_time, eq6_pipeline_time,
                                    lower_plan_pipelined,
                                    measured_stage_latencies, queue_specs,
                                    simulate_schedule, stage_latencies)

TINY = Device("tiny", compute_units=4096, onchip_bits=300_000,
              offchip_gbps=64.0, freq_mhz=500.0, reconfig_s=0.0)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _staged_plan(g, n_stages=3, evict_codec=None, depth_thresh=4096.0):
    """Hand-built plan: stages cut the topological order into equal thirds;
    optionally evict every deep (skip) edge with ``evict_codec``."""
    g.compute_buffer_depths()
    topo = g.topo()
    stage = {n: min(i * n_stages // len(topo), n_stages - 1)
             for i, n in enumerate(topo)}
    layers = {v.name: LayerPlan(name=v.name, stage=stage[v.name])
              for v in g.vertices()}
    streams = []
    for e in g.edges():
        evict = evict_codec is not None and e.buffer_depth > depth_thresh
        streams.append(StreamPlan(e.src, e.dst, evicted=evict,
                                  codec=evict_codec if evict else "none"))
    return ExecutionPlan(model=g.name, device="tiny", n_stages=n_stages,
                         layers=layers, streams=streams, topo_order=topo)


def _dse_plan(g, codecs=("none",), cut_kinds=("pool", "conv")):
    res = run_dse(g, TINY, DSEConfig(batch=1, codecs=codecs, word_bits=16,
                                     cut_kinds=cut_kinds))
    return plan_from_dse(g.name, TINY.name, res)


def _sequential_outputs(low, xs):
    return np.stack([np.asarray(low(xs[b])) for b in range(xs.shape[0])])


# =============================================================================
# Schedule
# =============================================================================

class TestSchedule:
    def test_shape_of_the_1f1b_diagram(self):
        s = build_schedule(3, 8)
        assert s.ticks == 10
        assert len(s.tasks()) == 3 * 8            # every (stage, mb) once
        assert s.active_stages(0) == [0]          # fill: only stage 0
        assert s.active_stages(2) == [0, 1, 2]    # steady: all stages
        assert s.active_stages(9) == [2]          # drain: only the tail
        assert [s.phase(t) for t in (0, 1, 2, 7, 8, 9)] == \
            ["fill", "fill", "steady", "steady", "drain", "drain"]

    def test_occupancy_and_stalls(self):
        s = build_schedule(4, 8)
        for j in range(4):
            assert s.stage_active_ticks(j) == 8
            assert s.stage_idle_ticks(j) == 3      # S-1 bubbles
            assert s.stage_occupancy(j) == 8 / 11

    def test_degenerate_single_stage(self):
        s = build_schedule(1, 5)
        assert s.ticks == 5 and s.phase(0) == "steady"

    def test_invalid_schedule_rejected(self):
        with pytest.raises(ValueError):
            build_schedule(0, 4)

    def test_eq5_eq6_estimators(self):
        lat = [3.0, 7.0, 2.0]
        assert eq5_sequential_time(lat) == 12.0
        assert eq6_pipeline_time(lat) == 7.0

    def test_stage_latencies_analytic_hook(self):
        g = build_unet_exec()
        plan = _staged_plan(g)
        lat = stage_latencies(g, plan)
        assert len(lat) == 3 and all(l > 0 for l in lat)
        hooked = stage_latencies(g, plan, hook=lambda j, sg: float(j + 1))
        assert hooked == [1.0, 2.0, 3.0]


# =============================================================================
# Queues
# =============================================================================

class TestQueues:
    def test_ring_buffer_stall_accounting(self):
        q = RingBuffer(2)
        assert q.pop() is None and q.pop_stalls == 1
        assert q.push("a") and q.push("b")
        assert not q.push("c") and q.push_stalls == 1   # over capacity
        # high_water saturates at capacity: the modelled ring never
        # physically holds more than `capacity` entries, the overflowing
        # push is accounted as a stall instead
        assert q.high_water == 2
        assert q.pop() == "a"

    def test_ring_buffer_push_full_pop_empty_counters(self):
        """Direct unit contract for the stall counters (ISSUE 6 fix):
        every push against a full ring counts exactly one push stall,
        every pop from an empty ring exactly one pop stall, and neither
        corrupts FIFO order or the saturated high-water mark."""
        q = RingBuffer(3)
        # pop-empty: N pops on an empty ring -> N pop stalls, nothing else
        for k in range(1, 4):
            assert q.pop() is None
            assert q.pop_stalls == k
        assert q.push_stalls == 0 and q.high_water == 0 and len(q) == 0

        # fill exactly to capacity: no stalls, high_water rides occupancy
        for i in range(3):
            assert q.push(i)
            assert q.high_water == i + 1
        assert q.push_stalls == 0

        # push-full: each overflowing push counts one stall; high_water
        # stays pinned at capacity (no off-by-one above the ring's size)
        for k in range(1, 3):
            assert not q.push(100 + k)
            assert q.push_stalls == k
            assert q.high_water == q.capacity == 3
        # FIFO order survives the overflow accounting
        assert [q.pop() for _ in range(5)] == [0, 1, 2, 101, 102]
        assert q.pop() is None and q.pop_stalls == 4

    def test_ring_buffer_emits_occupancy_and_stall_events(self):
        from repro.obs import TraceRecorder
        rec = TraceRecorder(clock=None)
        q = RingBuffer(2, name="a->b", recorder=rec)
        q.push("x", ts=0.0)
        q.push("y", ts=1.0)
        q.push("z", ts=2.0)          # overflow -> stall instant
        q.pop(ts=3.0)
        assert rec.totals["queue:a->b:occupancy"] == 2  # saturated, not 3
        names = [ev["name"] for ev in rec.chrome_trace()["traceEvents"]]
        assert "queue:a->b:push_stall" in names

    def test_specs_cover_crossing_edges_with_eq1_capacity(self):
        g = build_unet_exec()
        plan = _staged_plan(g)
        from repro.runtime.executor import analyze_plan
        an = analyze_plan(g, plan, use_pallas=False, interpret=True)
        specs = queue_specs(g, an.stage_of, an.out_shape)
        assert specs                                   # stages do cross
        for (u, w), s in specs.items():
            assert an.stage_of[w] > an.stage_of[u]
            assert s.delay == an.stage_of[w] - an.stage_of[u]
            # floored at the two DMA-burst FIFOs AND the executed
            # shift-register depth for the crossing
            assert s.capacity >= max(2, s.delay)
            assert s.capacity_words == 256.0           # Eq. 1 d_b'

    def test_simulation_high_water_tracks_stage_distance(self):
        g = build_unet_exec()
        plan = _staged_plan(g)
        from repro.runtime.executor import analyze_plan
        an = analyze_plan(g, plan, use_pallas=False, interpret=True)
        specs = queue_specs(g, an.stage_of, an.out_shape)
        queues = build_queues(specs)
        sim = simulate_schedule(
            build_schedule(3, 8), queues,
            producer_stage={e: an.stage_of[e[0]] for e in specs},
            consumer_stage={e: an.stage_of[e[1]] for e in specs})
        assert sim["ticks"] == 10
        for e, st in sim["queues"].items():
            assert st["high_water"] >= specs[e].delay
            assert st["occupancy"] == 0                # fully drained
            assert st["pop_stalls"] == 0


# =============================================================================
# Numerical equivalence with the sequential executor
# =============================================================================

class TestParity:
    def _check(self, g, plan, B=8, seed=0, in_shape=(64, 32)):
        low = lower_plan(g, plan, kernel_mode="reference")
        sx = lower_plan_pipelined(g, plan, microbatches=B,
                                  kernel_mode="reference")
        xs = jax.random.normal(jax.random.PRNGKey(seed), (B,) + in_shape,
                               jnp.float32)
        ys = np.asarray(sx(xs))
        want = _sequential_outputs(low, xs)
        np.testing.assert_allclose(ys, want, rtol=1e-5, atol=1e-6)
        return sx, low

    def test_dse_multistage_plan_unet(self):
        g = build_unet_exec()
        plan = _dse_plan(g)
        assert plan.n_stages >= 2
        self._check(g, plan)

    def test_dse_plan_with_bfp8_yolo_head(self):
        g = build_yolo_head_exec()
        plan = _dse_plan(g, codecs=("none", "bfp8"))
        self._check(g, plan, seed=1)

    def test_bfp8_skip_eviction_across_stages(self):
        """Cross-stage BFP8 spills carry *encoded* buffers through the
        pipeline and still reproduce the sequential codec error exactly."""
        g = build_unet_exec()
        plan = _staged_plan(g, evict_codec="bfp8")
        assert any(s.evicted for s in plan.streams)
        sx, low = self._check(g, plan, seed=2)
        # the codec really ran: pipelined output differs from the dense ref
        from repro.runtime.executor import reference_pipeline
        ref = reference_pipeline(g)
        x = jax.random.normal(jax.random.PRNGKey(3), (64, 32), jnp.float32)
        xs = jnp.broadcast_to(x, (8, 64, 32))
        rel = (np.abs(np.asarray(sx(xs))[0] - np.asarray(ref(x))).max()
               / np.abs(np.asarray(ref(x))).max())
        assert 0.0 < rel < 0.15

    def test_single_stage_plan_degenerates_to_batched_scan(self):
        g = build_unet_exec(positions=32, levels=2)
        plan = _staged_plan(g, n_stages=1)
        sx, _ = self._check(g, plan, B=4, in_shape=(32, 32))
        assert sx.n_stages == 1 and sx.report.ticks == 4

    def test_wrong_stream_shape_rejected(self):
        g = build_unet_exec()
        plan = _staged_plan(g)
        sx = lower_plan_pipelined(g, plan, microbatches=4,
                                  kernel_mode="reference")
        with pytest.raises(ValueError, match="stream shape"):
            sx(jnp.zeros((3, 64, 32), jnp.float32))

    def test_backward_stage_edge_rejected(self):
        g = build_unet_exec()
        plan = _staged_plan(g)
        # corrupt: force a later vertex into an earlier stage
        last = plan.topo_order[-1]
        plan.layers[last].stage = 0
        with pytest.raises(ValueError, match="backward|empty"):
            lower_plan_pipelined(g, plan, microbatches=4,
                                 kernel_mode="reference")


# =============================================================================
# StreamReport
# =============================================================================

class TestStreamReport:
    def test_spill_bit_volumes_bit_exact_vs_sequential(self):
        g = build_unet_exec()
        for plan in (_dse_plan(g, codecs=("none", "bfp8")),
                     _staged_plan(g, evict_codec="bfp8")):
            low = lower_plan(g, plan, kernel_mode="reference")
            sx = lower_plan_pipelined(g, plan, microbatches=8,
                                      kernel_mode="reference")
            assert isinstance(sx.report, StreamReport)
            assert sx.report.spills == low.report.spills
            assert (sx.report.total_offchip_bits
                    == low.report.total_offchip_bits)
            assert (sx.report.static_weight_bits
                    == low.report.static_weight_bits)

    def test_schedule_accounting_fields(self):
        g = build_unet_exec()
        plan = _staged_plan(g)
        sx = lower_plan_pipelined(g, plan, microbatches=8,
                                  kernel_mode="reference")
        r = sx.report
        assert r.n_stages == 3 and r.microbatches == 8 and r.ticks == 10
        assert r.stage_occupancy == [8 / 10] * 3
        assert r.stage_stalls == [2] * 3               # S-1 bubbles
        assert len(r.stage_latency) == 3
        assert r.eq5_time == sum(r.stage_latency)
        assert r.eq6_time == max(r.stage_latency)
        assert r.bottleneck_stage == r.stage_latency.index(max(r.stage_latency))
        s = r.summary()
        assert s["ticks"] == 10 and s["placement"] == "interleave"
        assert s["total_offchip_bits"] == r.total_offchip_bits


# =============================================================================
# ModelCheck: measured walk vs the Eq. 5/6 schedule and Eq. 1 queue sizing
# =============================================================================

class TestModelCheck:
    def test_steady_ticks_match_eq6_schedule_exactly(self):
        """The traced run's measured steady-state tick count equals the
        Eq. 6 schedule prediction B - S + 1 exactly (stub clock: the
        invariant is structural, not timing-dependent)."""
        from repro.obs import TraceRecorder
        g = build_unet_exec()
        plan = _staged_plan(g)
        sx = lower_plan_pipelined(g, plan, microbatches=8,
                                  kernel_mode="reference")
        ticking = [0.0]

        def stub_clock():
            ticking[0] += 1.0
            return ticking[0]

        rec = TraceRecorder(clock=stub_clock)
        xs = jax.random.normal(jax.random.PRNGKey(0), (8, 64, 32),
                               jnp.float32)
        ys, mc = sx.run_traced(xs, rec, measure_stages=False)
        assert ys.shape == (8, ys.shape[1])
        sched = sx.schedule
        assert mc.ticks_measured == mc.ticks_predicted == sched.ticks == 10
        assert (mc.steady_measured == mc.steady_predicted
                == sched.steady_ticks == 6)
        assert mc.ticks_ok and mc.queues_ok and mc.ok
        # and the emitted trace agrees: one steady tick span per steady tick
        steady = [s for s in rec.spans(track="pipeline")
                  if s["name"] == "tick" and s["cat"] == "steady"]
        assert len(steady) == sched.steady_ticks

    def test_deliberately_mis_sized_queue_is_flagged(self):
        """Shrinking one crossing's ring below its stage distance makes the
        schedule walk overflow it — ModelCheck must flag the design."""
        import dataclasses as dc
        from repro.obs import check_stream
        g = build_unet_exec()
        plan = _staged_plan(g)
        sx = lower_plan_pipelined(g, plan, microbatches=8,
                                  kernel_mode="reference")
        # correctly-sized queues (the lowering's own simulation) pass
        assert check_stream(sx.report).queues_ok

        specs = dict(sx._queue_specs)
        edge = max(specs, key=lambda e: specs[e].delay)
        assert specs[edge].delay >= 2
        specs[edge] = dc.replace(specs[edge], capacity=1)
        sim = simulate_schedule(
            sx.schedule, build_queues(specs),
            producer_stage={e: sx._stage_of[e[0]] for e in specs},
            consumer_stage={e: sx._stage_of[e[1]] for e in specs})
        mc = check_stream(sx.report, queue_stats={
            f"{u}->{w}": st for (u, w), st in sim["queues"].items()})
        assert not mc.queues_ok and not mc.ok
        bad = [q for q in mc.queues if not q.ok]
        assert bad and any(q.push_stalls > 0 for q in bad)
        assert f"{edge[0]}->{edge[1]}" in {q.edge for q in bad}


# =============================================================================
# Throughput: the Eq. 5 -> Eq. 6 move (ISSUE 2 acceptance)
# =============================================================================

class TestThroughput:
    def test_pipelined_beats_sequential_and_tracks_eq6(self):
        """>=3 stages, >=8 microbatches: executed steady-state throughput
        exceeds the sequential executor's and sits closer (log-space) to
        the Eq. 6 slowest-stage bound than to the Eq. 5 sum."""
        import time

        g = build_unet_exec()
        plan = _dse_plan(g)
        assert plan.n_stages >= 3
        B = 16
        low = lower_plan(g, plan, kernel_mode="reference")
        sx = lower_plan_pipelined(g, plan, microbatches=B,
                                  kernel_mode="reference")
        xs = jax.random.normal(jax.random.PRNGKey(0), (B, 64, 32),
                               jnp.float32)
        sx(xs).block_until_ready()                 # compile
        _sequential_outputs(low, xs)

        def frame_time(fn):
            best = math.inf
            for _ in range(5):
                t0 = time.perf_counter()
                fn()
                best = min(best, (time.perf_counter() - t0) / B)
            return best

        t_pipe = frame_time(lambda: sx(xs).block_until_ready())
        t_seq = frame_time(
            lambda: jax.block_until_ready([low(xs[b]) for b in range(B)]))
        lat = measured_stage_latencies(sx, xs[0])
        e5 = eq5_sequential_time(lat)
        e6 = eq6_pipeline_time(lat)
        assert e6 < e5                              # stages are not uniform
        assert t_pipe < t_seq, (t_pipe, t_seq)
        d6 = abs(math.log(t_pipe / e6))
        d5 = abs(math.log(t_pipe / e5))
        assert d6 < d5, (t_pipe, e6, e5)


# =============================================================================
# Plan determinism satellites
# =============================================================================

class TestPlanOrdering:
    def test_stage_layers_topological_not_insertion_order(self):
        g = build_unet_exec()
        topo = g.topo()
        # adversarial insertion order: reversed
        layers = {n: LayerPlan(name=n, stage=0) for n in reversed(topo)}
        plan = ExecutionPlan(model=g.name, device="t", n_stages=1,
                             layers=layers, streams=[], topo_order=topo)
        assert plan.stage_layers(0) == topo

    def test_plan_from_dse_layers_in_topo_order(self):
        g = build_unet_exec()
        plan = _dse_plan(g)
        assert plan.topo_order == g.topo()
        seen = []
        for j in range(plan.n_stages):
            seen += plan.stage_layers(j)
        assert seen == [n for n in g.topo()]       # stages tile the topo

    def test_from_json_ignores_unknown_keys(self):
        g = build_unet_exec(positions=32, levels=2)
        plan = _staged_plan(g, n_stages=2)
        import json
        d = json.loads(plan.to_json())
        d["a_future_field"] = {"x": 1}
        d["layers"][plan.topo_order[0]]["future_layer_knob"] = 3
        d["streams"][0]["future_stream_knob"] = True
        back = ExecutionPlan.from_json(json.dumps(d))
        assert back.n_stages == plan.n_stages
        assert back.stage_layers(0) == plan.stage_layers(0)
        assert back.streams[0].src == plan.streams[0].src

    def test_json_roundtrip_preserves_topo_order(self):
        g = build_unet_exec(positions=32, levels=2)
        plan = _staged_plan(g, n_stages=2)
        back = ExecutionPlan.from_json(plan.to_json())
        assert back.topo_order == plan.topo_order
        assert back.stage_layers(1) == plan.stage_layers(1)


# =============================================================================
# Multi-device stage placement (shard_map ring)
# =============================================================================

class TestShardMapPlacement:
    def test_ring_pipeline_matches_sequential(self):
        """One stage per (host-platform) device; ppermute-ring transit."""
        code = textwrap.dedent("""
            import numpy as np, jax, jax.numpy as jnp
            from repro.core import build_unet_exec
            from repro.core.plan import ExecutionPlan, LayerPlan, StreamPlan
            from repro.runtime.executor import lower_plan
            from repro.runtime.streamer import lower_plan_pipelined
            g = build_unet_exec()
            g.compute_buffer_depths()
            topo = g.topo(); S = 3
            stage = {n: min(i * S // len(topo), S - 1)
                     for i, n in enumerate(topo)}
            layers = {v.name: LayerPlan(name=v.name, stage=stage[v.name])
                      for v in g.vertices()}
            streams = [StreamPlan(e.src, e.dst,
                                  evicted=e.buffer_depth > 4096.0,
                                  codec="bfp8" if e.buffer_depth > 4096.0
                                  else "none")
                       for e in g.edges()]
            plan = ExecutionPlan(model=g.name, device="t", n_stages=S,
                                 layers=layers, streams=streams,
                                 topo_order=topo)
            B = 6
            xs = jax.random.normal(jax.random.PRNGKey(1), (B, 64, 32),
                                   jnp.float32)
            sx = lower_plan_pipelined(g, plan, microbatches=B,
                                      kernel_mode="reference")
            assert sx.placement == "shard_map", sx.placement
            low = lower_plan(g, plan, kernel_mode="reference")
            want = np.stack([np.asarray(low(xs[b])) for b in range(B)])
            np.testing.assert_allclose(np.asarray(sx(xs)), want,
                                       rtol=1e-5, atol=1e-6)
            print("OK")
        """)
        env = dict(os.environ,
                   XLA_FLAGS="--xla_force_host_platform_device_count=4",
                   PYTHONPATH=os.path.join(REPO, "src"))
        out = subprocess.run([sys.executable, "-c", code],
                             capture_output=True, text=True, env=env,
                             timeout=600)
        assert out.returncode == 0, out.stderr[-3000:]
        assert "OK" in out.stdout

    def test_shard_map_refused_without_devices(self):
        if len(jax.devices()) >= 2:
            pytest.skip("host has multiple devices")
        g = build_unet_exec()
        plan = _staged_plan(g)
        with pytest.raises(ValueError, match="devices"):
            lower_plan_pipelined(g, plan, microbatches=4,
                                 kernel_mode="reference",
                                 placement="shard_map")
