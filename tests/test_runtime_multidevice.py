"""Multi-device runtime tests: sharding rules, step lowering, gradient
compression — run in subprocesses with XLA host-device placeholders, since
device count locks at first jax init."""
import os
import subprocess
import sys
import textwrap


REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(n_devices: int, code: str) -> str:
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={n_devices}",
               PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


class TestShardingRules:
    def test_param_specs_divisibility_guarded(self):
        out = run_py(8, """
            import jax, jax.numpy as jnp
            from repro.configs import ARCHS
            from repro.runtime import sharding as SH
            from repro.runtime.steps import abstract_params
            mesh = jax.make_mesh((2, 4), ("data", "model"))
            for name in ("yi-6b", "whisper-large-v3", "olmoe-1b-7b"):
                cfg = ARCHS[name]
                sh = SH.param_shardings(cfg, abstract_params(cfg), mesh)
                for path, s in jax.tree_util.tree_leaves_with_path(sh):
                    pass   # construction alone validates divisibility guard
            print("OK")
        """)
        assert "OK" in out

    def test_small_mesh_train_step_runs(self):
        """An actual sharded train step executes on an 8-device host mesh."""
        out = run_py(8, """
            import jax, jax.numpy as jnp
            from repro.configs import ARCHS
            from repro.models import init_params
            from repro.optim.adamw import AdamWConfig, init_opt_state
            from repro.runtime.steps import make_train_step
            cfg = ARCHS["yi-6b"].reduced()
            mesh = jax.make_mesh((2, 4), ("data", "model"))
            opt_cfg = AdamWConfig(lr=1e-3)
            with mesh:
                step, (p_sh, o_sh), _ = make_train_step(
                    cfg, mesh, opt_cfg, remat="full", dtype=jnp.float32)
                params = jax.device_put(
                    init_params(jax.random.PRNGKey(0), cfg, jnp.float32), p_sh)
                opt = jax.device_put(init_opt_state(params, opt_cfg), o_sh)
                batch = {"tokens": jnp.zeros((4, 64), jnp.int32),
                         "labels": jnp.zeros((4, 64), jnp.int32)}
                fn = jax.jit(step)
                p2, o2, m = fn(params, opt, batch)
                l1 = float(m["loss"])
                p3, o3, m2 = fn(p2, o2, batch)
            assert float(m2["loss"]) < l1   # loss drops on repeated batch
            print("LOSS", l1, float(m2["loss"]))
        """)
        assert "LOSS" in out

    def test_decode_step_runs_sharded(self):
        out = run_py(8, """
            import jax, jax.numpy as jnp
            from repro.configs import ARCHS
            from repro.models import init_params, init_cache
            from repro.runtime.steps import make_decode_step
            cfg = ARCHS["yi-6b"].reduced()
            mesh = jax.make_mesh((2, 4), ("data", "model"))
            with mesh:
                step, (p_sh, c_sh), _ = make_decode_step(
                    cfg, mesh, batch=4, s_max=32, dtype=jnp.float32)
                params = jax.device_put(
                    init_params(jax.random.PRNGKey(0), cfg, jnp.float32), p_sh)
                cache = jax.device_put(
                    init_cache(cfg, 4, 32, jnp.float32), c_sh)
                logits, cache = jax.jit(step)(
                    params, cache, jnp.zeros((4, 1), jnp.int32),
                    jnp.zeros((4,), jnp.int32))
            assert logits.shape == (4, cfg.vocab)
            print("OK", bool(jnp.isfinite(logits).all()))
        """)
        assert "OK True" in out


class TestPodCompression:
    def test_compressed_grads_match_uncompressed_direction(self):
        """shard_map over a 2-pod mesh: int8-EF cross-pod grads track the
        exact mean within quantisation error."""
        out = run_py(8, """
            import jax, jax.numpy as jnp
            from jax.sharding import PartitionSpec as P
            from repro.optim.compress import (make_pod_compressed_grad_fn,
                                              init_error_state)
            mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
            def loss_fn(w, batch):
                return jnp.mean((batch @ w["w"]) ** 2)
            params = {"w": jnp.ones((16, 16)) * 0.1}
            batch = jax.random.normal(jax.random.PRNGKey(0), (8, 16))
            err = init_error_state(params)
            with mesh:
                fn = make_pod_compressed_grad_fn(loss_fn, mesh)
                grads, loss, new_err = jax.jit(fn)(params, batch, err)
            exact = jax.grad(lambda w: loss_fn(w, batch))(params)
            rel = (jnp.abs(grads["w"] - exact["w"]).max()
                   / jnp.abs(exact["w"]).max())
            assert float(rel) < 0.02, float(rel)
            print("REL", float(rel))
        """)
        assert "REL" in out
