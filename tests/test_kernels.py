"""Pallas kernel tests: interpret-mode allclose against the jnp oracles,
with shape/dtype sweeps and hypothesis property checks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.bfp8 import bfp8_dequant, bfp8_quant
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ops import evict_decode, evict_encode, fragmented_matmul
from repro.kernels.streamed_matmul import streamed_matmul, vmem_bytes


class TestStreamedMatmul:
    @pytest.mark.parametrize("M,K,N", [(128, 256, 128), (256, 512, 256),
                                       (128, 384, 512)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref_shapes(self, M, K, N, dtype):
        key = jax.random.PRNGKey(0)
        x = jax.random.normal(key, (M, K), dtype)
        ks = 128
        ws = jax.random.normal(key, (ks, N), dtype)
        wd = jax.random.normal(key, (K - ks, N), dtype)
        got = streamed_matmul(x, ws, wd, interpret=True)
        want = ref.streamed_matmul_ref(x, ws, wd)
        tol = 2e-2 if dtype == jnp.bfloat16 else 2e-4
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=tol, atol=tol)

    @pytest.mark.parametrize("static_fraction", [0.0, 0.25, 0.5, 1.0])
    def test_fragmented_matmul_fraction_invisible(self, static_fraction):
        """The m knob changes memory placement, never the math (Eq. 3)."""
        key = jax.random.PRNGKey(1)
        x = jax.random.normal(key, (128, 512), jnp.float32)
        w = jax.random.normal(key, (512, 256), jnp.float32)
        got = fragmented_matmul(x, w, static_fraction=static_fraction,
                                interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(x @ w),
                                   rtol=1e-4, atol=1e-4)

    def test_block_size_sweep(self):
        key = jax.random.PRNGKey(2)
        x = jax.random.normal(key, (256, 384), jnp.float32)
        ws = jax.random.normal(key, (128, 256), jnp.float32)
        wd = jax.random.normal(key, (256, 256), jnp.float32)
        want = ref.streamed_matmul_ref(x, ws, wd)
        for bm in (128, 256):
            for bn in (128, 256):
                got = streamed_matmul(x, ws, wd, bm=bm, bn=bn, bk=128,
                                      interpret=True)
                np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                           rtol=1e-4, atol=1e-4)

    def test_vmem_accounting_monotonic(self):
        """Bigger static region -> bigger VMEM claim (the Eq. 7 check)."""
        a = vmem_bytes(128, 4096, 128, 128, 128)
        b = vmem_bytes(1024, 4096, 128, 128, 128)
        assert b > a


class TestFlashAttention:
    @pytest.mark.parametrize("S,H,D", [(256, 2, 64), (512, 4, 128)])
    @pytest.mark.parametrize("causal", [True, False])
    def test_matches_ref(self, S, H, D, causal):
        key = jax.random.PRNGKey(3)
        q, k, v = (jax.random.normal(key, (2, S, H, D), jnp.float32)
                   for key in jax.random.split(key, 3))
        got = flash_attention(q, k, v, causal=causal, bq=128, bk=128,
                              interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)

    def test_bfloat16(self):
        key = jax.random.PRNGKey(4)
        q, k, v = (jax.random.normal(k2, (1, 256, 2, 64), jnp.bfloat16)
                   for k2 in jax.random.split(key, 3))
        got = flash_attention(q, k, v, causal=True, bq=128, bk=128,
                              interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=3e-2, atol=3e-2)

    def test_block_sweep_same_answer(self):
        key = jax.random.PRNGKey(5)
        q, k, v = (jax.random.normal(k2, (1, 512, 2, 64), jnp.float32)
                   for k2 in jax.random.split(key, 3))
        outs = [np.asarray(flash_attention(q, k, v, causal=True, bq=bq,
                                           bk=bk, interpret=True))
                for bq in (128, 256) for bk in (128, 256)]
        for o in outs[1:]:
            np.testing.assert_allclose(o, outs[0], rtol=1e-5, atol=1e-5)

    def test_matches_model_oracle(self):
        """Also agrees with the model's chunked_attention (the serving path)."""
        from repro.models.attention import chunked_attention
        key = jax.random.PRNGKey(6)
        q, k, v = (jax.random.normal(k2, (2, 256, 4, 64), jnp.float32)
                   for k2 in jax.random.split(key, 3))
        a = np.asarray(flash_attention(q, k, v, causal=True, bq=128, bk=128,
                                       interpret=True))
        b = np.asarray(chunked_attention(q, k, v, causal=True, chunk=128))
        np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)


class TestBFP8Kernel:
    @pytest.mark.parametrize("R,C,block", [(256, 128, 32), (512, 256, 64),
                                           (64, 512, 128)])
    def test_roundtrip_matches_ref(self, R, C, block):
        key = jax.random.PRNGKey(7)
        x = jax.random.normal(key, (R, C), jnp.float32) * 100
        man, exp = bfp8_quant(x, block=block, interpret=True)
        man_r, exp_r = ref.bfp8_quant_ref(x, block=block)
        np.testing.assert_array_equal(np.asarray(man), np.asarray(man_r))
        np.testing.assert_array_equal(np.asarray(exp), np.asarray(exp_r))
        out = bfp8_dequant(man, exp, block=block, interpret=True)
        want = ref.bfp8_dequant_ref(man_r, exp_r, block=block)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=1e-6)

    @given(st.integers(0, 2 ** 31 - 1))
    @settings(max_examples=20, deadline=None)
    def test_error_bound_property(self, seed):
        """|x - dequant(quant(x))| <= 2^(exp-7) per block, any input."""
        x = jax.random.normal(jax.random.PRNGKey(seed), (64, 128),
                              jnp.float32) * 10 ** (seed % 5)
        man, exp = evict_encode(x, interpret=True)
        out = evict_decode(man, exp, interpret=True)
        scale = np.exp2(np.asarray(exp, np.float32) - 6.0)
        err = np.abs(np.asarray(x) - np.asarray(out)).reshape(64, 4, 32)
        assert (err <= scale[..., None] * 0.5 + 1e-30).all()

    def test_compression_ratio(self):
        """8-bit mantissa + 1/4 exponent byte per 32-block vs bf16 words."""
        x = jax.random.normal(jax.random.PRNGKey(8), (128, 128), jnp.float32)
        man, exp = evict_encode(x, interpret=True)
        raw_bits = x.size * 16                  # stream words are bf16
        enc_bits = man.size * 8 + exp.size * 8
        assert enc_bits / raw_bits == pytest.approx((8 + 8 / 32) / 16)


# =============================================================================
# Streaming-conv kernel conformance matrix (ISSUE 10)
#
# Locks the contract ``runtime.executor.lower_plan`` relies on: for every
# lowerable op kind, the Pallas body (interpret mode on CPU) is *bit-exact*
# against the reference body on lossless edges, and the fused BFP8 boundary
# codec (ingress dequant / egress quant inside the same ``pallas_call``)
# produces bitwise the payload the unfused ``bfp8_spill_encode`` path
# would — on odd, non-128-aligned shapes.
#
# dwconv caveat: XLA:CPU contracts the tap sum into FMAs when jitted, so
# its reference composition must be *jitted* for bit-exactness (the
# executors always jit; eager comparison would see ~1 ULP drift).
# =============================================================================

from repro.core.builders import _XB, EXEC_MODELS, exec_input_shape
from repro.core.graph import Graph
from repro.core.plan import ExecutionPlan, LayerPlan, StreamPlan
from repro.kernels import streaming_conv as SC
from repro.kernels.ops import (KERNEL_REGISTRY, fusable_kinds, kernel_for,
                               lowerable_kinds, resolve_interpret)
from repro.runtime.executor import (FUSABLE_KINDS, _lower_vertex,
                                    analyze_plan, lower_plan)

BLOCK = 32
# odd / non-128-aligned (m, c): m is never a bm multiple, c is never a
# codec-block multiple — every padding path in the kernels is live
ODD_SHAPES = [(28, 24), (45, 40)]
VARIANTS = ("plain", "ingress", "egress", "both")


def _pad_c(a, block=BLOCK):
    c = a.shape[1]
    cp = ((c + block - 1) // block) * block
    return jnp.pad(a, ((0, 0), (0, cp - c)))


def _encode_ref(y):
    """The unfused spill payload: ``bfp8_quant_ref`` of the block-padded
    stripe — what ``bfp8_spill_encode`` produces in reference mode."""
    return ref.bfp8_quant_ref(_pad_c(y), block=BLOCK)


def _decode_ref(payload, c):
    man, exp = payload
    return ref.bfp8_dequant_ref(man, exp, block=BLOCK)[:, :c]


def _kind_io(kind, m, c, key):
    """(x, w, kernel_kwargs, reference_body) for one fusable kind."""
    kx, kw_ = jax.random.split(jax.random.PRNGKey(key))
    x = jax.random.normal(kx, (m, c), jnp.float32)
    extra = {"c": c}
    if kind == "conv":
        cout = c + 16                       # still not a block multiple
        w = jax.random.normal(kw_, (c, cout), jnp.float32) / np.sqrt(c)
        return x, w, extra, lambda xe: ref.conv2d_ref(xe, w)
    if kind == "dwconv":
        w = jax.random.normal(kw_, (3, c), jnp.float32)
        return x, w, extra, lambda xe: ref.dwconv_ref(xe, w)
    if kind == "pool":
        assert m % 2 == 0 or m % 3 == 0
        k = 2 if m % 2 == 0 else 3
        extra["m_out"] = m // k
        return x, None, extra, lambda xe: ref.pool_ref(xe, m // k)
    assert kind == "act"
    return x, None, extra, ref.act_relu_ref


def _call_kernel(kind, x, w, extra, *, payload=None, encode=False, bm=0,
                 bc=0):
    kw = dict(payload=payload, encode=encode, block=BLOCK, bm=bm,
              interpret=True)
    if kind == "conv":
        return SC.conv2d(x, w, bc=bc, **kw)
    if kind == "dwconv":
        return SC.dwconv(x, w, **kw)
    if kind == "pool":
        return SC.pool(x, extra["m_out"], c=extra["c"], **kw)
    return SC.act_relu(x, c=extra["c"], **kw)


class TestKernelConformanceMatrix:
    """Every fusable kind x fusion variant x odd shape: pallas-interpret
    against the (jitted) reference composition, bit-exact."""

    @pytest.mark.parametrize("m,c", ODD_SHAPES)
    @pytest.mark.parametrize("variant", VARIANTS)
    @pytest.mark.parametrize("kind", ("conv", "dwconv", "pool", "act"))
    def test_pallas_matches_reference(self, kind, variant, m, c):
        x, w, extra, body = _kind_io(kind, m, c, key=7)
        ingress = variant in ("ingress", "both")
        egress = variant in ("egress", "both")

        payload = _encode_ref(x) if ingress else None
        # reference composition: (decode ->) body (-> encode), jitted as
        # one function exactly like the executors trace it
        def composed(x, payload):
            xe = _decode_ref(payload, c) if ingress else x
            y = body(xe)
            return (y, _encode_ref(y)) if egress else y
        want = jax.jit(composed)(None if ingress else x, payload)

        got = _call_kernel(kind, None if ingress else x, w, extra,
                           payload=payload, encode=egress)
        if egress:
            (gy, (gman, gexp)), (wy, (wman, wexp)) = got, want
            np.testing.assert_array_equal(np.asarray(gy), np.asarray(wy))
            np.testing.assert_array_equal(np.asarray(gman),
                                          np.asarray(wman))
            np.testing.assert_array_equal(np.asarray(gexp),
                                          np.asarray(wexp))
        else:
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("kind", ("conv", "dwconv", "pool", "act"))
    def test_fused_codec_respects_bfp8_bound(self, kind):
        """The fused egress payload decodes back within the shared-exponent
        bound (|err| <= half the per-block scale) of the true output."""
        m, c = 28, 24
        x, w, extra, body = _kind_io(kind, m, c, key=11)
        y, payload = _call_kernel(kind, x, w, extra, encode=True)
        back = np.asarray(_decode_ref(payload, np.asarray(y).shape[1]))
        yv = np.asarray(y)
        exp = np.asarray(payload[1], np.float32)
        scale = np.exp2(exp - 6.0)                        # 2^(exp-7) * 2
        err = np.abs(_pad_c(jnp.asarray(yv)) - _pad_c(jnp.asarray(back)))
        err = np.asarray(err).reshape(yv.shape[0], -1, BLOCK)
        assert (err <= scale[..., None] * 0.5 + 1e-30).all()

    @pytest.mark.parametrize("bm,bc", [(5, 7), (28, 24), (128, 128),
                                       (13, 40)])
    def test_tile_sizes_never_change_results(self, bm, bc):
        """bm/bc are pure performance knobs: any tile size, same bits —
        including sizes that do not divide the axes."""
        m, c = 45, 40
        for kind in ("conv", "dwconv", "pool", "act"):
            x, w, extra, body = _kind_io(kind, m, c, key=3)
            base = _call_kernel(kind, x, w, extra, bm=0, bc=0)
            tiled = _call_kernel(kind, x, w, extra, bm=bm, bc=bc)
            np.testing.assert_array_equal(np.asarray(base),
                                          np.asarray(tiled))

    def test_fused_equals_unfused_same_quant_blocks(self):
        """decode->conv->encode fused into one pallas_call is bitwise the
        three-dispatch pipeline (same quant blocks on both sides)."""
        m, c = 28, 24
        x, w, extra, body = _kind_io("conv", m, c, key=19)
        payload = _encode_ref(x)
        y_f, pay_f = _call_kernel("conv", None, w, extra, payload=payload,
                                  encode=True)
        xe = _decode_ref(payload, c)
        y_u = _call_kernel("conv", xe, w, extra)
        pay_u = _encode_ref(y_u)
        np.testing.assert_array_equal(np.asarray(y_f), np.asarray(y_u))
        np.testing.assert_array_equal(np.asarray(pay_f[0]),
                                      np.asarray(pay_u[0]))
        np.testing.assert_array_equal(np.asarray(pay_f[1]),
                                      np.asarray(pay_u[1]))


class TestKernelRegistry:
    def test_every_lowerable_kind_registered(self):
        assert set(lowerable_kinds()) >= {
            "input", "conv", "matmul", "deconv", "dwconv", "pool", "act",
            "upsample", "add", "mul", "concat", "output"}

    def test_fusable_kinds_match_executor(self):
        assert set(fusable_kinds()) == set(FUSABLE_KINDS)

    def test_dispatch_rows(self):
        body, is_pallas = kernel_for("conv", use_pallas=True)
        assert body is SC.conv2d and is_pallas
        body, is_pallas = kernel_for("conv", use_pallas=False)
        assert body is ref.conv2d_ref and not is_pallas
        # kinds with no Pallas body fall back to reference in pallas mode
        body, is_pallas = kernel_for("concat", use_pallas=True)
        assert body is KERNEL_REGISTRY["concat"].reference and not is_pallas

    def test_resolve_interpret_explicit_wins(self):
        assert resolve_interpret(True) is True
        assert resolve_interpret(False) is False
        # None falls back to interpret-on-CPU (tests run on CPU)
        assert resolve_interpret(None) is True


# -----------------------------------------------------------------------------
# Graph-level conformance: lower_plan over every lowerable kind
# -----------------------------------------------------------------------------

def _all_kinds_graph():
    """A 12-vertex graph exercising every lowerable op kind once, on odd
    non-aligned shapes (m=28, c=24/40)."""
    g = Graph("allkinds")
    b = _XB(g)
    inp = b.xsimple(None, "input", 24, 28)
    c1 = b.xconv(inp, 24, 40, 28)
    a1 = b.xsimple(c1, "act", 40, 28)
    dw = b.xdwconv(a1, 40, 28)
    po = b.xsimple(dw, "pool", 40, 28, m_out=14)
    up = b.xsimple(po, "upsample", 40, 14, m_out=28)
    ad = b.xsimple([a1, up], "add", 40, 28)
    ml = b.xsimple([ad, dw], "mul", 40, 28)
    mm = b.xconv(ml, 40, 24, 28, kind="matmul")
    dc = b.xconv(mm, 24, 24, 28, kind="deconv")
    cc = b.xsimple([dc, inp], "concat", 48, 28)
    b.xsimple(cc, "output", 48, 28)
    return g


def _chain_graph():
    """Linear chain whose every internal edge has a single-input consumer —
    the topology where *ingress* fusion is legal on every hop."""
    g = Graph("chain")
    b = _XB(g)
    inp = b.xsimple(None, "input", 24, 28)
    c1 = b.xconv(inp, 24, 40, 28)
    a1 = b.xsimple(c1, "act", 40, 28)
    dw = b.xdwconv(a1, 40, 28)
    po = b.xsimple(dw, "pool", 40, 28, m_out=14)
    c2 = b.xconv(po, 40, 24, 14)
    b.xsimple(c2, "output", 24, 14)
    return g


def _evict_all_plan(g, codec):
    g.compute_buffer_depths()
    return ExecutionPlan(
        model=g.name, device="tiny", n_stages=1,
        layers={v.name: LayerPlan(name=v.name) for v in g.vertices()},
        streams=[StreamPlan(e.src, e.dst, evicted=True, codec=codec)
                 for e in g.edges()],
        topo_order=g.topo())


class TestGraphKernelConformance:
    """lower_plan end-to-end: reference vs pallas over {lossless,
    BFP8-evicted} plans covering every lowerable kind."""

    @pytest.mark.parametrize("codec", ["none", "bfp8"])
    def test_all_kinds_bit_exact_across_modes(self, codec):
        g = _all_kinds_graph()
        plan = _evict_all_plan(g, codec)
        x = jax.random.normal(jax.random.PRNGKey(0), (28, 24), jnp.float32)
        yr = np.asarray(lower_plan(g, plan, kernel_mode="reference",
                                   interpret=True)(x))
        yp = np.asarray(lower_plan(g, plan, kernel_mode="pallas",
                                   interpret=True)(x))
        np.testing.assert_array_equal(yr, yp)

    def test_bfp8_stays_near_lossless(self):
        """The compounding BFP8 error across every evicted edge stays small
        — and is non-zero, i.e. the codec really engaged."""
        g = _all_kinds_graph()
        x = jax.random.normal(jax.random.PRNGKey(0), (28, 24), jnp.float32)
        y0 = np.asarray(lower_plan(g, _evict_all_plan(g, "none"),
                                   kernel_mode="pallas", interpret=True)(x))
        yq = np.asarray(lower_plan(g, _evict_all_plan(g, "bfp8"),
                                   kernel_mode="pallas", interpret=True)(x))
        rel = np.linalg.norm(yq - y0) / np.linalg.norm(y0)
        assert 0.0 < rel < 0.2

    def test_chain_exercises_ingress_and_egress_fusion(self):
        """On the all-evicted chain, _lower_vertex fuses both directions
        for every fusable hop — and the fused run stays bit-exact against
        reference mode."""
        g = _chain_graph()
        plan = _evict_all_plan(g, "bfp8")
        an = analyze_plan(g, plan, use_pallas=True, interpret=True)
        fuse_in = [n for n in an.topo if _lower_vertex(g, n, an).fuse_in]
        fuse_out = [n for n in an.topo if _lower_vertex(g, n, an).fuse_out]
        assert len(fuse_in) >= 4 and len(fuse_out) >= 4
        x = jax.random.normal(jax.random.PRNGKey(1), (28, 24), jnp.float32)
        yr = np.asarray(lower_plan(g, plan, kernel_mode="reference",
                                   interpret=True)(x))
        yp = np.asarray(lower_plan(g, plan, kernel_mode="pallas",
                                   interpret=True)(x))
        np.testing.assert_array_equal(yr, yp)

    def test_plan_tile_sizes_thread_through(self):
        """ExecutionPlan.tile_bm/tile_bc reach the kernels and never change
        the bits (the autotune 'tile' move's safety contract)."""
        import dataclasses as dc
        g = _chain_graph()
        plan = _evict_all_plan(g, "bfp8")
        x = jax.random.normal(jax.random.PRNGKey(2), (28, 24), jnp.float32)
        y0 = np.asarray(lower_plan(g, plan, kernel_mode="pallas",
                                   interpret=True)(x))
        yt = np.asarray(lower_plan(g, dc.replace(plan, tile_bm=5,
                                                 tile_bc=7),
                                   kernel_mode="pallas", interpret=True)(x))
        np.testing.assert_array_equal(y0, yt)

    @pytest.mark.parametrize("model", sorted(EXEC_MODELS))
    def test_exec_models_parity(self, model):
        """The acceptance check: every executable model, BFP8-evicted deep
        edges, pallas == reference bit-exactly."""
        g = EXEC_MODELS[model]()
        g.compute_buffer_depths()
        plan = ExecutionPlan(
            model=g.name, device="tiny", n_stages=1,
            layers={v.name: LayerPlan(name=v.name) for v in g.vertices()},
            streams=[StreamPlan(e.src, e.dst,
                                evicted=e.buffer_depth > 2048.0,
                                codec="bfp8" if e.buffer_depth > 2048.0
                                else "none")
                     for e in g.edges()],
            topo_order=g.topo())
        assert any(s.evicted for s in plan.streams), model
        x = jax.random.normal(jax.random.PRNGKey(0), exec_input_shape(g),
                              jnp.float32)
        yr = np.asarray(lower_plan(g, plan, kernel_mode="reference",
                                   interpret=True)(x))
        yp = np.asarray(lower_plan(g, plan, kernel_mode="pallas",
                                   interpret=True)(x))
        np.testing.assert_array_equal(yr, yp)
