"""Pallas kernel tests: interpret-mode allclose against the jnp oracles,
with shape/dtype sweeps and hypothesis property checks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.bfp8 import bfp8_dequant, bfp8_quant
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ops import evict_decode, evict_encode, fragmented_matmul
from repro.kernels.streamed_matmul import streamed_matmul, vmem_bytes


class TestStreamedMatmul:
    @pytest.mark.parametrize("M,K,N", [(128, 256, 128), (256, 512, 256),
                                       (128, 384, 512)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref_shapes(self, M, K, N, dtype):
        key = jax.random.PRNGKey(0)
        x = jax.random.normal(key, (M, K), dtype)
        ks = 128
        ws = jax.random.normal(key, (ks, N), dtype)
        wd = jax.random.normal(key, (K - ks, N), dtype)
        got = streamed_matmul(x, ws, wd, interpret=True)
        want = ref.streamed_matmul_ref(x, ws, wd)
        tol = 2e-2 if dtype == jnp.bfloat16 else 2e-4
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=tol, atol=tol)

    @pytest.mark.parametrize("static_fraction", [0.0, 0.25, 0.5, 1.0])
    def test_fragmented_matmul_fraction_invisible(self, static_fraction):
        """The m knob changes memory placement, never the math (Eq. 3)."""
        key = jax.random.PRNGKey(1)
        x = jax.random.normal(key, (128, 512), jnp.float32)
        w = jax.random.normal(key, (512, 256), jnp.float32)
        got = fragmented_matmul(x, w, static_fraction=static_fraction,
                                interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(x @ w),
                                   rtol=1e-4, atol=1e-4)

    def test_block_size_sweep(self):
        key = jax.random.PRNGKey(2)
        x = jax.random.normal(key, (256, 384), jnp.float32)
        ws = jax.random.normal(key, (128, 256), jnp.float32)
        wd = jax.random.normal(key, (256, 256), jnp.float32)
        want = ref.streamed_matmul_ref(x, ws, wd)
        for bm in (128, 256):
            for bn in (128, 256):
                got = streamed_matmul(x, ws, wd, bm=bm, bn=bn, bk=128,
                                      interpret=True)
                np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                           rtol=1e-4, atol=1e-4)

    def test_vmem_accounting_monotonic(self):
        """Bigger static region -> bigger VMEM claim (the Eq. 7 check)."""
        a = vmem_bytes(128, 4096, 128, 128, 128)
        b = vmem_bytes(1024, 4096, 128, 128, 128)
        assert b > a


class TestFlashAttention:
    @pytest.mark.parametrize("S,H,D", [(256, 2, 64), (512, 4, 128)])
    @pytest.mark.parametrize("causal", [True, False])
    def test_matches_ref(self, S, H, D, causal):
        key = jax.random.PRNGKey(3)
        q, k, v = (jax.random.normal(key, (2, S, H, D), jnp.float32)
                   for key in jax.random.split(key, 3))
        got = flash_attention(q, k, v, causal=causal, bq=128, bk=128,
                              interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)

    def test_bfloat16(self):
        key = jax.random.PRNGKey(4)
        q, k, v = (jax.random.normal(k2, (1, 256, 2, 64), jnp.bfloat16)
                   for k2 in jax.random.split(key, 3))
        got = flash_attention(q, k, v, causal=True, bq=128, bk=128,
                              interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=3e-2, atol=3e-2)

    def test_block_sweep_same_answer(self):
        key = jax.random.PRNGKey(5)
        q, k, v = (jax.random.normal(k2, (1, 512, 2, 64), jnp.float32)
                   for k2 in jax.random.split(key, 3))
        outs = [np.asarray(flash_attention(q, k, v, causal=True, bq=bq,
                                           bk=bk, interpret=True))
                for bq in (128, 256) for bk in (128, 256)]
        for o in outs[1:]:
            np.testing.assert_allclose(o, outs[0], rtol=1e-5, atol=1e-5)

    def test_matches_model_oracle(self):
        """Also agrees with the model's chunked_attention (the serving path)."""
        from repro.models.attention import chunked_attention
        key = jax.random.PRNGKey(6)
        q, k, v = (jax.random.normal(k2, (2, 256, 4, 64), jnp.float32)
                   for k2 in jax.random.split(key, 3))
        a = np.asarray(flash_attention(q, k, v, causal=True, bq=128, bk=128,
                                       interpret=True))
        b = np.asarray(chunked_attention(q, k, v, causal=True, chunk=128))
        np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)


class TestBFP8Kernel:
    @pytest.mark.parametrize("R,C,block", [(256, 128, 32), (512, 256, 64),
                                           (64, 512, 128)])
    def test_roundtrip_matches_ref(self, R, C, block):
        key = jax.random.PRNGKey(7)
        x = jax.random.normal(key, (R, C), jnp.float32) * 100
        man, exp = bfp8_quant(x, block=block, interpret=True)
        man_r, exp_r = ref.bfp8_quant_ref(x, block=block)
        np.testing.assert_array_equal(np.asarray(man), np.asarray(man_r))
        np.testing.assert_array_equal(np.asarray(exp), np.asarray(exp_r))
        out = bfp8_dequant(man, exp, block=block, interpret=True)
        want = ref.bfp8_dequant_ref(man_r, exp_r, block=block)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=1e-6)

    @given(st.integers(0, 2 ** 31 - 1))
    @settings(max_examples=20, deadline=None)
    def test_error_bound_property(self, seed):
        """|x - dequant(quant(x))| <= 2^(exp-7) per block, any input."""
        x = jax.random.normal(jax.random.PRNGKey(seed), (64, 128),
                              jnp.float32) * 10 ** (seed % 5)
        man, exp = evict_encode(x, interpret=True)
        out = evict_decode(man, exp, interpret=True)
        scale = np.exp2(np.asarray(exp, np.float32) - 6.0)
        err = np.abs(np.asarray(x) - np.asarray(out)).reshape(64, 4, 32)
        assert (err <= scale[..., None] * 0.5 + 1e-30).all()

    def test_compression_ratio(self):
        """8-bit mantissa + 1/4 exponent byte per 32-block vs bf16 words."""
        x = jax.random.normal(jax.random.PRNGKey(8), (128, 128), jnp.float32)
        man, exp = evict_encode(x, interpret=True)
        raw_bits = x.size * 16                  # stream words are bf16
        enc_bits = man.size * 8 + exp.size * 8
        assert enc_bits / raw_bits == pytest.approx((8 + 8 / 32) / 16)
