"""Trip-count-aware HLO analysis: validated against programs with known
FLOP counts (XLA's own cost_analysis counts while bodies once — these tests
pin the behaviour our roofline depends on)."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_analysis import (collective_stats, cost_stats,
                                       memory_stats, trip_aware_stats)


def _stats(fn, *args):
    c = jax.jit(fn).lower(*args).compile()
    return trip_aware_stats(c.as_text()), c


class TestTripAwareFlops:
    def test_plain_matmul_exact(self):
        M, K, N = 128, 256, 512
        s, _ = _stats(lambda a, b: a @ b, jnp.ones((M, K)), jnp.ones((K, N)))
        assert s["flops_dot"] == pytest.approx(2 * M * K * N)

    def test_scan_multiplies_trip_count(self):
        n, M = 8, 128

        def f(x, w):
            y, _ = jax.lax.scan(lambda c, _: (c @ w, None), x, None, length=n)
            return y.sum()

        s, _ = _stats(f, jnp.ones((M, M)), jnp.ones((M, M)))
        assert s["flops_dot"] == pytest.approx(2 * n * M ** 3)
        assert s["max_multiplier"] == n

    def test_nested_scans_compose(self):
        M = 128

        def g(x, w):
            def outer(c, _):
                c2, _ = jax.lax.scan(lambda cc, _: (cc @ w, None), c, None,
                                     length=8)
                return c2, None
            y, _ = jax.lax.scan(outer, x, None, length=4)
            return y.sum()

        s, _ = _stats(g, jnp.ones((M, M)), jnp.ones((M, M)))
        assert s["flops_dot"] == pytest.approx(2 * 32 * M ** 3)
        assert s["max_multiplier"] == 32

    def test_grad_of_scan(self):
        n, M = 8, 128

        def f(x, w):
            y, _ = jax.lax.scan(lambda c, _: (c @ w, None), x, None, length=n)
            return y.sum()

        s, _ = _stats(jax.grad(f, argnums=1), jnp.ones((M, M)),
                      jnp.ones((M, M)))
        # fwd n dots + bwd 2n dots
        assert s["flops_dot"] == pytest.approx(2 * 3 * n * M ** 3, rel=0.01)

    def test_xla_cost_analysis_undercounts_scans(self):
        """The reason this module exists."""
        M = 128

        def mk(n):
            def f(x, w):
                y, _ = jax.lax.scan(lambda c, _: (c @ w, None), x, None,
                                    length=n)
                return y.sum()
            return f

        c2 = jax.jit(mk(2)).lower(jnp.ones((M, M)), jnp.ones((M, M))).compile()
        c8 = jax.jit(mk(8)).lower(jnp.ones((M, M)), jnp.ones((M, M))).compile()
        assert (cost_stats(c2)["flops"]
                == cost_stats(c8)["flops"])              # XLA: same!
        s2 = trip_aware_stats(c2.as_text())
        s8 = trip_aware_stats(c8.as_text())
        assert s8["flops_dot"] == pytest.approx(4 * s2["flops_dot"])


class TestStatsHelpers:
    def test_memory_and_cost_stats_present(self):
        c = jax.jit(lambda a: (a @ a).sum()).lower(jnp.ones((64, 64))).compile()
        m = memory_stats(c)
        assert "temp_size_in_bytes" in m
        assert cost_stats(c)["flops"] > 0

    def test_collective_stats_empty_on_single_device(self):
        c = jax.jit(lambda a: (a @ a).sum()).lower(jnp.ones((64, 64))).compile()
        s = collective_stats(c.as_text())
        assert s.total_bytes == 0.0 and s.n_ops == 0

    def test_trip_aware_no_loops(self):
        c = jax.jit(lambda a: a * 2).lower(jnp.ones((8,))).compile()
        s = trip_aware_stats(c.as_text())
        assert s["flops_dot"] == 0.0
        assert s["max_multiplier"] == 1.0
