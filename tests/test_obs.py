"""Observability layer tests (ISSUE 6 tentpole).

The contract under test:

* :class:`TraceRecorder` primitives are deterministic under an injected
  clock, and export valid Chrome trace-event JSON
  (``validate_chrome_trace`` is the same gate the CI smoke uses);
* the golden trace of a 2-stage UNet pipelined run: span ordering is
  fill -> steady -> drain, stage spans nest inside (share) their tick's
  interval, timestamps are monotone, and the span census matches the
  1F1B diagram exactly;
* **no-op parity** — running traced (null or live recorder) is
  bit-exact against the fused ``lax.scan`` path and leaves the lowered
  report untouched (zero report drift);
* spill-byte conservation is *emitted*: per edge,
  ``bytes_evicted == bytes_restored`` in the recorder totals;
* the façade round-trips :class:`ObsConfig` through
  ``Compiled.save``/``load`` and surfaces the :class:`ModelCheck` in
  ``Compiled.report()``;
* the serving front-end's per-request :class:`LatencyHistogram` counts
  every delivered frame.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.api import CompileSpec, Compiled
from repro.core import DSEConfig, build_unet_exec
from repro.core.plan import ExecutionPlan, LayerPlan, StreamPlan
from repro.core.resources import Device
from repro.obs import (LatencyHistogram, NULL_RECORDER, NullRecorder,
                       ObsConfig, TraceRecorder, validate_chrome_trace)
from repro.runtime.streamer import lower_plan_pipelined

TINY = Device("tiny_obs", compute_units=4096, onchip_bits=300_000,
              offchip_gbps=64.0, freq_mhz=500.0, reconfig_s=0.0)
DSE_CFG = DSEConfig(batch=1, codecs=("none", "bfp8"), word_bits=16,
                    cut_kinds=("pool", "conv"))


def _stub_clock(step=1.0, start=0.0):
    """A deterministic counting clock: each call advances by ``step``."""
    state = [start]

    def clock():
        state[0] += step
        return state[0]

    return clock


def _two_stage_plan(g, evict_codec="bfp8", depth_thresh=4096.0):
    """Hand-built 2-stage plan over ``g`` (same recipe as test_streamer):
    the topological order cut in half, deep skip edges evicted."""
    g.compute_buffer_depths()
    topo = g.topo()
    stage = {n: min(i * 2 // len(topo), 1) for i, n in enumerate(topo)}
    layers = {v.name: LayerPlan(name=v.name, stage=stage[v.name])
              for v in g.vertices()}
    streams = []
    for e in g.edges():
        evict = evict_codec is not None and e.buffer_depth > depth_thresh
        streams.append(StreamPlan(e.src, e.dst, evicted=evict,
                                  codec=evict_codec if evict else "none"))
    return ExecutionPlan(model=g.name, device="tiny", n_stages=2,
                         layers=layers, streams=streams, topo_order=topo)


def _two_stage_executor(B=4):
    g = build_unet_exec()
    sx = lower_plan_pipelined(g, _two_stage_plan(g), microbatches=B,
                              kernel_mode="reference")
    xs = jax.random.normal(jax.random.PRNGKey(0), (B, 64, 32), jnp.float32)
    return sx, xs


# =============================================================================
# Recorder primitives under a stub clock
# =============================================================================

class TestTraceRecorder:
    def test_now_is_recorder_relative(self):
        rec = TraceRecorder(clock=_stub_clock())    # __init__ consumes t=1
        assert rec.now() == 1.0
        assert rec.now() == 2.0

    def test_span_context_measures_and_mutates_args(self):
        rec = TraceRecorder(clock=_stub_clock())
        with rec.span("work", track="t", cat="c", args={"a": 1}) as sa:
            sa["fps"] = 2.5                         # attach a result mid-span
        (s,) = rec.spans(track="t")
        assert s["name"] == "work" and s["cat"] == "c"
        assert s["args"] == {"a": 1, "fps": 2.5}
        assert s["ts"] == 1.0 and s["dur"] == 1.0   # two clock reads apart

    def test_add_span_clamps_negative_duration(self):
        rec = TraceRecorder(clock=_stub_clock())
        rec.add_span("x", 5.0, -1.0)
        assert rec.spans()[0]["dur"] == 0.0

    def test_counter_sets_incr_accumulates(self):
        rec = TraceRecorder(clock=_stub_clock())
        rec.counter("spill:a->b:bytes_evicted", 10, ts=0.0)
        rec.incr("spill:a->b:bytes_evicted", 5, ts=1.0)
        rec.incr("spill:a->b:bytes_evicted", ts=2.0)      # default delta 1
        assert rec.totals == {"spill:a->b:bytes_evicted": 16}
        # the emitted counter arg is keyed by the series' last segment
        ev = [e for e in rec.chrome_trace()["traceEvents"] if e["ph"] == "C"]
        assert ev[-1]["args"] == {"bytes_evicted": 16}

    def test_tracks_become_threads_in_first_use_order(self):
        rec = TraceRecorder(clock=_stub_clock())
        rec.add_span("a", 0.0, 1.0, track="pipeline")
        rec.add_span("b", 0.0, 1.0, track="stage0")
        rec.add_span("c", 0.0, 1.0, track="pipeline")
        assert rec.track_name(0) == "pipeline"
        assert rec.track_name(1) == "stage0"
        with pytest.raises(KeyError):
            rec.track_name(7)
        assert len(rec.spans(track="pipeline")) == 2

    def test_chrome_export_metadata_and_microseconds(self):
        rec = TraceRecorder(clock=_stub_clock())
        rec.add_span("tick", 1.0, 0.5, track="pipeline", cat="steady")
        rec.instant("stall", ts=2.0, track="queues")
        rec.counter("q:occupancy", 3, ts=2.0)
        data = rec.chrome_trace()
        assert data["displayTimeUnit"] == "ms"
        evs = data["traceEvents"]
        meta = [e for e in evs if e["ph"] == "M"]
        assert {"name": "repro.obs"} in [e["args"] for e in meta
                                         if e["name"] == "process_name"]
        thread_names = {e["tid"]: e["args"]["name"] for e in meta
                        if e["name"] == "thread_name"}
        assert thread_names == {0: "pipeline", 1: "queues", 2: "counters"}
        (span,) = [e for e in evs if e["ph"] == "X"]
        assert span["ts"] == 1.0e6 and span["dur"] == 0.5e6  # seconds -> us
        (inst,) = [e for e in evs if e["ph"] == "i"]
        assert inst["s"] == "t"
        stats = validate_chrome_trace(data)
        assert stats["spans"] == 1 and stats["instants"] == 1
        assert stats["counters"] == 1

    def test_save_writes_loadable_valid_json(self, tmp_path):
        rec = TraceRecorder(clock=_stub_clock())
        with rec.span("frame"):
            pass
        p = rec.save(tmp_path / "trace.json")
        stats = validate_chrome_trace(json.loads(p.read_text()))
        assert stats["spans"] == 1


class TestNullRecorder:
    def test_no_op_contract(self):
        rec = NullRecorder()
        assert rec.enabled is False and NULL_RECORDER.enabled is False
        assert rec.now() == 0.0
        with rec.span("x", args={"a": 1}) as sa:
            sa["ignored"] = True                    # mutable but discarded
        rec.add_span("x", 0.0, 1.0)
        rec.instant("x")
        rec.counter("c", 1.0)
        rec.incr("c")
        assert rec.totals == {}

    def test_trace_recorder_is_a_drop_in(self):
        # instrumented code holds a NullRecorder-typed slot; the live
        # recorder substitutes via subclassing, not duck-typing luck
        assert isinstance(TraceRecorder(clock=_stub_clock()), NullRecorder)


# =============================================================================
# Chrome trace schema validation (the CI smoke's gate)
# =============================================================================

class TestValidateChromeTrace:
    def _valid(self):
        return {"traceEvents": [
            {"ph": "M", "name": "process_name", "pid": 0, "tid": 0,
             "args": {"name": "p"}},
            {"ph": "X", "name": "tick", "pid": 0, "tid": 1, "ts": 0.0,
             "dur": 1.0},
            {"ph": "i", "name": "stall", "pid": 0, "tid": 1, "ts": 2.0,
             "s": "t"},
            {"ph": "C", "name": "occ", "pid": 0, "tid": 2, "ts": 2.0,
             "args": {"occ": 3}},
        ]}

    def test_valid_trace_stats(self):
        stats = validate_chrome_trace(self._valid())
        assert stats == {"events": 4, "spans": 1, "instants": 1,
                         "counters": 1, "metadata": 1, "tracks": 3}

    @pytest.mark.parametrize("mutate,msg", [
        (lambda d: "not a dict", "traceEvents"),
        (lambda d: {"traceEvents": []}, "non-empty"),
        (lambda d: d["traceEvents"].__setitem__(1, "ev") or d,
         "not an object"),
        (lambda d: d["traceEvents"][1].update(ph="Z") or d, "unknown phase"),
        (lambda d: d["traceEvents"][1].update(name="") or d, "name"),
        (lambda d: d["traceEvents"][1].update(tid="one") or d, "integers"),
        (lambda d: d["traceEvents"][1].update(ts=-1.0) or d, "non-negative"),
        (lambda d: d["traceEvents"][1].__delitem__("dur") or d, "dur"),
        (lambda d: d["traceEvents"][3].update(args={"occ": "3"}) or d,
         "numbers"),
    ])
    def test_malformed_traces_rejected(self, mutate, msg):
        with pytest.raises(ValueError, match=msg):
            validate_chrome_trace(mutate(self._valid()))


# =============================================================================
# Golden trace: 2-stage UNet, B=4 -> T=5 (fill 1, steady 3, drain 1)
# =============================================================================

class TestGoldenTrace:
    def _traced(self):
        sx, xs = _two_stage_executor(B=4)
        rec = TraceRecorder(clock=_stub_clock())
        ys, mc = sx.run_traced(xs, rec, measure_stages=False)
        return sx, xs, rec, ys, mc

    def test_span_ordering_fill_steady_drain(self):
        _, _, rec, _, mc = self._traced()
        ticks = [s for s in rec.spans(track="pipeline") if s["name"] == "tick"]
        assert [s["cat"] for s in ticks] == \
            ["fill", "steady", "steady", "steady", "drain"]
        assert [s["args"]["tick"] for s in ticks] == [0, 1, 2, 3, 4]
        assert mc.ticks_measured == 5 and mc.steady_measured == 3
        assert mc.ok

    def test_timestamps_monotonic(self):
        _, _, rec, _, _ = self._traced()
        ticks = rec.spans(track="pipeline")
        ts = [s["ts"] for s in ticks]
        assert ts == sorted(ts) and len(set(ts)) == len(ts)  # strict
        for s in ticks:
            assert s["dur"] >= 0.0

    def test_stage_spans_nest_inside_their_tick(self):
        """Stage spans share their tick's exact interval — the overlap of
        stage0/stage1 lanes within a tick *is* the pipeline diagram."""
        _, _, rec, _, _ = self._traced()
        interval = {s["args"]["tick"]: (s["ts"], s["dur"])
                    for s in rec.spans(track="pipeline")}
        census = []
        for j in (0, 1):
            stage = rec.spans(track=f"stage{j}")
            assert [s["name"] for s in stage] == [f"mb{b}" for b in range(4)]
            for s in stage:
                t = s["args"]["tick"]
                assert (s["ts"], s["dur"]) == interval[t]
                assert s["args"]["stage"] == j
                census.append((t, j))
        # the 1F1B census: stage j runs microbatch b at tick t = b + j
        assert sorted(census) == sorted(
            (b + j, j) for j in (0, 1) for b in range(4))

    def test_golden_span_census_and_valid_export(self, tmp_path):
        _, _, rec, _, _ = self._traced()
        stats = validate_chrome_trace(
            json.loads(rec.save(tmp_path / "t.json").read_text()))
        # 5 tick spans + 2 stages x 4 microbatch spans, nothing else
        assert stats["spans"] == 5 + 8
        assert stats["instants"] == 0      # well-sized queues: no stalls
        # every crossing edge's ring emitted occupancy counters
        occ = [k for k in rec.totals if k.endswith(":occupancy")]
        assert occ and all(rec.totals[k] == 0 for k in occ)  # drained

    def test_spill_bytes_conserved_per_edge(self):
        sx, _, rec, _, _ = self._traced()
        assert sx.report.spills            # the plan does spill
        evicted = {k.split(":")[1]: v for k, v in rec.totals.items()
                   if k.startswith("spill:") and k.endswith(":bytes_evicted")}
        assert evicted
        for edge, n in evicted.items():
            assert n > 0
            assert rec.totals[f"spill:{edge}:bytes_restored"] == n
        for k, v in rec.totals.items():
            if k.startswith("bfp8:") and k.endswith(":encodes"):
                assert rec.totals[k.replace(":encodes", ":decodes")] == v


# =============================================================================
# No-op parity: tracing must not change a single bit
# =============================================================================

class TestNoOpParity:
    def test_traced_outputs_bit_exact_and_zero_report_drift(self):
        sx, xs = _two_stage_executor(B=4)
        before = sx.report.summary()
        y_fused = np.asarray(sx(xs))
        y_null, mc_null = sx.run_traced(xs, measure_stages=False)
        y_live, mc_live = sx.run_traced(xs, TraceRecorder(),
                                        measure_stages=False)
        np.testing.assert_array_equal(np.asarray(y_null), y_fused)
        np.testing.assert_array_equal(np.asarray(y_live), y_fused)
        # zero report drift: tracing leaves the lowered report untouched,
        # and the ModelCheck itself is recorder-independent
        assert sx.report.summary() == before
        assert mc_null.summary() == mc_live.summary()
        assert mc_null.ok and mc_live.ok


# =============================================================================
# Façade: ObsConfig round-trip, trace(), report()
# =============================================================================

def _spec(**kw):
    kw.setdefault("device", TINY)
    kw.setdefault("strategy", "dse")
    kw.setdefault("dse", DSE_CFG)
    kw.setdefault("kernel_mode", "reference")
    return CompileSpec(model="unet_exec", **kw)


class TestFacadeObs:
    def test_obsconfig_dict_roundtrip_ignores_unknown_keys(self):
        cfg = ObsConfig(enabled=True, trace_path="t.json")
        d = cfg.to_dict()
        assert d == {"enabled": True, "trace_path": "t.json", "slo": None,
                     "flight_capacity": 0, "flight_path": None}
        assert ObsConfig.from_dict(d) == cfg
        assert ObsConfig.from_dict(d | {"future_knob": 1}) == cfg
        assert ObsConfig.from_dict({}) == ObsConfig()

    def test_obsconfig_roundtrips_nested_slo_config(self):
        from repro.obs import SloConfig
        cfg = ObsConfig(slo=SloConfig(window=8, p99_target_s=0.5),
                        flight_capacity=256, flight_path="f.json")
        d = cfg.to_dict()
        assert d["slo"]["window"] == 8          # nests as a plain dict
        back = ObsConfig.from_dict(json.loads(json.dumps(d)))
        assert back == cfg and isinstance(back.slo, SloConfig)

    def test_save_load_roundtrips_obs_config(self, tmp_path):
        c = repro.compile(_spec(mode="staged",
                                obs=ObsConfig(enabled=True,
                                              trace_path="t.json")))
        p = c.save(tmp_path / "design.smof.json")
        c2 = Compiled.load(p)
        assert c2.spec.obs == ObsConfig(enabled=True, trace_path="t.json")
        # and a pre-obs artifact (no "obs" key) loads with the default
        d = json.loads(p.read_text())
        d.pop("obs")
        (tmp_path / "old.smof.json").write_text(json.dumps(d))
        assert Compiled.load(tmp_path / "old.smof.json").spec.obs \
            == ObsConfig()

    def test_pipelined_trace_writes_valid_trace_and_reports_modelcheck(
            self, tmp_path):
        c = repro.compile(_spec(mode="pipelined", microbatches=4))
        assert "model_check" not in c.report()      # not traced yet
        path = tmp_path / "run.json"
        y, mc = c.trace(path=path)
        assert mc is not None and mc.ticks_measured == mc.ticks_predicted
        validate_chrome_trace(json.loads(path.read_text()))
        rep = c.report()
        assert rep["model_check"]["ok"] == mc.ok
        assert rep["model_check"]["ticks"]["measured"] == mc.ticks_measured
        err = rep["model_check"]["max_stage_rel_err"]
        if c.plan.n_stages > 1:                     # measured-vs-fitted
            assert err is not None and err >= 0.0   # residuals per stage

    def test_staged_trace_records_frame_span_without_modelcheck(self):
        c = repro.compile(_spec(mode="staged"))
        rec = TraceRecorder(clock=_stub_clock())
        x = jax.random.normal(jax.random.PRNGKey(0), c.input_shape(),
                              jnp.float32)
        y, mc = c.trace(x, recorder=rec)
        assert mc is None
        np.testing.assert_array_equal(np.asarray(y), np.asarray(c.run(x)))
        (frame,) = rec.spans(track="host")
        assert frame["name"] == "frame"
        # sequential spill accounting: one round-trip per spilled edge
        for k, v in rec.totals.items():
            if k.startswith("spill:") and k.endswith(":bytes_evicted"):
                assert rec.totals[k.replace("_evicted", "_restored")] == v


# =============================================================================
# LatencyHistogram + the serving front-end integration
# =============================================================================

class TestLatencyHistogram:
    def test_empty_summary_is_zeroed(self):
        s = LatencyHistogram().summary()
        assert s == {"count": 0, "mean_s": 0.0, "p50_s": 0.0, "p95_s": 0.0,
                     "p99_s": 0.0, "min_s": 0.0, "max_s": 0.0}

    def test_records_and_conservative_quantiles(self):
        h = LatencyHistogram()
        for v in (1e-6, 1e-6, 1e-6, 1.0):
            h.record(v)
        s = h.summary()
        assert s["count"] == 4 and s["max_s"] == 1.0
        assert s["mean_s"] == pytest.approx((3e-6 + 1.0) / 4)
        assert s["p50_s"] == 1e-6                   # exact bucket edge
        assert 1.0 <= s["p95_s"] <= 2.0             # upper-edge conservative

    def test_overflow_bucket_reports_max(self):
        h = LatencyHistogram(base=1e-6, n_buckets=4)   # top edge: 8 us
        h.record(1.0)
        assert h.quantile(1.0) == 1.0               # overflow -> max_s
        assert h.counts[-1] == 1

    def test_stream_server_histogram_counts_every_frame(self):
        from repro.serving.engine import GraphStreamServer
        g = build_unet_exec(positions=32, levels=2)
        g.compute_buffer_depths()
        topo = g.topo()
        layers = {n: LayerPlan(name=n, stage=0) for n in topo}
        plan = ExecutionPlan(model=g.name, device="tiny", n_stages=1,
                             layers=layers,
                             streams=[StreamPlan(e.src, e.dst)
                                      for e in g.edges()],
                             topo_order=topo)
        srv = GraphStreamServer(g, plan, microbatches=2,
                                kernel_mode="reference")
        assert srv.latency.summary()["count"] == 0
        tickets = [srv.submit(np.zeros((32, 32), np.float32))
                   for _ in range(3)]               # 1.5 streams -> padding
        srv.flush()
        s = srv.latency.summary()
        assert s["count"] == len(tickets) == 3
        assert s["max_s"] > 0.0 and s["p95_s"] >= s["p50_s"] > 0.0
