"""Substrate tests: data pipeline, checkpointing, fault tolerance, optimizer,
gradient compression, serving engine, staged executor."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint.store import CheckpointStore
from repro.configs import ARCHS
from repro.data.pipeline import DataConfig, TokenPipeline, write_token_file
from repro.models import init_params, project_logits, forward
from repro.optim.adamw import (AdamWConfig, adamw_update, init_opt_state,
                               schedule)
from repro.optim.compress import (dequantize_int8, ef_compress_tree,
                                  init_error_state, quantize_int8)
from repro.runtime.fault import FaultConfig, FaultTolerantLoop
from repro.runtime.reconfigure import StagedExecutor, split_group_stages
from repro.serving.engine import ServingEngine


class TestDataPipeline:
    def test_deterministic_per_step(self):
        p = TokenPipeline(DataConfig(vocab=100, seq_len=8, global_batch=4))
        a, b = p.batch_at(7), p.batch_at(7)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_steps_differ(self):
        p = TokenPipeline(DataConfig(vocab=100, seq_len=8, global_batch=4))
        assert not np.array_equal(p.batch_at(0)["tokens"],
                                  p.batch_at(1)["tokens"])

    def test_labels_shifted(self):
        p = TokenPipeline(DataConfig(vocab=100, seq_len=8, global_batch=2))
        b = p.batch_at(0)
        assert b["tokens"].shape == b["labels"].shape == (2, 8)

    def test_file_source_roundtrip(self, tmp_path):
        path = str(tmp_path / "toks.bin")
        write_token_file(path, np.arange(10_000) % 50)
        p = TokenPipeline(DataConfig(vocab=50, seq_len=16, global_batch=2,
                                     source="file", path=path))
        b = p.batch_at(0)
        assert b["tokens"].max() < 50

    def test_prefetch_iterator_resumes(self):
        p = TokenPipeline(DataConfig(vocab=100, seq_len=8, global_batch=2))
        it = p.iter_from(5)
        first = next(it)
        np.testing.assert_array_equal(first["tokens"], p.batch_at(5)["tokens"])


class TestCheckpoint:
    def _tree(self):
        return {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
                "b": {"c": jnp.ones((5,), jnp.bfloat16)}}

    def test_roundtrip(self, tmp_path):
        store = CheckpointStore(str(tmp_path))
        t = self._tree()
        store.save(3, t, {"next_step": 4})
        out, extra = store.restore(jax.tree.map(np.asarray, t))
        np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(t["a"]))
        assert extra["next_step"] == 4

    def test_bfp8_roundtrip_close(self, tmp_path):
        store = CheckpointStore(str(tmp_path), bfp8=True)
        t = {"w": jnp.asarray(np.random.default_rng(0).normal(
            size=(64, 64)).astype(np.float32))}
        store.save(1, t)
        out, _ = store.restore(t)
        err = np.abs(np.asarray(out["w"]) - np.asarray(t["w"]))
        assert err.max() < np.abs(np.asarray(t["w"])).max() * 0.02

    def test_atomic_commit_no_tmp_left(self, tmp_path):
        store = CheckpointStore(str(tmp_path))
        store.save(1, self._tree())
        assert not list(tmp_path.glob("*.tmp"))
        assert store.latest_step() == 1

    def test_gc_keeps_last(self, tmp_path):
        store = CheckpointStore(str(tmp_path), keep_last=2)
        for s in (1, 2, 3, 4):
            store.save(s, self._tree())
        assert store.steps() == [3, 4]

    def test_async_save(self, tmp_path):
        store = CheckpointStore(str(tmp_path))
        store.save_async(5, self._tree())
        store.wait()
        assert store.latest_step() == 5

    def test_restore_with_new_sharding(self, tmp_path):
        """Elastic remesh: restore onto explicit shardings."""
        from jax.sharding import NamedSharding, PartitionSpec as P
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        store = CheckpointStore(str(tmp_path))
        t = self._tree()
        store.save(1, t)
        sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), t)
        out, _ = store.restore(t, shardings=sh)
        assert out["a"].sharding == NamedSharding(mesh, P())


class TestFaultTolerance:
    def _setup(self, tmp_path, fail_at=()):
        store = CheckpointStore(str(tmp_path))
        calls = {"n": 0}

        def step_fn(state, batch):
            return {"x": state["x"] + batch}

        def injector(step):
            if step in fail_at and calls.setdefault(f"f{step}", 0) < 1:
                calls[f"f{step}"] = 1
                raise RuntimeError(f"injected fault at {step}")

        loop = FaultTolerantLoop(step_fn, store,
                                 FaultConfig(checkpoint_every=3,
                                             max_retries=1),
                                 fault_injector=injector)
        return loop, store

    def test_clean_run(self, tmp_path):
        loop, store = self._setup(tmp_path)
        out = loop.run({"x": 0}, lambda s: 1, start_step=0, num_steps=10)
        assert out["x"] == 10
        assert store.latest_step() == 9  # checkpoint at step 9

    def test_transient_fault_retried(self, tmp_path):
        loop, _ = self._setup(tmp_path, fail_at=(4,))
        out = loop.run({"x": 0}, lambda s: 1, start_step=0, num_steps=8)
        assert out["x"] == 8
        assert any(e["kind"] == "retry" for e in loop.events)

    def test_restart_resumes_from_checkpoint(self, tmp_path):
        loop, store = self._setup(tmp_path)
        loop.run({"x": 0}, lambda s: 1, start_step=0, num_steps=7)
        # simulate a node failure + restart
        loop2, _ = self._setup(tmp_path)
        state, next_step = loop2.try_restore({"x": 0})
        assert next_step == 6
        out = loop2.run(state, lambda s: 1, start_step=next_step, num_steps=4)
        assert out["x"] == 10  # 6 from ckpt + 4 more

    def test_straggler_detection(self, tmp_path):
        import time as _t
        store = CheckpointStore(str(tmp_path))

        def slow_step(state, batch):
            if batch == 9:
                _t.sleep(0.25)
            else:
                _t.sleep(0.01)
            return state

        loop = FaultTolerantLoop(slow_step, store,
                                 FaultConfig(straggler_factor=3.0))
        loop.run({}, lambda s: s, start_step=0, num_steps=12)
        assert any(e["kind"] == "straggler" for e in loop.events)

    def test_recovery_events_land_in_metrics_registry(self, tmp_path):
        """ISSUE 7 satellite: with a MetricsRegistry attached, every
        fault-tolerance event mirrors into smof_fault_events_total{kind}
        and step wall times into the smof_fault_step_seconds histogram —
        same counts as the in-memory events list."""
        from collections import Counter as TallyCounter

        from repro.obs.metrics import MetricsRegistry

        reg = MetricsRegistry()
        store = CheckpointStore(str(tmp_path))
        calls = {}

        def step_fn(state, batch):
            return {"x": state["x"] + batch}

        def injector(step):
            if step == 4 and not calls.setdefault("f", 0):
                calls["f"] = 1
                raise RuntimeError("injected")

        loop = FaultTolerantLoop(step_fn, store,
                                 FaultConfig(checkpoint_every=3,
                                             max_retries=1),
                                 fault_injector=injector, metrics=reg)
        out = loop.run({"x": 0}, lambda s: 1, start_step=0, num_steps=9)
        assert out["x"] == 9
        fam = reg.get("smof_fault_events_total")
        tally = TallyCounter(e["kind"] for e in loop.events)
        assert tally["retry"] == 1 and tally["checkpoint"] >= 2
        for kind, n in tally.items():
            assert fam.labels(kind=kind).value == n
        snap = reg.snapshot()
        assert snap["smof_fault_step_seconds_count"] == len(loop.records)
        # and the exposition of the whole thing is scrapeable
        from repro.obs import parse_metrics_text
        assert "smof_fault_events_total" in \
            parse_metrics_text(reg.metrics_text())


class TestOptimizer:
    def test_schedule_warmup_and_decay(self):
        cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100)
        lrs = [float(schedule(cfg, jnp.asarray(s))) for s in (0, 5, 10, 100)]
        assert lrs[0] == 0.0
        assert lrs[1] == pytest.approx(5e-4)
        assert lrs[2] == pytest.approx(1e-3)
        assert lrs[3] == pytest.approx(1e-4, rel=0.05)

    @pytest.mark.parametrize("quantize", [False, True])
    def test_descends_quadratic(self, quantize):
        cfg = AdamWConfig(lr=0.05, weight_decay=0.0, warmup_steps=1,
                          total_steps=200, quantize_states=quantize)
        params = {"w": jnp.asarray([3.0, -2.0, 1.5])[None, :]}
        state = init_opt_state(params, cfg)
        for _ in range(150):
            grads = {"w": 2 * params["w"]}      # d/dw of w^2
            params, state, _ = adamw_update(params, grads, state, cfg)
        assert float(jnp.abs(params["w"]).max()) < 0.15

    def test_quantized_state_is_int8(self):
        cfg = AdamWConfig(quantize_states=True)
        params = {"w": jnp.ones((4, 256))}
        state = init_opt_state(params, cfg)
        assert state["m"]["w"]["q"].dtype == jnp.int8
        # 1 byte payload vs 4 bytes fp32
        from repro.optim.adamw import opt_state_bytes
        plain = init_opt_state(params, AdamWConfig())
        assert opt_state_bytes(state) < 0.4 * opt_state_bytes(plain)


class TestGradCompression:
    @given(st.integers(0, 10_000))
    @settings(max_examples=25, deadline=None)
    def test_quantize_roundtrip_bounded(self, seed):
        x = jax.random.normal(jax.random.PRNGKey(seed), (8, 64)) * 10
        q, s = quantize_int8(x)
        back = dequantize_int8(q, s)
        assert float(jnp.abs(back - x).max()) <= float(s.max()) * 0.51

    def test_error_feedback_reduces_bias(self):
        """With EF, the accumulated compressed sum tracks the true sum."""
        rng = np.random.default_rng(0)
        true_sum = np.zeros((4, 64), np.float32)
        ef_sum = np.zeros((4, 64), np.float32)
        err = {"g": jnp.zeros((4, 64), jnp.float32)}
        for _ in range(50):
            g = rng.normal(size=(4, 64)).astype(np.float32) * 0.01
            true_sum += g
            q, s, new_err = ef_compress_tree({"g": jnp.asarray(g)}, err)
            ef_sum += np.asarray(dequantize_int8(q["g"], s["g"]))
            err = {"g": new_err["g"]}
        # residual bounded by one final quantisation error, not accumulated
        resid = np.abs(true_sum - ef_sum).max()
        assert resid < 0.01

    def test_compression_ratio(self):
        g = {"w": jnp.ones((128, 128), jnp.float32)}
        q, s, _ = ef_compress_tree(g, init_error_state(g))
        raw = 128 * 128 * 4
        comp = 128 * 128 * 1 + 128 * 4
        assert comp / raw < 0.27


class TestServingEngine:
    def _engine(self, **kw):
        cfg = ARCHS["yi-6b"].reduced()
        params = init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
        return cfg, params, ServingEngine(cfg, params, max_batch=2, s_max=64,
                                          **kw)

    def test_generates_tokens(self):
        _, _, eng = self._engine()
        r = eng.submit(np.arange(8), max_new_tokens=5)
        eng.run_until_drained()
        assert r.done and len(r.out_tokens) == 5
        assert eng.stats.prefills == 1

    def test_continuous_batching_slots_reused(self):
        _, _, eng = self._engine()
        rs = [eng.submit(np.arange(4) + i, max_new_tokens=3)
              for i in range(5)]
        eng.run_until_drained()
        assert all(r.done for r in rs)
        assert eng.stats.prefills == 5          # 5 requests through 2 slots

    def test_greedy_matches_unbatched_forward(self):
        """Engine output == argmax decoding with the raw model."""
        cfg, params, eng = self._engine()
        prompt = np.arange(6)
        r = eng.submit(prompt, max_new_tokens=4)
        eng.run_until_drained()
        # reference: iterative full forward
        toks = list(prompt)
        out = []
        for _ in range(4):
            x, _, _ = forward(params, cfg, jnp.asarray(toks)[None])
            nxt = int(jnp.argmax(project_logits(params, cfg, x[:, -1]), -1)[0])
            out.append(nxt)
            toks.append(nxt)
        assert r.out_tokens == out

    def test_eviction_compresses(self):
        _, _, eng = self._engine(evict_to_host=True)
        eng.submit(np.arange(4), max_new_tokens=3)
        eng.run_until_drained()
        assert eng.stats.evicted_pages > 0
        assert (eng.stats.evicted_bytes_compressed
                < 0.6 * eng.stats.evicted_bytes_raw)


class TestStagedExecutor:
    def test_split_balanced(self):
        assert split_group_stages(8, 3) == [(0, 3), (3, 6), (6, 8)]
        assert split_group_stages(4, 8) == [(0, 1), (1, 2), (2, 3), (3, 4)]

    def test_staged_matches_monolithic(self):
        cfg = ARCHS["yi-6b"].reduced(n_layers=4)
        params = init_params(jax.random.PRNGKey(1), cfg, dtype=jnp.float32)
        toks = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0, cfg.vocab)
        x, _, _ = forward(params, cfg, toks)
        want = project_logits(params, cfg, x)
        ex = StagedExecutor(cfg, params, n_stages=2, compress_boundary=False)
        got = ex.forward_logits(toks)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)
        assert len(ex.timings) == 2

    def test_boundary_compression_small_error(self):
        cfg = ARCHS["yi-6b"].reduced(n_layers=4)
        params = init_params(jax.random.PRNGKey(1), cfg, dtype=jnp.float32)
        toks = jax.random.randint(jax.random.PRNGKey(2), (1, 16), 0, cfg.vocab)
        plain = StagedExecutor(cfg, params, n_stages=2,
                               compress_boundary=False)
        comp = StagedExecutor(cfg, params, n_stages=2, compress_boundary=True)
        a = np.asarray(plain.forward_logits(toks))
        b = np.asarray(comp.forward_logits(toks))
        # BFP8 boundary: small perturbation, same argmax almost everywhere
        agree = (a.argmax(-1) == b.argmax(-1)).mean()
        assert agree > 0.9
        eq5 = comp.eq5_latency(batch=1)
        assert eq5["boundary_compression"] < 0.6

    def test_eq5_accounting(self):
        cfg = ARCHS["yi-6b"].reduced(n_layers=4)
        params = init_params(jax.random.PRNGKey(1), cfg, dtype=jnp.float32)
        toks = jnp.zeros((1, 8), jnp.int32)
        ex = StagedExecutor(cfg, params, n_stages=4)
        ex.forward_logits(toks)
        eq5 = ex.eq5_latency(batch=1)
        assert eq5["n_stages"] == 4
        assert eq5["total_s"] >= eq5["compute_s"]
