"""Metrics registry + Prometheus exposition tests (ISSUE 7 tentpole).

The contract under test:

* child semantics — counters are monotone, gauges move freely, histograms
  proxy :class:`LatencyHistogram` (so ``.hist`` and the registry read one
  data structure);
* family/registry discipline — name/label validation, idempotent
  registration, kind- and label-set-mismatch rejection, the label-less
  proxy surface;
* exposition — ``metrics_text()`` is valid text format 0.0.4: label
  values escaped (backslash, quote, newline), histogram buckets
  cumulative with ``le="+Inf"`` == ``_count``, and the whole thing
  round-trips through the strict :func:`parse_metrics_text` (the same
  gate the CI scrape smoke uses);
* snapshots — ``snapshot()`` is flat and keyed like the exposition,
  ``delta_since`` reports exactly what moved;
* the parser rejects malformed exposition (no TYPE, duplicates, bad
  escapes, non-numeric values, non-cumulative buckets).
"""
import pytest

from repro.obs import parse_metrics_text
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricFamily,
                               MetricsRegistry, escape_label_value)


# =============================================================================
# Children
# =============================================================================

class TestChildren:
    def test_counter_is_monotone(self):
        c = Counter()
        c.inc()
        c.inc(2.5)
        assert c.value == 3.5
        with pytest.raises(ValueError, match="only go up"):
            c.inc(-1)
        assert c.value == 3.5

    def test_gauge_moves_freely(self):
        g = Gauge()
        g.set(10)
        g.dec(4)
        g.inc()
        assert g.value == 7.0

    def test_histogram_value_is_count_and_shares_hist(self):
        h = Histogram()
        h.observe(1e-3)
        h.observe(2e-3)
        assert h.value == 2.0
        assert h.hist.n == 2                     # same object, same counts
        assert h.summary() == h.hist.summary()
        assert h.quantile(0.5) == h.hist.quantile(0.5)


# =============================================================================
# Families + registry discipline
# =============================================================================

class TestRegistry:
    def test_registration_is_idempotent(self):
        r = MetricsRegistry()
        a = r.counter("smof_x_total", "help", ("k",))
        b = r.counter("smof_x_total", "different help ignored", ("k",))
        assert a is b
        assert "smof_x_total" in r and r.get("smof_x_total") is a

    def test_kind_and_labelset_mismatch_rejected(self):
        r = MetricsRegistry()
        r.counter("smof_x_total", labelnames=("k",))
        with pytest.raises(ValueError, match="already registered"):
            r.gauge("smof_x_total", labelnames=("k",))
        with pytest.raises(ValueError, match="already registered"):
            r.counter("smof_x_total", labelnames=("k", "j"))

    @pytest.mark.parametrize("name", ["1bad", "has space", "dash-ed", ""])
    def test_invalid_metric_names_rejected(self, name):
        with pytest.raises(ValueError, match="invalid metric name"):
            MetricsRegistry().counter(name)

    def test_invalid_and_reserved_label_names_rejected(self):
        r = MetricsRegistry()
        with pytest.raises(ValueError, match="invalid label name"):
            r.counter("smof_x_total", labelnames=("0bad",))
        with pytest.raises(ValueError, match="invalid label name"):
            r.counter("smof_y_total", labelnames=("__reserved",))
        with pytest.raises(ValueError, match="reserved"):
            r.histogram("smof_h_seconds", labelnames=("le",))

    def test_labels_resolve_one_child_per_combination(self):
        fam = MetricsRegistry().counter("smof_x_total", labelnames=("k",))
        fam.labels(k="a").inc()
        fam.labels(k="a").inc()
        fam.labels(k="b").inc(5)
        assert fam.labels(k="a").value == 2.0
        assert fam.labels(k="b").value == 5.0
        assert len(fam.children()) == 2
        with pytest.raises(ValueError, match="expected labels"):
            fam.labels(wrong="a")

    def test_labeled_family_refuses_labelless_proxy(self):
        fam = MetricsRegistry().counter("smof_x_total", labelnames=("k",))
        with pytest.raises(ValueError, match="call .labels"):
            fam.inc()

    def test_labelless_family_proxies_to_single_child(self):
        r = MetricsRegistry()
        r.counter("smof_c_total").inc(2)
        r.gauge("smof_g").set(7)
        r.histogram("smof_h_seconds").observe(1e-3)
        snap = r.snapshot()
        assert snap["smof_c_total"] == 2.0
        assert snap["smof_g"] == 7.0
        assert snap["smof_h_seconds_count"] == 1.0


# =============================================================================
# Exposition + the round-trip gate
# =============================================================================

def _full_registry() -> MetricsRegistry:
    r = MetricsRegistry()
    c = r.counter("smof_frames_total", "frames served", ("edge", "kind"))
    c.labels(edge="a->b", kind="evict").inc(3)
    c.labels(edge='we"ird\\path\nx', kind="restore").inc(1)
    r.gauge("smof_occupancy", "ring occupancy", ("edge",)) \
        .labels(edge="a->b").set(4)
    h = r.histogram("smof_latency_seconds", "per-request latency")
    for v in (1e-6, 3e-6, 1e-3, 0.5):
        h.observe(v)
    return r


class TestExposition:
    def test_escape_label_value(self):
        assert escape_label_value('a\\b"c\nd') == 'a\\\\b\\"c\\nd'

    def test_empty_registry_text_parses_to_nothing(self):
        assert parse_metrics_text(MetricsRegistry().metrics_text()) == {}

    def test_round_trip_preserves_every_sample(self):
        r = _full_registry()
        fams = parse_metrics_text(r.metrics_text())
        assert set(fams) == {"smof_frames_total", "smof_occupancy",
                             "smof_latency_seconds"}
        assert fams["smof_frames_total"]["type"] == "counter"
        assert fams["smof_frames_total"]["help"] == "frames served"
        # the parsed samples are exactly the snapshot, keys included —
        # escaped label values survive the round trip
        merged = {}
        for fam in fams.values():
            merged.update(fam["samples"])
        assert merged == r.snapshot()
        key = ('smof_frames_total{edge="we\\"ird\\\\path\\nx",'
               'kind="restore"}')
        assert merged[key] == 1.0

    def test_histogram_buckets_cumulative_and_inf_equals_count(self):
        fams = parse_metrics_text(_full_registry().metrics_text())
        s = fams["smof_latency_seconds"]["samples"]
        buckets = [(k, v) for k, v in s.items() if "_bucket{" in k]
        values = [v for _, v in buckets]
        assert values == sorted(values)          # cumulative
        assert s['smof_latency_seconds_bucket{le="+Inf"}'] == 4.0
        assert s["smof_latency_seconds_count"] == 4.0
        assert s["smof_latency_seconds_sum"] == pytest.approx(
            1e-6 + 3e-6 + 1e-3 + 0.5)

    def test_integer_values_render_without_trailing_zero(self):
        r = MetricsRegistry()
        r.counter("smof_n_total").inc(3)
        assert "smof_n_total 3\n" in r.metrics_text()

    def test_snapshot_delta_since_reports_what_moved(self):
        r = MetricsRegistry()
        c = r.counter("smof_a_total", labelnames=("k",))
        g = r.gauge("smof_g")
        c.labels(k="x").inc(2)
        g.set(1)
        before = r.snapshot()
        assert r.delta_since(before) == {}       # nothing moved
        c.labels(k="x").inc(3)
        c.labels(k="y").inc(1)                   # new sample counts from 0
        g.set(1)                                 # unchanged -> dropped
        assert r.delta_since(before) == {'smof_a_total{k="x"}': 3.0,
                                         'smof_a_total{k="y"}': 1.0}


class TestParserRejections:
    @pytest.mark.parametrize("text,msg", [
        ("smof_x_total 1\n", "no preceding # TYPE"),
        ("# TYPE smof_x_total counter\nsmof_x_total 1\nsmof_x_total 2\n",
         "duplicate sample"),
        ("# TYPE smof_x_total counter\n# TYPE smof_x_total counter\n",
         "duplicate TYPE"),
        ("# TYPE smof_x_total widget\n", "unknown type"),
        ("# TYPE smof_x_total counter\nsmof_x_total{k=\"a\\q\"} 1\n",
         r"bad escape"),
        ("# TYPE smof_x_total counter\nsmof_x_total{k=\"a} 1\n",
         "unterminated|malformed"),
        ("# TYPE smof_x_total counter\nsmof_x_total nan-ish\n",
         "non-numeric|malformed"),
        ("# TYPE smof_h histogram\n"
         'smof_h_bucket{le="1"} 5\nsmof_h_bucket{le="2"} 3\n'
         'smof_h_bucket{le="+Inf"} 5\nsmof_h_count 5\n',
         "not cumulative"),
        ("# TYPE smof_h histogram\n"
         'smof_h_bucket{le="1"} 2\nsmof_h_bucket{le="+Inf"} 2\n'
         "smof_h_count 3\n", "!= _count"),
        ("# TYPE smof_h histogram\n"
         'smof_h_bucket{le="1"} 2\nsmof_h_count 2\n', r"\+Inf"),
    ])
    def test_malformed_exposition_rejected(self, text, msg):
        with pytest.raises(ValueError, match=msg):
            parse_metrics_text(text)

    def test_plain_comments_and_blank_lines_ignored(self):
        fams = parse_metrics_text(
            "\n# just a comment\n# TYPE smof_x_total counter\n\n"
            "smof_x_total 1\n")
        assert fams["smof_x_total"]["samples"] == {"smof_x_total": 1.0}
