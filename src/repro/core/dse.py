"""Design Space Exploration (paper §IV-B, Algorithm 1).

Greedy, iterative optimisation of the per-vertex decision vector
``D_v = (s_i, s_o, p, a_i, a_o, m)`` to maximise throughput (Eq. 6) and
minimise latency (Eq. 5) under the device's on-chip resource and off-chip
bandwidth constraints (Eq. 7).  The five passes:

  1  resource-minimal initialisation — max partitions, min parallelism
  2  compute parallelism allocation  — speed up the slowest vertex
  3  on-chip memory allocation       — balance BRAM/URAM utilisation
  4  off-chip bandwidth allocation   — greedy by  L * delta_d / delta_BW
  5  partition merging               — merge when estimated perf improves
"""
from __future__ import annotations

import dataclasses
import math

from . import eviction, fragmentation
from .graph import Graph
from .partition import (Partitioning, fits, initial_partition, latency_s,
                        merge, subgraph_cost, throughput_fps)
from .resources import BRAM18K_BITS, URAM_BITS, Device


@dataclasses.dataclass
class DSEConfig:
    batch: int = 1
    codecs: tuple[str, ...] = ("none",)
    allow_eviction: bool = True
    allow_fragmentation: bool = True
    allow_merging: bool = True
    sparsity: float = 0.5            # calibration for c_bar (activations)
    alpha: float = 1.0               # read-order penalty (Eq. 2)
    frag_step: float = 0.125
    cut_kinds: tuple[str, ...] | None = None   # user partition-point filter
    max_iters: int = 400
    word_bits: int = 16


@dataclasses.dataclass
class DSEResult:
    partitioning: Partitioning
    throughput_fps: float
    latency_s: float
    history: list[dict]
    feasible: bool

    def summary(self) -> dict:
        g = self.partitioning.graph
        n_evicted = sum(1 for e in g.edges() if e.evicted)
        fragged = [(v.name, v.frag_ratio) for v in g.vertices() if v.frag_ratio > 0]
        return {
            "n_partitions": self.partitioning.n,
            "throughput_fps": self.throughput_fps,
            "latency_s": self.latency_s,
            "n_evicted_edges": n_evicted,
            "n_fragmented": len(fragged),
            "mean_frag_ratio": (sum(m for _, m in fragged) / len(fragged)) if fragged else 0.0,
            "feasible": self.feasible,
        }


def pack_onchip(weight_bits: float, buffer_bits: float, dev: Device) -> dict:
    """Pass 3 — balance BRAM/URAM utilisation (AMD devices).

    Weights prefer the deeper URAMs, buffers prefer BRAMs; overflow spills
    to the other type so the two utilisation ratios stay balanced.  Returns
    block counts and a feasibility flag.  Devices without discrete memory
    types (TPU views) pass through on total bits.
    """
    if dev.bram18k == 0 and dev.uram == 0:
        total = weight_bits + buffer_bits
        return {"feasible": total <= dev.onchip_bits, "bram": 0, "uram": 0,
                "util": total / max(dev.onchip_bits, 1.0)}
    uram_blocks = math.ceil(weight_bits / URAM_BITS) if dev.uram else 0
    bram_blocks = math.ceil(buffer_bits / BRAM18K_BITS)
    if uram_blocks > dev.uram:                      # spill weights to BRAM
        spill = (uram_blocks - dev.uram) * URAM_BITS
        uram_blocks = dev.uram
        bram_blocks += math.ceil(spill / BRAM18K_BITS)
    if dev.uram == 0:
        bram_blocks = math.ceil((weight_bits + buffer_bits) / BRAM18K_BITS)
    # balance: move weight blocks to URAM while BRAM util exceeds URAM util
    while (dev.uram and uram_blocks < dev.uram
           and bram_blocks / max(dev.bram18k, 1) > uram_blocks / dev.uram
           and bram_blocks >= URAM_BITS // BRAM18K_BITS):
        bram_blocks -= URAM_BITS // BRAM18K_BITS
        uram_blocks += 1
    return {
        "feasible": bram_blocks <= dev.bram18k and uram_blocks <= dev.uram,
        "bram": bram_blocks, "uram": uram_blocks,
        "util": max(bram_blocks / max(dev.bram18k, 1),
                    uram_blocks / max(dev.uram, 1)),
    }


def _snapshot(g: Graph) -> dict:
    """Capture all mutable design state (for candidate rollback)."""
    return {
        "v": {v.name: (v.par, v.frag_ratio, dict(v.meta)) for v in g.vertices()},
        "e": {(e.src, e.dst): (e.evicted, e.codec, e.buffer_depth) for e in g.edges()},
    }


def _restore(g: Graph, snap: dict) -> None:
    for v in g.vertices():
        v.par, v.frag_ratio, meta = snap["v"][v.name]
        v.meta = dict(meta)
    for e in g.edges():
        e.evicted, e.codec, e.buffer_depth = snap["e"][(e.src, e.dst)]


def _sg_feasible(p: Partitioning, i: int, dev: Device, cfg: DSEConfig) -> bool:
    c = subgraph_cost(p, i, sparsity=cfg.sparsity, alpha=cfg.alpha)
    if not fits(c, dev, word_bits=cfg.word_bits):
        return False
    sg = p.graph.subgraph(p.parts[i])
    pk = pack_onchip(fragmentation.onchip_weight_bits(sg),
                     eviction.onchip_buffer_bits(sg), dev)
    return bool(pk["feasible"])


def _alloc_off_chip(p: Partitioning, i: int, dev: Device, cfg: DSEConfig,
                    history: list[dict]) -> bool:
    """Pass 4 — spend off-chip bandwidth to free on-chip memory.

    Candidates from both mechanisms are pooled and applied best-merit-first
    (``L * delta_d / delta_BW``) until the subgraph fits or bandwidth runs
    out.  Returns True if the subgraph is feasible afterwards.
    """
    sg = p.graph.subgraph(p.parts[i])
    budget = dev.words_per_cycle_offchip(cfg.word_bits)
    for _ in range(200):
        if _sg_feasible(p, i, dev, cfg):
            return True
        cost = subgraph_cost(p, i, sparsity=cfg.sparsity, alpha=cfg.alpha)
        if (cost.bw_words_per_cycle > budget
                or cost.compute_units > dev.compute_units):
            # bandwidth / compute infeasibility cannot be bought back by
            # spending MORE off-chip bandwidth — bail out.
            return False
        cands: list[tuple[float, str, object]] = []
        if cfg.allow_eviction:
            for o in eviction.candidate_evictions(sg, codecs=cfg.codecs,
                                                  sparsity=cfg.sparsity,
                                                  alpha=cfg.alpha):
                cands.append((o.merit, "evict", o))
        if cfg.allow_fragmentation:
            for o in fragmentation.candidate_fragmentations(
                    sg, codecs=cfg.codecs, ratio_step=cfg.frag_step):
                cands.append((o.merit, "frag", o))
        if not cands:
            return False
        cands.sort(key=lambda t: t[0], reverse=True)
        affordable = [t for t in cands
                      if cost.bw_words_per_cycle + t[2].delta_bw_words_per_cycle <= budget]
        if not affordable:
            return False
        merit, kind, opt = affordable[0]
        if kind == "evict":
            eviction.apply_eviction(sg, opt)
        else:
            fragmentation.apply_fragmentation(sg, opt)
        history.append({"pass": 4, "part": i, "action": kind,
                        "target": getattr(opt, "vertex", getattr(opt, "edge", None)),
                        "merit": merit})
    return _sg_feasible(p, i, dev, cfg)


def _sg_feasible_relaxed(p: Partitioning, i: int, dev: Device,
                         cfg: DSEConfig) -> bool:
    """Compute + bandwidth constraints only (no on-chip memory check)."""
    c = subgraph_cost(p, i, sparsity=cfg.sparsity, alpha=cfg.alpha)
    return (c.compute_units <= dev.compute_units
            and c.bw_words_per_cycle <= dev.words_per_cycle_offchip(cfg.word_bits))


def _alloc_parallel(p: Partitioning, i: int, dev: Device, cfg: DSEConfig,
                    history: list[dict]) -> bool:
    """Pass 2 — raise parallelism of the slowest vertex while budgets allow.

    If the part's memory infeasibility cannot be fixed even by pass 4 (e.g.
    one conv's weights exceed the whole device and fragmentation is
    disabled), parallelism is still allocated under the compute/bandwidth
    budgets — the design stays flagged infeasible, but its throughput
    estimate remains meaningful for the ablation comparisons.
    """
    sg = p.graph.subgraph(p.parts[i])
    check = _sg_feasible
    if not (_sg_feasible(p, i, dev, cfg)
            or _alloc_off_chip(p, i, dev, cfg, history)):
        check = _sg_feasible_relaxed
    improved = False
    for _ in range(4096):
        verts = sorted(sg.vertices(), key=lambda v: v.latency(), reverse=True)
        moved = False
        for v in verts:
            if v.par >= v.max_par:
                continue
            used = sum(u.compute_units() for u in sg.vertices())
            headroom = dev.compute_units - used
            # try doubling; if that overshoots the budget, exact-fill with
            # whatever headroom remains (power-of-2-only wastes up to 2x)
            new_par = min(v.par * 2, v.max_par, v.par + int(headroom))
            extra = v.compute_units(new_par) - v.compute_units()
            if new_par <= v.par or extra > headroom:
                continue
            snap = _snapshot(p.graph)
            v.par = new_par
            if not (check(p, i, dev, cfg)
                    or _alloc_off_chip(p, i, dev, cfg, history)):
                _restore(p.graph, snap)
                continue
            history.append({"pass": 2, "part": i, "action": "par",
                            "vertex": v.name, "par": new_par})
            moved = improved = True
            break
        if not moved:
            break
    return improved


def run_dse(g: Graph, dev: Device, cfg: DSEConfig | None = None) -> DSEResult:
    """Algorithm 1."""
    cfg = cfg or DSEConfig()
    history: list[dict] = []
    for v in g.vertices():          # resource-minimal start
        v.par = v.min_par
        v.frag_ratio = 0.0
    for e in g.edges():
        e.evicted = False
        e.codec = "none"
    g.compute_buffer_depths()
    p = initial_partition(g, cut_kinds=cfg.cut_kinds)          # pass 1
    history.append({"pass": 1, "n_partitions": p.n})

    feasible = True
    for i in range(p.n):
        if not (_sg_feasible(p, i, dev, cfg)
                or _alloc_off_chip(p, i, dev, cfg, history)):
            feasible = False
        _alloc_parallel(p, i, dev, cfg, history)               # passes 2-4

    if cfg.allow_merging:                                      # pass 5
        for _ in range(cfg.max_iters):
            best: tuple[float, int, dict] | None = None
            cur = throughput_fps(p, dev, cfg.batch,
                                 sparsity=cfg.sparsity, alpha=cfg.alpha)
            for i in range(p.n - 1):
                snap = _snapshot(g)
                cand = merge(p, i)
                # the union shares one compute budget: restart its parallelism
                for name in cand.parts[i]:
                    g.vertex(name).par = g.vertex(name).min_par
                ok = (_sg_feasible(cand, i, dev, cfg)
                      or _alloc_off_chip(cand, i, dev, cfg, []))
                if ok:
                    _alloc_parallel(cand, i, dev, cfg, [])
                    thr = throughput_fps(cand, dev, cfg.batch,
                                         sparsity=cfg.sparsity, alpha=cfg.alpha)
                    if thr > cur and (best is None or thr > best[0]):
                        best = (thr, i, _snapshot(g))
                _restore(g, snap)
            if best is None:
                break
            thr, i, state = best
            p = merge(p, i)
            _restore(g, state)
            history.append({"pass": 5, "merged": i, "n_partitions": p.n,
                            "throughput": thr})

    thr = throughput_fps(p, dev, cfg.batch, sparsity=cfg.sparsity, alpha=cfg.alpha)
    lat = latency_s(p, dev, cfg.batch, sparsity=cfg.sparsity, alpha=cfg.alpha)
    feasible = feasible and all(_sg_feasible(p, i, dev, cfg) for i in range(p.n))
    return DSEResult(partitioning=p, throughput_fps=thr, latency_s=lat,
                     history=history, feasible=feasible)
