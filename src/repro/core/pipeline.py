"""Refined pipeline-depth estimation (paper §IV-C, Eq. 8-11).

The performance of a streaming design depends on the pipeline depth
``d_pG`` of the computation graph — the cycles elapsed before the pipeline
is fully primed.  fpgaConvNet's original model used a flat sum of vertex
depths; SMOF refines it by recognising that during the *pipeline-depth
region* a vertex consumes at its **initiation rate** ``r^st`` (set by how
fast its ancestors can feed it), which differs from its steady-state input
rate ``r^in`` (Fig. 5).

Implemented faithfully:

  Eq. 8   Interval_prev(v) = max_{a in ancestors(v)} (lambda_a + rho_a)
  Eq. 9   r^st(v) = r_v^in                        if ancestors(v) is empty
                  = sigma_v^in / Interval_prev(v) otherwise
  Eq. 10  Delay(G, v) = sum_{n in argmax path P_G(N_G^in, v)} rho_n / r^st(n)
  Eq. 11  d_pG = max_v Delay(G, v)

``ancestors`` means *direct* predecessors (the paper: "all nodes in graph G
that have direct connection to the node v").
"""
from __future__ import annotations

from .graph import Graph


def interval_prev(g: Graph, v: str, _memo: dict | None = None) -> float:
    """Eq. 8 — the interval leading up to vertex ``v``."""
    preds = g.predecessors(v)
    if not preds:
        return 0.0
    out = 0.0
    for a in preds:
        av = g.vertex(a)
        out = max(out, av.latency() + av.depth())
    return out


def initiation_rate(g: Graph, v: str) -> float:
    """Eq. 9 — ``r^st(v)`` in words/cycle."""
    vv = g.vertex(v)
    preds = g.predecessors(v)
    if not preds:
        return vv.rate_in()
    iv = interval_prev(g, v)
    return vv.in_words / max(iv, 1.0)


def vertex_delays(g: Graph) -> dict[str, float]:
    """Eq. 10 for every vertex, via one topological DP.

    ``Delay(G, v)`` sums ``rho_n / r^st(n)`` along the *longest* (max-delay)
    path from the graph input to ``v`` — a longest-path DP over the DAG
    rather than the exponential path enumeration ``P_G`` suggests.
    """
    delays: dict[str, float] = {}
    rates = {v: initiation_rate(g, v) for v in g.g.nodes}
    for n in g.topo():
        vv = g.vertex(n)
        own = vv.depth() / max(rates[n], 1e-12)
        preds = g.predecessors(n)
        best = max((delays[p] for p in preds), default=0.0)
        delays[n] = best + own
    return delays


def pipeline_depth(g: Graph) -> float:
    """Eq. 11 — ``d_pG`` in cycles."""
    d = vertex_delays(g)
    return max(d.values(), default=0.0)


def initiation_interval(g: Graph) -> float:
    """``II`` of the whole pipeline: the slowest vertex sets the frame rate."""
    return max((v.latency() for v in g.vertices()), default=1.0)
