"""SMOF core: streaming memory optimisation with smart off-chip eviction.

The paper's contribution (§III-IV) as a hardware-agnostic library: a layer
graph IR, the activation-eviction / weight-fragmentation / subgraph-
reconfiguration mechanisms with their cost models, the refined pipeline-depth
estimator, and the greedy iterative DSE (Algorithm 1).
"""
from .graph import Edge, Graph, Vertex, WEIGHTY
from .resources import (ALL_DEVICES, Device, get_device, TPU_V5E_KERNEL,
                        TPU_V5E_RUNTIME, U200, VCU118, VCU1525, ZCU102)
from .pipeline import (initiation_interval, initiation_rate, interval_prev,
                       pipeline_depth, vertex_delays)
from .eviction import (apply_eviction, candidate_evictions, evaluate_eviction,
                       EvictionOption)
from .fragmentation import (apply_fragmentation, candidate_fragmentations,
                            evaluate_fragmentation, FragmentationOption)
from .partition import (fits, initial_partition, latency_s, merge,
                        Partitioning, subgraph_cost, throughput_fps)
from .dse import DSEConfig, DSEResult, pack_onchip, run_dse
from .plan import ExecutionPlan, LayerPlan, plan_from_dse, StreamPlan
from .builders import (build_unet, build_unet3d, build_unet_exec,
                       build_x3d_exec, build_x3d_m, build_yolo_head_exec,
                       build_yolov8n, exec_input_shape, get_model,
                       EXEC_MODELS, PAPER_MODELS, TABLE3)

__all__ = [n for n in dir() if not n.startswith("_")]
