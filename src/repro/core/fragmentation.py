"""Weight fragmentation (paper §III-B, Eq. 3-4).

A weighty vertex's parameter memory of depth ``d`` is fragmented into a
static on-chip region and a dynamic region streamed from off-chip through a
shared time-multiplexed buffer, with fragmentation ratio ``m in [0,1]``:

  Eq. 3   delta_d  = m * d
  Eq. 4   delta_BW = m * r * c

``r`` is the rate at which the pipeline consumes weights (words/cycle) and
``c`` the compile-time-known weight compression ratio (weights are static,
so unlike activations there is no runtime variability and no read-order
penalty: the stream is sequential, alpha = 0).
"""
from __future__ import annotations

import dataclasses

from . import compression
from .graph import Graph, Vertex, WEIGHTY


@dataclasses.dataclass
class FragmentationOption:
    vertex: str
    ratio: float                    # proposed *additional* m
    codec: str
    delta_depth_words: float        # Eq. 3
    delta_bw_words_per_cycle: float # Eq. 4
    onchip_bits_saved: float
    lut_cost: float

    @property
    def merit(self) -> float:
        if self.delta_bw_words_per_cycle <= 0:
            return float("inf")
        return self.onchip_bits_saved / self.delta_bw_words_per_cycle


def weight_consumption_rate(v: Vertex) -> float:
    """Words/cycle at which the compute pipeline reads this vertex's weights.

    A fully-pipelined engine re-reads the whole weight set once per frame:
    r = weight_words / lambda_v.
    """
    return v.weight_words / max(v.latency(), 1.0)


def evaluate_fragmentation(g: Graph, name: str, ratio_step: float = 0.125,
                           codec: str = "none") -> FragmentationOption | None:
    v = g.vertex(name)
    if v.kind not in WEIGHTY or v.weight_words <= 0:
        return None
    new_m = min(v.frag_ratio + ratio_step, 1.0)
    step = new_m - v.frag_ratio
    if step <= 0:
        return None
    c = compression.estimate_ratio(codec, v.weight_bits, sparsity=0.3)
    r = weight_consumption_rate(v)
    delta_d = step * v.weight_words          # Eq. 3
    delta_bw = step * r * c                  # Eq. 4
    return FragmentationOption(
        vertex=name, ratio=step, codec=codec,
        delta_depth_words=delta_d,
        delta_bw_words_per_cycle=delta_bw,
        onchip_bits_saved=delta_d * v.weight_bits,
        lut_cost=compression.CODEC_LUT_COST[codec],
    )


def candidate_fragmentations(g: Graph, codecs: tuple[str, ...] = ("none",),
                             ratio_step: float = 0.125) -> list[FragmentationOption]:
    opts: list[FragmentationOption] = []
    for v in g.vertices():
        per_v = [o for c in codecs
                 if (o := evaluate_fragmentation(g, v.name, ratio_step, c)) is not None]
        if per_v:
            opts.append(max(per_v, key=lambda o: o.merit))
    opts.sort(key=lambda o: o.merit, reverse=True)
    return opts


def apply_fragmentation(g: Graph, opt: FragmentationOption) -> None:
    v = g.vertex(opt.vertex)
    v.frag_ratio = min(v.frag_ratio + opt.ratio, 1.0)
    v.meta["frag_codec"] = opt.codec


def onchip_weight_bits(g: Graph) -> float:
    return sum(v.static_weight_bits() for v in g.vertices())


def fragmentation_bw_words(g: Graph) -> float:
    """Aggregate Eq. 4 bandwidth (words/cycle) of all applied fragmentation."""
    total = 0.0
    for v in g.vertices():
        if v.frag_ratio > 0:
            codec = v.meta.get("frag_codec", "none")
            c = compression.estimate_ratio(codec, v.weight_bits, sparsity=0.3)
            total += weight_consumption_rate(v) * v.frag_ratio * c
    return total
