"""Resource models: the paper's FPGA devices and the TPU v5e target.

SMOF's constraints (Eq. 7) are expressed against a device budget of
compute units, on-chip memory bits, and off-chip bandwidth.  We keep that
abstraction and provide two families of instances:

* the four AMD FPGA devices used in the paper's evaluation (§V), with
  DSP / BRAM18K / URAM / LUT / DDR-bandwidth budgets — used by the
  paper-faithful reproduction benchmarks;
* TPU v5e, in two *views* matching DESIGN.md §2:
    - ``TPU_V5E_KERNEL``:  on-chip = VMEM, off-chip = HBM   (Pallas level)
    - ``TPU_V5E_RUNTIME``: on-chip = HBM,  off-chip = host DRAM over PCIe
      (staged-executor / offload level).

Everything is per *device*; the distributed runtime multiplies by mesh size
and adds ICI terms separately (see launch/ and benchmarks/roofline.py).
"""
from __future__ import annotations

import dataclasses

BRAM18K_BITS = 18 * 1024
URAM_BITS = 288 * 1024


@dataclasses.dataclass(frozen=True)
class Device:
    """A SMOF-visible resource budget.

    compute_units:   MACs/cycle available (DSPs on FPGA, MXU lanes on TPU).
    onchip_bits:     total "on-chip" storage in bits (BRAM+URAM / VMEM / HBM).
    offchip_gbps:    usable "off-chip" bandwidth, Gbit/s (DDR / HBM / PCIe).
    luts:            logic budget; codecs charge against it (FPGA only —
                     TPU views set it to 0 and codec cost becomes compute).
    freq_mhz:        pipeline clock.
    reconfig_s:      full-device reconfiguration time ``t_r`` (bitstream load
                     on FPGA; stage weight-swap estimate on TPU).
    """
    name: str
    compute_units: float
    onchip_bits: float
    offchip_gbps: float
    luts: float = 0.0
    freq_mhz: float = 200.0
    reconfig_s: float = 0.05
    bram18k: int = 0
    uram: int = 0

    @property
    def cycles_per_s(self) -> float:
        return self.freq_mhz * 1e6

    def words_per_cycle_offchip(self, word_bits: int) -> float:
        """Off-chip bandwidth expressed in stream words per cycle."""
        return (self.offchip_gbps * 1e9) / (word_bits * self.cycles_per_s)


def _fpga(name, dsp, bram18k, uram, luts, ddr_gbps, freq=200.0, reconfig=0.06):
    # compute budget in MACs/cycle: DSP48E2 packs 2 x 8-bit MACs (paper's
    # designs quantise weights/activations to 8 bit, §V-A).
    return Device(
        name=name, compute_units=dsp * 2,
        onchip_bits=bram18k * BRAM18K_BITS + uram * URAM_BITS,
        offchip_gbps=ddr_gbps, luts=luts, freq_mhz=freq, reconfig_s=reconfig,
        bram18k=bram18k, uram=uram,
    )


# -- paper devices (§V, Table V) ---------------------------------------------
# DDR bandwidths: ZCU102 1x DDR4-2400 (~154 Gbps); U200/VCU1525/VCU118 are
# VU9P-class boards with 4x DDR4-2400 banks (~614 Gbps total, matching
# Fig. 4's "225 Gbps (37%)" annotation for the U200 design).
ZCU102 = _fpga("zcu102", dsp=2520, bram18k=1824, uram=0, luts=274_000,
               ddr_gbps=154.0, freq=200.0)
U200 = _fpga("u200", dsp=6840, bram18k=4320, uram=960, luts=1_182_000,
             ddr_gbps=614.0, freq=250.0)
VCU1525 = _fpga("vcu1525", dsp=6840, bram18k=4320, uram=960, luts=1_182_000,
                ddr_gbps=614.0, freq=200.0)
VCU118 = _fpga("vcu118", dsp=6840, bram18k=4320, uram=960, luts=1_182_000,
               ddr_gbps=614.0, freq=240.0)

FPGA_DEVICES = {d.name: d for d in (ZCU102, U200, VCU1525, VCU118)}


# -- TPU v5e (target hardware; constants from the brief) -----------------------
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BYTES = 16 * 2**30
HBM_GBPS = 819 * 8.0              # 819 GB/s
VMEM_BYTES = 128 * 2**20
ICI_GBPS_PER_LINK = 50 * 8.0      # ~50 GB/s/link
HOST_LINK_GBPS = 32 * 8.0         # PCIe-class host link
TPU_FREQ_MHZ = 940.0

# MACs/cycle that saturate the MXU: peak_flops / (2 * f).
_TPU_MACS_PER_CYCLE = PEAK_FLOPS_BF16 / (2 * TPU_FREQ_MHZ * 1e6)

TPU_V5E_KERNEL = Device(
    name="tpu_v5e_kernel", compute_units=_TPU_MACS_PER_CYCLE,
    onchip_bits=VMEM_BYTES * 8.0, offchip_gbps=HBM_GBPS,
    luts=0.0, freq_mhz=TPU_FREQ_MHZ, reconfig_s=0.0,
)
TPU_V5E_RUNTIME = Device(
    name="tpu_v5e_runtime", compute_units=_TPU_MACS_PER_CYCLE,
    onchip_bits=HBM_BYTES * 8.0, offchip_gbps=HOST_LINK_GBPS,
    luts=0.0, freq_mhz=TPU_FREQ_MHZ,
    reconfig_s=0.010,  # stage weight-swap latency budget (host->HBM)
)

ALL_DEVICES = dict(FPGA_DEVICES, tpu_v5e_kernel=TPU_V5E_KERNEL,
                   tpu_v5e_runtime=TPU_V5E_RUNTIME)


def get_device(name: str) -> Device:
    try:
        return ALL_DEVICES[name]
    except KeyError:
        raise KeyError(f"unknown device {name!r}; have {sorted(ALL_DEVICES)}") from None
