"""Graph builders for the paper's evaluated CNNs (Table III).

Structurally faithful reconstructions of UNet, UNet3D, YOLOv8n and X3D-M as
SMOF layer graphs — most importantly with the *long skip connections* whose
deep synchronisation buffers the eviction mechanism targets.  Channel
configurations follow the original papers; Table III's MAC/param counts are
matched by `benchmarks/table3_models.py` within a small tolerance (the paper
itself notes "optimised UNet architectures tailored to the HW design
(variations in MACs)").
"""
from __future__ import annotations

import math
from typing import Callable

from .graph import Graph, Vertex


class _B:
    """Small chain-building helper."""

    def __init__(self, g: Graph, word_bits: int = 8, weight_bits: int = 8):
        self.g = g
        self.wb = word_bits
        self.qb = weight_bits
        self.n = 0

    def _name(self, kind: str) -> str:
        self.n += 1
        return f"{kind}_{self.n}"

    def conv(self, prev: str | None, cin: int, cout: int, spatial: tuple[int, ...],
             k: int = 3, stride: int = 1, kind: str = "conv",
             groups: int = 1) -> tuple[str, tuple[int, ...]]:
        out_sp = tuple(max(s // stride, 1) for s in spatial)
        vol_out = math.prod(out_sp)
        kd = k ** len(spatial)
        macs = kd * (cin // groups) * cout * vol_out
        weights = kd * (cin // groups) * cout
        v = Vertex(self._name(kind), kind,
                   work_macs=macs, weight_words=weights,
                   in_words=cin * math.prod(spatial), out_words=cout * vol_out,
                   word_bits=self.wb, weight_bits=self.qb,
                   base_depth=k * out_sp[-1] * max(cin // groups, 1),
                   max_par=min(kd * cin * cout, 16384))
        self.g.add(v)
        if prev:
            self.g.connect(prev, v.name)
        return v.name, out_sp

    def simple(self, prev: str | list[str] | None, kind: str, cin: int,
               spatial: tuple[int, ...], cout: int | None = None,
               out_spatial: tuple[int, ...] | None = None,
               max_par: int = 64) -> tuple[str, tuple[int, ...]]:
        cout = cout or cin
        out_sp = out_spatial or spatial
        v = Vertex(self._name(kind), kind,
                   in_words=cin * math.prod(spatial),
                   out_words=cout * math.prod(out_sp),
                   word_bits=self.wb, base_depth=2.0, max_par=max_par)
        self.g.add(v)
        preds = [prev] if isinstance(prev, str) else (prev or [])
        for p in preds:
            self.g.connect(p, v.name)
        return v.name, out_sp


# -----------------------------------------------------------------------------
# UNet (Ronneberger et al.) — input (3, 368, 480); 4 skip connections
# -----------------------------------------------------------------------------

def build_unet(input_hw: tuple[int, int] = (368, 480), cin: int = 3,
               base: int = 64, levels: int = 5, n_classes: int = 32) -> Graph:
    g = Graph("unet")
    b = _B(g)
    inp, sp = b.simple(None, "input", cin, input_hw)
    skips: list[tuple[str, int, tuple[int, int]]] = []
    prev, c = inp, cin
    # encoder
    for lv in range(levels):
        cout = base * (2 ** lv)
        prev, sp = b.conv(prev, c, cout, sp)
        prev, sp = b.simple(prev, "act", cout, sp)
        prev, sp = b.conv(prev, cout, cout, sp)
        prev, sp = b.simple(prev, "act", cout, sp)
        c = cout
        if lv < levels - 1:
            skips.append((prev, c, sp))
            prev, sp = b.simple(prev, "pool", c, sp,
                                out_spatial=tuple(s // 2 for s in sp))
    # decoder with long skips
    for lv in reversed(range(levels - 1)):
        cout = base * (2 ** lv)
        prev, sp = b.conv(prev, c, cout, sp, k=2, kind="deconv")
        sp = tuple(s * 2 for s in sp)
        g.vertex(prev).out_words = cout * math.prod(sp)
        skip, sc, ssp = skips.pop()
        prev, sp = b.simple([skip, prev], "concat", cout + sc, sp)
        prev, sp = b.conv(prev, cout + sc, cout, sp)
        prev, sp = b.simple(prev, "act", cout, sp)
        prev, sp = b.conv(prev, cout, cout, sp)
        prev, sp = b.simple(prev, "act", cout, sp)
        c = cout
    prev, sp = b.conv(prev, c, n_classes, sp, k=1)
    b.simple(prev, "output", n_classes, sp)
    return g


# -----------------------------------------------------------------------------
# UNet3D (Cicek et al.) — input (4, 155, 240, 240)
# -----------------------------------------------------------------------------

def build_unet3d(input_dhw: tuple[int, int, int] = (155, 240, 240), cin: int = 4,
                 base: int = 10, levels: int = 5, max_ch: int = 160,
                 n_classes: int = 3) -> Graph:
    g = Graph("unet3d")
    b = _B(g)
    inp, sp = b.simple(None, "input", cin, input_dhw)
    skips: list[tuple[str, int, tuple[int, ...]]] = []
    prev, c = inp, cin
    for lv in range(levels):
        c1 = min(base * (2 ** lv), max_ch)
        c2 = min(c1 * 2, max_ch)
        prev, sp = b.conv(prev, c, c1, sp)
        prev, sp = b.simple(prev, "act", c1, sp)
        prev, sp = b.conv(prev, c1, c2, sp)
        prev, sp = b.simple(prev, "act", c2, sp)
        c = c2
        if lv < levels - 1:
            skips.append((prev, c, sp))
            prev, sp = b.simple(prev, "pool", c, sp,
                                out_spatial=tuple(max(s // 2, 1) for s in sp))
    for lv in reversed(range(levels - 1)):
        cout = min(base * (2 ** lv) * 2, max_ch)
        prev, sp = b.conv(prev, c, c, sp, k=2, kind="deconv")
        sp = tuple(s * 2 for s in sp)
        g.vertex(prev).out_words = c * math.prod(sp)
        skip, sc, ssp = skips.pop()
        sp = ssp
        prev, sp = b.simple([skip, prev], "concat", c + sc, sp)
        prev, sp = b.conv(prev, c + sc, cout, sp)
        prev, sp = b.simple(prev, "act", cout, sp)
        prev, sp = b.conv(prev, cout, cout, sp)
        prev, sp = b.simple(prev, "act", cout, sp)
        c = cout
    prev, sp = b.conv(prev, c, n_classes, sp, k=1)
    b.simple(prev, "output", n_classes, sp)
    return g


# -----------------------------------------------------------------------------
# YOLOv8n — input (3, 640, 640); CSP backbone + PAN neck (branchy)
# -----------------------------------------------------------------------------

def _c2f(b: _B, prev: str, c: int, sp, n: int = 1) -> tuple[str, tuple]:
    """C2f block: split, n bottlenecks with residual adds, concat, fuse."""
    half = max(c // 2, 8)
    top, _ = b.conv(prev, c, half, sp, k=1)
    bot, _ = b.conv(prev, c, half, sp, k=1)
    feats = [top, bot]
    cur = bot
    for _ in range(n):
        h1, _ = b.conv(cur, half, half, sp)
        h1, _ = b.simple(h1, "act", half, sp)
        h2, _ = b.conv(h1, half, half, sp)
        cur, _ = b.simple([cur, h2], "add", half, sp)
        feats.append(cur)
    cat, _ = b.simple(feats, "concat", half * len(feats), sp)
    out, sp = b.conv(cat, half * len(feats), c, sp, k=1)
    return out, sp


def build_yolov8n(input_hw: tuple[int, int] = (640, 640), cin: int = 3,
                  widths=(16, 32, 64, 128, 256), n_classes: int = 80) -> Graph:
    g = Graph("yolov8n")
    b = _B(g)
    inp, sp = b.simple(None, "input", cin, input_hw)
    prev, c = inp, cin
    pyramid: list[tuple[str, int, tuple]] = []
    for i, w in enumerate(widths):
        prev, sp = b.conv(prev, c, w, sp, stride=2)
        prev, sp = b.simple(prev, "act", w, sp)
        c = w
        if i >= 1:
            prev, sp = _c2f(b, prev, c, sp, n=2 if i in (2, 3) else 1)
        if i >= 2:
            pyramid.append((prev, c, sp))
    # SPPF: 1x1 squeeze, cascaded pools re-concatenated, 1x1 fuse
    p3, p4, p5 = pyramid
    sq, _ = b.conv(p5[0], p5[1], p5[1] // 2, p5[2], k=1)
    pools = [sq]
    cur = sq
    for _ in range(3):
        cur, _ = b.simple(cur, "pool", p5[1] // 2, p5[2])
        pools.append(cur)
    cat, _ = b.simple(pools, "concat", p5[1] * 2, p5[2])
    sppf, _ = b.conv(cat, p5[1] * 2, p5[1], p5[2], k=1)
    p5 = (sppf, p5[1], p5[2])
    # PAN neck: top-down then bottom-up with skip concats (long branches)
    up5, _ = b.simple(p5[0], "upsample", p5[1], p5[2],
                      out_spatial=tuple(s * 2 for s in p5[2]))
    cat4, _ = b.simple([p4[0], up5], "concat", p4[1] + p5[1], p4[2])
    n4, _ = _c2f(b, cat4, p4[1], p4[2])
    up4, _ = b.simple(n4, "upsample", p4[1], p4[2],
                      out_spatial=tuple(s * 2 for s in p4[2]))
    cat3, _ = b.simple([p3[0], up4], "concat", p3[1] + p4[1], p3[2])
    n3, _ = _c2f(b, cat3, p3[1], p3[2])
    d3, _ = b.conv(n3, p3[1], p3[1], p3[2], stride=2)
    cat4b, _ = b.simple([d3, n4], "concat", p3[1] + p4[1], p4[2])
    n4b, _ = _c2f(b, cat4b, p4[1], p4[2])
    d4, _ = b.conv(n4b, p4[1], p4[1], p4[2], stride=2)
    cat5, _ = b.simple([d4, p5[0]], "concat", p4[1] + p5[1], p5[2])
    n5, _ = _c2f(b, cat5, p5[1], p5[2])
    # decoupled detect head: box + cls branch per scale
    outs = []
    hw_box, hw_cls = 64, 64
    for hd, cch, hsp in ((n3, p3[1], p3[2]), (n4b, p4[1], p4[2]), (n5, p5[1], p5[2])):
        bx, _ = b.conv(hd, cch, hw_box, hsp)
        bx, _ = b.conv(bx, hw_box, hw_box, hsp)
        bx, _ = b.conv(bx, hw_box, 4 * 16, hsp, k=1)
        cl, _ = b.conv(hd, cch, hw_cls, hsp)
        cl, _ = b.conv(cl, hw_cls, n_classes, hsp, k=1)
        o, _ = b.simple([bx, cl], "concat", 64 + n_classes, hsp)
        outs.append(o)
    b.simple(outs, "output", 3 * (64 + n_classes), p3[2])
    return g


# -----------------------------------------------------------------------------
# X3D-M — input (3, 16, 256, 256); mobile inverted-bottleneck 3D stages
# -----------------------------------------------------------------------------

def build_x3d_m(frames: int = 16, hw: int = 256, cin: int = 3,
                stage_channels=(24, 48, 96, 192), stage_depths=(3, 5, 11, 7),
                expansion: float = 2.25, n_classes: int = 101) -> Graph:
    g = Graph("x3d_m")
    b = _B(g)
    sp = (frames, hw, hw)
    inp, sp = b.simple(None, "input", cin, sp)
    # stem: 1x3x3 spatial + 3x1x1 temporal (approximated as two convs)
    prev, sp = b.conv(inp, cin, 24, (sp[1], sp[2]), stride=2)
    sp = (frames, hw // 2, hw // 2)
    g.vertex(prev).out_words = 24 * math.prod(sp)
    c = 24
    for ci, (w, d) in enumerate(zip(stage_channels, stage_depths)):
        for blk in range(d):
            stride = 2 if blk == 0 else 1          # every stage downsamples
            mid = int(w * expansion)
            res = prev
            h, _ = b.conv(prev, c, mid, sp, k=1)
            h, _ = b.simple(h, "act", mid, sp)
            out_sp = (sp[0], max(sp[1] // stride, 1), max(sp[2] // stride, 1))
            h, _ = b.conv(h, mid, mid, sp, k=3, stride=1, kind="dwconv", groups=mid)
            g.vertex(h).out_words = mid * math.prod(out_sp)
            sp2 = out_sp
            h, _ = b.simple(h, "act", mid, sp2)
            if blk % 2 == 0:                       # SE on alternate blocks
                se1, _ = b.conv(h, mid, max(mid // 16, 4), (1, 1, 1), k=1)
                se2, _ = b.conv(se1, max(mid // 16, 4), mid, (1, 1, 1), k=1)
                h, _ = b.simple([h, se2], "add", mid, sp2)
            h, _ = b.conv(h, mid, w, sp2, k=1)
            if stride == 1 and c == w:
                prev, _ = b.simple([res, h], "add", w, sp2)
            else:
                prev = h
            sp, c = sp2, w
    prev, _ = b.conv(prev, c, int(c * expansion), sp, k=1)
    c = int(c * expansion)
    prev, _ = b.simple(prev, "pool", c, sp, out_spatial=(1, 1, 1))
    prev, _ = b.conv(prev, c, 2048, (1, 1, 1), k=1)
    prev, _ = b.conv(prev, 2048, n_classes, (1, 1, 1), k=1)
    b.simple(prev, "output", n_classes, (1, 1, 1))
    return g


# -----------------------------------------------------------------------------
# Executable graphs (runtime/executor.py targets)
#
# The builders above are *cost-model* reconstructions at paper scale; the
# ``*_exec`` builders below emit small graphs whose vertices additionally
# carry ``meta["exec"]`` — the channel spec the executable lowering needs.
# Tensors flow as (positions, channels) f32 stripes; conv acts as a 1x1
# channel-mixing matmul, pool/upsample halve/double the position axis, and
# the long encoder->decoder skips create exactly the deep synchronisation
# buffers the paper's eviction mechanism attacks (§III-A).
#
# Channels are kept multiples of the BFP8 block (32) so an evicted stream's
# spill traffic hits the compile-time c_bar = (8 + 8/32)/word_bits exactly.
# -----------------------------------------------------------------------------

class _XB(_B):
    """Chain builder that also records the executable channel spec."""

    def xconv(self, prev: str | None, cin: int, cout: int, m: int,
              kind: str = "conv") -> str:
        name, _ = self.conv(prev, cin, cout, (m,), k=1, kind=kind)
        self.g.vertex(name).meta["exec"] = {"cin": cin, "cout": cout, "m": m}
        return name

    def xsimple(self, prev, kind: str, c: int, m: int, cout: int | None = None,
                m_out: int | None = None) -> str:
        name, _ = self.simple(prev, kind, c, (m,), cout=cout,
                              out_spatial=(m_out,) if m_out else None)
        self.g.vertex(name).meta["exec"] = {
            "cin": c, "cout": cout or c, "m": m, "m_out": m_out or m}
        return name

    def xdwconv(self, prev: str, c: int, m: int, taps: int = 3) -> str:
        """Depthwise temporal conv: per-channel mixing of ``taps`` adjacent
        positions (the 3x1x1 temporal kernel of X3D's 3D blocks, with the
        frame axis flattened into the position axis)."""
        name, _ = self.conv(prev, c, c, (m,), k=taps, kind="dwconv",
                            groups=c)
        self.g.vertex(name).meta["exec"] = {"cin": c, "cout": c, "m": m,
                                            "taps": taps}
        return name


def build_unet_exec(positions: int = 64, cin: int = 32, base: int = 32,
                    levels: int = 3, n_classes: int = 32) -> Graph:
    """UNet-style encoder/decoder with long skip concats, executable form.

    ``positions`` is the flattened spatial extent at full resolution; each
    pool halves it, each decoder upsample doubles it back, and every
    encoder level's output rides a long skip to the matching decoder
    concat — the topology whose synchronisation buffers SMOF evicts.
    """
    assert positions % (2 ** (levels - 1)) == 0
    g = Graph("unet_exec")
    b = _XB(g, word_bits=16, weight_bits=16)
    m = positions
    prev = b.xsimple(None, "input", cin, m)
    skips: list[tuple[str, int, int]] = []
    c = cin
    for lv in range(levels):
        cout = base * (2 ** lv)
        prev = b.xconv(prev, c, cout, m)
        prev = b.xsimple(prev, "act", cout, m)
        c = cout
        if lv < levels - 1:
            skips.append((prev, c, m))
            prev = b.xsimple(prev, "pool", c, m, m_out=m // 2)
            m //= 2
    for lv in reversed(range(levels - 1)):
        cout = base * (2 ** lv)
        prev = b.xsimple(prev, "upsample", c, m, m_out=m * 2)
        m *= 2
        prev = b.xconv(prev, c, cout, m, kind="deconv")
        skip, sc, sm = skips.pop()
        assert sm == m, (sm, m)
        prev = b.xsimple([skip, prev], "concat", sc + cout, m)
        prev = b.xconv(prev, sc + cout, cout, m)
        prev = b.xsimple(prev, "act", cout, m)
        c = cout
    prev = b.xconv(prev, c, n_classes, m)
    b.xsimple(prev, "output", n_classes, m)
    return g


def build_yolo_head_exec(positions: int = 64,
                         widths: tuple[int, int, int] = (32, 64, 128),
                         head: int = 32) -> Graph:
    """YOLO-style multi-scale detection head, executable form.

    A small backbone emits a three-level pyramid (P3/P4/P5); the PAN-style
    neck runs top-down then bottom-up with cross-scale concats, so pyramid
    features persist across many downstream layers — long branches with
    deep buffers, like the UNet skips but re-converging at several scales.
    """
    assert positions % 4 == 0
    g = Graph("yolo_head_exec")
    b = _XB(g, word_bits=16, weight_bits=16)
    m = positions
    prev = b.xsimple(None, "input", widths[0], m)
    pyramid: list[tuple[str, int, int]] = []
    c = widths[0]
    for i, w in enumerate(widths):
        prev = b.xconv(prev, c, w, m)
        prev = b.xsimple(prev, "act", w, m)
        c = w
        pyramid.append((prev, c, m))
        if i < len(widths) - 1:
            prev = b.xsimple(prev, "pool", c, m, m_out=m // 2)
            m //= 2
    (p3, c3, m3), (p4, c4, m4), (p5, c5, m5) = pyramid
    # top-down
    up5 = b.xsimple(p5, "upsample", c5, m5, m_out=m4)
    cat4 = b.xsimple([p4, up5], "concat", c4 + c5, m4)
    n4 = b.xconv(cat4, c4 + c5, c4, m4)
    up4 = b.xsimple(n4, "upsample", c4, m4, m_out=m3)
    cat3 = b.xsimple([p3, up4], "concat", c3 + c4, m3)
    n3 = b.xconv(cat3, c3 + c4, c3, m3)
    # bottom-up
    d3 = b.xsimple(n3, "pool", c3, m3, m_out=m4)
    cat4b = b.xsimple([d3, n4], "concat", c3 + c4, m4)
    n4b = b.xconv(cat4b, c3 + c4, c4, m4)
    d4 = b.xsimple(n4b, "pool", c4, m4, m_out=m5)
    cat5 = b.xsimple([d4, p5], "concat", c4 + c5, m5)
    n5 = b.xconv(cat5, c4 + c5, c5, m5)
    # decoupled per-scale heads
    outs = []
    for hd, cch, hm in ((n3, c3, m3), (n4b, c4, m4), (n5, c5, m5)):
        h1 = b.xconv(hd, cch, head, hm)
        h1 = b.xsimple(h1, "act", head, hm)
        h2 = b.xconv(h1, head, head, hm)
        outs.append(h2)
    out = b.xsimple(outs, "output", head, m3)
    # the sink consumes all three scales, not just the m3 stripe
    g.vertex(out).in_words = head * (m3 + m4 + m5)
    return g


def build_x3d_exec(positions: int = 64, cin: int = 32,
                   widths: tuple[int, ...] = (32, 64), depth: int = 2,
                   expansion: int = 2, n_classes: int = 32) -> Graph:
    """X3D-style temporal residual network, executable form.

    The position axis is the flattened (frames, spatial) extent; each stage
    is a chain of mobile-inverted-bottleneck blocks — 1x1 expand, depthwise
    *temporal* conv (``dwconv`` mixes adjacent positions per channel),
    squeeze-excitation (global pool -> bottleneck -> broadcast ``mul``), 1x1
    project — with residual adds.  Two long-buffer topologies for eviction
    to attack: the SE side branches re-converge after the whole excitation
    chain, and the stem output rides a temporal-feature-bank skip across
    every stage to a final concat (the deepest synchronisation buffer, like
    UNet's encoder->decoder skips but over the time axis).

    Channels stay multiples of the BFP8 block (32) so evicted streams hit
    the compile-time ``c_bar`` exactly.
    """
    assert positions % (2 ** (len(widths) - 1)) == 0
    g = Graph("x3d_exec")
    b = _XB(g, word_bits=16, weight_bits=16)
    m = positions
    inp = b.xsimple(None, "input", cin, m)
    # stem: 1x1 channel mix + temporal dwconv
    prev = b.xconv(inp, cin, widths[0], m)
    prev = b.xdwconv(prev, widths[0], m)
    stem = prev = b.xsimple(prev, "act", widths[0], m)
    c = widths[0]
    for si, w in enumerate(widths):
        if si > 0:                               # downsample between stages
            prev = b.xsimple(prev, "pool", c, m, m_out=m // 2)
            m //= 2
        mid = w * expansion
        for blk in range(depth):
            res = prev
            h = b.xconv(prev, c, mid, m)
            h = b.xsimple(h, "act", mid, m)
            h = b.xdwconv(h, mid, m)
            if blk % 2 == 0:                     # SE on alternate blocks
                se = b.xsimple(h, "pool", mid, m, m_out=1)      # global pool
                se = b.xconv(se, mid, 32, 1)
                se = b.xsimple(se, "act", 32, 1)
                se = b.xconv(se, 32, mid, 1)
                h = b.xsimple([h, se], "mul", mid, m)           # broadcast
            h = b.xconv(h, mid, w, m)
            prev = b.xsimple([res, h], "add", w, m) if c == w else h
            c = w
    # temporal feature bank: the stem output skips every stage, pooled down
    # to the final temporal resolution, and fuses by concat
    bank = stem
    bm = positions
    while bm > m:
        bank = b.xsimple(bank, "pool", widths[0], bm, m_out=bm // 2)
        bm //= 2
    prev = b.xsimple([bank, prev], "concat", widths[0] + c, m)
    prev = b.xconv(prev, widths[0] + c, n_classes, m)
    b.xsimple(prev, "output", n_classes, m)
    return g


EXEC_MODELS = {
    "unet_exec": build_unet_exec,
    "yolo_head_exec": build_yolo_head_exec,
    "x3d_exec": build_x3d_exec,
}


PAPER_MODELS = {
    "unet": build_unet,
    "unet3d": build_unet3d,
    "yolov8n": build_yolov8n,
    "x3d_m": build_x3d_m,
}


def get_model(name: str, registry: dict | None = None) -> Callable[..., Graph]:
    """The one registry lookup: executable (``*_exec``) and paper-scale
    cost-model builders by name, with a helpful error.

    ``registry`` narrows the search to one family (``EXEC_MODELS`` /
    ``PAPER_MODELS``); by default both are searched, exec first.
    """
    spaces = [registry] if registry is not None else [EXEC_MODELS, PAPER_MODELS]
    for space in spaces:
        if name in space:
            return space[name]
    known = sorted(set().union(*spaces))
    raise KeyError(f"unknown model {name!r}; known models: {', '.join(known)}")


def exec_input_shape(g: Graph) -> tuple[int, int]:
    """The (positions, channels) input stripe shape of an executable graph."""
    for v in g.vertices():
        if v.kind == "input":
            spec = v.meta.get("exec")
            if spec is None:
                raise ValueError(
                    f"graph {g.name!r} has no executable input spec — use a "
                    f"build_*_exec builder (see EXEC_MODELS)")
            return (spec["m"], spec["cin"])
    raise ValueError(f"graph {g.name!r} has no input vertex")

# Table III reference values (MACs in G, params in M) for validation.
TABLE3 = {
    "yolov8n": {"macs_g": 4.37, "params_m": 3.16, "layers": 115, "convs": 63},
    "unet": {"macs_g": 130.12, "params_m": 28.96, "layers": 53, "convs": 23},
    "unet3d": {"macs_g": 918.64, "params_m": 5.65, "layers": 52, "convs": 19},
    "x3d_m": {"macs_g": 6.97, "params_m": 3.82, "layers": 396, "convs": 115},
}
