"""ExecutionPlan — the contract between the SMOF DSE and the TPU runtime.

The DSE (core/dse.py) reasons about an abstract device; this module projects
its decisions onto concrete knobs the JAX runtime understands:

* partition list      -> staged-executor stages / PP stage boundaries
* eviction decisions  -> which long-lived streams (KV cache, encoder output,
                         1F1B stashes) are offloaded + their codec
* fragmentation m     -> per-layer static VMEM fraction for the
                         ``streamed_matmul`` kernel / host weight streaming
* parallelism p       -> per-layer sharding hints (TP width)
* remat policy        -> store / recompute / offload per activation class
"""
from __future__ import annotations

import dataclasses
import json
import logging
from typing import Any

from .dse import DSEResult

_LOG = logging.getLogger(__name__)

# On-disk plan format version.  Bump when ExecutionPlan/LayerPlan/StreamPlan
# gain or change serialised fields; ``from_json`` migrates older payloads
# forward (v1 = pre-provenance plans, before schema_version existed).
PLAN_SCHEMA_VERSION = 2


class PlanValidationError(ValueError):
    """A structurally invalid :class:`ExecutionPlan`.

    Raised by :meth:`ExecutionPlan.validate` (and therefore by
    ``from_json`` and the compile façade for manual plans) instead of
    letting a malformed decision vector reach the lowering, where it
    would surface as an opaque crash deep inside the pipelined streamer
    (a backwards stage crossing, for example, would otherwise build a
    negative-depth shift register)."""


def _known_fields(cls) -> set[str]:
    return {f.name for f in dataclasses.fields(cls)}


def _shim_kwargs(cls, d: dict, dropped: list[str], scope: str) -> dict:
    """Migration shim: keep the keys ``cls`` knows, *collect* the rest.

    Plans serialised by newer versions of the toolflow still load (forward
    compatibility of the on-disk format), but unlike a silent filter every
    dropped key is recorded in ``dropped`` (and logged by ``from_json``), so
    forward-compat events are observable instead of invisible data loss."""
    known = _known_fields(cls)
    for k in d:
        if k not in known:
            dropped.append(f"{scope}.{k}")
    return {k: v for k, v in d.items() if k in known}


@dataclasses.dataclass
class LayerPlan:
    name: str
    stage: int = 0
    tp_parallelism: int = 1
    weight_static_fraction: float = 1.0    # 1 - m
    weight_stream_codec: str = "none"


@dataclasses.dataclass
class StreamPlan:
    src: str
    dst: str
    evicted: bool = False
    codec: str = "none"


@dataclasses.dataclass
class ExecutionPlan:
    model: str
    device: str
    n_stages: int
    layers: dict[str, LayerPlan]
    streams: list[StreamPlan]
    remat: str = "none"                    # none | dots | full | offload
    microbatch: int = 1
    est_throughput_fps: float = 0.0
    est_latency_s: float = 0.0
    # Deterministic schedule order: the graph's topological order at plan
    # time.  Dict-insertion order of ``layers`` is an accident of how the
    # partitioner walked the graph; the pipelined streamer needs a stable
    # stage-internal schedule, so ``stage_layers`` sorts by this list when
    # present (layers not in the list keep insertion order, appended last).
    topo_order: list[str] = dataclasses.field(default_factory=list)
    # Pallas kernel tile sizes for the streaming_conv bodies (0 = kernel
    # default): row block per grid step and, for the conv family, the
    # out-channel block.  Results are tile-independent (bit-exact for any
    # value — tests/test_properties.py), so these are pure performance
    # knobs the autotuner's "tile" move explores for pallas candidates.
    tile_bm: int = 0
    tile_bc: int = 0
    # On-disk format version + provenance of the decisions.  ``provenance``
    # is free-form JSON the toolflow stamps at compile time (strategy,
    # device name, calibration s_per_cycle, autotune trajectory digest, ...)
    # so a saved artifact explains where its decisions came from.
    schema_version: int = PLAN_SCHEMA_VERSION
    provenance: dict[str, Any] = dataclasses.field(default_factory=dict)

    # keys the from_json migration shim dropped (newer-writer forward
    # compat); instance attribute set by from_json, never serialised
    dropped_keys: tuple[str, ...] = dataclasses.field(
        default=(), repr=False, compare=False, metadata={"transient": True})

    # -- serialisation --------------------------------------------------------
    def to_json(self) -> str:
        def enc(o: Any):
            if dataclasses.is_dataclass(o):
                return dataclasses.asdict(o)
            raise TypeError(type(o))
        d = dataclasses.asdict(self)
        d.pop("dropped_keys", None)            # transient, not on-disk format
        return json.dumps(d, default=enc, indent=1)

    @staticmethod
    def from_json(s: str) -> "ExecutionPlan":
        raw = json.loads(s)
        # v1 = pre-versioning plans (no schema_version field).  The loaded
        # plan is migrated to the *current* in-memory shape, so it carries
        # the current schema_version; the original is recorded in
        # provenance so the migration stays observable on re-serialise.
        orig_version = raw.get("schema_version", 1)
        raw["schema_version"] = PLAN_SCHEMA_VERSION
        dropped: list[str] = []
        d = _shim_kwargs(ExecutionPlan, raw, dropped, "plan")
        d["layers"] = {
            k: LayerPlan(**_shim_kwargs(LayerPlan, v, dropped, f"layers[{k}]"))
            for k, v in d["layers"].items()}
        d["streams"] = [
            StreamPlan(**_shim_kwargs(StreamPlan, v, dropped, f"streams[{i}]"))
            for i, v in enumerate(d["streams"])]
        plan = ExecutionPlan(**d)
        plan.dropped_keys = tuple(dropped)
        if orig_version != PLAN_SCHEMA_VERSION:
            plan.provenance.setdefault("migrated_from_schema_version",
                                       orig_version)
        if dropped:
            _LOG.warning(
                "ExecutionPlan.from_json (model=%r, schema v%s): dropped %d "
                "unknown key(s) written by a newer toolflow: %s",
                plan.model, orig_version, len(dropped), ", ".join(dropped))
        plan.validate()
        return plan

    # -- structural validation ------------------------------------------------
    def validate(self) -> None:
        """Reject decision vectors the lowering cannot execute.

        Checks the *plan-only* invariants (no graph needed): stage indices
        live in ``[0, n_stages)``, stage bounds are monotonic along every
        stream (an edge whose destination sits on an *earlier* stage than
        its source cannot be scheduled — the pipelined carry would need a
        negative delay), fragmentation fractions are in ``[0, 1]``, and the
        microbatch count is positive.  ``from_json`` calls this, so a
        corrupt or hand-edited artifact fails here with a typed
        :class:`PlanValidationError` instead of crashing the streamer.
        """
        errs: list[str] = []
        if self.n_stages < 1:
            errs.append(f"n_stages must be >= 1, got {self.n_stages}")
        if self.microbatch < 1:
            errs.append(f"microbatch must be >= 1, got {self.microbatch}")
        if self.tile_bm < 0:
            errs.append(f"tile_bm must be >= 0, got {self.tile_bm}")
        if self.tile_bc < 0:
            errs.append(f"tile_bc must be >= 0, got {self.tile_bc}")
        for name, lp in self.layers.items():
            if not 0 <= lp.stage < max(self.n_stages, 1):
                errs.append(f"layer {name!r} on stage {lp.stage}, outside "
                            f"[0, {self.n_stages})")
            if not 0.0 <= lp.weight_static_fraction <= 1.0:
                errs.append(f"layer {name!r} weight_static_fraction "
                            f"{lp.weight_static_fraction} outside [0, 1]")
            if lp.tp_parallelism < 1:
                errs.append(f"layer {name!r} tp_parallelism "
                            f"{lp.tp_parallelism} < 1")
        for s in self.streams:
            su, sv = self.layers.get(s.src), self.layers.get(s.dst)
            if su is not None and sv is not None and sv.stage < su.stage:
                errs.append(
                    f"stream {s.src}->{s.dst} crosses stages backwards "
                    f"({su.stage} -> {sv.stage}): stage bounds must be "
                    f"monotonic along every edge")
        if errs:
            raise PlanValidationError(
                f"invalid ExecutionPlan for model {self.model!r}: "
                + "; ".join(errs))

    def _order_key(self):
        pos = {n: i for i, n in enumerate(self.topo_order)}
        return lambda n: (pos.get(n, len(pos)),)

    def ordered_layers(self) -> list[str]:
        """All layer names in deterministic (topological) schedule order."""
        return sorted(self.layers, key=self._order_key())

    def stage_layers(self, stage: int) -> list[str]:
        return [n for n in self.ordered_layers()
                if self.layers[n].stage == stage]


def plan_from_dse(model: str, device: str, res: DSEResult,
                  remat: str = "none", microbatch: int = 1) -> ExecutionPlan:
    """Project a DSEResult into an ExecutionPlan."""
    g = res.partitioning.graph
    topo = g.topo()
    stage_of = {n: i for i, p in enumerate(res.partitioning.parts) for n in p}
    layers: dict[str, LayerPlan] = {}
    for n in topo:                         # deterministic insertion order too
        v = g.vertex(n)
        layers[n] = LayerPlan(
            name=n, stage=stage_of[n], tp_parallelism=v.par,
            weight_static_fraction=1.0 - v.frag_ratio,
            weight_stream_codec=v.meta.get("frag_codec", "none"),
        )
    streams = [StreamPlan(e.src, e.dst, e.evicted, e.codec) for e in g.edges()]
    return ExecutionPlan(
        model=model, device=device, n_stages=res.partitioning.n,
        layers=layers, streams=streams, remat=remat, microbatch=microbatch,
        est_throughput_fps=res.throughput_fps, est_latency_s=res.latency_s,
        topo_order=topo,
    )
