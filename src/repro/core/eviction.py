"""Activation eviction (paper §III-A, Eq. 1-2).

A deep on-chip buffer of depth ``d_b`` on an edge is replaced by two small
DMA-burst FIFOs of total depth ``d_b'`` plus an off-chip spill region.  The
saving and cost:

  Eq. 1   delta_d = d_b - d_b'     valid iff d_b > max(d_b', t_db)
  Eq. 2   delta_BW = r * c_bar * (1 + alpha)

``r`` is the stream's average data rate (words/cycle), ``c_bar`` the average
compression ratio of the chosen codec, and ``alpha >= 1`` penalises the read
bandwidth when the read order differs from the write order (random access).
"""
from __future__ import annotations

import dataclasses

from . import compression
from .graph import Graph

# Two DMA-burst FIFOs; sized for a 64-beat burst each (words).
DMA_FIFO_DEPTH = 128.0
# DMA round-trip delay ``t_db`` in cycles (queue + DDR/PCIe latency).
DMA_DELAY_CYCLES = 256.0


@dataclasses.dataclass
class EvictionOption:
    """One candidate eviction with its Eq. 1/2 terms."""
    edge: tuple[str, str]
    codec: str
    delta_depth_words: float        # Eq. 1 (in words)
    delta_bw_words_per_cycle: float # Eq. 2 (words/cycle, read+write)
    onchip_bits_saved: float        # L * delta_d
    lut_cost: float
    feasible: bool

    @property
    def merit(self) -> float:
        """The DSE ordering heuristic ``L * delta_d / delta_BW`` (§IV-B pass 4)."""
        if self.delta_bw_words_per_cycle <= 0:
            return float("inf")
        return self.onchip_bits_saved / self.delta_bw_words_per_cycle


def evaluate_eviction(g: Graph, src: str, dst: str, codec: str = "none",
                      sparsity: float = 0.5, alpha: float = 1.0,
                      fifo_depth: float = DMA_FIFO_DEPTH,
                      dma_delay: float = DMA_DELAY_CYCLES) -> EvictionOption:
    """Evaluate evicting the (src, dst) stream to off-chip memory."""
    e = g.edge(src, dst)
    sv = g.vertex(src)
    d_b = e.buffer_depth
    d_b_prime = 2.0 * fifo_depth
    feasible = d_b > max(d_b_prime, dma_delay)          # Eq. 1 constraint
    delta_d = max(d_b - d_b_prime, 0.0)
    c_bar = compression.estimate_ratio(codec, e.word_bits, sparsity=sparsity)
    r = sv.rate_out()
    delta_bw = r * c_bar * (1.0 + alpha)                # Eq. 2
    return EvictionOption(
        edge=(src, dst), codec=codec,
        delta_depth_words=delta_d,
        delta_bw_words_per_cycle=delta_bw,
        onchip_bits_saved=delta_d * e.word_bits,
        lut_cost=compression.CODEC_LUT_COST[codec] * 2,  # encode + decode
        feasible=feasible,
    )


def candidate_evictions(g: Graph, codecs: tuple[str, ...] = ("none",),
                        sparsity: float = 0.5, alpha: float = 1.0) -> list[EvictionOption]:
    """All feasible evictions on all edges, best codec per edge first."""
    opts: list[EvictionOption] = []
    for e in g.edges():
        if e.evicted:
            continue
        per_edge = [evaluate_eviction(g, e.src, e.dst, codec=c,
                                      sparsity=sparsity, alpha=alpha)
                    for c in codecs]
        per_edge = [o for o in per_edge if o.feasible and o.delta_depth_words > 0]
        if per_edge:
            opts.append(max(per_edge, key=lambda o: o.merit))
    opts.sort(key=lambda o: o.merit, reverse=True)
    return opts


def apply_eviction(g: Graph, opt: EvictionOption,
                   fifo_depth: float = DMA_FIFO_DEPTH) -> None:
    e = g.edge(*opt.edge)
    e.evicted = True
    e.codec = opt.codec
    e.buffer_depth = 2.0 * fifo_depth


def onchip_buffer_bits(g: Graph) -> float:
    """Total on-chip FIFO storage currently required by the graph's edges."""
    return sum(e.buffer_depth * e.word_bits for e in g.edges())


def eviction_bw_words(g: Graph, sparsity: float = 0.5, alpha: float = 1.0) -> float:
    """Aggregate Eq. 2 bandwidth (words/cycle) of all applied evictions."""
    total = 0.0
    for e in g.edges():
        if e.evicted:
            c_bar = compression.estimate_ratio(e.codec, e.word_bits, sparsity=sparsity)
            total += g.vertex(e.src).rate_out() * c_bar * (1.0 + alpha)
    return total
