"""LM architectures as SMOF graphs — the paper's DSE driving the TPU runtime.

Each transformer/SSM layer becomes a vertex chain (qkv -> attn -> o -> ffn /
router -> experts), KV caches and long-lived streams become edges with deep
buffers, and the device is TPU_V5E_RUNTIME (on-chip = HBM, off-chip = host
DRAM).  The DSE's outputs map onto runtime knobs via core.plan:

  subgraph partition  -> StagedExecutor stages
  fragmentation m     -> host weight streaming fraction / streamed_matmul
                         static fraction
  eviction flags      -> KV / boundary-stream host offload (+BFP8 codec)

Word units: one "word" = one bf16 element; one cycle = 1/f at 940 MHz.
"""
from __future__ import annotations

from repro.models.config import ArchConfig

from .graph import Graph, Vertex


def _tokens(batch: int, seq: int) -> int:
    return batch * seq


def build_lm_graph(cfg: ArchConfig, *, batch: int, seq: int,
                   kind: str = "prefill") -> Graph:
    """Layer-level SMOF graph for one (arch x shape) workload.

    ``kind``: prefill | decode.  Decode models one token against a cache of
    ``seq`` (the cache is the deep "buffer" an eviction can spill).
    """
    g = Graph(f"{cfg.name}:{kind}")
    d, hd = cfg.d_model, cfg.hd
    toks = _tokens(batch, seq if kind == "prefill" else 1)
    cache_words = batch * seq * cfg.n_kv_heads * hd * 2

    inp = g.add(Vertex("input", "input", in_words=toks * d,
                       out_words=toks * d, word_bits=16))
    emb = g.add(Vertex("embed", "embed", work_macs=0,
                       weight_words=cfg.vocab * d, weight_bits=16,
                       in_words=toks, out_words=toks * d,
                       base_depth=2, max_par=4096))
    g.connect("input", "embed", words=toks)
    prev = emb.name

    for i in range(cfg.n_layers):
        kind_i = cfg.layer_kind(i)
        lid = f"L{i}"
        if kind_i == "attn":
            qkv_w = d * (cfg.n_heads + 2 * cfg.n_kv_heads) * hd
            qkv = g.add(Vertex(f"{lid}.qkv", "matmul",
                               work_macs=toks * qkv_w, weight_words=qkv_w,
                               weight_bits=16, in_words=toks * d,
                               out_words=toks * (cfg.n_heads + 2 * cfg.n_kv_heads) * hd,
                               base_depth=d, max_par=1 << 17))
            g.connect(prev, qkv.name)
            att_macs = (toks * seq * cfg.n_heads * hd * 2 if kind == "prefill"
                        else toks * seq * cfg.n_heads * hd * 2)
            att = g.add(Vertex(f"{lid}.attn", "attention",
                               work_macs=att_macs,
                               in_words=toks * cfg.n_heads * hd,
                               out_words=toks * cfg.n_heads * hd,
                               base_depth=seq, max_par=1 << 15))
            e = g.connect(qkv.name, att.name)
            # the KV cache is THE deep buffer of LM serving: its residency
            # is what eviction trades against host bandwidth
            e.buffer_depth = float(cache_words)
            o = g.add(Vertex(f"{lid}.o", "matmul",
                             work_macs=toks * cfg.n_heads * hd * d,
                             weight_words=cfg.n_heads * hd * d,
                             weight_bits=16,
                             in_words=toks * cfg.n_heads * hd,
                             out_words=toks * d, base_depth=d,
                             max_par=1 << 17))
            g.connect(att.name, o.name)
            prev = o.name
        else:   # mamba / mlstm / slstm: one fused mixer vertex
            mix_w = cfg._mixer_params(kind_i)
            mix = g.add(Vertex(f"{lid}.{kind_i}", "ssm_scan",
                               work_macs=toks * mix_w, weight_words=mix_w,
                               weight_bits=16, in_words=toks * d,
                               out_words=toks * d, base_depth=d,
                               max_par=1 << 16))
            g.connect(prev, mix.name)
            prev = mix.name

        if cfg.d_ff > 0:
            mult = 3 if cfg.act in ("swiglu", "geglu") else 2
            if cfg.layer_is_moe(i):
                m = cfg.moe
                rt = g.add(Vertex(f"{lid}.router", "router",
                                  work_macs=toks * d * m.n_experts,
                                  weight_words=d * m.n_experts,
                                  weight_bits=16, in_words=toks * d,
                                  out_words=toks * m.n_experts,
                                  base_depth=2, max_par=4096))
                g.connect(prev, rt.name)
                exp_w = m.n_experts * mult * d * cfg.d_ff
                ex = g.add(Vertex(f"{lid}.experts", "expert",
                                  work_macs=toks * m.top_k * mult * d * cfg.d_ff,
                                  weight_words=exp_w, weight_bits=16,
                                  in_words=toks * d, out_words=toks * d,
                                  base_depth=cfg.d_ff, max_par=1 << 18))
                g.connect(rt.name, ex.name)
                # router->experts is bursty: deep reorder buffer
                g.edge(rt.name, ex.name).buffer_depth = float(
                    toks * m.top_k)
                prev = ex.name
            else:
                ff = g.add(Vertex(f"{lid}.ffn", "matmul",
                                  work_macs=toks * mult * d * cfg.d_ff,
                                  weight_words=mult * d * cfg.d_ff,
                                  weight_bits=16, in_words=toks * d,
                                  out_words=toks * d, base_depth=cfg.d_ff,
                                  max_par=1 << 18))
                g.connect(prev, ff.name)
                prev = ff.name

    head = g.add(Vertex("lm_head", "matmul",
                        work_macs=toks * d * cfg.vocab,
                        weight_words=(0 if cfg.tie_embeddings
                                      else cfg.vocab * d),
                        weight_bits=16, in_words=toks * d,
                        out_words=toks * cfg.vocab, base_depth=d,
                        max_par=1 << 17))
    g.connect(prev, head.name)
    out = g.add(Vertex("output", "output", in_words=toks * cfg.vocab,
                       out_words=toks * cfg.vocab))
    g.connect(head.name, out.name)
    return g
