"""Subgraph partitioning & reconfiguration (paper §III-C, Eq. 5-6).

The DAG is cut into N subgraphs scheduled sequentially on one device through
reconfiguration.  Each subgraph processes the whole batch ``b`` in streaming
mode, then the device is reprogrammed (``t_ri``):

  Eq. 5   t = sum_i (b * II_i + d_pi) / f  +  N * t_ri        [seconds]
  Eq. 6   Theta = b / t                                        [frames/s]

Constraints (Eq. 7): per-subgraph on-chip resources, off-chip bandwidth, and
compute dependency (producers of any vertex are in the same or an earlier
subgraph — guaranteed here by cutting along a topological order).
"""
from __future__ import annotations

import dataclasses

from . import eviction, fragmentation
from .graph import Graph
from .pipeline import initiation_interval, pipeline_depth
from .resources import Device


@dataclasses.dataclass
class Partitioning:
    """An ordered list of subgraphs, each a list of vertex names."""
    graph: Graph
    parts: list[list[str]]

    def __post_init__(self) -> None:
        self.validate()

    @property
    def n(self) -> int:
        return len(self.parts)

    def subgraphs(self) -> list[Graph]:
        return [self.graph.subgraph(p) for p in self.parts]

    def validate(self) -> None:
        """Compute-dependency constraint: producers same-or-earlier subgraph."""
        where: dict[str, int] = {}
        for i, p in enumerate(self.parts):
            for v in p:
                if v in where:
                    raise ValueError(f"vertex {v!r} assigned twice")
                where[v] = i
        missing = set(self.graph.g.nodes) - set(where)
        if missing:
            raise ValueError(f"unassigned vertices: {sorted(missing)[:5]}")
        for u, w in self.graph.g.edges:
            if where[u] > where[w]:
                raise ValueError(
                    f"dependency violation: {u!r} (part {where[u]}) feeds "
                    f"{w!r} (part {where[w]})")

    def boundary_words(self, i: int) -> tuple[float, float]:
        """(input, output) stream words crossing subgraph ``i``'s boundary."""
        mine = set(self.parts[i])
        w_in = w_out = 0.0
        for u, w in self.graph.g.edges:
            e = self.graph.edge(u, w)
            if u not in mine and w in mine:
                w_in += e.words
            elif u in mine and w not in mine:
                w_out += e.words
        return w_in, w_out


@dataclasses.dataclass
class SubgraphCost:
    ii_cycles: float
    depth_cycles: float
    compute_units: float
    onchip_bits: float
    bw_words_per_cycle: float    # eviction + fragmentation + boundary I/O
    lut_cost: float


def subgraph_cost(p: Partitioning, i: int, sparsity: float = 0.5,
                  alpha: float = 1.0) -> SubgraphCost:
    sg = p.graph.subgraph(p.parts[i])
    ii = initiation_interval(sg)
    # boundary streams always cross off-chip (subgraphs run one at a time)
    b_in, b_out = p.boundary_words(i)
    bw = (eviction.eviction_bw_words(sg, sparsity=sparsity, alpha=alpha)
          + fragmentation.fragmentation_bw_words(sg)
          + (b_in + b_out) / max(ii, 1.0))
    lut = sum(2 * _codec_lut(e.codec) for e in sg.edges() if e.evicted)
    lut += sum(_codec_lut(v.meta.get("frag_codec", "none"))
               for v in sg.vertices() if v.frag_ratio > 0)
    return SubgraphCost(
        ii_cycles=ii,
        depth_cycles=pipeline_depth(sg),
        compute_units=sum(v.compute_units() for v in sg.vertices()),
        onchip_bits=(fragmentation.onchip_weight_bits(sg)
                     + eviction.onchip_buffer_bits(sg)),
        bw_words_per_cycle=bw,
        lut_cost=lut,
    )


def _codec_lut(codec: str) -> float:
    from .compression import CODEC_LUT_COST
    return CODEC_LUT_COST.get(codec, 0)


def fits(cost: SubgraphCost, dev: Device, word_bits: int = 16,
         base_lut_frac: float = 0.55) -> bool:
    """Eq. 7 feasibility of one subgraph on ``dev``.

    ``base_lut_frac`` models the logic consumed by the compute pipeline
    itself; codecs charge on top of it (FPGA mode only — TPU views have
    ``luts == 0`` and skip the check).
    """
    if cost.compute_units > dev.compute_units:
        return False
    if cost.onchip_bits > dev.onchip_bits:
        return False
    if cost.bw_words_per_cycle > dev.words_per_cycle_offchip(word_bits):
        return False
    if dev.luts > 0 and cost.lut_cost > dev.luts * (1.0 - base_lut_frac):
        return False
    return True


def latency_s(p: Partitioning, dev: Device, batch: int,
              sparsity: float = 0.5, alpha: float = 1.0) -> float:
    """Eq. 5 — total latency of one batch through all subgraphs."""
    f = dev.cycles_per_s
    total = 0.0
    for i in range(p.n):
        c = subgraph_cost(p, i, sparsity=sparsity, alpha=alpha)
        total += (batch * c.ii_cycles + c.depth_cycles) / f
    # Eq. 5's N*t_ri term: a single-subgraph design keeps its bitstream
    # resident (Table V marks these "-"), so reconfiguration only costs
    # when the device is actually time-multiplexed.
    if p.n > 1:
        total += p.n * dev.reconfig_s
    return total


def throughput_fps(p: Partitioning, dev: Device, batch: int,
                   sparsity: float = 0.5, alpha: float = 1.0) -> float:
    """Eq. 6."""
    return batch / latency_s(p, dev, batch, sparsity=sparsity, alpha=alpha)


def initial_partition(g: Graph, cut_kinds: tuple[str, ...] | None = None) -> Partitioning:
    """DSE pass 1 seed: as many subgraphs as possible (resource-minimal).

    Cut after every vertex whose kind is in ``cut_kinds`` (None = cut
    everywhere), walking a topological order so dependencies hold.
    """
    topo = g.topo()
    parts: list[list[str]] = []
    cur: list[str] = []
    for v in topo:
        cur.append(v)
        if cut_kinds is None or g.vertex(v).kind in cut_kinds:
            parts.append(cur)
            cur = []
    if cur:
        parts.append(cur)
    return Partitioning(g, parts)


def merge(p: Partitioning, i: int) -> Partitioning:
    """Merge subgraphs i and i+1 (DSE pass 5 candidate)."""
    if not (0 <= i < p.n - 1):
        raise IndexError(i)
    parts = [list(x) for x in p.parts]
    parts[i] = parts[i] + parts[i + 1]
    del parts[i + 1]
    return Partitioning(p.graph, parts)
