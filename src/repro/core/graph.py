"""Layer-graph IR for SMOF.

The CNN / LM workload is abstracted to a DAG (paper §III-A): vertices are
operations (conv, pool, matmul, attention, ...) and edges are data streams
between them.  Every quantity the SMOF cost models need lives here:

* per-vertex: work (MACs), weight footprint, streaming rates, parallelism,
  latency ``lambda_v`` and pipeline depth ``rho_v``;
* per-edge: stream volume per frame, word width, and the *buffer depth*
  ``d_b`` required to synchronise branches (the quantity activation eviction
  attacks).

Units are kept abstract — "words" and "cycles" — so the same IR drives both
the FPGA-faithful reproduction (words = 8/16-bit fixed point, cycles at
200-250 MHz) and the TPU adaptation (words = bf16 elements, f = 940 MHz).
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Iterator

import networkx as nx

# Operation categories.  ``WEIGHTY`` ops own parameters and are candidates for
# weight fragmentation; ``BRANCH`` points create the deep buffers that
# activation eviction targets.
OP_KINDS = (
    "input", "output",
    "conv", "dwconv", "deconv", "pool", "upsample", "act", "norm",
    "add", "mul", "concat", "split", "matmul", "attention", "kv_append",
    "router", "expert", "ssm_scan", "embed", "reshape",
)
WEIGHTY = {"conv", "dwconv", "deconv", "matmul", "expert", "embed", "norm", "ssm_scan"}


@dataclasses.dataclass
class Vertex:
    """One streaming operation.

    Attributes
    ----------
    work_macs:       multiply-accumulates per frame (0 for data-movement ops).
    weight_words:    parameter words owned by this vertex.
    in_words:        input stream volume per frame (``sigma_v^in``).
    out_words:       output stream volume per frame.
    word_bits:       stream word width ``L`` (Eq. 4 heuristic uses it).
    base_depth:      intrinsic pipeline depth at parallelism 1 (``rho_v``
                     before rate scaling), e.g. a conv line buffer.
    min_par/max_par: legal parallelism range (``p`` in ``D_v``).
    """
    name: str
    kind: str
    work_macs: float = 0.0
    weight_words: float = 0.0
    in_words: float = 1.0
    out_words: float = 1.0
    word_bits: int = 16
    weight_bits: int = 8
    base_depth: float = 1.0
    min_par: int = 1
    max_par: int = 1
    # mutable design state (filled by the DSE) ------------------------------
    par: int = 1
    frag_ratio: float = 0.0          # m in [0,1], Eq. 3/4
    meta: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self) -> None:
        if self.kind not in OP_KINDS:
            raise ValueError(f"unknown op kind {self.kind!r} for vertex {self.name!r}")
        self.par = max(self.par, self.min_par)

    # -- performance models (fpgaConvNet-style, simplified) -----------------
    def latency(self, par: int | None = None) -> float:
        """``lambda_v``: cycles to stream one frame through this vertex."""
        p = self.par if par is None else par
        # Work-dominated ops are limited by MACs/cycle; movement ops by words.
        cycles_work = self.work_macs / max(p, 1)
        cycles_io = max(self.in_words, self.out_words) / max(p, 1)
        return max(cycles_work, cycles_io, 1.0)

    def depth(self, par: int | None = None) -> float:
        """``rho_v``: pipeline depth (cycles before the first output word)."""
        p = self.par if par is None else par
        return max(self.base_depth / max(p, 1), 1.0)

    def rate_in(self, par: int | None = None) -> float:
        """Standard input rate ``r_v^in`` in words/cycle."""
        return self.in_words / self.latency(par)

    def rate_out(self, par: int | None = None) -> float:
        return self.out_words / self.latency(par)

    # -- resource models ------------------------------------------------------
    def compute_units(self, par: int | None = None) -> float:
        """DSPs (FPGA) / MXU lanes (TPU) consumed at parallelism ``p``."""
        p = self.par if par is None else par
        return float(p) if self.work_macs > 0 else 0.0

    def static_weight_bits(self) -> float:
        """On-chip weight storage after fragmentation (Eq. 3 applied)."""
        return self.weight_words * (1.0 - self.frag_ratio) * self.weight_bits

    def weight_stream_words_per_frame(self) -> float:
        """Dynamic-region words fetched from off-chip per frame (Eq. 4's m*r)."""
        return self.weight_words * self.frag_ratio


@dataclasses.dataclass
class Edge:
    """A stream between two vertices.

    ``buffer_depth`` is ``d_b`` — the on-chip FIFO depth needed to absorb the
    latency mismatch between the two endpoints (deep for long skips).  It is
    computed by :func:`Graph.compute_buffer_depths` from the pipeline-depth
    model, and activation eviction replaces it with ``d_b'`` (two DMA FIFOs).
    """
    src: str
    dst: str
    words: float = 1.0               # stream volume per frame
    word_bits: int = 16
    buffer_depth: float = 1.0        # d_b
    # mutable design state ---------------------------------------------------
    evicted: bool = False            # a_i/a_o flags materialise here
    codec: str = "none"              # none | rle | huffman | bfp8


class Graph:
    """DAG of :class:`Vertex` linked by :class:`Edge` (networkx-backed)."""

    def __init__(self, name: str = "g") -> None:
        self.name = name
        self.g = nx.DiGraph()

    # -- construction ---------------------------------------------------------
    def add(self, v: Vertex) -> Vertex:
        if v.name in self.g:
            raise ValueError(f"duplicate vertex {v.name!r}")
        self.g.add_node(v.name, v=v)
        return v

    def connect(self, src: str, dst: str, words: float | None = None,
                word_bits: int | None = None) -> Edge:
        sv, dv = self.vertex(src), self.vertex(dst)
        e = Edge(src=src, dst=dst,
                 words=float(sv.out_words if words is None else words),
                 word_bits=word_bits or sv.word_bits)
        self.g.add_edge(src, dst, e=e)
        return e

    # -- access ---------------------------------------------------------------
    def vertex(self, name: str) -> Vertex:
        return self.g.nodes[name]["v"]

    def edge(self, src: str, dst: str) -> Edge:
        return self.g.edges[src, dst]["e"]

    def vertices(self) -> Iterator[Vertex]:
        for n in self.g.nodes:
            yield self.g.nodes[n]["v"]

    def edges(self) -> Iterator[Edge]:
        for u, vn in self.g.edges:
            yield self.g.edges[u, vn]["e"]

    def topo(self) -> list[str]:
        return list(nx.topological_sort(self.g))

    def predecessors(self, name: str) -> list[str]:
        return list(self.g.predecessors(name))

    def in_edges(self, name: str) -> list[Edge]:
        """Incoming edges in predecessor (insertion) order — the order
        multi-input ops (concat, add) consume their operands, which the
        executable lowering must preserve."""
        return [self.edge(p, name) for p in self.predecessors(name)]

    def successors(self, name: str) -> list[str]:
        return list(self.g.successors(name))

    def sources(self) -> list[str]:
        return [n for n in self.g.nodes if self.g.in_degree(n) == 0]

    def sinks(self) -> list[str]:
        return [n for n in self.g.nodes if self.g.out_degree(n) == 0]

    # -- surgery (generator / shrinker hooks) ---------------------------------
    def remove_edge(self, src: str, dst: str) -> None:
        """Drop one edge (the shrinker's cheapest simplification)."""
        self.g.remove_edge(src, dst)

    def remove_vertex(self, name: str, reconnect: bool = False) -> None:
        """Drop a vertex and its incident edges.

        With ``reconnect=True`` (single-predecessor vertices only), every
        successor is re-wired to the predecessor — how the fuzz shrinker
        deletes a shape-preserving op from a failing case without breaking
        the surrounding topology.  Re-wired edges keep the successor-side
        edge's attributes, so eviction flags survive the splice.
        """
        if reconnect:
            preds = self.predecessors(name)
            if len(preds) != 1:
                raise ValueError(
                    f"cannot reconnect around {name!r}: it has "
                    f"{len(preds)} predecessors (need exactly 1)")
            p = preds[0]
            for s in self.successors(name):
                if self.g.has_edge(p, s):
                    raise ValueError(
                        f"cannot reconnect around {name!r}: edge "
                        f"{(p, s)} already exists")
                e = self.edge(name, s)
                self.g.add_edge(p, s, e=dataclasses.replace(e, src=p))
        self.g.remove_node(name)

    def validate(self) -> None:
        """Structural invariants every lowerable graph must satisfy.

        The fuzz generator and shrinker call this after every construction
        or surgery step: the graph must be a DAG, its unique source must be
        the ``input`` vertex, its sinks must all be ``output`` vertices,
        and every multi-input op must actually have inputs.  Violations
        raise ``ValueError`` with all problems listed.
        """
        errs: list[str] = []
        if not nx.is_directed_acyclic_graph(self.g):
            errs.append("graph has a cycle")
        srcs = self.sources()
        if len(srcs) != 1 or (srcs and self.vertex(srcs[0]).kind != "input"):
            errs.append(f"expected one 'input' source, got {srcs}")
        for n in self.sinks():
            if self.vertex(n).kind != "output":
                errs.append(f"sink {n!r} is {self.vertex(n).kind!r}, "
                            f"not 'output'")
        for v in self.vertices():
            if v.kind not in ("input",) and not self.predecessors(v.name):
                errs.append(f"non-input vertex {v.name!r} has no inputs")
        if errs:
            raise ValueError(f"invalid graph {self.name!r}: " + "; ".join(errs))

    def first_node(self) -> str:
        """``N_G^in`` — the first node of the graph (unique source expected)."""
        srcs = self.sources()
        return srcs[0]

    # -- serialisation --------------------------------------------------------
    def to_json_dict(self) -> dict:
        """JSON-able structural dump: vertices and edges in insertion order.

        Order matters beyond aesthetics: ``in_edges`` feeds multi-input ops
        (concat, add) their operands in predecessor insertion order, so the
        round-trip must preserve it — ``from_json_dict`` re-adds nodes and
        edges in exactly this order.  Mutable design state (``par``,
        ``frag_ratio``, eviction flags) is included, so a dump taken after
        a DSE run reproduces the explored graph, not the pristine one.
        """
        return {
            "name": self.name,
            "vertices": [dataclasses.asdict(self.g.nodes[n]["v"])
                         for n in self.g.nodes],
            # grouped by destination, predecessors in insertion order:
            # re-adding in this sequence reproduces each node's operand
            # order exactly (nx stores pred adjacency by insertion)
            "edges": [dataclasses.asdict(e)
                      for n in self.g.nodes for e in self.in_edges(n)],
        }

    @staticmethod
    def from_json_dict(d: dict) -> "Graph":
        g = Graph(name=d["name"])
        for vd in d["vertices"]:
            g.add(Vertex(**vd))
        for ed in d["edges"]:
            e = Edge(**ed)
            g.g.add_edge(e.src, e.dst, e=e)
        return g

    # -- aggregate stats ------------------------------------------------------
    def total_macs(self) -> float:
        return sum(v.work_macs for v in self.vertices())

    def total_weight_words(self) -> float:
        return sum(v.weight_words for v in self.vertices())

    def subgraph(self, names: Iterable[str]) -> "Graph":
        names = list(names)
        sg = Graph(name=f"{self.name}:sub")
        for n in names:
            sg.g.add_node(n, v=self.g.nodes[n]["v"])
        for u, vn in self.g.edges:
            if u in sg.g and vn in sg.g:
                sg.g.add_edge(u, vn, e=self.g.edges[u, vn]["e"])
        return sg

    # -- buffer-depth computation (what eviction attacks) ---------------------
    def compute_buffer_depths(self) -> None:
        """Fill ``Edge.buffer_depth`` for every edge.

        Sequential edges get a small rate-mismatch buffer.  Branch edges
        (src has >1 consumer, or paths re-converge) must hold the data
        produced while the *slower* sibling path catches up: depth equals the
        path-delay difference (in cycles) times the stream rate — the deep
        buffers on long skip connections in UNet-like topologies (paper
        §III-A).
        """
        from .pipeline import vertex_delays  # local import to avoid a cycle
        delay = vertex_delays(self)
        for u, w in self.g.edges:
            e: Edge = self.g.edges[u, w]["e"]
            uv, wv = self.vertex(u), self.vertex(w)
            # base: double-buffer one burst of the producer
            base = max(2.0 * uv.rate_out() * min(uv.latency(), 64.0), 2.0)
            mismatch = 0.0
            preds = self.predecessors(w)
            if len(preds) > 1:
                # merge point: this edge must buffer until the slowest branch
                # arrives — difference between the slowest sibling's delay and
                # the producer's own delay, at the producer's output rate.
                slowest = max(delay[p] for p in preds)
                mismatch = max(slowest - delay[u], 0.0) * uv.rate_out()
            e.buffer_depth = max(base, mismatch, 2.0)
