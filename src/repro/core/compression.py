"""Lossless and calibrated-lossy codecs for off-chip streams (paper §III-A/V-C).

SMOF encodes evicted activations and fragmented weights before they cross the
off-chip boundary, to stretch the DDR bandwidth budget.  The paper supports
Run-Length Encoding and Huffman coding "applied to each data word
independently"; weights have a compile-time-known ratio ``c`` while
activations use a calibration-estimated average ``c_bar`` (with the runtime
variability studied in Fig. 8).

We implement, bit-exactly and with real encode/decode round-trips:

* **RLE** over equal consecutive words — effective on post-ReLU zero runs;
* **Huffman** with canonical codes built from a calibration histogram;
* **BFP8** block-floating-point (shared exponent + int8 mantissas per block)
  — the paper's own §V-A quantisation format, reused here as the TPU-native
  eviction codec (fixed, compile-time-known 8.25 bits/word at block 32).

Ratios are reported as ``encoded_bits / raw_bits`` (smaller is better), the
``c`` / ``c_bar`` of Eq. 2 and Eq. 4.
"""
from __future__ import annotations

import collections
import dataclasses
import heapq

import numpy as np

# =============================================================================
# RLE
# =============================================================================

def rle_encode(words: np.ndarray, max_run: int = 256) -> tuple[np.ndarray, np.ndarray]:
    """Encode a 1-D integer word stream into (values, run_lengths)."""
    w = np.asarray(words).ravel()
    if w.size == 0:
        return w[:0], w[:0].astype(np.int64)
    change = np.flatnonzero(np.diff(w)) + 1
    starts = np.concatenate([[0], change])
    ends = np.concatenate([change, [w.size]])
    vals, runs = [], []
    for s, e in zip(starts, ends):
        n = e - s
        while n > 0:
            take = min(n, max_run)
            vals.append(w[s]); runs.append(take)
            n -= take
    return np.asarray(vals, dtype=w.dtype), np.asarray(runs, dtype=np.int64)


def rle_decode(vals: np.ndarray, runs: np.ndarray) -> np.ndarray:
    return np.repeat(vals, runs)


def rle_ratio(words: np.ndarray, word_bits: int, run_bits: int = 8) -> float:
    vals, runs = rle_encode(words, max_run=2**run_bits)
    raw = words.size * word_bits
    enc = vals.size * (word_bits + run_bits)
    return enc / max(raw, 1)


# =============================================================================
# Huffman (canonical)
# =============================================================================

@dataclasses.dataclass
class HuffmanCode:
    lengths: dict[int, int]            # symbol -> code length
    codes: dict[int, tuple[int, int]]  # symbol -> (code, length)

    @property
    def symbols(self) -> list[int]:
        return sorted(self.lengths)


def huffman_build(hist: dict[int, int]) -> HuffmanCode:
    """Build a canonical Huffman code from a symbol histogram."""
    if not hist:
        raise ValueError("empty histogram")
    if len(hist) == 1:
        sym = next(iter(hist))
        return HuffmanCode({sym: 1}, {sym: (0, 1)})
    heap = [(cnt, i, [s]) for i, (s, cnt) in enumerate(sorted(hist.items()))]
    heapq.heapify(heap)
    lengths: dict[int, int] = collections.defaultdict(int)
    tie = len(heap)
    while len(heap) > 1:
        c1, _, s1 = heapq.heappop(heap)
        c2, _, s2 = heapq.heappop(heap)
        for s in s1 + s2:
            lengths[s] += 1
        heapq.heappush(heap, (c1 + c2, tie, s1 + s2))
        tie += 1
    # canonical code assignment: sort by (length, symbol)
    order = sorted(lengths, key=lambda s: (lengths[s], s))
    codes: dict[int, tuple[int, int]] = {}
    code, prev_len = 0, 0
    for s in order:
        code <<= (lengths[s] - prev_len)
        codes[s] = (code, lengths[s])
        prev_len = lengths[s]
        code += 1
    return HuffmanCode(dict(lengths), codes)


def huffman_encode(words: np.ndarray, code: HuffmanCode) -> tuple[bytes, int]:
    """Encode to a bitstream; returns (payload, bit_count)."""
    bits = bytearray()
    acc, nacc = 0, 0
    for s in np.asarray(words).ravel().tolist():
        c, ln = code.codes[int(s)]
        acc = (acc << ln) | c
        nacc += ln
        while nacc >= 8:
            nacc -= 8
            bits.append((acc >> nacc) & 0xFF)
    total_bits = sum(code.codes[int(s)][1] for s in np.asarray(words).ravel().tolist())
    if nacc:
        bits.append((acc << (8 - nacc)) & 0xFF)
    return bytes(bits), total_bits


def huffman_decode(payload: bytes, nbits: int, code: HuffmanCode,
                   dtype=np.int64) -> np.ndarray:
    """Decode a bitstream produced by :func:`huffman_encode`."""
    # decoding table: (length, code) -> symbol
    table = {(ln, c): s for s, (c, ln) in code.codes.items()}
    out = []
    acc, nacc, consumed = 0, 0, 0
    it = iter(payload)
    while consumed < nbits:
        if nacc == 0:
            acc = next(it); nacc = 8
        acc_bit = (acc >> (nacc - 1)) & 1
        nacc -= 1
        consumed += 1
        out.append(acc_bit)
    # walk bit-by-bit
    syms, cur, ln = [], 0, 0
    for b in out:
        cur = (cur << 1) | b
        ln += 1
        if (ln, cur) in table:
            syms.append(table[(ln, cur)])
            cur, ln = 0, 0
    return np.asarray(syms, dtype=dtype)


def huffman_ratio(words: np.ndarray, word_bits: int,
                  calibration: np.ndarray | None = None) -> float:
    """Bits-out/bits-in using a code built on ``calibration`` (or the data)."""
    calib = words if calibration is None else calibration
    hist = collections.Counter(np.asarray(calib).ravel().tolist())
    code = huffman_build(dict(hist))
    w = np.asarray(words).ravel()
    # symbols unseen in calibration fall back to an escape of word_bits+1
    enc_bits = 0
    for s in w.tolist():
        enc_bits += code.codes[int(s)][1] if int(s) in code.codes else word_bits + 1
    return enc_bits / max(w.size * word_bits, 1)


# =============================================================================
# BFP8 — block floating point (shared exponent, int8 mantissa)
# =============================================================================

@dataclasses.dataclass
class BFP8Blocks:
    mantissas: np.ndarray  # int8, same count as input
    exponents: np.ndarray  # int8 per block
    block: int
    orig_len: int
    shape: tuple


def bfp8_encode(x: np.ndarray, block: int = 32) -> BFP8Blocks:
    """Channel/block-wise BFP8: one shared exponent per ``block`` values."""
    flat = np.asarray(x, dtype=np.float32).ravel()
    n = flat.size
    pad = (-n) % block
    fp = np.pad(flat, (0, pad))
    fp = fp.reshape(-1, block)
    amax = np.abs(fp).max(axis=1)
    exp = np.where(amax > 0, np.ceil(np.log2(np.maximum(amax, 1e-38))), 0.0)
    scale = 2.0 ** (exp - 6.0)            # 7 mantissa bits incl. sign -> +-127
    man = np.clip(np.round(fp / scale[:, None]), -127, 127).astype(np.int8)
    return BFP8Blocks(man, exp.astype(np.int8), block, n, np.asarray(x).shape)


def bfp8_decode(b: BFP8Blocks) -> np.ndarray:
    scale = 2.0 ** (b.exponents.astype(np.float32) - 6.0)
    out = b.mantissas.astype(np.float32) * scale[:, None]
    return out.ravel()[: b.orig_len].reshape(b.shape)


def bfp8_ratio(word_bits: int = 16, block: int = 32) -> float:
    """Compile-time-known ratio: 8 bits/word + 8 exponent bits per block."""
    return (8.0 + 8.0 / block) / word_bits


# =============================================================================
# Ratio estimation front-end used by the DSE (Eq. 2's c_bar, Eq. 4's c)
# =============================================================================

CODECS = ("none", "rle", "huffman", "bfp8")

# LUT cost per parallel stream for FPGA-mode designs (paper §V-C: "a fixed
# encoding and decoding cost in LUTs and FFs per data stream").
CODEC_LUT_COST = {"none": 0, "rle": 950, "huffman": 5200, "bfp8": 1400}


def estimate_ratio(codec: str, word_bits: int,
                   sample: np.ndarray | None = None,
                   sparsity: float = 0.5) -> float:
    """``c_bar`` for a stream.  With a calibration ``sample`` the ratio is
    measured; otherwise an analytic post-ReLU model parameterised by
    ``sparsity`` (fraction of zero words) is used."""
    if codec == "none":
        return 1.0
    if codec == "bfp8":
        return bfp8_ratio(word_bits)
    if sample is not None:
        q = np.clip(np.round(np.asarray(sample, np.float64) * 127), -127, 127).astype(np.int64)
        return rle_ratio(q, word_bits) if codec == "rle" else huffman_ratio(q, word_bits)
    if codec == "rle":
        # zero runs: geometric run model. expected words kept ~ (1 - s) + s/E[run]
        erun = 1.0 / max(1.0 - sparsity, 1e-3)
        kept = (1.0 - sparsity) + sparsity / erun
        return min(kept * (word_bits + 8) / word_bits, 1.0 + 8.0 / word_bits)
    if codec == "huffman":
        # entropy model: H = s*log(1/s) + (1-s)*(log(1/(1-s)) + word_bits - 1)
        s = min(max(sparsity, 1e-6), 1 - 1e-6)
        h = (-s * np.log2(s) - (1 - s) * np.log2(1 - s)) + (1 - s) * (word_bits - 1)
        return float(min(h / word_bits + 0.02, 1.05))
    raise ValueError(f"unknown codec {codec!r}")
