"""Batched serving engine with continuous batching and SMOF cache eviction.

SMOF is an inference toolflow, so this is the system's end-to-end driver:
requests enter a queue, get packed into fixed decode slots (continuous
batching — a finished request's slot is immediately refilled), prefill runs
per-request, and decode advances all active slots in lockstep.

The paper's activation eviction shows up here as **KV-page eviction**: when
a slot's cache page goes cold (its request finished) or the configured
residency budget is exceeded, pages are evicted to the host in BFP8 (the
§V-A codec) and restored on demand — Eq. 1/2's on-chip <-> off-chip trade
with HBM as "on-chip" and host DRAM as "off-chip".  ``resident_limit``
keeps the most recently finished requests' pages parked in HBM
(restoration is exact and free); older page-sets spill to the host
oldest-first, so the eviction *order* is the retirement order.

``GraphStreamServer`` is the CNN-side counterpart: a batched front-end
that packs submitted frames into fixed-length microbatch streams and runs
them through the pipelined streaming executor (``runtime/streamer``).
``GraphStreamServer.autotuned`` runs the closed-loop autotuner
(``repro.optim.autotune``) first and serves the measured-best plan.
"""
from __future__ import annotations

import collections
import dataclasses
import queue
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compression import bfp8_decode, bfp8_encode
from repro.models import decode_step, forward, init_cache, project_logits
from repro.models.config import ArchConfig
from repro.obs.metrics import MetricsRegistry


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                    # (S,) int32
    max_new_tokens: int = 16
    eos: int | None = None
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


class _RegistryStats:
    """Base for the registry-backed stats views.

    The engines used to keep hand-rolled stats dataclasses next to the
    metrics; now the :class:`~repro.obs.metrics.MetricsRegistry` is the
    single source of truth and these views are *live reads* of it — the
    legacy attribute surface (``stats.prefills`` etc.) maps each field to
    its metric sample, and ``report()`` is the registry snapshot filtered
    to this front-end's namespace.
    """

    _PREFIX = "smof_"

    def __init__(self, registry: MetricsRegistry) -> None:
        self._registry = registry

    def _value(self, name: str, **labels) -> int:
        fam = self._registry.get(name)
        return int(fam.labels(**labels).value)

    def report(self) -> dict:
        """All of this front-end's samples, from the registry snapshot."""
        return {k: v for k, v in self._registry.snapshot().items()
                if k.startswith(self._PREFIX)}

    def __repr__(self) -> str:
        return f"{type(self).__name__}({self.report()})"


class EngineStats(_RegistryStats):
    """Live view of the decode engine's counters (see ``_RegistryStats``)."""

    _PREFIX = "smof_engine_"

    @property
    def prefills(self) -> int:
        return self._value("smof_engine_prefills_total")

    @property
    def decode_steps(self) -> int:
        return self._value("smof_engine_decode_steps_total")

    @property
    def generated(self) -> int:
        return self._value("smof_engine_generated_tokens_total")

    @property
    def evicted_pages(self) -> int:
        return self._value("smof_engine_evicted_pages_total")

    @property
    def restored_pages(self) -> int:
        return self._value("smof_engine_restored_pages_total")

    @property
    def evicted_bytes_raw(self) -> int:
        return self._value("smof_engine_evicted_bytes_total", kind="raw")

    @property
    def evicted_bytes_compressed(self) -> int:
        return self._value("smof_engine_evicted_bytes_total",
                           kind="compressed")


class ServingEngine:
    def __init__(self, cfg: ArchConfig, params, *, max_batch: int = 4,
                 s_max: int = 256, dtype=jnp.float32,
                 evict_to_host: bool = False, resident_limit: int = 0,
                 sampler: Callable | None = None,
                 metrics: MetricsRegistry | None = None):
        self.cfg = cfg
        self.params = params
        self.B = max_batch
        self.s_max = s_max
        self.dtype = dtype
        self.evict_to_host = evict_to_host
        # retired page-sets allowed to stay parked in HBM before the oldest
        # spills to the host (0 = spill immediately on retire)
        self.resident_limit = resident_limit
        self.sampler = sampler or (lambda logits: jnp.argmax(logits, -1))
        self.cache = init_cache(cfg, max_batch, s_max, dtype=dtype)
        self.slots: list[Request | None] = [None] * max_batch
        self.pos = np.zeros(max_batch, np.int32)
        self.queue: "queue.Queue[Request]" = queue.Queue()
        # every engine counter lives in one MetricsRegistry (own registry by
        # default so engines never cross-talk; pass one to share a scrape
        # surface); self.stats is a live view over it
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        m = self.metrics
        self._c_prefills = m.counter(
            "smof_engine_prefills_total", "prompt prefills run")
        self._c_decode = m.counter(
            "smof_engine_decode_steps_total", "lockstep decode steps")
        self._c_generated = m.counter(
            "smof_engine_generated_tokens_total",
            "tokens sampled across all slots")
        self._c_evicted_pages = m.counter(
            "smof_engine_evicted_pages_total",
            "KV pages BFP8-evicted across the HBM -> host boundary")
        self._c_restored_pages = m.counter(
            "smof_engine_restored_pages_total",
            "KV pages restored into HBM (resident or via BFP8 decode)")
        self._c_evicted_bytes = m.counter(
            "smof_engine_evicted_bytes_total",
            "KV eviction traffic in bytes, raw (bf16 words) vs compressed",
            ("kind",))
        self._h_latency = m.histogram(
            "smof_engine_request_latency_seconds",
            "submit -> retire wall clock per request")
        self.stats = EngineStats(m)
        # submit -> retire wall clock per request (log-bucketed); the same
        # LatencyHistogram the registry histogram exposes, one data structure
        self.latency = self._h_latency.labels().hist
        self._submit_ts: dict[int, float] = {}
        self.host_store: dict[int, dict] = {}    # rid -> evicted pages
        # rid -> raw pages still in HBM, in retirement order (FIFO eviction)
        self.resident_store: "collections.OrderedDict[int, dict]" = \
            collections.OrderedDict()
        self._next_rid = 0
        self._decode = jax.jit(
            lambda p, c, t, pos: decode_step(p, cfg, t, pos, c))

    # -- request intake ------------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16,
               eos: int | None = None) -> Request:
        r = Request(rid=self._next_rid, prompt=np.asarray(prompt, np.int32),
                    max_new_tokens=max_new_tokens, eos=eos)
        self._next_rid += 1
        self._submit_ts[r.rid] = time.perf_counter()
        self.queue.put(r)
        return r

    # -- slot management -------------------------------------------------------------
    def _fill_slots(self) -> None:
        for b in range(self.B):
            if self.slots[b] is None and not self.queue.empty():
                r = self.queue.get()
                self._prefill(b, r)
                self.slots[b] = r

    def _prefill(self, slot: int, r: Request) -> None:
        """Run the prompt through the full forward, writing slot ``slot``."""
        S = len(r.prompt)
        assert S < self.s_max, (S, self.s_max)
        toks = jnp.asarray(r.prompt, jnp.int32)[None]
        one_cache = init_cache(self.cfg, 1, self.s_max, dtype=self.dtype)
        x, new_cache, _ = forward(self.params, self.cfg, toks,
                                  cache=one_cache)
        logits = project_logits(self.params, self.cfg, x[:, -1])
        first = int(np.asarray(self.sampler(logits))[0])
        r.out_tokens.append(first)
        self.cache = jax.tree.map(
            lambda c, n: c.at[:, slot].set(n[:, 0]), self.cache, new_cache)
        self.pos[slot] = S
        self._c_prefills.inc()

    def _retire(self, slot: int) -> None:
        r = self.slots[slot]
        if r is not None:
            t0 = self._submit_ts.pop(r.rid, None)
            if t0 is not None:
                self.latency.record(time.perf_counter() - t0)
        if r is not None and self.evict_to_host:
            pages = self._snapshot_slot(slot)
            if self.resident_limit > 0:
                self.resident_store[r.rid] = pages
                while len(self.resident_store) > self.resident_limit:
                    # budget exceeded: spill the OLDEST retired page-set
                    old_rid, old_pages = self.resident_store.popitem(last=False)
                    self._host_evict(old_rid, old_pages)
            else:
                self._host_evict(r.rid, pages)
        self.slots[slot] = None
        self.pos[slot] = 0

    # -- KV eviction (paper Eq. 1/2 at the HBM<->host level) -------------------------
    def _snapshot_slot(self, slot: int) -> dict:
        """Copy one slot's KV pages out of the decode cache (still in HBM)."""
        pages = {}

        def snap_leaf(path, c):
            name = "/".join(str(getattr(p, "key", p)) for p in path)
            pages[name] = c[:, slot]
            return c
        jax.tree_util.tree_map_with_path(snap_leaf, self.cache)
        return pages

    def _host_evict(self, rid: int, pages: dict) -> None:
        """BFP8-encode a page-set across the HBM -> host boundary."""
        enc_pages = {}
        for name, page in pages.items():
            page = np.asarray(page, np.float32)
            enc = bfp8_encode(page)
            self._c_evicted_bytes.labels(kind="raw").inc(
                page.size * 2)                                 # bf16 words
            self._c_evicted_bytes.labels(kind="compressed").inc(
                enc.mantissas.size + enc.exponents.size)
            enc_pages[name] = enc
        self.host_store[rid] = enc_pages
        self._c_evicted_pages.inc(len(enc_pages))

    def restore_request(self, rid: int, slot: int) -> None:
        """Bring an evicted request's pages back into HBM (resumption).

        Pages still parked under ``resident_limit`` restore exactly; pages
        that crossed to the host come back through the BFP8 codec.
        """
        resident = self.resident_store.pop(rid, None)

        def page_for(name, c):
            if resident is not None:
                return np.asarray(resident[name])
            return bfp8_decode(self.host_store[rid][name])

        def restore_leaf(path, c):
            name = "/".join(str(getattr(p, "key", p)) for p in path)
            page = np.asarray(page_for(name, c)).astype(np.asarray(c).dtype)
            self._c_restored_pages.inc()
            return c.at[:, slot].set(jnp.asarray(page))
        self.cache = jax.tree_util.tree_map_with_path(restore_leaf, self.cache)
        if resident is None:
            del self.host_store[rid]

    # -- decode loop ---------------------------------------------------------------
    def step(self) -> int:
        """One lockstep decode step over all active slots; returns #active."""
        self._fill_slots()
        active = [b for b, s in enumerate(self.slots) if s is not None]
        if not active:
            return 0
        last = np.zeros((self.B, 1), np.int32)
        for b in active:
            last[b, 0] = self.slots[b].out_tokens[-1]
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(last),
            jnp.asarray(self.pos, jnp.int32))
        nxt = np.asarray(self.sampler(logits))
        self._c_decode.inc()
        for b in active:
            r = self.slots[b]
            self.pos[b] += 1
            r.out_tokens.append(int(nxt[b]))
            self._c_generated.inc()
            if (len(r.out_tokens) >= r.max_new_tokens
                    or (r.eos is not None and int(nxt[b]) == r.eos)
                    or self.pos[b] >= self.s_max - 1):
                r.done = True
                self._retire(b)
        return len(active)

    def run_until_drained(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if self.step() == 0 and self.queue.empty():
                return

    def metrics_text(self) -> str:
        """Prometheus text exposition of this engine's registry."""
        return self.metrics.metrics_text()


# =============================================================================
# Batched exec-graph front-end feeding the pipelined streamer
# =============================================================================

class StreamServerStats(_RegistryStats):
    """Live view of the stream server's counters (see ``_RegistryStats``)."""

    _PREFIX = "smof_server_"

    @property
    def frames_in(self) -> int:
        return self._value("smof_server_frames_in_total")

    @property
    def frames_out(self) -> int:
        return self._value("smof_server_frames_out_total")

    @property
    def streams_run(self) -> int:
        return self._value("smof_server_streams_total")

    @property
    def padded_frames(self) -> int:
        # bubble frames added to fill the last stream
        return self._value("smof_server_padded_frames_total")


class GraphStreamServer:
    """Packs submitted frames into microbatch streams for the streamer.

    The pipelined executor is traced for a fixed stream length ``B``
    (`runtime/streamer`): this front-end queues individual frames, cuts the
    queue into length-``B`` streams (zero-padding the tail — padding frames
    are executed as pipeline bubbles and dropped), runs each stream through
    the one jitted multi-microbatch step, and hands results back by ticket.

    Construction goes through the compile façade (``repro.api``): pass a
    ready :class:`~repro.api.CompileSpec` (``spec=``), an already-lowered
    ``StreamingExecutor`` (``executor=``, what ``Compiled.serve()`` does),
    or the legacy ``(g, plan, microbatches=..., **lowering knobs)`` form —
    which is folded into a spec, so the lowering-kwarg plumbing lives in
    exactly one place.
    """

    def __init__(self, g=None, plan=None, *, microbatches: int = 8,
                 executor=None, spec=None, metrics: MetricsRegistry | None = None,
                 slo=None, resident_limit: int = 0, **lower_kw):
        from repro.api import CompileSpec, compile as smof_compile
        if executor is None:
            if spec is None:
                spec = CompileSpec(model=g, strategy="manual-plan",
                                   mode="pipelined", plan=plan,
                                   microbatches=microbatches, **lower_kw)
            executor = smof_compile(spec).executor
        self.executor = executor
        self.microbatches = executor.microbatches
        # flushed-but-unclaimed results allowed to stay resident (live
        # arrays) before the oldest is evicted to the byte-packed host
        # store; 0 = unbounded.  Restoration is exact — results are
        # finished outputs, so unlike the KV pages there is nothing to
        # re-quantise and the eviction must be lossless.
        self.resident_limit = resident_limit
        # registry-backed accounting (own registry by default; pass one to
        # share a scrape surface, e.g. Compiled.serve threads the artifact's)
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        m = self.metrics
        self._c_frames_in = m.counter(
            "smof_server_frames_in_total", "frames submitted to the server")
        self._c_frames_out = m.counter(
            "smof_server_frames_out_total", "frames delivered by flush")
        self._c_streams = m.counter(
            "smof_server_streams_total",
            "fixed-length microbatch streams executed")
        self._c_padded = m.counter(
            "smof_server_padded_frames_total",
            "bubble frames padded onto stream tails")
        self._h_latency = m.histogram(
            "smof_server_frame_latency_seconds",
            "submit -> flush-delivery wall clock per frame")
        self._c_slo = m.counter(
            "smof_server_slo_evaluations_total",
            "per-flush SLO evaluations, by verdict", ("verdict",))
        self.stats = StreamServerStats(m)
        # submit -> flush-delivery wall clock per frame (log-bucketed):
        # queueing delay + padding bubbles + the stream's pipeline run; the
        # same LatencyHistogram the registry histogram exposes
        self.latency = self._h_latency.labels().hist
        self.slo = slo                       # obs.slo.SloEvaluator | None
        self.flight = None                   # obs.flight.FlightRecorder | None
        # per stream executed, every spill record moves offchip_bits once
        # per microbatch in each direction (evict + restore) — the window
        # samples the SLO's spill-bandwidth objectives score, split by
        # direction so one-sided saturation stays visible
        _one_way = sum(
            r.offchip_bits // 8
            for r in getattr(executor.report, "spills", ())
        ) * self.microbatches
        self._evict_bytes_per_stream = _one_way
        self._restore_bytes_per_stream = _one_way
        self._spill_bytes_per_stream = _one_way * 2
        self._c_evicted_results = m.counter(
            "smof_server_evicted_results_total",
            "flushed results spilled to the host store (resident_limit)")
        self._c_restored_results = m.counter(
            "smof_server_restored_results_total",
            "evicted results restored on claim (exact, byte-packed)")
        self.autotune_result = None          # set by .autotuned()
        self._pending: list[tuple[int, np.ndarray]] = []
        # ticket -> output, oldest-flushed first (the eviction order)
        self._results: "collections.OrderedDict[int, np.ndarray]" = \
            collections.OrderedDict()
        # ticket -> (raw bytes, dtype, shape): the off-chip side of the
        # resident budget — exact restore by construction
        self._host_results: dict[int, tuple[bytes, np.dtype, tuple]] = {}
        self._submit_ts: dict[int, float] = {}
        self._next_ticket = 0

    @classmethod
    def autotuned(cls, g, dev, *, autotune_cfg=None, **lower_kw
                  ) -> "GraphStreamServer":
        """Serve the *measured-best* plan instead of the default DSE plan.

        Compiles ``strategy="autotune"`` through the façade: the closed
        loop (``repro.optim.autotune``) executes every candidate through
        the pipelined streamer, and the server is built around the winning
        plan at the autotuner's microbatch depth.  The full
        :class:`~repro.optim.autotune.AutotuneResult` (trajectory +
        calibration report) is kept on ``server.autotune_result``.
        """
        from repro.api import CompileSpec, compile as smof_compile
        from repro.optim.autotune import AutotuneConfig
        cfg = autotune_cfg or AutotuneConfig()
        compiled = smof_compile(CompileSpec(
            model=g, device=dev, strategy="autotune", mode="pipelined",
            autotune_cfg=cfg, microbatches=cfg.microbatches, **lower_kw))
        return compiled.serve()

    @property
    def report(self):
        return self.executor.report

    def submit(self, frame: np.ndarray) -> int:
        """Queue one (positions, channels) frame; returns a ticket id."""
        self._pending.append((self._next_ticket,
                              np.asarray(frame, np.float32)))
        self._submit_ts[self._next_ticket] = time.perf_counter()
        self._next_ticket += 1
        self._c_frames_in.inc()
        return self._next_ticket - 1

    def flush(self) -> dict[int, np.ndarray]:
        """Run all queued frames; returns {ticket: output} for this flush.

        With an attached SLO evaluator (:meth:`enable_slo`), every stream
        run lands one window observation and is re-scored — breaches fire
        the evaluator's ``on_breach`` hooks (e.g. a flight-recorder dump)
        and the verdict counts into ``smof_server_slo_evaluations_total``.
        """
        out: dict[int, np.ndarray] = {}
        B = self.microbatches
        while self._pending:
            chunk, self._pending = self._pending[:B], self._pending[B:]
            xs = np.stack([f for _, f in chunk])
            pad = B - len(chunk)
            if pad:
                xs = np.concatenate(
                    [xs, np.zeros((pad,) + xs.shape[1:], np.float32)])
                self._c_padded.inc(pad)
            t_run = time.perf_counter()
            ys = np.asarray(self.executor(jnp.asarray(xs)))
            run_s = time.perf_counter() - t_run
            self._c_streams.inc()
            now = time.perf_counter()
            for (ticket, _), y in zip(chunk, ys):
                out[ticket] = y
                self._c_frames_out.inc()
                t0 = self._submit_ts.pop(ticket, None)
                if t0 is not None:
                    self.latency.record(now - t0)
            if self.slo is not None:
                self.slo.observe(frames=len(chunk), seconds=run_s,
                                 spill_bytes=self._spill_bytes_per_stream,
                                 evict_bytes=self._evict_bytes_per_stream,
                                 restore_bytes=self._restore_bytes_per_stream)
                verdict = self.slo.evaluate().verdict
                self._c_slo.labels(verdict=verdict).inc()
        self._results.update(out)
        if self.resident_limit > 0:
            while len(self._results) > self.resident_limit:
                # budget exceeded: spill the OLDEST unclaimed result —
                # same retirement-order policy as the decode engine's
                # KV pages, but lossless (finished outputs)
                ticket, y = self._results.popitem(last=False)
                self._host_results[ticket] = (y.tobytes(), y.dtype, y.shape)
                self._c_evicted_results.inc()
        return out

    # -- observability surface ------------------------------------------------
    def metrics_text(self) -> str:
        """Prometheus text exposition of this server's registry."""
        return self.metrics.metrics_text()

    def roofline_fps(self) -> float | None:
        """The served plan's Eq. 6 throughput bound in frames/s, when the
        plan's provenance carries a calibrated ``s_per_cycle`` (autotuned
        artifacts do): ``1 / (eq6_cycles * s_per_cycle)``."""
        plan = getattr(self.executor, "plan", None)
        spc = plan.provenance.get("s_per_cycle") if plan is not None else None
        eq6 = getattr(self.executor.report, "eq6_time", None)
        if spc and eq6:
            return 1.0 / (eq6 * spc)
        return None

    def enable_slo(self, cfg=None, *, roofline_fps=None, bw_gbps=None,
                   stream_budgets=None):
        """Attach a rolling-window SLO evaluator, re-scored on every flush.

        ``roofline_fps`` defaults to :meth:`roofline_fps` (calibrated
        plans only); ``bw_gbps`` is the device's off-chip budget for the
        spill-bandwidth objective.  ``stream_budgets`` (per-kind Gbps,
        e.g. ``MemoryModel.budget_gbps_by_kind()``) scores the split
        evict/restore objectives against the arbiter's grants; defaults
        to the executor report's channel model when the plan was compiled
        with one.  Returns the evaluator so callers can hook
        ``on_breach`` (e.g. ``FlightRecorder.on_slo_report``).
        """
        from repro.obs.slo import SloEvaluator
        if roofline_fps is None:
            roofline_fps = self.roofline_fps()
        if stream_budgets is None:
            mem = getattr(self.executor.report, "memory", None)
            if mem is not None:
                stream_budgets = mem.budget_gbps_by_kind()
        self.slo = SloEvaluator(cfg, roofline_fps=roofline_fps,
                                bw_gbps=bw_gbps, latency=self.latency,
                                stream_budgets=stream_budgets)
        return self.slo

    def result(self, ticket: int) -> np.ndarray:
        """Claim a flushed output (one-shot: the server does not keep
        delivered results, so a long-lived front-end stays bounded).

        Results evicted under ``resident_limit`` restore bit-exactly from
        the host byte store."""
        if ticket in self._host_results:
            raw, dtype, shape = self._host_results.pop(ticket)
            self._c_restored_results.inc()
            return np.frombuffer(raw, dtype=dtype).reshape(shape)
        return self._results.pop(ticket)
