"""Jitted public wrappers for the Pallas kernels.

``interpret`` defaults to True when no TPU is present so the same call
sites run on CPU (kernel bodies executed in Python) and compile to Mosaic
on real hardware.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .bfp8 import bfp8_dequant, bfp8_quant
from .flash_attention import flash_attention
from .streamed_matmul import (streamed_matmul, streamed_matmul_padded,
                              vmem_bytes)
from . import ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("static_fraction", "bm", "bk",
                                             "bn", "interpret"))
def fragmented_matmul(x: jax.Array, w: jax.Array, *,
                      static_fraction: float = 0.5, bm: int = 128,
                      bk: int = 128, bn: int = 128,
                      interpret: bool | None = None) -> jax.Array:
    """y = x @ w with the leading ``static_fraction`` of w's rows pinned in
    VMEM (the paper's 1 - m) and the rest streamed — the public form of the
    weight-fragmentation kernel, splitting w at a 128-aligned row."""
    K = w.shape[0]
    ks = max(int(round(static_fraction * K / 128.0)) * 128, 0)
    ks = min(ks, K - 128) if K > 128 else 0
    if interpret is None:
        interpret = not _on_tpu()
    if ks <= 0:
        return streamed_matmul(x, w[:128], w[128:], bm=bm, bk=bk, bn=bn,
                               interpret=interpret) if K > 128 else \
            jnp.dot(x, w, preferred_element_type=jnp.float32).astype(x.dtype)
    return streamed_matmul(x, w[:ks], w[ks:], bm=bm, bk=bk, bn=bn,
                           interpret=interpret)


def flash_attn(q, k, v, *, causal=True, bq=256, bk=256, interpret=None):
    if interpret is None:
        interpret = not _on_tpu()
    return flash_attention(q, k, v, causal=causal, bq=bq, bk=bk,
                           interpret=interpret)


def evict_encode(x: jax.Array, *, block: int = 32, interpret=None):
    """Quantise an eviction stream to BFP8 before it leaves HBM."""
    if interpret is None:
        interpret = not _on_tpu()
    return bfp8_quant(x, block=block, interpret=interpret)


def evict_decode(man, exp, *, block: int = 32, dtype=jnp.float32,
                 interpret=None):
    if interpret is None:
        interpret = not _on_tpu()
    return bfp8_dequant(man, exp, block=block, dtype=dtype,
                        interpret=interpret)


__all__ = ["fragmented_matmul", "flash_attn", "evict_encode", "evict_decode",
           "streamed_matmul", "streamed_matmul_padded", "flash_attention",
           "bfp8_quant", "bfp8_dequant", "vmem_bytes", "ref"]
