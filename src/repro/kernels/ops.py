"""Jitted public wrappers for the Pallas kernels, plus the kernel
registry the executors dispatch through.

``interpret`` defaults to True when no TPU is present so the same call
sites run on CPU (kernel bodies executed in Python) and compile to Mosaic
on real hardware.  The resolution lives in exactly one place
(:func:`resolve_interpret`): the executors resolve a ``CompileSpec``'s
``interpret`` once at lowering time and thread the concrete bool down, so
a façade-compiled artifact replays with the same kernel path it was saved
with instead of re-deciding per wrapper call.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp

from .bfp8 import bfp8_dequant, bfp8_quant
from .flash_attention import flash_attention
from .streamed_matmul import (streamed_matmul, streamed_matmul_padded,
                              vmem_bytes)
from . import ref
from . import streaming_conv


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_interpret(interpret: bool | None) -> bool:
    """The one shared ``interpret`` resolution: an explicit flag (e.g. a
    saved ``CompileSpec.interpret``) wins; ``None`` falls back to
    interpret-on-CPU.  Every wrapper and both executors route through
    here, so the backend decision cannot diverge between call sites."""
    return (not _on_tpu()) if interpret is None else bool(interpret)


# =============================================================================
# Kernel registry: op kind -> {reference, pallas} bodies
# =============================================================================

@dataclasses.dataclass(frozen=True)
class KernelEntry:
    """One lowerable op kind's dispatch row.

    ``pallas=None`` means the kind has no Pallas body (data movement /
    variadic ops) and the reference body runs in every kernel mode —
    ``kernel_for`` reports which body was actually selected.
    ``fuse_bfp8`` marks kinds whose Pallas body can fuse the BFP8
    boundary codec (ingress ``payload=`` / egress ``encode=True``).
    """
    kind: str
    reference: Callable
    pallas: Callable | None = None
    fuse_bfp8: bool = False


KERNEL_REGISTRY: dict[str, KernelEntry] = {}


def _register(entry: KernelEntry) -> None:
    KERNEL_REGISTRY[entry.kind] = entry


for _kind in ("conv", "matmul", "deconv"):
    _register(KernelEntry(kind=_kind, reference=ref.conv2d_ref,
                          pallas=streaming_conv.conv2d, fuse_bfp8=True))
_register(KernelEntry(kind="dwconv", reference=ref.dwconv_ref,
                      pallas=streaming_conv.dwconv, fuse_bfp8=True))
_register(KernelEntry(kind="pool", reference=ref.pool_ref,
                      pallas=streaming_conv.pool, fuse_bfp8=True))
_register(KernelEntry(kind="act", reference=ref.act_relu_ref,
                      pallas=streaming_conv.act_relu, fuse_bfp8=True))
# data-movement / variadic kinds: reference body in every mode
for _kind in ("input", "upsample", "add", "mul", "concat", "output"):
    _register(KernelEntry(kind=_kind, reference=lambda *a, **k: None))


def kernel_for(kind: str, *, use_pallas: bool
               ) -> tuple[Callable | None, bool]:
    """(body, is_pallas) for one op kind under the resolved kernel mode.
    Kinds with no Pallas body fall back to their reference body (and
    ``is_pallas`` is False) — the conformance matrix sweeps them anyway
    to lock the fallback's parity."""
    entry = KERNEL_REGISTRY.get(kind)
    if entry is None:
        return None, False
    if use_pallas and entry.pallas is not None:
        return entry.pallas, True
    return entry.reference, False


def fusable_kinds() -> tuple[str, ...]:
    """Op kinds whose Pallas body fuses the BFP8 boundary codec."""
    return tuple(k for k, e in KERNEL_REGISTRY.items() if e.fuse_bfp8)


def lowerable_kinds() -> tuple[str, ...]:
    return tuple(KERNEL_REGISTRY)


# =============================================================================
# Jitted public wrappers
# =============================================================================

@functools.partial(jax.jit, static_argnames=("static_fraction", "bm", "bk",
                                             "bn", "interpret"))
def fragmented_matmul(x: jax.Array, w: jax.Array, *,
                      static_fraction: float = 0.5, bm: int = 128,
                      bk: int = 128, bn: int = 128,
                      interpret: bool | None = None) -> jax.Array:
    """y = x @ w with the leading ``static_fraction`` of w's rows pinned in
    VMEM (the paper's 1 - m) and the rest streamed — the public form of the
    weight-fragmentation kernel, splitting w at a 128-aligned row."""
    K = w.shape[0]
    ks = max(int(round(static_fraction * K / 128.0)) * 128, 0)
    ks = min(ks, K - 128) if K > 128 else 0
    interpret = resolve_interpret(interpret)
    if ks <= 0:
        return streamed_matmul(x, w[:128], w[128:], bm=bm, bk=bk, bn=bn,
                               interpret=interpret) if K > 128 else \
            jnp.dot(x, w, preferred_element_type=jnp.float32).astype(x.dtype)
    return streamed_matmul(x, w[:ks], w[ks:], bm=bm, bk=bk, bn=bn,
                           interpret=interpret)


def flash_attn(q, k, v, *, causal=True, bq=256, bk=256, interpret=None):
    return flash_attention(q, k, v, causal=causal, bq=bq, bk=bk,
                           interpret=resolve_interpret(interpret))


def evict_encode(x: jax.Array, *, block: int = 32, interpret=None):
    """Quantise an eviction stream to BFP8 before it leaves HBM."""
    return bfp8_quant(x, block=block, interpret=resolve_interpret(interpret))


def evict_decode(man, exp, *, block: int = 32, dtype=jnp.float32,
                 interpret=None):
    return bfp8_dequant(man, exp, block=block, dtype=dtype,
                        interpret=resolve_interpret(interpret))


__all__ = ["fragmented_matmul", "flash_attn", "evict_encode", "evict_decode",
           "streamed_matmul", "streamed_matmul_padded", "flash_attention",
           "bfp8_quant", "bfp8_dequant", "vmem_bytes", "ref",
           "resolve_interpret", "KernelEntry", "KERNEL_REGISTRY",
           "kernel_for", "fusable_kinds", "lowerable_kinds",
           "streaming_conv"]
