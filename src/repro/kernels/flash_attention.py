"""Flash attention Pallas kernel (prefill hot-spot).

Blockwise causal attention with the online-softmax recurrence held in VMEM
scratch across the KV grid axis.  Grid: (batch*heads, q blocks, kv blocks)
with kv innermost, so the (bq, D) output tile and its (bq,) max/sum
accumulators are revisited in VMEM and flushed once per q block.

Causal-block skipping: fully-masked kv blocks (k_start > q_end) write
nothing and skip the dot — on TPU the MXU work for the upper triangle is
elided at the block level, which is where the 2x causal saving comes from.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0 ** 30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, bq: int, bk: int, nk: int, causal: bool, scale: float):
    iq, ik = pl.program_id(1), pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # skip blocks entirely above the diagonal
    run = (not causal) or (ik * bk <= iq * bq + bq - 1)

    @pl.when(run)
    def _block():
        q = q_ref[0].astype(jnp.float32) * scale            # (bq, D)
        k = k_ref[0].astype(jnp.float32)                    # (bk, D)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if causal:
            q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_prev * alpha + p.sum(axis=-1)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ik == nk - 1)
    def _flush():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, bq: int = 256, bk: int = 256,
                    interpret: bool = False) -> jax.Array:
    """q, k, v: (B, S, H, D) (kv heads already repeated to H).  Returns
    (B, S, H, D)."""
    B, S, H, D = q.shape
    bq, bk = min(bq, S), min(bk, S)
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    nq, nk = S // bq, S // bk
    scale = D ** -0.5

    # fold (B, H) into one grid axis; layout (BH, S, D)
    def fold(t):
        return jnp.moveaxis(t, 2, 1).reshape(B * H, S, D)

    qf, kf, vf = fold(q), fold(k), fold(v)
    out = pl.pallas_call(
        functools.partial(_kernel, bq=bq, bk=bk, nk=nk, causal=causal,
                          scale=scale),
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),       # running max
            pltpu.VMEM((bq,), jnp.float32),       # running sum
            pltpu.VMEM((bq, D), jnp.float32),     # output accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return jnp.moveaxis(out.reshape(B, H, S, D), 1, 2)