"""Streaming conv / dwconv / pool Pallas kernels with a fused BFP8
boundary codec — the kernel-level analogue of the paper's line-buffer
dataflow (§III) for the executable graphs' op vocabulary.

Layout (docs/KERNELS.md has the full picture):

* every kernel walks a **row-block grid**: one grid step owns a
  ``(bm, C)`` stripe of positions, the software form of a line buffer
  that consumes a sliding window of rows per cycle.  The channel-mixing
  ops (``conv``/``matmul``/``deconv``) additionally tile the *output*
  channel axis by ``bc`` with the **full K axis per grid step** — a
  single ``jnp.dot`` per tile, no K-split accumulation, which is what
  makes tiled results bit-exact against the untiled reference dot.
* **fused ingress**: when the op's input edge arrives BFP8-evicted, the
  kernel takes the spill payload (int8 mantissas + per-block int8 shared
  exponents) and dequantises per block *inside* the ``pallas_call``
  (``bfp8.bfp8_dequant_values``) instead of round-tripping through a
  separate ``bfp8_dequant`` dispatch.
* **fused egress**: when the op's output edge is BFP8-evicted, the same
  ``pallas_call`` emits the f32 activation *and* its quantised spill
  payload (multi-output ``out_specs``).  Quantisation blocks are
  row-local ``(1, block)`` runs along the channel axis, so egress fusion
  pins the full (block-padded) channel width per row-block — ``bm``
  still tiles, ``bc`` does not apply — and the payload is bitwise the
  one ``runtime.executor.bfp8_spill_encode`` would produce.

Padding rules: rows pad with zeros to the row-block multiple (padded
rows are computed and sliced away — zero rows cannot perturb real rows
since nothing reduces over the position axis except ``pool``, whose
grid is aligned to whole output rows).  Egress channel padding matches
``bfp8_spill_encode`` exactly: pad to ``round_up(c, block)`` with
zeros, quantise the padded stripe.

Everything here is numerics-only: traffic accounting stays in
``runtime.executor`` / the DSE.  ``interpret`` is resolved by the
caller (``kernels.ops.resolve_interpret`` / the executors) — these
wrappers take a concrete bool.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .bfp8 import bfp8_dequant_values, bfp8_quant_values
from .streamed_matmul import _round_up

DEFAULT_BM = 128            # row-block default (positions per grid step)
DEFAULT_BC = 128            # out-channel-block default (conv family only)

# Module-level codec indirection: the fused kernels look these up at trace
# time, so the differential fuzzer's fault injector can skew the *fused*
# codec specifically (testing.oracle FAULTS) without touching the
# standalone bfp8 stripe kernels.
_quant_vals = bfp8_quant_values
_dequant_vals = bfp8_dequant_values


def _tile(n: int, b: int, default: int) -> int:
    """Resolve a tile size: 0 means 'auto' (default, clamped to the axis)."""
    b = b if b > 0 else default
    return min(b, n) if n > 0 else b


def _pad_rows(x: jax.Array, mp: int) -> jax.Array:
    m = x.shape[0]
    return x if m == mp else jnp.pad(x, ((0, mp - m), (0, 0)))


def _pad_payload(payload, mp: int):
    man, exp = payload
    return _pad_rows(man, mp), _pad_rows(exp, mp)


# =============================================================================
# conv / matmul / deconv — 1x1 channel mixing, y = x @ w
# =============================================================================

def _conv_kernel(x_ref, w_ref, o_ref):
    o_ref[...] = jnp.dot(x_ref[...], w_ref[...],
                         preferred_element_type=jnp.float32)


def _conv_dec_kernel(man_ref, exp_ref, w_ref, o_ref, *, block, cin):
    x = _dequant_vals(man_ref[...], exp_ref[...], block=block)[:, :cin]
    o_ref[...] = jnp.dot(x, w_ref[...], preferred_element_type=jnp.float32)


def _conv_enc_kernel(x_ref, w_ref, o_ref, man_ref, exp_ref, *, block):
    y = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = y
    man_ref[...], exp_ref[...] = _quant_vals(y, block=block)


def _conv_dec_enc_kernel(man_ref, exp_ref, w_ref, o_ref, yman_ref, yexp_ref,
                         *, block, cin):
    x = _dequant_vals(man_ref[...], exp_ref[...], block=block)[:, :cin]
    y = jnp.dot(x, w_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = y
    yman_ref[...], yexp_ref[...] = _quant_vals(y, block=block)


def conv2d(x, w, *, payload=None, encode=False, block: int = 32,
           bm: int = 0, bc: int = 0, interpret: bool = False):
    """Tiled streaming 1x1 conv: ``y = x @ w`` over a row-block grid.

    x: (m, cin) f32 — or pass ``payload=(man, exp)`` (int8 spill buffers,
    channel axis padded to the codec block) for a BFP8-evicted input edge;
    the per-block dequant then runs inside the kernel.  ``encode=True``
    additionally emits the output's BFP8 spill payload from the same
    ``pallas_call`` and returns ``(y, (man, exp))``.

    Bit-exact contract: ``y`` equals ``jnp.dot(x, w)`` (with ``x`` the
    dequantised input where applicable) and the egress payload equals
    ``bfp8_quant`` of the block-padded ``y`` — for every ``bm``/``bc``.
    """
    cin, n = w.shape
    if payload is not None:
        man, exp = payload
        m, c_pad = man.shape
        assert c_pad == _round_up(cin, block), (man.shape, cin, block)
    else:
        m = x.shape[0]
        assert x.shape[1] == cin, (x.shape, w.shape)
    bm = _tile(m, bm, DEFAULT_BM)
    mp = _round_up(m, bm)

    if not encode:
        bc = _tile(n, bc, DEFAULT_BC)
        npad = _round_up(n, bc)
        wp = jnp.pad(w, ((0, 0), (0, npad - n)))
        grid = (mp // bm, npad // bc)
        if payload is None:
            y = pl.pallas_call(
                _conv_kernel, grid=grid,
                in_specs=[pl.BlockSpec((bm, cin), lambda i, j: (i, 0)),
                          pl.BlockSpec((cin, bc), lambda i, j: (0, j))],
                out_specs=pl.BlockSpec((bm, bc), lambda i, j: (i, j)),
                out_shape=jax.ShapeDtypeStruct((mp, npad), jnp.float32),
                interpret=interpret,
            )(_pad_rows(x, mp), wp)
        else:
            y = pl.pallas_call(
                functools.partial(_conv_dec_kernel, block=block, cin=cin),
                grid=grid,
                in_specs=[pl.BlockSpec((bm, c_pad), lambda i, j: (i, 0)),
                          pl.BlockSpec((bm, c_pad // block),
                                       lambda i, j: (i, 0)),
                          pl.BlockSpec((cin, bc), lambda i, j: (0, j))],
                out_specs=pl.BlockSpec((bm, bc), lambda i, j: (i, j)),
                out_shape=jax.ShapeDtypeStruct((mp, npad), jnp.float32),
                interpret=interpret,
            )(*_pad_payload(payload, mp), wp)
        return y[:m, :n]

    # egress fusion: full (block-padded) channel width per row-block so the
    # row-local quant blocks line up with bfp8_spill_encode's padding
    npad = _round_up(n, block)
    wp = jnp.pad(w, ((0, 0), (0, npad - n)))
    out_specs = [pl.BlockSpec((bm, npad), lambda i: (i, 0)),
                 pl.BlockSpec((bm, npad), lambda i: (i, 0)),
                 pl.BlockSpec((bm, npad // block), lambda i: (i, 0))]
    out_shape = [jax.ShapeDtypeStruct((mp, npad), jnp.float32),
                 jax.ShapeDtypeStruct((mp, npad), jnp.int8),
                 jax.ShapeDtypeStruct((mp, npad // block), jnp.int8)]
    if payload is None:
        y, man_o, exp_o = pl.pallas_call(
            functools.partial(_conv_enc_kernel, block=block),
            grid=(mp // bm,),
            in_specs=[pl.BlockSpec((bm, cin), lambda i: (i, 0)),
                      pl.BlockSpec((cin, npad), lambda i: (0, 0))],
            out_specs=out_specs, out_shape=out_shape, interpret=interpret,
        )(_pad_rows(x, mp), wp)
    else:
        y, man_o, exp_o = pl.pallas_call(
            functools.partial(_conv_dec_enc_kernel, block=block, cin=cin),
            grid=(mp // bm,),
            in_specs=[pl.BlockSpec((bm, c_pad), lambda i: (i, 0)),
                      pl.BlockSpec((bm, c_pad // block), lambda i: (i, 0)),
                      pl.BlockSpec((cin, npad), lambda i: (0, 0))],
            out_specs=out_specs, out_shape=out_shape, interpret=interpret,
        )(*_pad_payload(payload, mp), wp)
    return y[:m, :n], (man_o[:m], exp_o[:m])


# =============================================================================
# dwconv — depthwise temporal conv, 'same' padding, halo rows via pl.ds
# =============================================================================

def _dw_mix(xp, w, base, bm, taps):
    """The reference tap sum on a row tile: ``sum`` in the same order as
    ``runtime.executor._dwconv`` so the accumulation is bit-identical."""
    return sum(w[k][None, :] *
               jax.lax.dynamic_slice_in_dim(xp, base + k, bm, axis=0)
               for k in range(taps))


def _dwconv_kernel(xp_ref, w_ref, o_ref, *, bm, taps):
    base = pl.program_id(0) * bm
    w = w_ref[...]
    # halo read: taps overlapping (bm, c) windows from the un-blocked,
    # 'same'-padded input — BlockSpecs cannot overlap, pl.ds can
    o_ref[...] = sum(w[k][None, :] * xp_ref[pl.ds(base + k, bm), :]
                     for k in range(taps))


def _dwconv_dec_kernel(man_ref, exp_ref, w_ref, o_ref, *, block, c, bm,
                       taps, mp):
    x = _dequant_vals(man_ref[...], exp_ref[...], block=block)[:, :c]
    pad = taps // 2
    xp = jnp.pad(x, ((pad, (taps - 1 - pad) + (mp - x.shape[0])), (0, 0)))
    o_ref[...] = _dw_mix(xp, w_ref[...], pl.program_id(0) * bm, bm, taps)


def _dwconv_enc_kernel(xp_ref, w_ref, o_ref, man_ref, exp_ref, *, block,
                       bm, taps):
    base = pl.program_id(0) * bm
    w = w_ref[...]
    y = sum(w[k][None, :] * xp_ref[pl.ds(base + k, bm), :]
            for k in range(taps))
    o_ref[...] = y
    c = y.shape[1]
    yq = jnp.pad(y, ((0, 0), (0, _round_up(c, block) - c)))
    man_ref[...], exp_ref[...] = _quant_vals(yq, block=block)


def _dwconv_dec_enc_kernel(man_ref, exp_ref, w_ref, o_ref, yman_ref,
                           yexp_ref, *, block, c, bm, taps, mp):
    x = _dequant_vals(man_ref[...], exp_ref[...], block=block)[:, :c]
    pad = taps // 2
    xp = jnp.pad(x, ((pad, (taps - 1 - pad) + (mp - x.shape[0])), (0, 0)))
    y = _dw_mix(xp, w_ref[...], pl.program_id(0) * bm, bm, taps)
    o_ref[...] = y
    yq = jnp.pad(y, ((0, 0), (0, _round_up(c, block) - c)))
    yman_ref[...], yexp_ref[...] = _quant_vals(yq, block=block)


def dwconv(x, w, *, payload=None, encode=False, block: int = 32,
           bm: int = 0, interpret: bool = False):
    """Streaming depthwise temporal conv (w: (taps, c), 'same' padding).

    Row-block grid with a ``taps``-row halo: the input stays un-blocked
    (index map pins it) and each grid step reads its overlapping windows
    with ``pl.ds`` — the line-buffer access pattern.  Fusion flags as in
    :func:`conv2d`; ``payload`` carries ``c`` via ``w.shape[1]``.
    """
    taps, c = w.shape
    if payload is not None:
        man, exp = payload
        m, c_pad = man.shape
        assert c_pad == _round_up(c, block), (man.shape, c, block)
    else:
        m = x.shape[0]
        assert x.shape[1] == c, (x.shape, w.shape)
    bm = _tile(m, bm, DEFAULT_BM)
    mp = _round_up(m, bm)
    pad = taps // 2
    cq = _round_up(c, block)
    grid = (mp // bm,)

    if payload is None:
        xp = jnp.pad(x, ((pad, (taps - 1 - pad) + (mp - m)), (0, 0)))
        in_specs = [pl.BlockSpec(xp.shape, lambda i: (0, 0)),
                    pl.BlockSpec((taps, c), lambda i: (0, 0))]
        if not encode:
            y = pl.pallas_call(
                functools.partial(_dwconv_kernel, bm=bm, taps=taps),
                grid=grid, in_specs=in_specs,
                out_specs=pl.BlockSpec((bm, c), lambda i: (i, 0)),
                out_shape=jax.ShapeDtypeStruct((mp, c), jnp.float32),
                interpret=interpret)(xp, w)
            return y[:m]
        y, man_o, exp_o = pl.pallas_call(
            functools.partial(_dwconv_enc_kernel, block=block, bm=bm,
                              taps=taps),
            grid=grid, in_specs=in_specs,
            out_specs=[pl.BlockSpec((bm, c), lambda i: (i, 0)),
                       pl.BlockSpec((bm, cq), lambda i: (i, 0)),
                       pl.BlockSpec((bm, cq // block), lambda i: (i, 0))],
            out_shape=[jax.ShapeDtypeStruct((mp, c), jnp.float32),
                       jax.ShapeDtypeStruct((mp, cq), jnp.int8),
                       jax.ShapeDtypeStruct((mp, cq // block), jnp.int8)],
            interpret=interpret)(xp, w)
        return y[:m], (man_o[:m], exp_o[:m])

    # ingress-fused: the payload stays un-blocked too (the decode is
    # row-local but the halo needs neighbouring rows)
    in_specs = [pl.BlockSpec((m, c_pad), lambda i: (0, 0)),
                pl.BlockSpec((m, c_pad // block), lambda i: (0, 0)),
                pl.BlockSpec((taps, c), lambda i: (0, 0))]
    if not encode:
        y = pl.pallas_call(
            functools.partial(_dwconv_dec_kernel, block=block, c=c, bm=bm,
                              taps=taps, mp=mp),
            grid=grid, in_specs=in_specs,
            out_specs=pl.BlockSpec((bm, c), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((mp, c), jnp.float32),
            interpret=interpret)(man, exp, w)
        return y[:m]
    y, man_o, exp_o = pl.pallas_call(
        functools.partial(_dwconv_dec_enc_kernel, block=block, c=c, bm=bm,
                          taps=taps, mp=mp),
        grid=grid, in_specs=in_specs,
        out_specs=[pl.BlockSpec((bm, c), lambda i: (i, 0)),
                   pl.BlockSpec((bm, cq), lambda i: (i, 0)),
                   pl.BlockSpec((bm, cq // block), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((mp, c), jnp.float32),
                   jax.ShapeDtypeStruct((mp, cq), jnp.int8),
                   jax.ShapeDtypeStruct((mp, cq // block), jnp.int8)],
        interpret=interpret)(man, exp, w)
    return y[:m], (man_o[:m], exp_o[:m])


# =============================================================================
# pool — position-axis mean, grid aligned to whole output rows
# =============================================================================

def _pool_kernel(x_ref, o_ref, *, k):
    x = x_ref[...]
    o_ref[...] = x.reshape(o_ref.shape[0], k, x.shape[1]).mean(axis=1)


def _pool_dec_kernel(man_ref, exp_ref, o_ref, *, block, c, k):
    x = _dequant_vals(man_ref[...], exp_ref[...], block=block)[:, :c]
    o_ref[...] = x.reshape(o_ref.shape[0], k, c).mean(axis=1)


def _pool_enc_kernel(x_ref, o_ref, man_ref, exp_ref, *, block, k):
    x = x_ref[...]
    c = x.shape[1]
    y = x.reshape(o_ref.shape[0], k, c).mean(axis=1)
    o_ref[...] = y
    yq = jnp.pad(y, ((0, 0), (0, _round_up(c, block) - c)))
    man_ref[...], exp_ref[...] = _quant_vals(yq, block=block)


def _pool_dec_enc_kernel(man_ref, exp_ref, o_ref, yman_ref, yexp_ref, *,
                         block, c, k):
    x = _dequant_vals(man_ref[...], exp_ref[...], block=block)[:, :c]
    y = x.reshape(o_ref.shape[0], k, c).mean(axis=1)
    o_ref[...] = y
    yq = jnp.pad(y, ((0, 0), (0, _round_up(c, block) - c)))
    yman_ref[...], yexp_ref[...] = _quant_vals(yq, block=block)


def pool(x, m_out: int, *, c: int | None = None, payload=None, encode=False,
         block: int = 32, bm: int = 0, interpret: bool = False):
    """Streaming mean-pool (m -> m_out rows).  The row-block grid tiles
    *output* rows by ``bm``, each step consuming the aligned ``bm * k``
    input rows (k = m / m_out) — windows never straddle a grid step, so
    tiling cannot reassociate any window's mean.  Fusion flags as in
    :func:`conv2d`; ingress needs ``c`` (the payload is block-padded)."""
    if payload is not None:
        assert c is not None, "pool ingress fusion needs the channel count"
        man, exp = payload
        m, c_pad = man.shape
        assert c_pad == _round_up(c, block), (man.shape, c, block)
    else:
        m, c = x.shape
    if m % m_out:
        raise ValueError(f"pool needs m_out | m, got {m} -> {m_out}")
    k = m // m_out
    bo = _tile(m_out, bm, DEFAULT_BM)
    mop = _round_up(m_out, bo)
    cq = _round_up(c, block)
    grid = (mop // bo,)

    if payload is None:
        xp = _pad_rows(x, mop * k)
        in_specs = [pl.BlockSpec((bo * k, c), lambda i: (i, 0))]
        args = (xp,)
        dec_kw = {}
        kern, kern_enc = _pool_kernel, _pool_enc_kernel
    else:
        in_specs = [pl.BlockSpec((bo * k, c_pad), lambda i: (i, 0)),
                    pl.BlockSpec((bo * k, c_pad // block),
                                 lambda i: (i, 0))]
        args = _pad_payload(payload, mop * k)
        dec_kw = {"c": c}
        kern, kern_enc = _pool_dec_kernel, _pool_dec_enc_kernel
    if not encode:
        extra = dict(block=block, **dec_kw) if dec_kw else {}
        y = pl.pallas_call(
            functools.partial(kern, k=k, **extra),
            grid=grid, in_specs=in_specs,
            out_specs=pl.BlockSpec((bo, c), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((mop, c), jnp.float32),
            interpret=interpret)(*args)
        return y[:m_out]
    y, man_o, exp_o = pl.pallas_call(
        functools.partial(kern_enc, block=block, k=k, **dec_kw),
        grid=grid, in_specs=in_specs,
        out_specs=[pl.BlockSpec((bo, c), lambda i: (i, 0)),
                   pl.BlockSpec((bo, cq), lambda i: (i, 0)),
                   pl.BlockSpec((bo, cq // block), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((mop, c), jnp.float32),
                   jax.ShapeDtypeStruct((mop, cq), jnp.int8),
                   jax.ShapeDtypeStruct((mop, cq // block), jnp.int8)],
        interpret=interpret)(*args)
    return y[:m_out], (man_o[:m_out], exp_o[:m_out])


# =============================================================================
# act — relu, the cheapest op that still rides the fused codec
# =============================================================================

def _act_kernel(x_ref, o_ref):
    o_ref[...] = jax.nn.relu(x_ref[...])


def _act_dec_kernel(man_ref, exp_ref, o_ref, *, block, c):
    x = _dequant_vals(man_ref[...], exp_ref[...], block=block)[:, :c]
    o_ref[...] = jax.nn.relu(x)


def _act_enc_kernel(x_ref, o_ref, man_ref, exp_ref, *, block):
    y = jax.nn.relu(x_ref[...])
    o_ref[...] = y
    c = y.shape[1]
    yq = jnp.pad(y, ((0, 0), (0, _round_up(c, block) - c)))
    man_ref[...], exp_ref[...] = _quant_vals(yq, block=block)


def _act_dec_enc_kernel(man_ref, exp_ref, o_ref, yman_ref, yexp_ref, *,
                        block, c):
    x = _dequant_vals(man_ref[...], exp_ref[...], block=block)[:, :c]
    y = jax.nn.relu(x)
    o_ref[...] = y
    yq = jnp.pad(y, ((0, 0), (0, _round_up(c, block) - c)))
    yman_ref[...], yexp_ref[...] = _quant_vals(yq, block=block)


def act_relu(x, *, c: int | None = None, payload=None, encode=False,
             block: int = 32, bm: int = 0, interpret: bool = False):
    """Streaming relu over the row-block grid; fusion flags as in
    :func:`conv2d` (ingress needs ``c``)."""
    if payload is not None:
        assert c is not None, "act ingress fusion needs the channel count"
        man, exp = payload
        m, c_pad = man.shape
        assert c_pad == _round_up(c, block), (man.shape, c, block)
    else:
        m, c = x.shape
    bm = _tile(m, bm, DEFAULT_BM)
    mp = _round_up(m, bm)
    cq = _round_up(c, block)
    grid = (mp // bm,)

    if payload is None:
        in_specs = [pl.BlockSpec((bm, c), lambda i: (i, 0))]
        args = (_pad_rows(x, mp),)
        if not encode:
            y = pl.pallas_call(
                _act_kernel, grid=grid, in_specs=in_specs,
                out_specs=pl.BlockSpec((bm, c), lambda i: (i, 0)),
                out_shape=jax.ShapeDtypeStruct((mp, c), jnp.float32),
                interpret=interpret)(*args)
            return y[:m]
        kern = functools.partial(_act_enc_kernel, block=block)
    else:
        in_specs = [pl.BlockSpec((bm, c_pad), lambda i: (i, 0)),
                    pl.BlockSpec((bm, c_pad // block), lambda i: (i, 0))]
        args = _pad_payload(payload, mp)
        if not encode:
            y = pl.pallas_call(
                functools.partial(_act_dec_kernel, block=block, c=c),
                grid=grid, in_specs=in_specs,
                out_specs=pl.BlockSpec((bm, c), lambda i: (i, 0)),
                out_shape=jax.ShapeDtypeStruct((mp, c), jnp.float32),
                interpret=interpret)(*args)
            return y[:m]
        kern = functools.partial(_act_dec_enc_kernel, block=block, c=c)
    y, man_o, exp_o = pl.pallas_call(
        kern, grid=grid, in_specs=in_specs,
        out_specs=[pl.BlockSpec((bm, c), lambda i: (i, 0)),
                   pl.BlockSpec((bm, cq), lambda i: (i, 0)),
                   pl.BlockSpec((bm, cq // block), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((mp, c), jnp.float32),
                   jax.ShapeDtypeStruct((mp, cq), jnp.int8),
                   jax.ShapeDtypeStruct((mp, cq // block), jnp.int8)],
        interpret=interpret)(*args)
    return y[:m], (man_o[:m], exp_o[:m])


__all__ = ["conv2d", "dwconv", "pool", "act_relu", "DEFAULT_BM",
           "DEFAULT_BC"]
