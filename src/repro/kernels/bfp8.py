"""BFP8 quant / dequant Pallas kernels — the paper's §V-A block-floating-
point format as the on-device eviction codec.

Evicted streams (KV pages, skip activations, fragmented weight panels) pass
through these before crossing the HBM<->host boundary: 16-bit words become
8-bit mantissas + one shared exponent per ``block`` values, the fixed
compile-time ratio ``(8 + 8/block)/16`` the DSE's Eq. 2/4 uses.

Tiling: one grid step processes a (rows_per_step, C) stripe held in VMEM;
the block reduction (amax -> exponent) is a lane-wise reshape, which keeps
everything in 8x128-friendly layouts.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def bfp8_quant_values(x, *, block: int):
    """Value-level quantisation math: (R, C) f32 -> (int8 mantissas (R, C),
    int8 shared exponents (R, C//block)).

    The single source of truth for the codec's numerics — the stripe
    kernels below and the fused streaming_conv ingress/egress kernels all
    call this, so a fused boundary codec cannot drift from the standalone
    ``bfp8_quant``/``bfp8_dequant`` pair by construction."""
    x = x.astype(jnp.float32)                           # (R, C)
    R, C = x.shape
    xb = x.reshape(R, C // block, block)
    amax = jnp.max(jnp.abs(xb), axis=-1)                # (R, C//block)
    exp = jnp.where(amax > 0,
                    jnp.ceil(jnp.log2(jnp.maximum(amax, 1e-38))), 0.0)
    scale = jnp.exp2(exp - 6.0)
    man = jnp.clip(jnp.round(xb / scale[..., None]), -127, 127)
    return man.reshape(R, C).astype(jnp.int8), exp.astype(jnp.int8)


def bfp8_dequant_values(man, exp, *, block: int, dtype=jnp.float32):
    """Value-level dequantisation math (inverse layout of
    :func:`bfp8_quant_values`)."""
    man = man.astype(jnp.float32)
    R, C = man.shape
    scale = jnp.exp2(exp.astype(jnp.float32) - 6.0)
    out = man.reshape(R, C // block, block) * scale[..., None]
    return out.reshape(R, C).astype(dtype)


def _quant_kernel(x_ref, man_ref, exp_ref, *, block: int):
    man_ref[...], exp_ref[...] = bfp8_quant_values(x_ref[...], block=block)


def _dequant_kernel(man_ref, exp_ref, o_ref, *, block: int):
    o_ref[...] = bfp8_dequant_values(man_ref[...], exp_ref[...], block=block,
                                     dtype=o_ref.dtype)


def bfp8_quant(x: jax.Array, *, block: int = 32, rows: int = 256,
               interpret: bool = False):
    """x: (R, C), C % block == 0 -> (mantissa int8 (R,C), exponent int8
    (R, C/block))."""
    R, C = x.shape
    rows = min(rows, R)
    assert R % rows == 0 and C % block == 0, (x.shape, rows, block)
    return pl.pallas_call(
        functools.partial(_quant_kernel, block=block),
        grid=(R // rows,),
        in_specs=[pl.BlockSpec((rows, C), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((rows, C), lambda i: (i, 0)),
                   pl.BlockSpec((rows, C // block), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((R, C), jnp.int8),
                   jax.ShapeDtypeStruct((R, C // block), jnp.int8)],
        interpret=interpret,
    )(x)


def bfp8_dequant(man: jax.Array, exp: jax.Array, *, block: int = 32,
                 rows: int = 256, dtype=jnp.float32,
                 interpret: bool = False) -> jax.Array:
    R, C = man.shape
    rows = min(rows, R)
    assert R % rows == 0
    return pl.pallas_call(
        functools.partial(_dequant_kernel, block=block),
        grid=(R // rows,),
        in_specs=[pl.BlockSpec((rows, C), lambda i: (i, 0)),
                  pl.BlockSpec((rows, C // block), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rows, C), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, C), dtype),
        interpret=interpret,
    )(man, exp)
