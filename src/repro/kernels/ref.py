"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def streamed_matmul_ref(x: jax.Array, w_static: jax.Array,
                        w_dyn: jax.Array) -> jax.Array:
    """y = x @ [w_static; w_dyn] — the fragmentation split is semantically
    invisible; only the memory placement differs."""
    w = jnp.concatenate([w_static, w_dyn], axis=0)
    return jnp.dot(x, w, preferred_element_type=jnp.float32
                   ).astype(x.dtype)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        *, causal: bool = True) -> jax.Array:
    """Plain softmax attention.  q,k,v: (B, S, H, D) (kv heads pre-repeated)."""
    B, S, H, D = q.shape
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (D ** -0.5)
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None, None], s, -2.0 ** 30)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", w, v.astype(jnp.float32))
    return out.astype(q.dtype)


def conv2d_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """1x1 channel mixing (conv/matmul/deconv): y = x @ w."""
    return jnp.dot(x, w, preferred_element_type=jnp.float32
                   ).astype(x.dtype)


def dwconv_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise temporal conv, 'same' zero padding; w: (taps, c).  The
    tap sum runs in Python ``sum`` order — the accumulation order the
    streaming_conv kernels replicate for bit parity."""
    taps = w.shape[0]
    pad = taps // 2
    xp = jnp.pad(x, ((pad, taps - 1 - pad), (0, 0)))
    m = x.shape[0]
    return sum(w[k][None, :] * xp[k:k + m] for k in range(taps))


def pool_ref(x: jax.Array, m_out: int) -> jax.Array:
    """Position-axis mean to m_out rows."""
    m, c = x.shape
    if m % m_out:
        raise ValueError(f"pool needs m_out | m, got {m} -> {m_out}")
    return x.reshape(m_out, m // m_out, c).mean(axis=1)


def act_relu_ref(x: jax.Array) -> jax.Array:
    return jax.nn.relu(x)


def bfp8_quant_ref(x: jax.Array, block: int = 32):
    """Block floating point: int8 mantissas + per-block exponent.
    x: (R, C) with C % block == 0.  Returns (mantissa i8, exponent i8)."""
    R, C = x.shape
    xb = x.astype(jnp.float32).reshape(R, C // block, block)
    amax = jnp.max(jnp.abs(xb), axis=-1)
    exp = jnp.where(amax > 0,
                    jnp.ceil(jnp.log2(jnp.maximum(amax, 1e-38))), 0.0)
    scale = jnp.exp2(exp - 6.0)
    man = jnp.clip(jnp.round(xb / scale[..., None]), -127, 127)
    return man.reshape(R, C).astype(jnp.int8), exp.astype(jnp.int8)


def bfp8_dequant_ref(man: jax.Array, exp: jax.Array, block: int = 32,
                     dtype=jnp.float32) -> jax.Array:
    R, C = man.shape
    scale = jnp.exp2(exp.astype(jnp.float32) - 6.0)
    out = man.astype(jnp.float32).reshape(R, C // block, block) \
        * scale[..., None]
    return out.reshape(R, C).astype(dtype)
