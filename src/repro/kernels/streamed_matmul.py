"""Weight-fragmentation matmul (paper §III-B, Fig. 2 -> TPU).

``y = x @ [W_static; W_dyn]`` where the *static* region of the weight matrix
is pinned in VMEM for the whole kernel invocation and the *dynamic* region
streams from HBM block-by-block — exactly the paper's static/dynamic memory
fragmentation with BRAM->VMEM and DDR->HBM.

How the pinning works: ``W_static``'s BlockSpec index_map is constant in the
``m`` (row-block) grid axis, and ``n`` is the OUTERMOST grid dimension, so
Pallas's pipeline revisiting keeps each static column-panel resident in VMEM
across every row block — it is fetched once per ``n`` instead of once per
``(m, n)``.  The dynamic panels are indexed by ``(k, n)`` and double-buffered
by the pipeline, i.e. streamed.  Per-invocation HBM traffic:

    static:   K_s * N                 (fetched once)
    dynamic:  M/bm * K_d * N          (re-fetched for every row block)

so for row-block counts > 1 the static fraction directly cuts HBM bytes —
the Eq. 3/4 trade-off with VMEM capacity as the "on-chip" constraint.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(xs_ref, xd_ref, ws_ref, wd_ref, o_ref, acc_ref, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        # static contribution once per (n, m): x_static @ W_static from VMEM
        acc_ref[...] = jnp.dot(xs_ref[...], ws_ref[...],
                               preferred_element_type=jnp.float32)

    acc_ref[...] += jnp.dot(xd_ref[...], wd_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def streamed_matmul(x: jax.Array, w_static: jax.Array, w_dyn: jax.Array,
                    *, bm: int = 128, bk: int = 128, bn: int = 128,
                    interpret: bool = False) -> jax.Array:
    """x: (M, K); w_static: (Ks, N); w_dyn: (Kd, N); K = Ks + Kd.

    Block sizes default to the MXU-aligned 128; ``Ks`` must be a multiple of
    the VMEM lane tile (128 for f32/bf16) and small enough that a (Ks, bn)
    panel fits VMEM alongside the streaming buffers.
    """
    M, K = x.shape
    Ks, N = w_static.shape
    Kd, N2 = w_dyn.shape
    assert N == N2 and K == Ks + Kd, (x.shape, w_static.shape, w_dyn.shape)
    assert M % bm == 0 and N % bn == 0 and Kd % bk == 0 and Ks % 128 == 0
    nm, nn, nk = M // bm, N // bn, Kd // bk

    x_static = x[:, :Ks]
    x_dyn = x[:, Ks:]

    grid = (nn, nm, nk)   # n outermost => static panel persists across m
    return pl.pallas_call(
        functools.partial(_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, Ks), lambda n, m, k: (m, 0)),     # x_static
            pl.BlockSpec((bm, bk), lambda n, m, k: (m, k)),     # x_dyn
            pl.BlockSpec((Ks, bn), lambda n, m, k: (0, n)),     # W_static (pinned)
            pl.BlockSpec((bk, bn), lambda n, m, k: (k, n)),     # W_dyn (streamed)
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda n, m, k: (m, n)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        # fp32 accumulator tile lives in VMEM across the k loop
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x_static, x_dyn, w_static, w_dyn)


def _round_up(n: int, mult: int) -> int:
    return ((n + mult - 1) // mult) * mult


def streamed_matmul_padded(x: jax.Array, w: jax.Array, *,
                           static_fraction: float = 0.5, bm: int = 128,
                           bk: int = 128, bn: int = 128,
                           interpret: bool = False) -> jax.Array:
    """``y = x @ w`` through :func:`streamed_matmul` for ARBITRARY shapes.

    The raw kernel needs MXU-aligned dimensions (``M % bm``, ``N % bn``,
    ``Ks % 128``, ``Kd % bk`` all zero); executable layer graphs come with
    whatever channel counts the model dictates.  This wrapper zero-pads
    ``x``/``w`` up to alignment (padded rows/columns contribute exact
    zeros), splits ``w``'s rows at the 128-aligned point closest to
    ``static_fraction`` (the plan's ``1 - m``), and slices the result back.
    A weight matrix too small to split (K <= 128 after padding) falls back
    to a plain dot — there is no dynamic region worth streaming.
    """
    M, K = x.shape
    K2, N = w.shape
    assert K == K2, (x.shape, w.shape)
    Mp, Np = _round_up(M, bm), _round_up(N, bn)
    Kp = _round_up(K, 128)
    if Kp <= 128:
        return jnp.dot(x, w, preferred_element_type=jnp.float32
                       ).astype(x.dtype)
    ks = int(round(static_fraction * Kp / 128.0)) * 128
    ks = max(min(ks, Kp - bk), 128)   # >= one static panel + one dyn block
    kd = _round_up(Kp - ks, bk)
    Kp = ks + kd
    xp = jnp.pad(x, ((0, Mp - M), (0, Kp - K)))
    wp = jnp.pad(w, ((0, Kp - K), (0, Np - N)))
    y = streamed_matmul(xp, wp[:ks], wp[ks:], bm=bm, bk=bk, bn=bn,
                        interpret=interpret)
    return y[:M, :N]


def vmem_bytes(Ks: int, N: int, bm: int, bk: int, bn: int,
               itemsize: int = 2) -> int:
    """VMEM working set the kernel claims: pinned static panel + double-
    buffered streaming blocks + accumulator (the Eq. 7 on-chip check)."""
    pinned = Ks * bn * itemsize
    stream = 2 * (bm * Ks + bm * bk + bk * bn) * itemsize
    acc = bm * bn * 4 + bm * bn * itemsize
    return pinned + stream + acc
