"""AdamW with optionally block-quantised (int8) moment states.

The quantised variant is the runtime-level twin of the paper's weight
fragmentation: optimizer moments are the largest training-time residents
(2 x fp32 per parameter), and storing them in a compressed format — int8
mantissas with a per-row fp32 scale, the same shape of trick as the paper's
BFP8 §V-A format — frees the "on-chip" (HBM) budget exactly like moving the
dynamic weight region off-chip.  For grok-1-314b on a 256-chip pod this is
the difference between fitting and not fitting (EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    quantize_states: bool = False     # int8 m/v with per-row scales
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_frac."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


# -- int8 row-quantised storage ------------------------------------------------

def _q8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Quantise along the last axis: int8 payload + fp32 row scale."""
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-20) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dq8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def init_opt_state(params: Any, cfg: AdamWConfig) -> dict:
    def zeros_like_f32(p):
        return jnp.zeros(p.shape, jnp.float32)

    if not cfg.quantize_states:
        return {"m": jax.tree.map(zeros_like_f32, params),
                "v": jax.tree.map(zeros_like_f32, params),
                "step": jnp.zeros((), jnp.int32)}

    def qzeros(p):
        return {"q": jnp.zeros(p.shape, jnp.int8),
                "s": jnp.zeros(p.shape[:-1] + (1,), jnp.float32)}

    return {"m": jax.tree.map(qzeros, params),
            "v": jax.tree.map(qzeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(params: Any, grads: Any, state: dict,
                 cfg: AdamWConfig) -> tuple[Any, dict, dict]:
    """One AdamW step.  Returns (params, state, metrics)."""
    step = state["step"] + 1
    lr = schedule(cfg, step)
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    quant = cfg.quantize_states       # static — structure, not a traced leaf
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m_f = _dq8(m["q"], m["s"]) if quant else m
        v_f = _dq8(v["q"], v["s"]) if quant else v
        m_f = cfg.b1 * m_f + (1 - cfg.b1) * g
        v_f = cfg.b2 * v_f + (1 - cfg.b2) * g * g
        upd = (m_f / bc1) / (jnp.sqrt(v_f / bc2) + cfg.eps)
        decay = cfg.weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else 0.0
        new_p = (p.astype(jnp.float32) - lr * (upd + decay)).astype(p.dtype)
        if quant:
            mq, ms = _q8(m_f)
            vq, vs = _q8(v_f)
            return new_p, {"q": mq, "s": ms}, {"q": vq, "s": vs}
        return new_p, m_f, v_f

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_state = {"m": treedef.unflatten([o[1] for o in out]),
                 "v": treedef.unflatten([o[2] for o in out]),
                 "step": step}
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_params, new_state, metrics


def opt_state_bytes(state: dict) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(state))
