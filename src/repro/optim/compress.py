"""Gradient compression for cross-pod data parallelism.

The multi-pod mesh's ``pod`` axis crosses the slow inter-pod links, so the
per-step gradient all-reduce there is the collective-roofline term the
§Perf loop attacks for training cells.  int8 quantisation with **error
feedback** (the residual of each step's quantisation is added back into the
next step's gradient) keeps SGD/Adam convergence while cutting cross-pod
bytes 4x vs f32 / 2x vs bf16.

``compressed_psum`` runs the quantise -> psum -> dequantise sequence inside
``shard_map`` over the pod axis; per-pod backward passes stay GSPMD-sharded
over (data, model) via auto axes.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Row-wise (last-axis) int8 with fp32 scales."""
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(amax.astype(jnp.float32), 1e-20) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_int8(q: jax.Array, scale: jax.Array, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def ef_compress_tree(grads: Any, error: Any) -> tuple[Any, Any, Any]:
    """Error-feedback compression over a pytree.

    Returns (quantised payloads, scales, new error residuals).  The
    residual ``g + e - dq(q(g + e))`` is carried to the next step.
    """
    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, s = quantize_int8(corrected)
        back = dequantize_int8(q, s)
        return q, s, corrected - back

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(error)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (tdef.unflatten([o[0] for o in out]),
            tdef.unflatten([o[1] for o in out]),
            tdef.unflatten([o[2] for o in out]))


def init_error_state(grads_like: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)


def compressed_psum(grads: Any, error: Any, axis_name: str = "pod"
                    ) -> tuple[Any, Any]:
    """Quantise + all-reduce over ``axis_name`` + dequantise, with error
    feedback.  Call INSIDE shard_map/pmap over the pod axis.

    Senders must agree on the scale before int payloads can be summed, so a
    cheap pmax over the (tiny) row scales runs first — the wire payload is
    then int8 mantissas + one shared fp32 scale per row: 4x fewer bytes on
    the slow inter-pod links than fp32 gradients.
    """
    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        amax = jnp.max(jnp.abs(corrected), axis=-1, keepdims=True)
        scale = jnp.maximum(amax.astype(jnp.float32), 1e-20) / 127.0
        scale = jax.lax.pmax(scale, axis_name)          # shared scale
        q = jnp.clip(jnp.round(corrected / scale), -127, 127).astype(jnp.int8)
        new_e = corrected - q.astype(jnp.float32) * scale
        summed = jax.lax.psum(q.astype(jnp.int32), axis_name)
        n = jax.lax.psum(1, axis_name)
        return (summed.astype(jnp.float32) * scale) / n, new_e

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(error)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (tdef.unflatten([o[0] for o in out]),
            tdef.unflatten([o[1] for o in out]))


def make_pod_compressed_grad_fn(loss_fn, mesh):
    """Per-pod backward + int8-EF cross-pod reduction, via shard_map over
    the ``pod`` axis (data/model stay GSPMD-auto inside each pod).

    loss_fn(params, batch) -> scalar.  Returns
    fn(params, batch, error) -> (grads, loss, new_error)
    where ``batch`` is pod-sharded on its leading axis and ``params`` are
    replicated across pods.
    """
    def per_pod(params, batch, error):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        grads, new_error = compressed_psum(grads, error, axis_name="pod")
        loss = jax.lax.pmean(loss, "pod")
        return grads, loss, new_error

    # manual over the pod axis only; data/model stay GSPMD-auto
    in_specs = (P(), P("pod"), P())
    out_specs = (P(), P(), P())
    if hasattr(jax, "shard_map"):
        return jax.shard_map(per_pod, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False,
                             axis_names={"pod"})
    # pre-0.7 jax: shard_map lives in jax.experimental and spells the
    # manual/auto split as `auto=` and replication checking as `check_rep=`
    from jax.experimental.shard_map import shard_map
    return shard_map(per_pod, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=False,
                     auto=frozenset(mesh.axis_names) - {"pod"})
