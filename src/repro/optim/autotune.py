"""Closed-loop DSE autotuner: measure plans through the streaming executors.

``core.dse.run_dse`` ranks designs with the *analytical* Eq. 5/Eq. 6 stage
latency model — cycles at the device's nominal frequency.  H2PIPE's lesson
(arXiv 2408.09209) is that such a search is only trustworthy once the
latency model is calibrated against the real pipeline.  This module closes
that loop:

1. **seed** — Algorithm 1 produces the default plan (the baseline);
2. **perturb** — SA-style moves mutate the plan genome, mirroring the
   knobs ``run_dse``'s allocator owns: stage split points
   (split / merge), the eviction edge set (evict / unevict, deep-buffer
   edges first, codec per ``AutotuneConfig.codecs``), and per-layer weight
   fragmentation ratios (frag, ±``frag_step``);
3. **measure** — every candidate is lowered by
   ``runtime.streamer.lower_plan_pipelined`` and executed on a real
   microbatch stream; steady-state fps is recorded per candidate (plus
   per-stage wall-clock latencies for accepted ones, as a diagnostic);
4. **calibrate** — in steady state one pipeline tick costs the slowest
   stage (Eq. 6), so a least-squares fit of each candidate's measured
   seconds-per-frame against its analytic ``eq6`` cycles yields
   ``s_per_cycle``, turning the ``schedule.stage_latencies`` model into a
   calibrated predictor (:func:`calibrated_latency_hook`); the
   :class:`CalibrationReport` quantifies prediction error before/after;
5. **re-rank** — the trajectory carries predicted-vs-measured fps per
   candidate, and the best *measured* plan wins (the seed is candidate 0,
   so the winner is never worse than the default DSE plan).

Measurement is injectable (``measure_fps`` / ``measure_stages``) so tests
can drive the whole loop with a deterministic stub clock.
"""
from __future__ import annotations

import bisect
import dataclasses
import json
import math
import random
import time
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.builders import exec_input_shape
from repro.core.dse import DSEConfig, run_dse
from repro.core.graph import Graph
from repro.core.pipeline import initiation_interval
from repro.core.plan import ExecutionPlan, LayerPlan, StreamPlan, plan_from_dse
from repro.core.resources import Device
from repro.memory import ChannelConfig, build_memory_model
from repro.obs.trace import NULL_RECORDER
from repro.runtime.executor import (WEIGHT_KINDS, analyze_plan,
                                    resolve_kernel_mode)
from repro.runtime.streamer import (StreamingExecutor, eq5_sequential_time,
                                    eq6_pipeline_time,
                                    lower_plan_pipelined,
                                    measured_stage_latencies, stage_latencies,
                                    stage_weight_bits)

MOVES = ("split", "merge", "evict", "unevict", "frag", "tile")

# candidate Pallas tile sizes for the "tile" move (0 = kernel default).
# Results are tile-independent (bit-exact — tests/test_properties.py), so
# these are pure performance knobs; only proposed when the resolved kernel
# mode actually dispatches to the streaming_conv Pallas bodies.
TILE_BM_CHOICES = (0, 8, 16, 32, 64, 128)
TILE_BC_CHOICES = (0, 32, 64, 128)


@dataclasses.dataclass
class AutotuneConfig:
    """Knobs of the measured-in-the-loop search.

    ``n_candidates`` counts *evaluated* plans including the seed; every
    candidate costs one pipelined lowering (a jit trace) plus measurement,
    so smoke configs keep it small.  ``dse`` configures the seed plan's
    Algorithm 1 run (default: eviction+fragmentation-friendly settings at
    16-bit words).
    """
    n_candidates: int = 12
    microbatches: int = 8
    seed: int = 0
    init_temperature: float = 0.2     # SA temperature, relative fps units
    cooling: float = 0.85
    codecs: tuple[str, ...] = ("bfp8",)
    frag_step: float = 0.125
    min_static_fraction: float = 0.25
    max_stages: int = 6
    repeats: int = 3
    warmup: int = 1
    kernel_mode: str = "auto"
    dse: DSEConfig | None = None
    #: opt-in off-chip channel model: candidates whose aggregate stream
    #: demand oversubscribes the channel are *pruned* (recorded with
    #: ``pruned=True``, fps 0, never lowered or measured), and the
    #: trajectory carries the contended Eq. 6 ranking alongside the
    #: uncontended one.
    channel: ChannelConfig | None = None


@dataclasses.dataclass
class CandidateRecord:
    """Predicted-vs-measured bookkeeping for one evaluated plan."""
    index: int
    move: str                  # "seed" or the SA move that produced it
    accepted: bool             # became the SA current point
    n_stages: int
    n_evicted: int
    n_fragged: int
    fps_measured: float        # steady-state frames/s through the streamer
    eq5_cycles: float          # analytic sequential frame time (cycles)
    eq6_cycles: float          # analytic slowest-stage frame time (cycles)
    stage_cycles: list[float]  # analytic L_j
    # measured L_j wall clock, stage-by-stage dispatch — a per-stage
    # diagnostic recorded for accepted candidates only (dispatch overhead
    # the fused pipeline amortises makes it unsuitable for the tick fit)
    stage_seconds: list[float] = dataclasses.field(default_factory=list)
    fps_eq6_pre: float = 0.0   # Eq. 6 at nominal frequency (uncalibrated)
    fps_eq6_cal: float = 0.0   # Eq. 6 with the fitted s_per_cycle
    best_so_far: bool = False
    # channel-model fields (cfg.channel set): contended Eq. 6 frame time,
    # whether aggregate stream demand fits the channel, and whether the
    # candidate was pruned before lowering (infeasible -> never measured)
    eq6_contended_cycles: float = 0.0
    feasible: bool = True
    pruned: bool = False

    @property
    def bottleneck_stage(self) -> int:
        """The stage setting Eq. 6's ``max_j(L_j)`` for this candidate —
        the attribution the search is otherwise blind to."""
        return max(range(len(self.stage_cycles)),
                   key=lambda j: self.stage_cycles[j])


@dataclasses.dataclass
class CalibrationReport:
    """Fit of the analytic stage-latency model to measured tick times.

    In steady state one pipeline tick costs the slowest stage — Eq. 6 —
    so ``s_per_cycle`` is the least-squares (through-origin) scale mapping
    each candidate's analytic ``eq6_cycles`` to its *measured* per-frame
    (per-tick) seconds through the streamer.  ``pre_err`` / ``post_err``
    are ``|log(t_pred / t_meas)|`` of the winning plan's Eq. 6 frame time
    before calibration (cycles at ``freq_mhz``) and after (cycles x
    ``s_per_cycle``); the closed loop is working when
    ``post_err < pre_err``.
    """
    s_per_cycle: float
    n_points: int
    freq_mhz: float
    pre_err: float
    post_err: float

    @property
    def improved(self) -> bool:
        return self.post_err < self.pre_err

    def summary(self) -> dict:
        return dataclasses.asdict(self) | {"improved": self.improved}


@dataclasses.dataclass
class AutotuneResult:
    model: str
    device: str
    best_plan: ExecutionPlan
    best_fps: float            # measured, pipelined
    baseline_fps: float        # measured fps of the seed (default DSE) plan
    trajectory: list[CandidateRecord]
    calibration: CalibrationReport
    microbatches: int
    recorder: object = None    # obs recorder the search narrated into

    def summary(self) -> dict:
        return {
            "model": self.model,
            "device": self.device,
            "candidates": len(self.trajectory),
            "microbatches": self.microbatches,
            "baseline_fps": self.baseline_fps,
            "best_fps": self.best_fps,
            "speedup": self.best_fps / max(self.baseline_fps, 1e-30),
            "best_n_stages": self.best_plan.n_stages,
            "best_evicted": sum(1 for s in self.best_plan.streams if s.evicted),
            "best_fragged": sum(1 for lp in self.best_plan.layers.values()
                                if lp.weight_static_fraction < 1.0),
            "calibration": self.calibration.summary(),
        }

    def trajectory_rows(self) -> list[dict]:
        """Flat per-candidate rows (the ``--autotune`` JSON/CSV schema)."""
        return [{
            "candidate": r.index, "move": r.move, "accepted": r.accepted,
            "best_so_far": r.best_so_far, "n_stages": r.n_stages,
            "evicted": r.n_evicted, "fragged": r.n_fragged,
            "fps_measured": r.fps_measured, "fps_eq6_pre": r.fps_eq6_pre,
            "fps_eq6_cal": r.fps_eq6_cal,
            "bottleneck_stage": r.bottleneck_stage,
            "eq6_contended_cycles": r.eq6_contended_cycles,
            "feasible": r.feasible, "pruned": r.pruned,
        } for r in self.trajectory]

    def to_json(self) -> str:
        return json.dumps({
            "summary": self.summary(),
            "trajectory": self.trajectory_rows(),
            "best_plan": json.loads(self.best_plan.to_json()),
        }, indent=1)


# =============================================================================
# Measurement hooks (injectable — tests stub these for determinism)
# =============================================================================

def measure_pipelined_fps(sx: StreamingExecutor, xs: jax.Array, *,
                          repeats: int = 3, warmup: int = 1) -> float:
    """Steady-state frames/s of one pipelined executor.

    Best-of-N wall clock over the whole stream, normalised by the
    schedule's tick count ``T = B + S - 1`` rather than by ``B``: the run
    includes the fill/drain bubbles, but in steady state the pipeline
    retires one frame per tick, so ``T / wall`` is the steady-state rate.
    Dividing by ``B`` instead would charge the S-1 bubble ticks to the
    frames and bias any cross-plan comparison against deeper pipelines.
    """
    for _ in range(warmup):
        sx(xs).block_until_ready()
    best = math.inf
    for _ in range(repeats):
        t0 = time.perf_counter()
        sx(xs).block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return sx.report.ticks / best


def calibrated_latency_hook(s_per_cycle: float):
    """A ``schedule.stage_latencies`` hook predicting measured *seconds*:
    the analytic initiation interval scaled by the fitted ``s_per_cycle``."""
    return lambda j, sg: s_per_cycle * initiation_interval(sg)


# =============================================================================
# Plan genome: the mutable decision vector the SA moves act on
# =============================================================================

@dataclasses.dataclass
class _Genome:
    bounds: list[int]                       # topo indices starting stages 1..
    evict: dict[tuple[str, str], str]       # edge -> codec
    frac: dict[str, float]                  # layer -> static weight fraction
    tile_bm: int = 0                        # Pallas row block (0 = default)
    tile_bc: int = 0                        # Pallas out-channel block

    def clone(self) -> "_Genome":
        return _Genome(list(self.bounds), dict(self.evict), dict(self.frac),
                       self.tile_bm, self.tile_bc)


def _genome_from_plan(plan: ExecutionPlan, topo: list[str]) -> _Genome:
    # stages must be contiguous along topo order; normalise with a cummax
    # so any valid plan (producers never after consumers) maps cleanly
    bounds, cur = [], 0
    for i, n in enumerate(topo):
        s = max(plan.layers[n].stage, cur)
        if s > cur:
            bounds.append(i)
            cur = s
    evict = {(s.src, s.dst): s.codec for s in plan.streams if s.evicted}
    frac = {n: lp.weight_static_fraction for n, lp in plan.layers.items()
            if lp.weight_static_fraction < 1.0}
    return _Genome(bounds=bounds, evict=evict, frac=frac,
                   tile_bm=plan.tile_bm, tile_bc=plan.tile_bc)


def _plan_from_genome(g: Graph, topo: list[str], genome: _Genome, *,
                      model: str, device: str,
                      microbatch: int) -> ExecutionPlan:
    bounds = sorted(genome.bounds)
    layers = {}
    for i, n in enumerate(topo):
        layers[n] = LayerPlan(
            name=n, stage=bisect.bisect_right(bounds, i),
            weight_static_fraction=genome.frac.get(n, 1.0))
    streams = [StreamPlan(e.src, e.dst,
                          evicted=(e.src, e.dst) in genome.evict,
                          codec=genome.evict.get((e.src, e.dst), "none"))
               for e in g.edges()]
    return ExecutionPlan(model=model, device=device,
                         n_stages=len(bounds) + 1, layers=layers,
                         streams=streams, microbatch=microbatch,
                         topo_order=topo, tile_bm=genome.tile_bm,
                         tile_bc=genome.tile_bc)


def _propose(genome: _Genome, g: Graph, topo: list[str],
             deep_edges: list[tuple[str, str]], weighty: list[str],
             rng: random.Random, cfg: AutotuneConfig, *,
             tile_moves: bool = False) -> tuple[_Genome, str] | None:
    """One SA move on a clone of ``genome``; None when no move applies.

    ``tile_moves`` gates the "tile" move on the resolved kernel mode: the
    tile genes only reach the streaming_conv Pallas bodies, so proposing
    them under reference dispatch would measure pure noise."""
    moves = [m for m in MOVES if tile_moves or m != "tile"]
    rng.shuffle(moves)
    for move in moves:
        cand = genome.clone()
        if move == "split" and len(cand.bounds) + 1 < cfg.max_stages:
            options = [i for i in range(1, len(topo))
                       if i not in cand.bounds]
            if options:
                cand.bounds = sorted(cand.bounds + [rng.choice(options)])
                return cand, move
        elif move == "merge" and cand.bounds:
            cand.bounds.remove(rng.choice(cand.bounds))
            return cand, move
        elif move == "evict":
            options = [e for e in deep_edges if e not in cand.evict]
            if options:
                cand.evict[rng.choice(options)] = rng.choice(cfg.codecs)
                return cand, move
        elif move == "unevict" and cand.evict:
            del cand.evict[rng.choice(sorted(cand.evict))]
            return cand, move
        elif move == "frag" and weighty:
            name = rng.choice(weighty)
            cur = cand.frac.get(name, 1.0)
            new = min(1.0, max(cfg.min_static_fraction,
                               cur + rng.choice((-1, 1)) * cfg.frag_step))
            if new != cur:
                if new >= 1.0:
                    cand.frac.pop(name, None)
                else:
                    cand.frac[name] = new
                return cand, move
        elif move == "tile":
            if rng.random() < 0.5:
                options = [b for b in TILE_BM_CHOICES if b != cand.tile_bm]
                cand.tile_bm = rng.choice(options)
            else:
                options = [b for b in TILE_BC_CHOICES if b != cand.tile_bc]
                cand.tile_bc = rng.choice(options)
            return cand, move
    return None


# =============================================================================
# The autotuner
# =============================================================================

def autotune(g: Graph, dev: Device, cfg: AutotuneConfig | None = None, *,
             measure_fps: Callable[[StreamingExecutor, jax.Array], float]
             | None = None,
             measure_stages: Callable[[StreamingExecutor, jax.Array],
                                      list[float]] | None = None,
             recorder=NULL_RECORDER, metrics=None) -> AutotuneResult:
    """Measured-in-the-loop plan search over executable graph ``g``.

    The seed candidate is the default DSE plan (``run_dse`` under
    ``cfg.dse``); subsequent candidates are SA perturbations of the plan
    genome, each *executed* through the pipelined streamer on a
    ``cfg.microbatches``-deep stream.  Returns the best measured plan, the
    full predicted-vs-measured trajectory, and the latency-model
    calibration fitted from every measured stage.

    ``recorder`` (an ``obs`` recorder) narrates the search: one span per
    candidate on the ``autotune`` track, carrying the move, acceptance,
    measured fps and the bottleneck-stage attribution.  ``metrics`` (a
    :class:`~repro.obs.metrics.MetricsRegistry`) keeps live per-candidate
    accounting: ``smof_autotune_candidates_total`` by acceptance plus
    baseline/best-fps and calibration gauges.
    """
    cfg = cfg or AutotuneConfig()
    rng = random.Random(cfg.seed)
    m_cand = m_best = m_baseline = m_spc = None
    if metrics is not None:
        m_cand = metrics.counter(
            "smof_autotune_candidates_total",
            "evaluated SA candidates, by acceptance", ("accepted",))
        m_best = metrics.gauge(
            "smof_autotune_best_fps", "best measured pipelined fps so far")
        m_baseline = metrics.gauge(
            "smof_autotune_baseline_fps",
            "measured fps of the seed (default DSE) plan")
        m_spc = metrics.gauge(
            "smof_autotune_s_per_cycle",
            "calibrated seconds per model cycle (through-origin fit)")
    measure_fps = measure_fps or (
        lambda sx, xs: measure_pipelined_fps(sx, xs, repeats=cfg.repeats,
                                             warmup=cfg.warmup))
    measure_stages = measure_stages or (
        lambda sx, x: measured_stage_latencies(sx, x, repeats=cfg.repeats,
                                               warmup=cfg.warmup))

    # -- seed: the default DSE plan ------------------------------------------
    dse_cfg = cfg.dse or DSEConfig(batch=1, codecs=("none",) + cfg.codecs,
                                   word_bits=16, cut_kinds=("pool", "conv"))
    res = run_dse(g, dev, dse_cfg)
    seed_plan = plan_from_dse(g.name, dev.name, res,
                              microbatch=cfg.microbatches)
    topo = g.topo()
    genome = _genome_from_plan(seed_plan, topo)

    g.compute_buffer_depths()
    in_out = {n for n in topo if g.vertex(n).kind in ("input", "output")}
    ranked = sorted((e for e in g.edges()
                     if e.src not in in_out and e.dst not in in_out),
                    key=lambda e: e.buffer_depth, reverse=True)
    deep_edges = [(e.src, e.dst) for e in ranked[:max(len(ranked) // 2, 1)]]
    weighty = [n for n in topo if g.vertex(n).kind in WEIGHT_KINDS]
    # tile genes only matter when the resolved mode dispatches to Pallas
    tile_moves = resolve_kernel_mode(cfg.kernel_mode, None)[0]

    in_shape = exec_input_shape(g)
    x = jax.random.normal(jax.random.PRNGKey(cfg.seed), in_shape, jnp.float32)
    xs = jnp.broadcast_to(x, (cfg.microbatches,) + in_shape)

    def channel_view(plan: ExecutionPlan) -> tuple[bool, float]:
        """(feasible, contended eq6 cycles) under ``cfg.channel`` — from
        the analytic models only, no lowering, so pruning an infeasible
        candidate costs a plan analysis instead of a jit trace."""
        if cfg.channel is None:
            return True, 0.0
        an = analyze_plan(g, plan, use_pallas=False, interpret=False)
        mem = build_memory_model(
            spills=an.spills,
            weight_bits_by_stage=stage_weight_bits(g, an),
            stage_of=an.stage_of,
            base_latencies=stage_latencies(g, plan),
            gbps=dev.offchip_gbps, freq_mhz=dev.freq_mhz,
            config=cfg.channel, microbatches=cfg.microbatches)
        return mem.arbitration.feasible, mem.eq6_contended_cycles

    def evaluate(genome: _Genome, index: int, move: str, *,
                 prune: bool = True
                 ) -> tuple[CandidateRecord, ExecutionPlan,
                            StreamingExecutor | None]:
        plan = _plan_from_genome(g, topo, genome, model=g.name,
                                 device=dev.name,
                                 microbatch=cfg.microbatches)
        feasible, eq6c = channel_view(plan)
        cyc = stage_latencies(g, plan)               # analytic, cycles
        rec = CandidateRecord(
            index=index, move=move, accepted=False,
            n_stages=plan.n_stages,
            n_evicted=sum(1 for s in plan.streams if s.evicted),
            n_fragged=sum(1 for lp in plan.layers.values()
                          if lp.weight_static_fraction < 1.0),
            fps_measured=0.0,
            eq5_cycles=eq5_sequential_time(cyc),
            eq6_cycles=eq6_pipeline_time(cyc),
            stage_cycles=list(cyc),
            eq6_contended_cycles=eq6c, feasible=feasible)
        if prune and not feasible:
            rec.pruned = True
            if recorder.enabled:
                recorder.instant(f"prune:{move}", track="autotune",
                                 args={"candidate": index,
                                       "eq6_contended_cycles": eq6c})
            return rec, plan, None
        with recorder.span(f"candidate{index}", track="autotune", cat=move,
                           args={"candidate": index, "move": move}) as sa:
            sx = lower_plan_pipelined(g, plan, microbatches=cfg.microbatches,
                                      kernel_mode=cfg.kernel_mode,
                                      channel=cfg.channel, device=dev)
            rec.fps_measured = measure_fps(sx, xs)
            sa.update({"fps_measured": rec.fps_measured,
                       "n_stages": rec.n_stages,
                       "bottleneck_stage": rec.bottleneck_stage})
        return rec, plan, sx

    trajectory: list[CandidateRecord] = []
    # the seed is always measured (prune=False): it anchors the baseline
    # fps, and an infeasible-but-measured seed is strictly better than no
    # plan at all — only *moves away* from it get pruned
    rec, plan, sx = evaluate(genome, 0, "seed", prune=False)
    rec.accepted = rec.best_so_far = True
    rec.stage_seconds = list(measure_stages(sx, x))
    trajectory.append(rec)
    baseline_fps = cur_fps = best_fps = rec.fps_measured
    best_plan, best_rec = plan, rec
    if m_cand is not None:
        m_cand.labels(accepted="true").inc()
        m_baseline.set(baseline_fps)
        m_best.set(best_fps)

    temp = cfg.init_temperature
    for i in range(1, cfg.n_candidates):
        prop = _propose(genome, g, topo, deep_edges, weighty, rng, cfg,
                        tile_moves=tile_moves)
        if prop is None:
            break
        cand, move = prop
        rec, plan, sx = evaluate(cand, i, move)
        if rec.pruned:
            # bandwidth-infeasible: recorded, never accepted, never best
            trajectory.append(rec)
            if m_cand is not None:
                m_cand.labels(accepted="false").inc()
            temp *= cfg.cooling
            continue
        delta = (rec.fps_measured - cur_fps) / max(cur_fps, 1e-30)
        accept = delta >= 0 or rng.random() < math.exp(delta / max(temp, 1e-9))
        if accept:
            genome, cur_fps = cand, rec.fps_measured
            rec.accepted = True
            rec.stage_seconds = list(measure_stages(sx, x))
        if recorder.enabled:
            recorder.instant(f"{'accept' if accept else 'reject'}:{move}",
                             track="autotune",
                             args={"candidate": i,
                                   "fps_measured": rec.fps_measured})
        if m_cand is not None:
            m_cand.labels(accepted="true" if accept else "false").inc()
        if rec.fps_measured > best_fps:
            best_fps, best_plan, best_rec = rec.fps_measured, plan, rec
            rec.best_so_far = True
            if m_best is not None:
                m_best.set(best_fps)
        trajectory.append(rec)
        temp *= cfg.cooling

    # -- calibrate the latency model against measured tick times -------------
    # steady-state tick time == Eq. 6 slowest-stage time, so each candidate
    # contributes one (analytic eq6 cycles, measured seconds/frame) point
    pts = [(r.eq6_cycles, 1.0 / r.fps_measured) for r in trajectory
           if r.eq6_cycles > 0 and r.fps_measured > 0]
    denom = sum(a * a for a, _ in pts)
    s_per_cycle = (sum(a * m for a, m in pts) / denom) if denom else 0.0
    if m_spc is not None:
        m_spc.set(s_per_cycle)
    nominal = 1.0 / (dev.freq_mhz * 1e6)
    for r in trajectory:
        r.fps_eq6_pre = 1.0 / (r.eq6_cycles * nominal)
        # with a channel model the ranking estimate is the *contended*
        # Eq. 6 — the channel, not compute, may set the bottleneck
        eff = (max(r.eq6_contended_cycles, r.eq6_cycles)
               if cfg.channel is not None else r.eq6_cycles)
        if s_per_cycle > 0 and math.isfinite(eff) and eff > 0:
            r.fps_eq6_cal = 1.0 / (eff * s_per_cycle)

    t_meas = 1.0 / best_rec.fps_measured
    pre_err = abs(math.log((best_rec.eq6_cycles * nominal) / t_meas))
    post_err = (abs(math.log((best_rec.eq6_cycles * s_per_cycle) / t_meas))
                if s_per_cycle > 0 else math.inf)
    calib = CalibrationReport(s_per_cycle=s_per_cycle, n_points=len(pts),
                              freq_mhz=dev.freq_mhz, pre_err=pre_err,
                              post_err=post_err)

    best_plan.est_throughput_fps = best_rec.fps_eq6_cal
    best_plan.est_latency_s = best_rec.eq5_cycles * (s_per_cycle or nominal)
    return AutotuneResult(model=g.name, device=dev.name, best_plan=best_plan,
                          best_fps=best_fps, baseline_fps=baseline_fps,
                          trajectory=trajectory, calibration=calib,
                          microbatches=cfg.microbatches,
                          recorder=recorder if recorder.enabled else None)


# =============================================================================
# CLI entry point — routed through the compile façade (repro.api)
# =============================================================================

def main(argv: list[str] | None = None) -> None:
    """``python -m repro.optim.autotune``: closed-loop search via the
    façade.  Compiles ``strategy="autotune"`` and prints the summary; with
    ``--save`` the winning design lands as a versioned ``Compiled``
    artifact any fresh process can ``repro.Compiled.load`` and serve."""
    import argparse

    from repro.api import add_compile_args, compile as smof_compile, \
        spec_from_args
    from repro.core.builders import EXEC_MODELS

    ap = argparse.ArgumentParser(prog="repro.optim.autotune")
    # "reference" is plan-free — nothing to autotune — so it is not offered
    add_compile_args(ap, models=EXEC_MODELS, default_model="unet_exec",
                     default_mode="pipelined",
                     modes=("staged", "pipelined"))
    ap.add_argument("--candidates", type=int, default=12,
                    help="evaluated plans incl. the seed")
    ap.add_argument("--microbatches", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the AutotuneResult trajectory as JSON")
    ap.add_argument("--save", default=None, metavar="PATH",
                    help="save the compiled winner as a Compiled artifact")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a Chrome trace of the search (one span per "
                         "candidate, with bottleneck-stage attribution)")
    args = ap.parse_args(argv)

    from repro.obs import ObsConfig

    cfg = AutotuneConfig(n_candidates=args.candidates,
                         microbatches=args.microbatches, seed=args.seed)
    compiled = smof_compile(spec_from_args(
        args, strategy="autotune", autotune_cfg=cfg, seed=args.seed,
        microbatches=args.microbatches,
        obs=ObsConfig(enabled=args.trace is not None,
                      trace_path=args.trace)))
    res = compiled.autotune_result
    print(json.dumps(res.summary(), indent=1))
    if args.json:
        with open(args.json, "w") as f:
            f.write(res.to_json())
    if args.trace and res.recorder is not None:
        print(f"trace: {res.recorder.save(args.trace)}")
    if args.save:
        print(f"saved: {compiled.save(args.save)}")


if __name__ == "__main__":
    main()
