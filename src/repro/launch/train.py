"""Production training launcher.

    python -m repro.launch.train --arch yi-6b --steps 100 \
        [--mesh single|multi|host] [--smoke] [--ckpt-dir DIR] [--restore]

On the real cluster ``--mesh single|multi`` builds the production mesh
(jax.distributed.initialize is called when JAX_COORDINATOR is set); on this
container ``--smoke`` runs the reduced config on the host mesh.  The loop
is fault-tolerant: async checkpoints, deterministic data resume, straggler
logging (runtime/fault.py).
"""
from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp

from repro.checkpoint.store import CheckpointStore
from repro.configs import ARCHS
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import init_params
from repro.optim.adamw import AdamWConfig, init_opt_state
from repro.runtime.fault import FaultConfig, FaultTolerantLoop
from repro.runtime.steps import make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--mesh", default="host", choices=("host", "single", "multi"))
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--remat", default="full", choices=("none", "dots", "full"))
    ap.add_argument("--quantize-opt", action="store_true")
    ap.add_argument("--ckpt-dir", default="checkpoints")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--restore", action="store_true")
    ap.add_argument("--dtype", default="float32", choices=("float32", "bfloat16"))
    args = ap.parse_args()

    if os.environ.get("JAX_COORDINATOR"):      # multi-host cluster
        jax.distributed.initialize()

    cfg = ARCHS[args.arch]
    if args.smoke:
        cfg = cfg.reduced()
    dtype = jnp.bfloat16 if args.dtype == "bfloat16" else jnp.float32
    mesh = (make_host_mesh() if args.mesh == "host"
            else make_production_mesh(multi_pod=args.mesh == "multi"))
    opt_cfg = AdamWConfig(lr=args.lr, total_steps=args.steps,
                          quantize_states=args.quantize_opt)
    data = TokenPipeline(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                    global_batch=args.batch))
    store = CheckpointStore(args.ckpt_dir, keep_last=3)

    with mesh:
        step_fn, (p_sh, o_sh), donate = make_train_step(
            cfg, mesh, opt_cfg, remat=args.remat, dtype=dtype)
        params = jax.device_put(
            init_params(jax.random.PRNGKey(0), cfg, dtype=dtype), p_sh)
        opt = jax.device_put(init_opt_state(params, opt_cfg), o_sh)
        jstep = jax.jit(step_fn, donate_argnums=donate)

        losses = []

        def run_step(state, batch):
            p, o = state
            p, o, metrics = jstep(p, o, jax.tree.map(jnp.asarray, batch))
            losses.append(float(metrics["loss"]))
            return (p, o)

        loop = FaultTolerantLoop(run_step, store,
                                 FaultConfig(checkpoint_every=args.ckpt_every))
        state, start = ((params, opt), 0)
        if args.restore:
            state, start = loop.try_restore((params, opt),
                                            shardings=(p_sh, o_sh))
            print(f"restored; resuming at step {start}")
        state = loop.run(state, data.batch_at, start_step=start,
                         num_steps=args.steps - start)
    print(f"{cfg.name}: {len(losses)} steps, loss {losses[0]:.4f} -> "
          f"{losses[-1]:.4f}; events: {[e['kind'] for e in loop.events]}")


if __name__ == "__main__":
    main()
