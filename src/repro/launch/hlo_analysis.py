"""Post-compile HLO analysis: collective-traffic accounting for §Roofline.

``compiled.cost_analysis()`` reports FLOPs and bytes but NOT collective
traffic, so we parse the optimised HLO text and sum the *operand* bytes of
every communication op:

  all-reduce           operand = result
  all-gather           operand = result / group_size
  reduce-scatter       operand = result * group_size
  all-to-all           operand = result
  collective-permute   operand = result

Async pairs (``-start`` / ``-done``) are counted once, on the start op.
"""
from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}

_COLL = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
         "collective-permute")

# e.g.  bf16[2,4096,512]{2,1,0}
_TYPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# e.g.  replica_groups=[32,16]<=[512]   or  replica_groups={{0,1},{2,3}}
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


@dataclasses.dataclass
class CollectiveStats:
    """Byte counts are PER-DEVICE operand bytes, summed over ops."""
    by_kind: dict
    total_bytes: float
    n_ops: int

    def to_json(self) -> dict:
        return {"by_kind": self.by_kind, "total_bytes": self.total_bytes,
                "n_ops": self.n_ops}


def collective_stats(hlo_text: str) -> CollectiveStats:
    by_kind: dict[str, float] = {}
    n_ops = 0
    for line in hlo_text.splitlines():
        stripped = line.strip()
        op = None
        for kind in _COLL:
            # match " = TYPE kind(" and async "kind-start("; skip -done
            if (f" {kind}(" in stripped or f" {kind}-start(" in stripped):
                op = kind
                break
        if op is None or "-done(" in stripped:
            continue
        # the first type token after "= " is the result type; tuples (async
        # start) list operand then result — take the LAST full shape, which
        # is the payload, and fall back to the first.
        after = stripped.split("= ", 1)
        if len(after) != 2:
            continue
        types = _TYPE_RE.findall(after[1].split("(")[0])
        if not types:
            continue
        result_bytes = max(_shape_bytes(dt, dims) for dt, dims in types)
        g = _group_size(stripped)
        if op == "all-gather":
            operand = result_bytes / max(g, 1)
        elif op == "reduce-scatter":
            operand = result_bytes * max(g, 1)
        else:
            operand = result_bytes
        by_kind[op] = by_kind.get(op, 0.0) + operand
        n_ops += 1
    return CollectiveStats(by_kind=by_kind,
                           total_bytes=sum(by_kind.values()), n_ops=n_ops)


# =============================================================================
# Trip-count-aware accounting
#
# XLA's cost_analysis() counts each while-loop body ONCE (verified on this
# jax build), so for scan-over-layers models both FLOPs and collective bytes
# are understated by the trip count.  We parse the optimised HLO into its
# computations, recover each loop's trip count from its condition
# computation, propagate call-multipliers from the entry computation, and
# re-account dot FLOPs and collective operand bytes with multipliers.
# =============================================================================

_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->.*\{\s*$")
_OP_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\w+)\[([\d,]*)\]")
_PARAM_RE = re.compile(r"([\w.\-]+):\s*(\w+)\[([\d,]*)\]")
_CALL_ATTR_RE = re.compile(
    r"(?:calls|body|condition|to_apply)=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_DOT_DIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _parse_computations(hlo: str) -> dict[str, dict]:
    """name -> {"lines": [...], "entry": bool, "params": {name: (dt, dims)}}"""
    comps: dict[str, dict] = {}
    cur = None
    for line in hlo.splitlines():
        m = _COMP_HDR_RE.match(line.strip())
        if m and line.rstrip().endswith("{"):
            name = m.group(2)
            params = {pm.group(1): (pm.group(2), pm.group(3))
                      for pm in _PARAM_RE.finditer(m.group(3))}
            cur = {"lines": [], "entry": bool(m.group(1)), "params": params}
            comps[name] = cur
        elif cur is not None:
            if line.strip() == "}":
                cur = None
            else:
                cur["lines"].append(line)
    return comps


def _trip_count(cond_comp: dict) -> int:
    """Loop bound heuristic: the largest integer constant compared in the
    condition computation (scan conditions are `i < N`)."""
    consts = [int(c) for ln in cond_comp["lines"]
              for c in _CONST_RE.findall(ln)]
    return max(consts, default=1)


def _multipliers(comps: dict[str, dict]) -> dict[str, float]:
    entry = next((n for n, c in comps.items() if c["entry"]), None)
    mult: dict[str, float] = {}
    if entry is None:
        return {n: 1.0 for n in comps}
    stack = [(entry, 1.0)]
    while stack:
        name, m = stack.pop()
        if name not in comps or mult.get(name, 0.0) >= m:
            continue
        mult[name] = max(mult.get(name, 0.0), m)
        for ln in comps[name]["lines"]:
            callees = _CALL_ATTR_RE.findall(ln)
            br = _BRANCHES_RE.search(ln)
            if br:
                callees += [c.strip().lstrip("%") for c in br.group(1).split(",")]
            if " while(" in ln or ln.strip().startswith("while"):
                trip = 1
                cond = re.search(r"condition=%?([\w.\-]+)", ln)
                if cond and cond.group(1) in comps:
                    trip = _trip_count(comps[cond.group(1)])
                for c in callees:
                    stack.append((c, m * trip))
            else:
                for c in callees:
                    stack.append((c, m))
    for n in comps:
        mult.setdefault(n, 1.0)
    return mult


def _symbols(comp: dict) -> dict[str, tuple[str, str]]:
    syms = dict(comp["params"])
    for ln in comp["lines"]:
        m = _OP_DEF_RE.match(ln)
        if m:
            syms[m.group(1)] = (m.group(2), m.group(3))
    return syms


def _dims(dims_str: str) -> list[int]:
    return [int(d) for d in dims_str.split(",") if d]


def trip_aware_stats(hlo: str) -> dict:
    """Trip-count-aware dot FLOPs + collective operand bytes (per device)."""
    comps = _parse_computations(hlo)
    mult = _multipliers(comps)
    flops = 0.0
    dot_bytes = 0.0
    by_kind: dict[str, float] = {}
    n_ops = 0
    trip_counts = {}
    for name, comp in comps.items():
        m = mult[name]
        syms = _symbols(comp)
        for ln in comp["lines"]:
            s = ln.strip()
            # ---- dots ----------------------------------------------------
            if " dot(" in s:
                mdef = _OP_DEF_RE.match(ln)
                mc = _DOT_DIMS_RE.search(s)
                if not (mdef and mc):
                    continue
                out_dims = _dims(mdef.group(3))
                # lhs operand: first %ref inside dot(...)
                ops = re.findall(r"dot\(([^)]*)\)", s)
                lhs_shape = None
                if ops:
                    # modern HLO inlines operand types (`dot(f32[M,K]{1,0}
                    # %lhs, ...)`), so the first shape token IS the lhs;
                    # older dumps carry bare %refs -> resolve via syms.
                    tm = _TYPE_RE.search(ops[0])
                    if tm:
                        lhs_shape = _dims(tm.group(2))
                    else:
                        ref = ops[0].split(",")[0].strip().lstrip("%")
                        if ref in syms:
                            lhs_shape = _dims(syms[ref][1])
                if lhs_shape is None:
                    continue
                contract = 1
                for ci in _dims(mc.group(1)):
                    if ci < len(lhs_shape):
                        contract *= lhs_shape[ci]
                f = 2.0 * contract
                for d in out_dims:
                    f *= d
                flops += f * m
                out_bytes = _shape_bytes(mdef.group(2), mdef.group(3))
                dot_bytes += (out_bytes + out_bytes * contract
                              / max(out_dims[-1] if out_dims else 1, 1)) * m
                continue
            # ---- collectives ----------------------------------------------
            for kind in _COLL:
                if (f" {kind}(" in s or f" {kind}-start(" in s) \
                        and "-done(" not in s:
                    after = s.split("= ", 1)
                    if len(after) != 2:
                        break
                    types = _TYPE_RE.findall(after[1].split("(")[0])
                    if not types:
                        break
                    rb = max(_shape_bytes(dt, dims) for dt, dims in types)
                    g = _group_size(s)
                    operand = (rb / max(g, 1) if kind == "all-gather"
                               else rb * max(g, 1) if kind == "reduce-scatter"
                               else rb)
                    by_kind[kind] = by_kind.get(kind, 0.0) + operand * m
                    n_ops += 1
                    break
        if m > 1:
            trip_counts[name] = m
    return {
        "flops_dot": flops,
        "dot_bytes": dot_bytes,
        "collectives": CollectiveStats(by_kind=by_kind,
                                       total_bytes=sum(by_kind.values()),
                                       n_ops=n_ops).to_json(),
        "n_looped_computations": len(trip_counts),
        "max_multiplier": max(trip_counts.values(), default=1.0),
    }


def memory_stats(compiled) -> dict:
    ma = compiled.memory_analysis()
    if ma is None:
        return {}
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes")
    return {k: getattr(ma, k, 0) for k in keys}


def cost_stats(compiled) -> dict:
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):   # older jax: one dict per program
        ca = ca[0] if ca else {}
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0))}
