import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without real hardware: the
production mesh (16x16 single-pod / 2x16x16 multi-pod) is built from 512
placeholder host devices, every step function is lowered from pure
ShapeDtypeStructs (zero allocation), compiled, and its memory / cost /
collective statistics are recorded for EXPERIMENTS.md §Dry-run and the
§Roofline analysis.

Usage:
  python -m repro.launch.dryrun --arch yi-6b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out results/dryrun]
"""
import argparse
import json
import pathlib
import time
import traceback

import jax

from repro.configs import ARCHS, SHAPES, cell_applicable
from repro.launch.hlo_analysis import (collective_stats, cost_stats,
                                       memory_stats, trip_aware_stats)
from repro.launch.mesh import make_production_mesh
from repro.runtime.steps import (input_specs, make_decode_step,
                                 make_prefill_step, make_train_step)
from repro.optim.adamw import AdamWConfig

# grok-1 (314B params) needs quantised optimizer moments to fit 16 GB/chip
# on a single pod — the runtime-level twin of the paper's fragmentation.
QUANTIZED_OPT_ARCHS = {"grok-1-314b", "qwen2-vl-72b", "jamba-v0.1-52b"}


def run_cell(arch_name: str, shape_name: str, multi_pod: bool,
             out_dir: pathlib.Path, remat: str = "full") -> dict:
    cfg = ARCHS[arch_name]
    shape = SHAPES[shape_name]
    mesh_tag = "multipod" if multi_pod else "singlepod"
    rec: dict = {"arch": arch_name, "shape": shape_name, "mesh": mesh_tag,
                 "remat": remat}
    ok, reason = cell_applicable(cfg, shape)
    if not ok:
        rec["skipped"] = reason
        _write(out_dir, rec)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    rec["n_devices"] = mesh.size
    opt_cfg = AdamWConfig(quantize_states=arch_name in QUANTIZED_OPT_ARCHS)
    specs = input_specs(cfg, shape, mesh, opt_cfg=opt_cfg)

    t0 = time.time()
    with mesh:
        if shape.kind == "train":
            step, _, _ = make_train_step(cfg, mesh, opt_cfg, remat=remat)
            args = (specs["params"], specs["opt_state"], specs["batch"])
            donate = (0, 1)
        elif shape.kind == "prefill":
            step, _, _ = make_prefill_step(cfg, mesh, shape.global_batch,
                                           shape.seq_len)
            args = (specs["params"], specs["cache"], specs["batch"])
            donate = (1,)
        else:
            step, _, _ = make_decode_step(cfg, mesh, shape.global_batch,
                                          shape.seq_len)
            args = (specs["params"], specs["cache"], specs["token"],
                    specs["pos"])
            donate = (1,)
        lowered = jax.jit(step, donate_argnums=donate).lower(*args)
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)

    mem = memory_stats(compiled)
    cost = cost_stats(compiled)
    print(f"[{arch_name}/{shape_name}/{mesh_tag}] memory_analysis:", mem)
    print(f"[{arch_name}/{shape_name}/{mesh_tag}] cost_analysis:", cost)
    hlo = compiled.as_text()
    coll = collective_stats(hlo)
    rec["trip_aware"] = trip_aware_stats(hlo)
    # XLA:CPU's float-normalization holds bf16 while-state in f32, roughly
    # doubling temp vs a native-bf16 TPU compile; record both the raw CPU
    # numbers and a TPU-adjusted estimate (temp * 0.55, donation-aliased).
    args_b = mem.get("argument_size_in_bytes", 0)
    temp_b = mem.get("temp_size_in_bytes", 0)
    tpu_est = args_b + int(temp_b * 0.55)
    rec.update({
        "memory": mem, "cost": cost, "collectives": coll.to_json(),
        "per_device_bytes": args_b + temp_b,
        "per_device_bytes_tpu_est": tpu_est,
        "fits_hbm": args_b + temp_b < 16 * 2 ** 30,
        "fits_hbm_tpu_est": tpu_est < 16 * 2 ** 30,
    })
    _write(out_dir, rec)
    return rec


def _write(out_dir: pathlib.Path, rec: dict) -> None:
    out_dir.mkdir(parents=True, exist_ok=True)
    name = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.json"
    (out_dir / name).write_text(json.dumps(rec, indent=1))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default=None)
    ap.add_argument("--shape", choices=sorted(SHAPES), default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape) cell for the chosen mesh")
    ap.add_argument("--remat", default="full", choices=("none", "dots", "full"))
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()
    out = pathlib.Path(args.out)

    cells = ([(a, s) for a in sorted(ARCHS) for s in SHAPES]
             if args.all else [(args.arch, args.shape)])
    failures = 0
    for arch, shape in cells:
        tag = "multipod" if args.multi_pod else "singlepod"
        path = out / f"{arch}__{shape}__{tag}.json"
        if args.skip_existing and path.exists():
            rec = json.loads(path.read_text())
            if "error" not in rec:
                print(f"skip {arch}/{shape}/{tag} (exists)")
                continue
        try:
            rec = run_cell(arch, shape, args.multi_pod, out, remat=args.remat)
            status = ("SKIP " + rec["skipped"]) if "skipped" in rec else (
                f"ok lower={rec['lower_s']}s compile={rec['compile_s']}s "
                f"fits={rec['fits_hbm']}")
            print(f"{arch:18s} {shape:12s} {tag}: {status}", flush=True)
        except Exception as e:  # noqa: BLE001 — record and continue
            failures += 1
            traceback.print_exc()
            _write(out, {"arch": arch, "shape": shape, "mesh": tag,
                         "error": f"{type(e).__name__}: {e}"})
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
