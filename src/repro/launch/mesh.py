"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (jax locks the device count on first init, and the
smoke tests / benches must see 1 CPU device while the dry-run sees 512
placeholders via XLA_FLAGS).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """A 1x1 mesh on the single real CPU device (examples / tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))
