"""Production serving launcher (continuous batching + KV eviction).

    python -m repro.launch.serve --arch yi-6b --smoke --requests 8

Real-cluster mode would jit the prefill/decode steps against the production
mesh (see launch/dryrun.py for the per-cell artifacts); the runnable path
here drives the ServingEngine end-to-end on the host mesh.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.models import init_params
from repro.serving.engine import ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--s-max", type=int, default=256)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--no-evict", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = ARCHS[args.arch].reduced() if args.smoke else ARCHS[args.arch]
    params = init_params(jax.random.PRNGKey(args.seed), cfg,
                         dtype=jnp.float32)
    eng = ServingEngine(cfg, params, max_batch=args.slots, s_max=args.s_max,
                        evict_to_host=not args.no_evict)
    rng = np.random.default_rng(args.seed)
    reqs = [eng.submit(rng.integers(0, cfg.vocab, args.prompt_len),
                       max_new_tokens=args.max_new)
            for _ in range(args.requests)]
    t0 = time.time()
    eng.run_until_drained()
    dt = time.time() - t0
    st = eng.stats
    print(f"{cfg.name}: {len(reqs)} requests via {args.slots} slots in {dt:.2f}s")
    print(f"  tokens/s={st.generated / dt:.1f} prefills={st.prefills} "
          f"decode_steps={st.decode_steps}")
    if st.evicted_bytes_raw:
        print(f"  kv evicted: {st.evicted_bytes_raw / 1e6:.2f} MB -> "
              f"{st.evicted_bytes_compressed / 1e6:.2f} MB "
              f"(c_bar={st.evicted_bytes_compressed / st.evicted_bytes_raw:.2f})")


if __name__ == "__main__":
    main()
