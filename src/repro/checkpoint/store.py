"""Sharded, async, atomically-committed checkpoints with optional BFP8
compression — the persistence layer for fault tolerance and elastic
rescaling.

Layout:  <dir>/step_<N>/  manifest.json + one .npy per pytree leaf
(flattened key paths).  Writes go to ``step_<N>.tmp`` and are renamed into
place only after everything (incl. the manifest) is fsync'd — a crashed
save can never produce a half-readable checkpoint.  ``save_async`` runs the
serialisation on a worker thread so the train loop only blocks on the
previous save's completion (one outstanding save, bounded memory).

BFP8 mode stores bf16/f32 leaves in the paper's §V-A block-floating-point
format (about 2x smaller); restore dequantises transparently.

Elastic restore: ``restore(..., shardings=...)`` re-lays out every leaf for
a NEW mesh via device_put, so a job restarted on a different device count
resumes from the same step (runtime/fault.py drives this).
"""
from __future__ import annotations

import concurrent.futures as cf
import json
import os
import pathlib
import shutil
from typing import Any

import jax
import numpy as np

from repro.core.compression import bfp8_decode, bfp8_encode, BFP8Blocks


def _flat(tree: Any) -> dict[str, Any]:
    out = {}
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out


class CheckpointStore:
    def __init__(self, directory: str, *, bfp8: bool = False,
                 keep_last: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.bfp8 = bfp8
        self.keep_last = keep_last
        self._pool = cf.ThreadPoolExecutor(max_workers=1)
        self._pending: cf.Future | None = None

    # -- save -----------------------------------------------------------------
    def save(self, step: int, tree: Any, extra: dict | None = None) -> None:
        flat = {k: np.asarray(v) for k, v in _flat(tree).items()}
        self._write(step, flat, extra or {})

    def save_async(self, step: int, tree: Any,
                   extra: dict | None = None) -> None:
        """Snapshot to host now, serialise on the worker thread."""
        self.wait()
        flat = {k: np.asarray(v) for k, v in _flat(tree).items()}
        self._pending = self._pool.submit(self._write, step, flat, extra or {})

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def _write(self, step: int, flat: dict[str, np.ndarray],
               extra: dict) -> None:
        final = self.dir / f"step_{step}"
        tmp = self.dir / f"step_{step}.tmp"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": step, "bfp8": self.bfp8, "extra": extra,
                    "leaves": {}}
        for i, (key, arr) in enumerate(sorted(flat.items())):
            fname = f"leaf_{i}.npy"
            meta = {"file": fname, "dtype": str(arr.dtype),
                    "shape": list(arr.shape)}
            if self.bfp8 and arr.dtype in (np.float32, np.float16) or \
                    (self.bfp8 and arr.dtype.name == "bfloat16"):
                blocks = bfp8_encode(np.asarray(arr, np.float32))
                np.save(tmp / fname, blocks.mantissas)
                np.save(tmp / f"exp_{i}.npy", blocks.exponents)
                meta.update({"codec": "bfp8", "exp_file": f"exp_{i}.npy",
                             "block": blocks.block,
                             "orig_len": blocks.orig_len})
            else:
                if arr.dtype.name == "bfloat16":
                    meta["dtype"] = "bfloat16"
                    arr = arr.view(np.uint16)
                np.save(tmp / fname, arr)
            manifest["leaves"][key] = meta
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)                     # atomic commit
        self._gc()

    def _gc(self) -> None:
        steps = sorted(self.steps())
        for s in steps[:-self.keep_last]:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    # -- restore ----------------------------------------------------------------
    def steps(self) -> list[int]:
        return sorted(int(p.name.split("_")[1]) for p in self.dir.glob("step_*")
                      if p.is_dir() and not p.name.endswith(".tmp"))

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, template: Any, step: int | None = None,
                shardings: Any = None) -> tuple[Any, dict]:
        """Restore into the structure of ``template``; optional re-layout
        onto new ``shardings`` (elastic remesh)."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self.dir / f"step_{step}"
        manifest = json.loads((d / "manifest.json").read_text())
        flat_t = _flat(template)
        flat_s = _flat(shardings) if shardings is not None else {}
        out = {}
        for key, leaf in flat_t.items():
            meta = manifest["leaves"][key]
            arr = np.load(d / meta["file"])
            if meta.get("codec") == "bfp8":
                exp = np.load(d / meta["exp_file"])
                arr = bfp8_decode(BFP8Blocks(arr, exp, meta["block"],
                                             meta["orig_len"],
                                             tuple(meta["shape"])))
            if meta["dtype"] == "bfloat16":
                arr = arr.view(jax.numpy.bfloat16.dtype) \
                    if arr.dtype == np.uint16 else arr
            arr = np.asarray(arr).reshape(meta["shape"])
            target_dtype = getattr(leaf, "dtype", None)
            if target_dtype is not None and arr.dtype != target_dtype:
                arr = arr.astype(target_dtype)
            if key in flat_s:
                out[key] = jax.device_put(arr, flat_s[key])
            else:
                out[key] = jax.numpy.asarray(arr)
        # unflatten back into the template structure
        leaves, treedef = jax.tree_util.tree_flatten(template)
        keys = list(_flat(template).keys())
        restored = treedef.unflatten([out[k] for k in keys])
        return restored, manifest["extra"]
