"""Model zoo: configs + pure-JAX implementations of all assigned families
(dense GQA transformers, MoE, Mamba/mLSTM/sLSTM mixers, enc-dec, VLM stub).
"""
from .config import ArchConfig, MoECfg
from .model import (decode_step, forward, init_cache, init_params, lm_loss,
                    param_count, project_logits)

__all__ = ["ArchConfig", "MoECfg", "decode_step", "forward", "init_cache",
           "init_params", "lm_loss", "param_count", "project_logits"]
