"""Model assembly: params init, full-sequence forward (train / prefill),
single-token decode — all scanning over *layer groups* so HLO size is O(1)
in depth (DESIGN.md §6).

A "group" is one period of the layer pattern (1 for homogeneous stacks,
8 for jamba's 7-mamba:1-attn or xLSTM's 7-mLSTM:1-sLSTM).  Parameters are
stacked over groups; `lax.scan` threads the residual stream through them.
Heterogeneous positions inside a group are unrolled in the scan body.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from . import attention as A
from . import moe as M
from . import ssm as S
from .common import apply_norm, dense_init, norm_params
from .config import ArchConfig

Params = dict
LOSS_CHUNK = 512


# =============================================================================
# init
# =============================================================================

def _mixer_params(key, kind: str, cfg, dtype) -> dict:
    if kind == "attn":
        return A.attn_params(key, cfg, dtype)
    if kind == "mamba":
        return S.mamba_params(key, cfg, dtype)
    if kind == "mlstm":
        return S.mlstm_params(key, cfg, dtype)
    if kind == "slstm":
        return S.slstm_params(key, cfg, dtype)
    raise ValueError(kind)


def _layer_params(key, cfg: ArchConfig, pos_in_group: int, layer_idx: int,
                  dtype, cross_attn: bool = False) -> dict:
    ks = jax.random.split(key, 6)
    kind = cfg.layer_kind(layer_idx)
    p = {
        "norm1": norm_params(cfg.norm, cfg.d_model, dtype),
        "mixer": _mixer_params(ks[0], kind, cfg, dtype),
    }
    if cross_attn:
        p["norm_x"] = norm_params(cfg.norm, cfg.d_model, dtype)
        p["cross"] = A.attn_params(ks[1], cfg, dtype)
    if cfg.d_ff > 0:
        p["norm2"] = norm_params(cfg.norm, cfg.d_model, dtype)
        if cfg.layer_is_moe(layer_idx):
            p["moe"] = M.moe_params(ks[2], cfg, dtype)
        else:
            p["ffn"] = M.dense_ffn_params(ks[3], cfg, dtype)
    return p


def _stack_groups(key, cfg: ArchConfig, dtype, cross_attn: bool,
                  n_layers: int) -> dict:
    """Per-position params stacked over groups: {pos_j: stacked pytree}."""
    gs = cfg.group_size
    n_groups = n_layers // gs
    out: dict[str, Any] = {}
    keys = jax.random.split(key, n_layers).reshape(n_groups, gs, -1)
    for j in range(gs):
        per_group = [
            _layer_params(keys[g, j], cfg, j, g * gs + j, dtype, cross_attn)
            for g in range(n_groups)]
        out[f"pos_{j}"] = jax.tree.map(lambda *xs: jnp.stack(xs), *per_group)
    return out


def init_params(key, cfg: ArchConfig, dtype=jnp.bfloat16) -> Params:
    ks = jax.random.split(key, 5)
    p: Params = {
        "embed": dense_init(ks[0], (cfg.vocab, cfg.d_model), dtype, scale=0.02),
        "groups": _stack_groups(ks[1], cfg, dtype,
                                cross_attn=cfg.is_encdec,
                                n_layers=cfg.n_layers),
        "final_norm": norm_params(cfg.norm, cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(ks[2], (cfg.vocab, cfg.d_model), dtype,
                                  scale=0.02)
    if cfg.is_encdec:
        enc_cfg = dataclasses.replace(cfg, pattern=("attn",), moe=None)
        p["encoder"] = {
            "groups": _stack_groups(ks[3], enc_cfg, dtype, cross_attn=False,
                                    n_layers=cfg.encoder_layers),
            "final_norm": norm_params(cfg.norm, cfg.d_model, dtype),
        }
    return p


def param_count(params: Params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


# =============================================================================
# layer application
# =============================================================================

def _apply_layer(lp: dict, x, cfg: ArchConfig, layer_idx: int, *,
                 pos, enc=None, cache=None, mode: str):
    """One transformer/SSM layer.  mode: "full" (train/prefill) | "decode".
    Returns (x, new_cache, aux)."""
    kind = cfg.layer_kind(layer_idx)
    h = apply_norm(cfg.norm, x, lp["norm1"])
    new_cache: dict = {}
    aux = jnp.zeros((), jnp.float32)
    if kind == "attn":
        if mode == "full":
            # cache production == serving prefill == forward-only -> the
            # causal-block-skipping (dynamic trip) attention is safe
            out, (k, v) = A.prefill_attention(lp["mixer"], h, cfg, pos,
                                              inference=cache is not None)
            if cache is not None:
                S_max = cache["k"].shape[1]
                pad = [(0, 0), (0, S_max - k.shape[1]), (0, 0), (0, 0)]
                new_cache = {"k": jnp.pad(k, pad).astype(cache["k"].dtype),
                             "v": jnp.pad(v, pad).astype(cache["v"].dtype)}
        else:
            out, (ck, cv) = A.decode_attention(
                lp["mixer"], h, cfg, (cache["k"], cache["v"]), pos)
            new_cache = {"k": ck, "v": cv}
    elif kind == "mamba":
        fn = S.mamba_forward if mode == "full" else S.mamba_decode
        out, st = (fn(lp["mixer"], h, cfg) if mode == "full"
                   else fn(lp["mixer"], h, cfg, cache))
        new_cache = st
    elif kind == "mlstm":
        out, st = (S.mlstm_forward(lp["mixer"], h, cfg) if mode == "full"
                   else S.mlstm_decode(lp["mixer"], h, cfg, cache))
        new_cache = st
    elif kind == "slstm":
        out, st = (S.slstm_forward(lp["mixer"], h, cfg) if mode == "full"
                   else S.slstm_decode(lp["mixer"], h, cfg, cache))
        new_cache = st
    else:
        raise ValueError(kind)
    x = x + out

    if "cross" in lp:
        hx = apply_norm(cfg.norm, x, lp["norm_x"])
        if mode == "full":
            x = x + A.cross_attention(lp["cross"], hx, enc, cfg)
        else:
            # decode: cross-KV precomputed at prefill
            x = x + _cross_decode(lp["cross"], hx, cfg, cache)
        if cache is not None and mode == "full":
            new_cache.update(_cross_kv(lp["cross"], enc, cfg))
        elif cache is not None:
            new_cache.update({k: cache[k] for k in ("xk", "xv") if k in cache})

    if cfg.d_ff > 0:
        h2 = apply_norm(cfg.norm, x, lp["norm2"])
        if "moe" in lp:
            out2, a = M.apply_moe(lp["moe"], h2, cfg)
            aux = aux + a
        else:
            out2 = M.apply_dense_ffn(lp["ffn"], h2, cfg)
        x = x + out2
    return x, new_cache, aux


def _cross_kv(p: dict, enc, cfg) -> dict:
    B, T, _ = enc.shape
    k = (enc @ p["wk"]).reshape(B, T, cfg.n_kv_heads, cfg.hd)
    v = (enc @ p["wv"]).reshape(B, T, cfg.n_kv_heads, cfg.hd)
    return {"xk": k, "xv": v}


def _cross_decode(p: dict, x, cfg, cache: dict):
    B = x.shape[0]
    q = (x @ p["wq"]).reshape(B, 1, cfg.n_heads, cfg.hd)
    out = A.chunked_attention(q, cache["xk"], cache["xv"], causal=False,
                              chunk=min(512, cache["xk"].shape[1]))
    return out.reshape(B, 1, cfg.n_heads * cfg.hd) @ p["wo"]


# =============================================================================
# cache construction
# =============================================================================

def init_cache(cfg: ArchConfig, batch: int, s_max: int,
               dtype=jnp.bfloat16) -> dict:
    """Stacked-over-groups cache pytree: {pos_j: per-kind state}."""
    gs, ng = cfg.group_size, cfg.n_groups
    cache: dict[str, Any] = {}
    for j in range(gs):
        kind = cfg.layer_kind(j)
        if kind == "attn":
            c = {"k": jnp.zeros((batch, s_max, cfg.n_kv_heads, cfg.hd), dtype),
                 "v": jnp.zeros((batch, s_max, cfg.n_kv_heads, cfg.hd), dtype)}
            if cfg.is_encdec:
                c.update({
                    "xk": jnp.zeros((batch, cfg.enc_frames, cfg.n_kv_heads,
                                     cfg.hd), dtype),
                    "xv": jnp.zeros((batch, cfg.enc_frames, cfg.n_kv_heads,
                                     cfg.hd), dtype)})
        elif kind == "mamba":
            c = S.mamba_cache(batch, cfg, dtype)
        elif kind == "mlstm":
            c = S.mlstm_cache(batch, cfg, dtype)
        elif kind == "slstm":
            c = S.slstm_cache(batch, cfg, dtype)
        else:
            raise ValueError(kind)
        cache[f"pos_{j}"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (ng,) + x.shape), c)
    return cache


# =============================================================================
# forward passes
# =============================================================================

def _embed(params, cfg, tokens, patch_embeds=None):
    x = jnp.take(params["embed"], tokens, axis=0)
    if patch_embeds is not None and cfg.vlm_patches:
        P = patch_embeds.shape[1]
        x = jnp.concatenate([patch_embeds.astype(x.dtype), x[:, P:]], axis=1)
    return x


def _encoder_forward(params, cfg: ArchConfig, frames):
    """Whisper encoder over precomputed frame embeddings (B, T, d)."""
    enc_cfg = dataclasses.replace(cfg, pattern=("attn",), moe=None,
                                  encoder_layers=0)
    x = frames
    T = frames.shape[1]
    pos = jnp.arange(T)[None]

    def body(x, gp):
        lp = gp["pos_0"]
        h = apply_norm(cfg.norm, x, lp["norm1"])
        x = x + A.encoder_attention(lp["mixer"], h, enc_cfg, pos)
        h2 = apply_norm(cfg.norm, x, lp["norm2"])
        x = x + M.apply_dense_ffn(lp["ffn"], h2, enc_cfg)
        return x, None

    x, _ = jax.lax.scan(body, x, params["encoder"]["groups"])
    return apply_norm(cfg.norm, x, params["encoder"]["final_norm"])


def forward(params: Params, cfg: ArchConfig, tokens: jax.Array, *,
            enc_frames=None, patch_embeds=None, cache=None,
            remat: str = "none", pos_offset=None):
    """Full-sequence forward.  Returns (hidden, new_cache, aux_loss).

    tokens: (B, S) int32.  With ``cache`` given (prefill), per-layer KV /
    state caches are produced.  ``pos_offset``: (B,) start positions.
    """
    B, Sq = tokens.shape
    x = _embed(params, cfg, tokens, patch_embeds)
    pos = jnp.arange(Sq)[None]
    if pos_offset is not None:
        pos = pos + pos_offset[:, None]
    enc = _encoder_forward(params, cfg, enc_frames) if cfg.is_encdec else None

    gs = cfg.group_size

    def group_body(carry, gxs):
        x, aux = carry
        gp = gxs["params"]
        gc = gxs.get("cache")
        new_gc = {}
        for j in range(gs):
            lp = gp[f"pos_{j}"]
            c_j = gc[f"pos_{j}"] if gc is not None else None
            x, nc, a = _apply_layer(lp, x, cfg, j, pos=pos, enc=enc,
                                    cache=c_j, mode="full")
            new_gc[f"pos_{j}"] = nc
            aux = aux + a
        return (x, aux), new_gc

    if remat == "full":
        group_body = jax.checkpoint(group_body)
    elif remat == "dots":
        group_body = jax.checkpoint(
            group_body,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

    xs = {"params": params["groups"]}
    if cache is not None:
        xs["cache"] = cache
    (x, aux), new_cache = jax.lax.scan(
        group_body, (x, jnp.zeros((), jnp.float32)), xs)
    x = apply_norm(cfg.norm, x, params["final_norm"])
    return x, (new_cache if cache is not None else None), aux


def decode_step(params: Params, cfg: ArchConfig, token: jax.Array,
                pos: jax.Array, cache: dict):
    """One decode step.  token: (B, 1); pos: (B,).  Returns (logits, cache)."""
    x = jnp.take(params["embed"], token, axis=0)
    gs = cfg.group_size

    def group_body(x, gxs):
        gp, gc = gxs["params"], gxs["cache"]
        new_gc = {}
        for j in range(gs):
            x, nc, _ = _apply_layer(gp[f"pos_{j}"], x, cfg, j, pos=pos,
                                    cache=gc[f"pos_{j}"], mode="decode")
            new_gc[f"pos_{j}"] = nc
        return x, new_gc

    x, new_cache = jax.lax.scan(
        group_body, x, {"params": params["groups"], "cache": cache})
    x = apply_norm(cfg.norm, x, params["final_norm"])
    logits = project_logits(params, cfg, x[:, 0])
    return logits, new_cache


def project_logits(params: Params, cfg: ArchConfig, x: jax.Array):
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    return (x @ head.T).astype(jnp.float32)


def lm_loss(params: Params, cfg: ArchConfig, tokens, labels, *,
            enc_frames=None, patch_embeds=None, remat: str = "none"):
    """Next-token cross-entropy, computed in sequence chunks so the full
    (B, S, V) logits tensor never materialises."""
    x, _, aux = forward(params, cfg, tokens, enc_frames=enc_frames,
                        patch_embeds=patch_embeds, remat=remat)
    B, Sq, d = x.shape
    C = min(LOSS_CHUNK, Sq)
    assert Sq % C == 0
    xc = jnp.moveaxis(x.reshape(B, Sq // C, C, d), 1, 0)
    lc = jnp.moveaxis(labels.reshape(B, Sq // C, C), 1, 0)

    @jax.checkpoint
    def chunk_loss(tot, xs):
        xb, lb = xs
        logits = project_logits(params, cfg, xb)             # (B, C, V) f32
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lb[..., None], axis=-1)[..., 0]
        return tot + (lse - gold).sum(), None

    tot, _ = jax.lax.scan(chunk_loss, jnp.zeros((), jnp.float32), (xc, lc))
    loss = tot / (B * Sq)
    return loss + 0.01 * aux
