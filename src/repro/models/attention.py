"""GQA attention: chunked (flash-style) prefill and KV-cache decode.

The prefill path streams over KV chunks with an online-softmax accumulator —
``jax.lax.scan`` keeps the HLO O(1) in sequence length and bounds the live
score block to (B, H, S_q, chunk), which is what lets the 32k-token cells
fit the dry-run memory analysis.  It is also the jnp oracle for the Pallas
flash kernel (kernels/flash_attention.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import apply_mrope, apply_rope, dense_init, text_mrope_positions

NEG_INF = -2.0 ** 30


def _divisor_chunk(n: int, target: int) -> int:
    """Largest chunk <= target that divides n (handles e.g. whisper's 1500)."""
    c = min(target, n)
    while n % c:
        c -= 1
    return c


def attn_params(key, cfg, dtype) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d, hd = cfg.d_model, cfg.hd
    return {
        "wq": dense_init(k1, (d, cfg.n_heads * hd), dtype),
        "wk": dense_init(k2, (d, cfg.n_kv_heads * hd), dtype),
        "wv": dense_init(k3, (d, cfg.n_kv_heads * hd), dtype),
        "wo": dense_init(k4, (cfg.n_heads * hd, d), dtype),
    }


def _project_qkv(p: dict, x: jax.Array, cfg, pos: jax.Array,
                 repeat_kv: bool = False):
    """QKV projections.  With ``repeat_kv`` the KV weight blocks are
    broadcast to all H query heads BEFORE the matmul, so the resulting
    activations have a full H head axis that shards cleanly over the
    ``model`` mesh axis (a KH=4 head axis cannot shard over 16) — the extra
    weight copies are tiny next to the activation all-gather they avoid."""
    B, S, _ = x.shape
    hd, KH, H = cfg.hd, cfg.n_kv_heads, cfg.n_heads
    G = H // KH
    wk, wv = p["wk"], p["wv"]
    if repeat_kv and G > 1:
        d = wk.shape[0]
        wk = jnp.repeat(wk.reshape(d, KH, hd), G, axis=1).reshape(d, H * hd)
        wv = jnp.repeat(wv.reshape(d, KH, hd), G, axis=1).reshape(d, H * hd)
        KH = H
    from repro.runtime.hints import constrain
    q = constrain((x @ p["wq"]).reshape(B, S, H, hd), "dp", None, "tp", None)
    k = constrain((x @ wk).reshape(B, S, KH, hd), "dp", None, "tp", None)
    v = constrain((x @ wv).reshape(B, S, KH, hd), "dp", None, "tp", None)
    if cfg.rope == "rope":
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    elif cfg.rope == "mrope":
        mpos = text_mrope_positions(pos)
        q = apply_mrope(q, mpos, cfg.mrope_sections, cfg.rope_theta)
        k = apply_mrope(k, mpos, cfg.mrope_sections, cfg.rope_theta)
    return q, k, v


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      *, causal: bool, chunk: int = 1024, q_chunk: int = 512,
                      q_offset: int = 0, skip_masked: bool = False) -> jax.Array:
    """Flash-style online-softmax attention: outer scan over query blocks,
    inner scan over KV blocks.

    q: (B, Sq, H, D); k, v: (B, Sk, KH, D) with H a multiple of KH (GQA; KV
    heads are broadcast to H so the head axis shards cleanly over the
    ``model`` mesh axis).  The live score block is (B, q_chunk, H, chunk) and
    each query-block body is rematerialised in the backward pass, so both
    the forward temp and the autodiff residuals stay O(S * H * D).
    """
    B, Sq, H, D = q.shape
    _, Sk, KH, _ = k.shape
    G = H // KH
    scale = D ** -0.5
    chunk = _divisor_chunk(Sk, chunk)
    q_chunk = _divisor_chunk(Sq, q_chunk)
    nk, nq = Sk // chunk, Sq // q_chunk

    from repro.runtime.hints import constrain
    if G > 1:  # broadcast KV heads -> clean head sharding over "model"
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    # (n, B, blk, H, D) — keep heads on the tensor axis ("tp"): without this
    # GSPMD loses the head sharding through rope/reshape and replicates the
    # whole attention across the model axis (§Perf/H1: 16x flops).
    kb = constrain(jnp.moveaxis(k.reshape(B, nk, chunk, H, D), 1, 0),
                   None, "dp", None, "tp", None)
    vb = constrain(jnp.moveaxis(v.reshape(B, nk, chunk, H, D), 1, 0),
                   None, "dp", None, "tp", None)
    qb = constrain(jnp.moveaxis((q * scale).reshape(B, nq, q_chunk, H, D), 1, 0),
                   None, "dp", None, "tp", None)

    def q_block_fn(_, xs):
        qi, iq = xs                                        # (B,qc,H,D), idx
        qf = qi.astype(jnp.float32)
        q_pos = q_offset + iq * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, kj, vj, jk):
            m, l, o = carry
            s = jnp.einsum("bqhd,bkhd->bqhk", qf, kj.astype(jnp.float32))
            if causal:
                k_pos = jk * chunk + jnp.arange(chunk)
                mask = q_pos[:, None] >= k_pos[None, :]
                s = jnp.where(mask[None, :, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + p.sum(axis=-1)
            o_new = o * alpha[..., None] + jnp.einsum(
                "bqhk,bkhd->bqhd", p, vj.astype(jnp.float32))
            return m_new, l_new, o_new

        m0 = jnp.full((B, q_chunk, H), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, q_chunk, H), jnp.float32)
        o0 = jnp.zeros((B, q_chunk, H, D), jnp.float32)
        if skip_masked and causal:
            # inference-only causal block skipping: only the kv blocks at or
            # below this q block's diagonal run (dynamic trip count — not
            # differentiable, so the train path keeps the full scan).
            nk_eff = ((iq + 1) * q_chunk + q_offset + chunk - 1) // chunk
            nk_eff = jnp.minimum(nk_eff, nk)

            def body(j, carry):
                kj = jax.lax.dynamic_index_in_dim(kb, j, 0, keepdims=False)
                vj = jax.lax.dynamic_index_in_dim(vb, j, 0, keepdims=False)
                return kv_step(carry, kj, vj, j)

            m, l, o = jax.lax.fori_loop(0, nk_eff, body, (m0, l0, o0))
        else:
            def kv_block(carry, kv):
                kj, vj, jk = kv
                return kv_step(carry, kj, vj, jk), None

            (m, l, o), _ = jax.lax.scan(kv_block, (m0, l0, o0),
                                        (kb, vb, jnp.arange(nk)))
        out = o / jnp.maximum(l, 1e-30)[..., None]
        return None, out.astype(q.dtype)

    q_block = q_block_fn if skip_masked else jax.checkpoint(q_block_fn)
    _, blocks = jax.lax.scan(q_block, None, (qb, jnp.arange(nq)))
    return jnp.moveaxis(blocks, 0, 1).reshape(B, Sq, H, D)


def prefill_attention(p: dict, x: jax.Array, cfg, pos: jax.Array,
                      *, chunk: int = 1024, inference: bool = False):
    """Full-sequence causal self-attention; returns (out, (k, v) cache).
    The returned cache keeps the true KH KV heads (strided slice of the
    weight-repeated heads).  ``inference`` enables causal block skipping
    (dynamic-trip loop, forward-only)."""
    B, S, _ = x.shape
    G = cfg.n_heads // cfg.n_kv_heads
    q, k, v = _project_qkv(p, x, cfg, pos, repeat_kv=True)
    out = chunked_attention(q, k, v, causal=True, chunk=min(chunk, S),
                            skip_masked=inference)
    out = out.reshape(B, S, cfg.n_heads * cfg.hd) @ p["wo"]
    return out, (k[:, :, ::G], v[:, :, ::G])


def decode_attention(p: dict, x: jax.Array, cfg, cache: tuple, pos: jax.Array):
    """Single-token decode against a (B, S_max, KH, D) KV cache.

    ``pos``: (B,) absolute position of the incoming token.  The cache is
    updated in place at ``pos`` and positions > pos are masked out.
    """
    B, S1, _ = x.shape
    assert S1 == 1
    ck, cv = cache
    S_max = ck.shape[1]
    q, k, v = _project_qkv(p, x, cfg, pos[:, None])
    # scatter the new kv at pos — .at[].set lowers to a scatter, which GSPMD
    # keeps sharded on a sequence-sharded cache (a dynamic-update-slice
    # would all-gather the shard axis)
    rows = jnp.arange(ck.shape[0])
    ck = ck.at[rows, pos].set(k[:, 0].astype(ck.dtype))
    cv = cv.at[rows, pos].set(v[:, 0].astype(cv.dtype))
    KH, D = cfg.n_kv_heads, cfg.hd
    G = cfg.n_heads // KH
    # NB: never .astype() the cache — XLA hoists the convert out of the
    # layer-group scan and materialises the full stacked cache in f32.
    # Mixed-precision dots with a f32 accumulator keep the cache bf16.
    qf = (q * D ** -0.5).reshape(B, KH, G, D).astype(ck.dtype)
    s = jnp.einsum("bhgd,bkhd->bhgk", qf, ck,
                   preferred_element_type=jnp.float32)
    mask = jnp.arange(S_max)[None] <= pos[:, None]        # (B, S_max)
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", w.astype(cv.dtype), cv,
                   preferred_element_type=jnp.float32)
    out = o.reshape(B, 1, cfg.n_heads * D).astype(x.dtype) @ p["wo"]
    return out, (ck, cv)


def cross_attention(p: dict, x: jax.Array, enc: jax.Array, cfg,
                    chunk: int = 512):
    """Decoder->encoder cross attention (whisper).  enc: (B, T, d)."""
    B, S, _ = x.shape
    T = enc.shape[1]
    hd = cfg.hd
    q = (x @ p["wq"]).reshape(B, S, cfg.n_heads, hd)
    k = (enc @ p["wk"]).reshape(B, T, cfg.n_kv_heads, hd)
    v = (enc @ p["wv"]).reshape(B, T, cfg.n_kv_heads, hd)
    out = chunked_attention(q, k, v, causal=False, chunk=min(chunk, T))
    return out.reshape(B, S, cfg.n_heads * hd) @ p["wo"]


def encoder_attention(p: dict, x: jax.Array, cfg, pos: jax.Array,
                      chunk: int = 512):
    """Non-causal self-attention (whisper encoder)."""
    B, T, _ = x.shape
    q, k, v = _project_qkv(p, x, cfg, pos, repeat_kv=True)
    out = chunked_attention(q, k, v, causal=False, chunk=min(chunk, T))
    return out.reshape(B, T, cfg.n_heads * cfg.hd) @ p["wo"]
