"""Shared model components: norms, activations, RoPE / M-RoPE, embeddings."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


# -- initialisers --------------------------------------------------------------

def dense_init(key, shape, dtype, scale: float | None = None):
    """Truncated-normal fan-in init."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


# -- norms ---------------------------------------------------------------------

def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w.astype(jnp.float32)).astype(dt)


def layernorm(x: jax.Array, w: jax.Array, b: jax.Array,
              eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


def apply_norm(kind: str, x: jax.Array, p: dict) -> jax.Array:
    if kind == "rmsnorm":
        return rmsnorm(x, p["w"])
    return layernorm(x, p["w"], p["b"])


def norm_params(kind: str, d: int, dtype) -> dict:
    if kind == "rmsnorm":
        return {"w": jnp.ones((d,), dtype)}
    return {"w": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}


# -- activations ---------------------------------------------------------------

def gated_act(kind: str, up: jax.Array, gate: jax.Array) -> jax.Array:
    if kind == "swiglu":
        return jax.nn.silu(gate) * up
    if kind == "geglu":
        return jax.nn.gelu(gate) * up
    raise ValueError(kind)


# -- rotary embeddings -----------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float64)
                            / head_dim))


def apply_rope(x: jax.Array, pos: jax.Array, theta: float = 1e6) -> jax.Array:
    """x: (..., S, H, D); pos: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(d, theta), jnp.float32)        # (D/2,)
    ang = pos[..., :, None].astype(jnp.float32) * freqs           # (..., S, D/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, pos: jax.Array, sections: tuple[int, int, int],
                theta: float = 1e6) -> jax.Array:
    """Multimodal RoPE (qwen2-vl): the rotary dims are split into three
    sections (temporal, height, width), each rotated by its own position
    stream.  ``pos``: (3, ..., S) — for pure text all three streams are the
    same token index.  x: (..., S, H, D)."""
    d = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(d, theta), jnp.float32)        # (D/2,)
    # section id per rotary frequency: [0]*s0 + [1]*s1 + [2]*s2
    sec = np.concatenate([np.full(s, i) for i, s in enumerate(sections)])
    assert sec.shape[0] == d // 2, (sections, d)
    sec = jnp.asarray(sec)
    # pick the per-frequency position stream: (..., S, D/2)
    pos_f = jnp.take(pos.astype(jnp.float32), sec, axis=0)        # (..., S)? ->
    # pos: (3, B, S) -> take along axis 0 with sec (D/2,) gives (D/2, B, S)
    pos_f = jnp.moveaxis(pos_f, 0, -1)                            # (B, S, D/2)
    ang = pos_f * freqs
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def text_mrope_positions(pos: jax.Array) -> jax.Array:
    """For text-only tokens the three M-RoPE streams coincide."""
    return jnp.broadcast_to(pos[None], (3,) + pos.shape)
