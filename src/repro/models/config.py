"""Architecture configuration system.

One :class:`ArchConfig` per assigned architecture (src/repro/configs/<id>.py)
plus reduced "smoke" variants for CPU tests.  The config fully determines the
parameter pytree, the layer pattern (dense / MoE / SSM / hybrid interleave),
and the scan grouping used to keep HLO size O(1) in depth.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Literal

Family = Literal["dense", "moe", "ssm", "hybrid", "audio", "vlm"]


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    every_k_layers: int = 1         # jamba: MoE every other layer
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0               # 0 -> d_model // n_heads
    moe: MoECfg | None = None
    # layer mixer pattern, cycled over depth. entries: "attn" | "mamba"
    # | "mlstm" | "slstm".  jamba = 7 mamba : 1 attn; xlstm = 7 mlstm : 1 slstm
    pattern: tuple[str, ...] = ("attn",)
    encoder_layers: int = 0         # whisper: encoder depth (enc-dec if > 0)
    enc_frames: int = 1500          # whisper: fixed encoder positions
    rope: Literal["rope", "mrope", "none"] = "rope"
    mrope_sections: tuple[int, int, int] = (16, 24, 24)
    act: Literal["swiglu", "geglu", "gelu"] = "swiglu"
    norm: Literal["rmsnorm", "layernorm"] = "rmsnorm"
    tie_embeddings: bool = False
    d_state: int = 16               # mamba SSM state size
    d_conv: int = 4                 # mamba conv width
    ssm_expand: int = 2             # mamba/mlstm inner expansion
    vlm_patches: int = 0            # qwen2-vl: stub patch positions
    rope_theta: float = 1e6
    # ---- derived -----------------------------------------------------------

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    def layer_kind(self, i: int) -> str:
        return self.pattern[i % len(self.pattern)]

    def layer_is_moe(self, i: int) -> bool:
        return self.moe is not None and (i % self.moe.every_k_layers
                                         == self.moe.every_k_layers - 1)

    @property
    def group_size(self) -> int:
        """Layers per scan group: the period of (pattern x MoE cadence)."""
        period = len(self.pattern)
        if self.moe is not None:
            period = math.lcm(period, self.moe.every_k_layers)
        return period

    @property
    def n_groups(self) -> int:
        assert self.n_layers % self.group_size == 0, (
            f"{self.name}: n_layers={self.n_layers} not divisible by "
            f"group_size={self.group_size}")
        return self.n_layers // self.group_size

    @property
    def is_subquadratic(self) -> bool:
        """True if inter-token mixing is O(1)-state (SSM / hybrid / xLSTM)."""
        return any(k in ("mamba", "mlstm", "slstm") for k in self.pattern)

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    # ---- parameter counting (for roofline MODEL_FLOPS) ----------------------
    def _attn_params(self) -> int:
        qkv = self.d_model * (self.n_heads + 2 * self.n_kv_heads) * self.hd
        o = self.n_heads * self.hd * self.d_model
        return qkv + o

    def _ffn_params(self, moe_layer: bool) -> tuple[int, int]:
        """(total, active) FFN params for one layer."""
        mult = 3 if self.act in ("swiglu", "geglu") else 2
        dense = mult * self.d_model * self.d_ff
        if moe_layer and self.moe is not None:
            total = self.moe.n_experts * dense + self.d_model * self.moe.n_experts
            active = self.moe.top_k * dense + self.d_model * self.moe.n_experts
            return total, active
        return dense, dense

    def _mixer_params(self, kind: str) -> int:
        d, di, ds = self.d_model, self.d_inner, self.d_state
        if kind == "attn":
            return self._attn_params()
        if kind == "mamba":
            # in_proj (d -> 2*di), conv, x-dependent (dt, B, C), out_proj
            return (d * 2 * di + self.d_conv * di + di * (ds * 2 + di // 16 + 1)
                    + di * ds + di * d)
        if kind == "mlstm":
            # in_proj (d -> 2*di: main + gate), diagonal q/k transforms,
            # per-head i/f gate projections, out_proj
            return d * 2 * di + 2 * di + 2 * di + di * d
        if kind == "slstm":
            # 4 input-gate projections + block-diagonal (per-head) recurrence
            return 4 * d * d + 4 * d * d // max(self.n_heads, 1) + 4 * d
        raise ValueError(kind)

    def param_counts(self) -> dict[str, float]:
        """Returns total and active (MoE) parameter counts."""
        emb = self.vocab * self.d_model
        total = active = emb if self.tie_embeddings else 2 * emb
        for i in range(self.n_layers):
            m = self._mixer_params(self.layer_kind(i))
            if self.d_ff > 0:
                f_total, f_active = self._ffn_params(self.layer_is_moe(i))
            else:
                f_total = f_active = 0
            total += m + f_total
            active += m + f_active
        if self.is_encdec:
            for _ in range(self.encoder_layers):
                m = self._attn_params()
                f = (3 if self.act == "swiglu" else 2) * self.d_model * self.d_ff
                total += m + f
                active += m + f
            # decoder cross-attention
            total += self.n_layers * self._attn_params()
            active += self.n_layers * self._attn_params()
        return {"total": float(total), "active": float(active)}

    def reduced(self, **overrides) -> "ArchConfig":
        """A tiny same-family config for CPU smoke tests."""
        d = {
            "name": self.name + "-smoke",
            "n_layers": self.group_size,
            "d_model": 64,
            "n_heads": 4,
            "n_kv_heads": min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            "d_ff": 128 if self.d_ff else 0,
            "vocab": 256,
            "head_dim": 16,
            "encoder_layers": min(self.encoder_layers, 2),
            "enc_frames": 16 if self.is_encdec else self.enc_frames,
            "vlm_patches": 8 if self.vlm_patches else 0,
            "d_state": 8,
            # capacity_factor=4 -> no token drops, so the decode-equivalence
            # invariant holds exactly (saturated capacity legitimately breaks
            # prefill<->decode equality in capacity-routed MoE)
            "moe": (MoECfg(n_experts=4, top_k=min(self.moe.top_k, 2),
                           every_k_layers=self.moe.every_k_layers,
                           capacity_factor=4.0)
                    if self.moe else None),
            "mrope_sections": (4, 2, 2),   # sums to head_dim(16) // 2
        }
        d.update(overrides)
        return dataclasses.replace(self, **d)
