"""Mixture-of-Experts with top-k routing and capacity-bounded dispatch.

GShard-style: tokens are organised into fixed-size groups so the dispatch /
combine einsums stay O(tokens * group * d) rather than quadratic in the
global token count.  Overflowing tokens (beyond each expert's capacity) are
dropped — their residual stream passes through unchanged.

Expert weights: (E, d, f) so that either the expert axis (EP) or the hidden
axis (TP) can be mesh-sharded depending on divisibility (runtime/sharding).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .common import dense_init, gated_act

GROUP = 512  # tokens per dispatch group


def moe_params(key, cfg, dtype) -> dict:
    m = cfg.moe
    d, f, E = cfg.d_model, cfg.d_ff, m.n_experts
    ks = jax.random.split(key, 4)
    p = {
        "router": dense_init(ks[0], (d, E), jnp.float32),
        "w_up": dense_init(ks[1], (E, d, f), dtype),
        "w_down": dense_init(ks[2], (E, f, d), dtype),
    }
    if cfg.act in ("swiglu", "geglu"):
        p["w_gate"] = dense_init(ks[3], (E, d, f), dtype)
    return p


def capacity(group: int, cfg) -> int:
    m = cfg.moe
    c = int(math.ceil(group * m.top_k * m.capacity_factor / m.n_experts))
    return max(min(c, group), 1)


def route(x2d: jax.Array, router_w: jax.Array, cfg):
    """x2d: (G, T, d) grouped tokens -> (dispatch, combine, aux_loss).

    dispatch: (G, T, E, C) one-hot; combine: same shape with gate weights.
    """
    m = cfg.moe
    G, T, _ = x2d.shape
    E, C = m.n_experts, capacity(T, cfg)
    logits = x2d.astype(jnp.float32) @ router_w            # (G, T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, m.top_k)    # (G, T, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)            # renormalise top-k

    # position of each (token, k) in its expert's queue, counted over the
    # flattened (T * k) priority order
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)  # (G, T, k, E)
    flat = onehot.reshape(G, T * m.top_k, E)
    pos = jnp.cumsum(flat, axis=1) - flat                    # (G, T*k, E)
    pos_k = (pos * flat).sum(-1).reshape(G, T, m.top_k)      # queue slot
    expert_pos = pos_k.astype(jnp.int32)
    keep = (pos_k < C) & (gate_vals > 0)

    slot_oh = jax.nn.one_hot(expert_pos, C, dtype=jnp.float32)   # (G,T,k,C)
    slot_oh = slot_oh * keep[..., None]
    dispatch = jnp.einsum("gtke,gtkc->gtec", onehot, slot_oh)    # (G,T,E,C)
    combine = jnp.einsum("gtk,gtke,gtkc->gtec",
                         gate_vals, onehot, slot_oh)
    # load-balancing auxiliary loss (Switch)
    density = onehot.sum(2).mean(1)                              # (G, E)
    density_proxy = probs.mean(1)                                # (G, E)
    aux = (density * density_proxy).sum(-1).mean() * E
    return dispatch, combine, aux


def apply_moe(p: dict, x: jax.Array, cfg) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out, aux_loss)."""
    B, S, d = x.shape
    N = B * S
    T = min(GROUP, N)
    assert N % T == 0, (N, T)
    from repro.runtime.hints import axis_size, constrain as _constrain
    # decode-size token counts (N = batch) are best left to GSPMD: forcing
    # EP/TP layouts there makes XLA all-gather expert weights instead
    # (measured 6x regression on MoE decode cells — EXPERIMENTS §Perf/H6)
    if N < 2048:
        def constrain(t, *spec):
            return t
    else:
        constrain = _constrain
    xg = constrain(x.reshape(N // T, T, d), "dp", None, None)
    dispatch, combine, aux = route(xg, p["router"], cfg)
    dd, cc = dispatch.astype(x.dtype), combine.astype(x.dtype)
    # EP when the expert axis divides the model axis, TP on d_ff otherwise
    ep = cfg.moe.n_experts % max(axis_size("tp"), 1) == 0
    xe = constrain(jnp.einsum("gtd,gtec->gecd", xg, dd),
                   "dp", "tp" if ep else None, None, None)     # (G,E,C,d)
    up = jnp.einsum("gecd,edf->gecf", xe, p["w_up"])
    if "w_gate" in p:
        gate = jnp.einsum("gecd,edf->gecf", xe, p["w_gate"])
        h = gated_act(cfg.act, up, gate)
    else:
        h = jax.nn.gelu(up)
    h = constrain(h, "dp", "tp" if ep else None, None,
                  None if ep else "tp")
    out = jnp.einsum("gecf,efd->gecd", h, p["w_down"])
    y = jnp.einsum("gecd,gtec->gtd", out, cc)
    return y.reshape(B, S, d), aux


def dense_ffn_params(key, cfg, dtype) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {"w_up": dense_init(ks[0], (d, f), dtype),
         "w_down": dense_init(ks[1], (f, d), dtype)}
    if cfg.act in ("swiglu", "geglu"):
        p["w_gate"] = dense_init(ks[2], (d, f), dtype)
    return p


def apply_dense_ffn(p: dict, x: jax.Array, cfg) -> jax.Array:
    up = x @ p["w_up"]
    if "w_gate" in p:
        h = gated_act(cfg.act, up, x @ p["w_gate"])
    else:
        h = jax.nn.gelu(up)
    return h @ p["w_down"]
