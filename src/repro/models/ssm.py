"""State-space / recurrent mixers: Mamba (jamba), mLSTM + sLSTM (xLSTM).

Design notes (DESIGN.md §Arch-applicability):

* **Mamba**: selective SSM; prefill/train uses a `lax.scan` over time with an
  O(d_inner * d_state) carry (HLO stays O(1) in sequence length), decode is a
  single-step state update.  This is the TPU-idiomatic replacement for the
  CUDA selective-scan kernel.
* **mLSTM**: matrix-memory LSTM implemented in the *chunkwise-parallel* form
  of gated linear attention (intra-chunk attention-like block + inter-chunk
  state carry), which keeps the training backward pass O(S * d) instead of
  materialising per-step outer products.  q/k use diagonal (per-channel)
  transforms to match the published parameter budget.
* **sLSTM**: scalar-memory LSTM with block-diagonal (per-head) recurrence;
  inherently sequential -> `lax.scan` over time.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import dense_init

# =============================================================================
# Mamba
# =============================================================================

def mamba_params(key, cfg, dtype) -> dict:
    d, di, ds = cfg.d_model, cfg.d_inner, cfg.d_state
    dt_rank = max(di // 16, 1)
    ks = jax.random.split(key, 6)
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di), dtype),
        "conv_w": dense_init(ks[1], (cfg.d_conv, di), dtype, scale=0.5),
        "x_proj": dense_init(ks[2], (di, dt_rank + 2 * ds), dtype),
        "dt_proj": dense_init(ks[3], (dt_rank, di), dtype),
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, ds + 1, dtype=jnp.float32), (di, ds))).astype(jnp.float32),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[5], (di, d), dtype),
    }


def _mamba_dbc(p, xin, cfg):
    """delta (B,S,di), Bmat/Cmat (B,S,ds) from the conv output."""
    dt_rank = p["dt_proj"].shape[0]
    proj = xin @ p["x_proj"]
    dt, Bm, Cm = jnp.split(proj, [dt_rank, dt_rank + cfg.d_state], axis=-1)
    delta = jax.nn.softplus(dt @ p["dt_proj"]).astype(jnp.float32)
    return delta, Bm.astype(jnp.float32), Cm.astype(jnp.float32)


def _causal_conv(x, w, state=None):
    """Depthwise causal conv over time.  x: (B,S,di); w: (K,di).
    ``state``: (B, K-1, di) previous inputs (decode) or None (zero-pad)."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros(x.shape[:1] + (K - 1,) + x.shape[2:], x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)                  # (B, S+K-1, di)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(K))
    new_state = xp[:, -(K - 1):] if K > 1 else pad
    return out, new_state


def mamba_forward(p: dict, x: jax.Array, cfg):
    """Full-sequence selective scan.  x: (B,S,d) -> (y, final_state)."""
    B, S, d = x.shape
    di, ds = cfg.d_inner, cfg.d_state
    xz = x @ p["in_proj"]
    xin, z = jnp.split(xz, 2, axis=-1)
    xin, conv_state = _causal_conv(xin, p["conv_w"])
    xin = jax.nn.silu(xin)
    delta, Bm, Cm = _mamba_dbc(p, xin, cfg)
    A = -jnp.exp(p["A_log"])                                # (di, ds)
    xf = xin.astype(jnp.float32)

    def step(h, t):
        dt_t, B_t, C_t, x_t = t                              # (B,di) (B,ds) ..
        da = jnp.exp(dt_t[..., None] * A)                    # (B, di, ds)
        db = dt_t[..., None] * B_t[:, None, :]               # (B, di, ds)
        h = da * h + db * x_t[..., None]
        y = (h * C_t[:, None, :]).sum(-1)                    # (B, di)
        return h, y

    # two-level scan: the outer carry (one (B,di,ds) state per chunk) is all
    # autodiff saves; each chunk's inner steps are rematerialised in the
    # backward pass — without this, the per-step (B,di,ds) discretisations
    # would be stashed for all S steps.
    L = S
    for cand in (128, 64, 32, 16, 8, 4, 2, 1):
        if S % cand == 0:
            L = cand
            break

    @jax.checkpoint
    def chunk_body(h, ts_chunk):
        return jax.lax.scan(step, h, ts_chunk)

    def split(a):   # (B,S,...) -> (n, L, B, ...)
        return jnp.moveaxis(a, 1, 0).reshape(S // L, L, *a.shape[:1],
                                             *a.shape[2:])

    ts = tuple(split(a) for a in (delta, Bm, Cm, xf))
    h0 = jnp.zeros((B, di, ds), jnp.float32)
    h, ys = jax.lax.scan(chunk_body, h0, ts)
    y = jnp.moveaxis(ys.reshape(S, B, di), 0, 1) + xf * p["D"]
    y = (y.astype(x.dtype) * jax.nn.silu(z)) @ p["out_proj"]
    return y, {"conv": conv_state, "h": h}


def mamba_decode(p: dict, x: jax.Array, cfg, cache: dict):
    """Single-token update.  x: (B,1,d)."""
    B = x.shape[0]
    xz = x[:, 0] @ p["in_proj"]
    xin, z = jnp.split(xz, 2, axis=-1)
    xin3, conv_state = _causal_conv(xin[:, None], p["conv_w"], cache["conv"])
    xin = jax.nn.silu(xin3[:, 0])
    delta, Bm, Cm = _mamba_dbc(p, xin[:, None], cfg)
    delta, Bm, Cm = delta[:, 0], Bm[:, 0], Cm[:, 0]
    A = -jnp.exp(p["A_log"])
    da = jnp.exp(delta[..., None] * A)
    db = delta[..., None] * Bm[:, None, :]
    h = da * cache["h"] + db * xin.astype(jnp.float32)[..., None]
    y = (h * Cm[:, None, :]).sum(-1) + xin.astype(jnp.float32) * p["D"]
    y = (y.astype(x.dtype) * jax.nn.silu(z)) @ p["out_proj"]
    return y[:, None], {"conv": conv_state, "h": h}


def mamba_cache(B, cfg, dtype) -> dict:
    return {"conv": jnp.zeros((B, cfg.d_conv - 1, cfg.d_inner), dtype),
            "h": jnp.zeros((B, cfg.d_inner, cfg.d_state), jnp.float32)}


# =============================================================================
# mLSTM — chunkwise-parallel gated linear attention
# =============================================================================

MLSTM_CHUNK = 64


def mlstm_params(key, cfg, dtype) -> dict:
    d, di, H = cfg.d_model, cfg.d_inner, cfg.n_heads
    ks = jax.random.split(key, 5)
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di), dtype),
        "wq": jnp.ones((di,), dtype), "wk": jnp.ones((di,), dtype),
        "gate_proj": dense_init(ks[1], (d, 2 * H), jnp.float32, scale=0.02),
        "gate_bias": jnp.concatenate([jnp.zeros((H,)), 3.0 * jnp.ones((H,))]
                                     ).astype(jnp.float32),
        "out_proj": dense_init(ks[2], (di, d), dtype),
    }


def _mlstm_qkv_gates(p, x, cfg):
    B, S, _ = x.shape
    H = cfg.n_heads
    dh = cfg.d_inner // H
    xm, z = jnp.split(x @ p["in_proj"], 2, axis=-1)
    q = (xm * p["wq"]).reshape(B, S, H, dh)
    k = (xm * p["wk"]).reshape(B, S, H, dh) * dh ** -0.5
    v = xm.reshape(B, S, H, dh)
    gates = x.astype(jnp.float32) @ p["gate_proj"] + p["gate_bias"]
    i_gate, f_gate = jnp.split(gates, 2, axis=-1)            # (B,S,H)
    log_f = jax.nn.log_sigmoid(f_gate)
    i_gate = jnp.exp(jax.nn.log_sigmoid(i_gate))             # in (0,1), stable
    return q, k, v, i_gate, log_f, z


def mlstm_forward(p: dict, x: jax.Array, cfg):
    """Chunkwise-parallel form.  x: (B,S,d) -> (y, state)."""
    B, S, d = x.shape
    H, dh = cfg.n_heads, cfg.d_inner // cfg.n_heads
    L = min(MLSTM_CHUNK, S)
    assert S % L == 0
    n = S // L
    q, k, v, ig, lf, z = _mlstm_qkv_gates(p, x, cfg)

    @jax.checkpoint
    def chunk(carry, xs):
        C, nrm = carry                                       # (B,H,dh,dh) (B,H,dh)
        qc, kc, vc, ic, lfc = xs                             # (B,L,H,*) ...
        qf = qc.astype(jnp.float32)
        kf = kc.astype(jnp.float32)
        vf = vc.astype(jnp.float32)
        F = jnp.cumsum(lfc, axis=1)                          # (B,L,H)
        Ftot = F[:, -1]                                      # (B,H)
        # intra-chunk: decay(t,s) = exp(F_t - F_s) for s <= t
        dmat = F[:, :, None, :] - F[:, None, :, :]           # (B,L,L,H)
        causal = jnp.tril(jnp.ones((L, L), bool))
        decay = jnp.where(causal[None, :, :, None], jnp.exp(dmat), 0.0)
        s = jnp.einsum("blhd,bmhd->blmh", qf, kf) * decay \
            * ic[:, None, :, :]                              # (B,L,L,H)
        y_intra = jnp.einsum("blmh,bmhd->blhd", s, vf)
        # inter-chunk: q_t reads the carried state, decayed by exp(F_t)
        y_inter = jnp.einsum("blhd,bhde->blhe", qf * jnp.exp(F)[..., None], C)
        nrm_t = (jnp.einsum("blhd,bhd->blh", qf * jnp.exp(F)[..., None], nrm)
                 + s.sum(2))                                 # (B,L,H)
        y = (y_intra + y_inter) / jnp.maximum(
            jnp.abs(nrm_t)[..., None], 1.0)
        # state update: C' = exp(Ftot) C + sum_s exp(Ftot - F_s) i_s k_s v_s^T
        w = jnp.exp(Ftot[:, None] - F) * ic                  # (B,L,H)
        C_new = jnp.exp(Ftot)[..., None, None] * C + jnp.einsum(
            "blhd,blhe->bhde", kf * w[..., None], vf)
        nrm_new = jnp.exp(Ftot)[..., None] * nrm + (kf * w[..., None]).sum(1)
        return (C_new, nrm_new), y

    def split_chunks(a):
        return jnp.moveaxis(a.reshape(B, n, L, *a.shape[2:]), 1, 0)

    C0 = jnp.zeros((B, H, dh, dh), jnp.float32)
    n0 = jnp.zeros((B, H, dh), jnp.float32)
    xs = tuple(split_chunks(a) for a in (q, k, v, ig, lf))

    # sqrt(n) checkpointing over chunks: the (B,H,dh,dh) matrix state is
    # the dominant residual (dh can be 1024), so saving it per-chunk for
    # the backward pass is O(n) copies; a two-level scan saves only
    # O(sqrt(n)) outer carries and rematerialises the inner ones.
    n1 = 1
    for cand in range(int(n ** 0.5), 0, -1):
        if n % cand == 0:
            n1 = cand
            break
    n2 = n // n1

    @jax.checkpoint
    def outer(carry, xs_outer):
        return jax.lax.scan(chunk, carry, xs_outer)

    xs2 = jax.tree.map(lambda a: a.reshape(n1, n2, *a.shape[1:]), xs)
    (C, nrm), ys = jax.lax.scan(outer, (C0, n0), xs2)
    ys = ys.reshape(n, *ys.shape[2:])
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, cfg.d_inner).astype(x.dtype)
    y = (y * jax.nn.silu(z)) @ p["out_proj"]
    return y, {"C": C, "n": nrm}


def mlstm_decode(p: dict, x: jax.Array, cfg, cache: dict):
    B = x.shape[0]
    H, dh = cfg.n_heads, cfg.d_inner // cfg.n_heads
    q, k, v, ig, lf, z = _mlstm_qkv_gates(p, x, cfg)
    qf, kf, vf = (a[:, 0].astype(jnp.float32) for a in (q, k, v))
    f = jnp.exp(lf[:, 0])                                    # (B,H)
    i = ig[:, 0]
    C = f[..., None, None] * cache["C"] + i[..., None, None] * jnp.einsum(
        "bhd,bhe->bhde", kf, vf)
    nrm = f[..., None] * cache["n"] + i[..., None] * kf
    y = jnp.einsum("bhd,bhde->bhe", qf, C)
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qf, nrm)), 1.0)
    y = (y / denom[..., None]).reshape(B, 1, cfg.d_inner).astype(x.dtype)
    y = (y * jax.nn.silu(z)) @ p["out_proj"]
    return y, {"C": C, "n": nrm}


def mlstm_cache(B, cfg, dtype) -> dict:
    H, dh = cfg.n_heads, cfg.d_inner // cfg.n_heads
    return {"C": jnp.zeros((B, H, dh, dh), jnp.float32),
            "n": jnp.zeros((B, H, dh), jnp.float32)}


# =============================================================================
# sLSTM — scalar memory, block-diagonal recurrence, sequential scan
# =============================================================================

def slstm_params(key, cfg, dtype) -> dict:
    d, H = cfg.d_model, cfg.n_heads
    dh = d // H
    ks = jax.random.split(key, 3)
    return {
        "w_in": dense_init(ks[0], (d, 4 * d), dtype),
        "r": dense_init(ks[1], (H, dh, 4 * dh), dtype, scale=0.3 / dh ** 0.5),
        "bias": jnp.zeros((4 * d,), jnp.float32),
        "out_proj": dense_init(ks[2], (d, d), dtype),
    }


def _slstm_step(p, cfg, carry, zx):
    """One timestep of the stabilised sLSTM.  zx: (B, 4d) input projection."""
    h, c, n, m = carry                                        # (B,d) each
    B, d = h.shape
    H = cfg.n_heads
    dh = d // H
    rec = jnp.einsum("bhx,hxy->bhy", h.reshape(B, H, dh).astype(jnp.float32),
                     p["r"].astype(jnp.float32)).reshape(B, 4 * d)
    g = zx.astype(jnp.float32) + rec + p["bias"]
    zi, ii, fi, oi = jnp.split(g, 4, axis=-1)
    zt = jnp.tanh(zi)
    ot = jax.nn.sigmoid(oi)
    log_i, log_f = ii, jax.nn.log_sigmoid(fi)
    m_new = jnp.maximum(log_f + m, log_i)                     # stabiliser
    i_s = jnp.exp(log_i - m_new)
    f_s = jnp.exp(log_f + m - m_new)
    c_new = f_s * c + i_s * zt
    n_new = f_s * n + i_s
    h_new = ot * c_new / jnp.maximum(n_new, 1.0)
    return (h_new, c_new, n_new, m_new)


def slstm_forward(p: dict, x: jax.Array, cfg):
    B, S, d = x.shape
    zx = x @ p["w_in"]                                        # (B,S,4d)

    def step(carry, z_t):
        new = _slstm_step(p, cfg, carry, z_t)
        return new, new[0]

    L = next(c for c in (128, 64, 32, 16, 8, 4, 2, 1) if S % c == 0)

    @jax.checkpoint
    def chunk_body(carry, z_chunk):
        return jax.lax.scan(step, carry, z_chunk)

    zc = jnp.moveaxis(zx, 1, 0).reshape(S // L, L, B, 4 * d)
    init = tuple(jnp.zeros((B, d), jnp.float32) for _ in range(4))
    carry, hs = jax.lax.scan(chunk_body, init, zc)
    y = jnp.moveaxis(hs.reshape(S, B, d), 0, 1).astype(x.dtype) @ p["out_proj"]
    return y, {"h": carry[0], "c": carry[1], "n": carry[2], "m": carry[3]}


def slstm_decode(p: dict, x: jax.Array, cfg, cache: dict):
    zx = x[:, 0] @ p["w_in"]
    carry = (cache["h"], cache["c"], cache["n"], cache["m"])
    h, c, n, m = _slstm_step(p, cfg, carry, zx)
    y = h[:, None].astype(x.dtype) @ p["out_proj"]
    return y, {"h": h, "c": c, "n": n, "m": m}


def slstm_cache(B, cfg, dtype) -> dict:
    d = cfg.d_model
    return {k: jnp.zeros((B, d), jnp.float32) for k in ("h", "c", "n", "m")}
