"""The shared off-chip channel: burst-granular bandwidth accounting.

SMOF's eviction story assumes off-chip memory is *there* — Eq. 2 prices
each evicted stream's bandwidth, the device sheet caps the total — but
until this subsystem every stream enjoyed a private, infinite channel.
H2PIPE's measurement is that the shared HBM/DDR port is the first-order
effect: streams contend, and the channel moves data in DMA bursts, not
single words.

:class:`OffChipChannel` is the physical model everything else in
``repro.memory`` prices against:

* capacity is ``Device.offchip_gbps`` converted to **bits per model
  cycle** at the device clock — the same cycle unit as the Eq. 5/6 stage
  latency model, so transfer times and compute latencies subtract
  directly;
* transactions are whole DMA bursts of ``DMA_FIFO_DEPTH`` words (the
  FIFO the paper sizes Eq. 1's ``d_b'`` from): a stream moving
  ``bits_per_frame`` bits pays for ``ceil(bits / burst_bits)`` bursts —
  small stripes round *up* to a burst, exactly the quantisation a DDR
  controller imposes;
* a pipeline tick of ``tick_cycles`` model cycles gives the channel a
  budget of ``bits_per_cycle * tick_cycles`` bits to move — the cycle
  budget the arbiter divides between streams.

:class:`ChannelConfig` is the user-facing knob set (policy + gbps
override) that travels on ``CompileSpec.channel`` and round-trips through
``Compiled.save``/``load`` with the same forward-compat policy as
``ObsConfig``: unknown keys from a newer writer are ignored.

This module is deliberately dependency-free (no JAX) so property tests
and the fuzz generator can drive it standalone.
"""
from __future__ import annotations

import dataclasses
import math

from ..core.eviction import DMA_FIFO_DEPTH

__all__ = ["POLICIES", "ChannelConfig", "OffChipChannel"]

#: Arbitration policies the arbiter implements (see ``arbiter.py``).
POLICIES = ("round-robin", "fixed-priority", "weighted-fair")


@dataclasses.dataclass(frozen=True)
class ChannelConfig:
    """User-facing channel knobs (``CompileSpec.channel``).

    ``policy`` picks the arbiter's sharing discipline; ``gbps`` overrides
    the device sheet's ``offchip_gbps`` (``None``: use the device);
    ``word_bits`` sets the burst word width (DMA bursts move
    ``DMA_FIFO_DEPTH`` such words).  The three ``*_weight`` fields are the
    weighted-fair shares per stream kind — ignored by the other policies.
    """
    policy: str = "round-robin"
    gbps: float | None = None
    word_bits: int = 16
    weight_fetch_weight: float = 1.0
    evict_weight: float = 1.0
    restore_weight: float = 1.0

    def __post_init__(self) -> None:
        if self.policy not in POLICIES:
            raise ValueError(f"unknown channel policy {self.policy!r}; "
                             f"pick one of {POLICIES}")
        if self.gbps is not None and self.gbps <= 0:
            raise ValueError(f"channel gbps must be > 0, got {self.gbps}")
        if self.word_bits < 1:
            raise ValueError(f"word_bits must be >= 1, got {self.word_bits}")
        for f in ("weight_fetch_weight", "evict_weight", "restore_weight"):
            if getattr(self, f) < 0:
                raise ValueError(
                    f"{f} must be >= 0, got {getattr(self, f)}")

    def kind_weight(self, kind: str) -> float:
        return {"weight-fetch": self.weight_fetch_weight,
                "activation-evict": self.evict_weight,
                "activation-restore": self.restore_weight}.get(kind, 1.0)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "ChannelConfig":
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in known})


class OffChipChannel:
    """One shared off-chip port, priced in model cycles and DMA bursts.

    gbps
        the port's raw bandwidth (``Device.offchip_gbps`` or the config's
        override).
    freq_mhz
        the device clock the Eq. 5/6 cycle counts are expressed in; the
        conversion ``bits_per_cycle = gbps * 1e9 / (freq_mhz * 1e6)``
        puts channel capacity and stage latency in the same unit.
    word_bits / fifo_depth
        one DMA burst moves ``fifo_depth`` words of ``word_bits`` each —
        the transaction granularity all transfers round up to.
    """

    def __init__(self, gbps: float, *, freq_mhz: float,
                 word_bits: int = 16,
                 fifo_depth: float = DMA_FIFO_DEPTH) -> None:
        if gbps <= 0 or freq_mhz <= 0:
            raise ValueError(f"need gbps > 0 and freq_mhz > 0, got "
                             f"{gbps=} {freq_mhz=}")
        self.gbps = float(gbps)
        self.freq_mhz = float(freq_mhz)
        self.word_bits = int(word_bits)
        self.fifo_depth = float(fifo_depth)

    @property
    def cycles_per_s(self) -> float:
        return self.freq_mhz * 1e6

    @property
    def bits_per_cycle(self) -> float:
        """Channel capacity per model cycle (the arbiter's budget unit)."""
        return self.gbps * 1e9 / self.cycles_per_s

    @property
    def burst_bits(self) -> int:
        """One DMA transaction: ``DMA_FIFO_DEPTH`` words."""
        return int(self.fifo_depth * self.word_bits)

    def n_bursts(self, bits: int) -> int:
        """Whole DMA bursts needed to move ``bits`` (0 bits -> 0 bursts)."""
        if bits <= 0:
            return 0
        return math.ceil(bits / self.burst_bits)

    def quantized_bits(self, bits: int) -> int:
        """``bits`` rounded up to whole bursts — what the port really moves."""
        return self.n_bursts(bits) * self.burst_bits

    def cycle_budget(self, tick_cycles: float) -> float:
        """Bits the channel can move during one ``tick_cycles`` tick."""
        return self.bits_per_cycle * max(tick_cycles, 0.0)

    def transfer_cycles(self, bits: int, rate_bits_per_cycle: float) -> float:
        """Model cycles to move ``bits`` (burst-quantised) at a granted
        rate; ``inf`` when the stream was starved (rate 0 but bits > 0)."""
        q = self.quantized_bits(bits)
        if q == 0:
            return 0.0
        if rate_bits_per_cycle <= 0:
            return math.inf
        return q / rate_bits_per_cycle

    def summary(self) -> dict:
        return {
            "gbps": self.gbps,
            "freq_mhz": self.freq_mhz,
            "bits_per_cycle": self.bits_per_cycle,
            "burst_bits": self.burst_bits,
        }
