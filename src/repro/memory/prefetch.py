"""Double-buffered weight prefetch scheduling with stage-start deadlines.

SMOF's weight fragmentation keeps a static fraction of each layer's
weights pinned on-chip and streams the rest from off-chip **every
frame**.  For the pipeline not to stall, stage ``j``'s streamed fragment
for microbatch ``b`` must be resident before the stage starts computing
``b`` — a hard deadline set by the 1F1B diagram (stage ``j`` runs
microbatch ``b`` at tick ``j + b``).

The prefetcher models the classic double-buffer: two weight slots per
stage, one being computed from while the other fills.  Per (stage,
microbatch) it emits one :class:`PrefetchSlot`:

* the **initial fill** (``b = 0``) may start one tick before the stream
  (the warmup tick every DMA pipeline gets), so its budget is
  ``(j + 1) * tick_cycles`` — deeper stages get more slack, exactly the
  fill-phase bubbles of the 1F1B schedule;
* every **steady slot** (``b >= 1``) starts when the previous microbatch
  starts computing and must land one tick later: budget =
  ``tick_cycles``.

A slot whose transfer (at the arbiter's granted rate, burst-quantised)
exceeds its budget is a **deadline miss** — the stage would stall on
weights.  Misses are counted, not failed: the contended latency model
already prices the slowdown; the miss count is the attribution ("which
stage's fragment is too big for its share").

``tick_cycles`` is injectable, so unit tests drive the deadline math
with a stub clock.  Like the rest of ``repro.memory``, no JAX.
"""
from __future__ import annotations

import dataclasses
import math

from .channel import OffChipChannel

__all__ = ["PrefetchSlot", "PrefetchReport", "prefetch_schedule"]

_EPS = 1e-9


@dataclasses.dataclass(frozen=True)
class PrefetchSlot:
    """One double-buffer fill: stage ``j``'s streamed fragment for one
    microbatch, with its start tick, deadline and cycle budget."""
    stage: int
    microbatch: int
    bits: int                 # streamed fragment volume (exact)
    quantized_bits: int       # burst-rounded (what the port moves)
    start_tick: int           # fetch may begin here (-1: warmup tick)
    deadline_tick: int        # stage-start tick of this microbatch
    budget_cycles: float      # (deadline - start) * tick_cycles
    transfer_cycles: float    # at the arbiter's granted rate

    @property
    def slack_cycles(self) -> float:
        """Budget minus transfer; negative slack is a miss."""
        return self.budget_cycles - self.transfer_cycles

    @property
    def missed(self) -> bool:
        return self.transfer_cycles > self.budget_cycles + _EPS

    def summary(self) -> dict:
        return dataclasses.asdict(self) | {
            "slack_cycles": self.slack_cycles,
            "missed": self.missed,
        }


@dataclasses.dataclass
class PrefetchReport:
    """The whole stream's prefetch schedule + deadline accounting."""
    slots: list[PrefetchSlot]
    tick_cycles: float

    @property
    def deadline_misses(self) -> int:
        return sum(1 for s in self.slots if s.missed)

    @property
    def worst_slack_cycles(self) -> float:
        """Most negative slack across slots (0.0 when no slots)."""
        return min((s.slack_cycles for s in self.slots), default=0.0)

    def misses_by_stage(self) -> dict[int, int]:
        out: dict[int, int] = {}
        for s in self.slots:
            if s.missed:
                out[s.stage] = out.get(s.stage, 0) + 1
        return out

    def summary(self) -> dict:
        return {
            "n_slots": len(self.slots),
            "tick_cycles": self.tick_cycles,
            "deadline_misses": self.deadline_misses,
            "worst_slack_cycles": (self.worst_slack_cycles
                                   if math.isfinite(self.worst_slack_cycles)
                                   else None),
            "misses_by_stage": {str(k): v
                                for k, v in self.misses_by_stage().items()},
        }


def prefetch_schedule(weight_bits_by_stage: dict[int, int],
                      granted_rate_by_stage: dict[int, float], *,
                      tick_cycles: float, microbatches: int,
                      channel: OffChipChannel) -> PrefetchReport:
    """Build the double-buffered prefetch schedule for one stream run.

    weight_bits_by_stage
        exact streamed-fragment bits per stage (stages with 0 bits get no
        slots — their weights are fully static).
    granted_rate_by_stage
        the stage's weight-fetch stream rate from the arbiter
        [bits/cycle]; a starved stage (rate 0) gets infinite transfer
        time and misses every deadline.
    tick_cycles
        one pipeline tick in model cycles (Eq. 6's ``max_j L_j`` in
        production; a stub constant in unit tests).
    """
    if tick_cycles <= 0:
        raise ValueError(f"tick_cycles must be > 0, got {tick_cycles}")
    if microbatches < 1:
        raise ValueError(f"need >= 1 microbatch, got {microbatches}")
    slots: list[PrefetchSlot] = []
    for stage in sorted(weight_bits_by_stage):
        bits = int(weight_bits_by_stage[stage])
        if bits <= 0:
            continue
        rate = granted_rate_by_stage.get(stage, 0.0)
        xfer = channel.transfer_cycles(bits, rate)
        q = channel.quantized_bits(bits)
        for b in range(microbatches):
            start = -1 if b == 0 else stage + b - 1
            deadline = stage + b
            budget = (deadline - start) * tick_cycles
            slots.append(PrefetchSlot(
                stage=stage, microbatch=b, bits=bits, quantized_bits=q,
                start_tick=start, deadline_tick=deadline,
                budget_cycles=budget, transfer_cycles=xfer))
    return PrefetchReport(slots=slots, tick_cycles=tick_cycles)
