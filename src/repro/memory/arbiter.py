"""Bandwidth arbitration over the shared off-chip channel.

Every per-frame off-chip flow of a plan registers as a *stream*:

``weight-fetch``
    one per stage with a streamed (non-static) weight fragment — the
    fragment is re-fetched every frame (SMOF's weight fragmentation);
``activation-evict`` / ``activation-restore``
    one pair per spill record: the producer stage writes the encoded
    stripe off-chip, the consumer stage reads it back (Eq. 2 traffic).

The arbiter divides the channel's per-cycle bit budget between the
registered streams under one of three policies:

``round-robin``
    equal-share water-filling: every stream gets the same rate until it
    is satisfied, leftover capacity re-divides among the still-hungry;
``fixed-priority``
    strict priority by stream kind (weight-fetch first — a late weight
    fragment stalls compute directly — then restore, then evict), grant
    order within a kind follows registration; low-priority streams can
    starve when the channel oversubscribes, which is the point;
``weighted-fair``
    water-filling with per-kind weights from :class:`ChannelConfig`.

Every policy is **work-conserving** (capacity is only left idle when all
demand is met), never grants a stream more than it asked for, and never
exceeds channel capacity — the hypothesis properties in
``tests/test_properties.py`` pin all three invariants.

From the allocation falls the **contended** extension of the Eq. 5/6
stage-latency model: stage ``j``'s streams need ``X_j = sum_s
quantized_bits_s / granted_rate_s`` model cycles of channel time per
frame; the DMA FIFOs double-buffer transfers behind compute, so

    L_j^cont = max(L_j, X_j)          (transfer hides behind compute,
                                       or compute hides behind transfer)

and Eq. 5/6 over ``L^cont`` give the contended sequential/pipelined
frame times.  ``max`` guarantees ``L^cont >= L`` pointwise, so the
contended bound can never beat the uncontended one — the ordering the
``ContentionCheck`` and the fuzz oracles gate on.
"""
from __future__ import annotations

import dataclasses
import math

from .channel import ChannelConfig, OffChipChannel

__all__ = ["STREAM_KINDS", "PRIORITY_ORDER", "StreamDemand",
           "StreamAllocation", "ArbiterReport", "ChannelArbiter",
           "contended_stage_latencies", "contention_stall_cycles"]

#: The three off-chip flow kinds a plan generates.
STREAM_KINDS = ("weight-fetch", "activation-evict", "activation-restore")

#: fixed-priority grant order: late weights stall compute directly, a
#: missing restore starves the consumer, an evict is buffered by the FIFO.
PRIORITY_ORDER = ("weight-fetch", "activation-restore", "activation-evict")

_EPS = 1e-9


@dataclasses.dataclass(frozen=True)
class StreamDemand:
    """One registered off-chip stream (per-frame volume, exact bits)."""
    name: str
    kind: str
    stage: int
    bits_per_frame: int       # exact (SpillRecord.offchip_bits / weight sum)

    def __post_init__(self) -> None:
        if self.kind not in STREAM_KINDS:
            raise ValueError(f"unknown stream kind {self.kind!r}; "
                             f"pick one of {STREAM_KINDS}")
        if self.bits_per_frame < 0:
            raise ValueError(f"stream {self.name!r}: negative bits "
                             f"{self.bits_per_frame}")


@dataclasses.dataclass(frozen=True)
class StreamAllocation:
    """One stream's share of the channel after arbitration."""
    name: str
    kind: str
    stage: int
    bits_per_frame: int       # raw demand (exact)
    quantized_bits: int       # burst-rounded volume the port really moves
    bursts: int
    demand_rate: float        # quantized_bits / tick_cycles  [bits/cycle]
    granted_rate: float       # arbiter's grant               [bits/cycle]
    granted_gbps: float       # granted_rate at the device clock

    @property
    def satisfied(self) -> bool:
        return self.granted_rate >= self.demand_rate - _EPS

    @property
    def transfer_cycles(self) -> float:
        """Model cycles to move one frame's volume at the granted rate."""
        if self.quantized_bits == 0:
            return 0.0
        if self.granted_rate <= 0:
            return math.inf
        return self.quantized_bits / self.granted_rate

    def summary(self) -> dict:
        return dataclasses.asdict(self) | {
            "satisfied": self.satisfied,
            "transfer_cycles": self.transfer_cycles,
        }


def _water_fill(demands: list[float], weights: list[float],
                capacity: float) -> list[float]:
    """Weighted max-min fair allocation (water-filling).

    Repeatedly shares the remaining capacity in proportion to weight;
    streams whose demand falls below their share are granted exactly
    their demand and removed, freeing capacity for the rest.  Equal
    weights degrade to round-robin equal share.
    """
    n = len(demands)
    granted = [0.0] * n
    active = [i for i in range(n) if demands[i] > 0 and weights[i] > 0]
    cap = max(capacity, 0.0)
    while active and cap > _EPS:
        total_w = sum(weights[i] for i in active)
        share = cap / total_w
        sat = [i for i in active if demands[i] <= weights[i] * share + _EPS]
        if not sat:
            for i in active:
                granted[i] = weights[i] * share
            return granted
        for i in sat:
            granted[i] = demands[i]
            cap -= demands[i]
            active.remove(i)
    return granted


def _priority_fill(demands: list[float], order: list[int],
                   capacity: float) -> list[float]:
    """Strict-priority allocation: grant ``min(demand, remaining)`` in
    ``order``; later streams see only what is left (possibly nothing)."""
    granted = [0.0] * len(demands)
    cap = max(capacity, 0.0)
    for i in order:
        take = min(demands[i], cap)
        granted[i] = take
        cap -= take
    return granted


def _grant(policy: str, demands: list[float], weights: list[float],
           order: list[int], capacity: float) -> list[float]:
    """Dispatch one allocation round.  Module-level on purpose: the
    conformance harness's ``oversubscribe-channel`` fault monkeypatches
    this to skip the capacity cap, and the ``ContentionCheck`` /
    ``channel_model`` oracles must then catch the oversubscription."""
    if policy == "fixed-priority":
        return _priority_fill(demands, order, capacity)
    if policy == "round-robin":
        return _water_fill(demands, [1.0] * len(demands), capacity)
    if policy == "weighted-fair":
        return _water_fill(demands, weights, capacity)
    raise ValueError(f"unknown arbitration policy {policy!r}")


@dataclasses.dataclass
class ArbiterReport:
    """One arbitration round: per-stream grants + channel totals."""
    policy: str
    capacity_bits_per_cycle: float
    tick_cycles: float
    streams: list[StreamAllocation]

    @property
    def total_demand_rate(self) -> float:
        return sum(s.demand_rate for s in self.streams)

    @property
    def total_granted_rate(self) -> float:
        return sum(s.granted_rate for s in self.streams)

    @property
    def feasible(self) -> bool:
        """Aggregate demand fits the channel budget (no stream slowed)."""
        return self.total_demand_rate <= (self.capacity_bits_per_cycle
                                          * (1.0 + _EPS) + _EPS)

    @property
    def utilization(self) -> float:
        if self.capacity_bits_per_cycle <= 0:
            return 0.0
        return self.total_granted_rate / self.capacity_bits_per_cycle

    def by_kind(self) -> dict[str, list[StreamAllocation]]:
        out: dict[str, list[StreamAllocation]] = {k: [] for k in STREAM_KINDS}
        for s in self.streams:
            out[s.kind].append(s)
        return out

    def granted_gbps_by_kind(self) -> dict[str, float]:
        """Per-kind effective bandwidth — the SLO layer's per-stream
        budgets (what each direction was actually granted, not the flat
        device number)."""
        out = {k: 0.0 for k in STREAM_KINDS}
        for s in self.streams:
            out[s.kind] += s.granted_gbps
        return out

    def transfer_cycles_by_stage(self, n_stages: int) -> list[float]:
        """``X_j``: channel cycles stage ``j``'s streams need per frame."""
        out = [0.0] * n_stages
        for s in self.streams:
            if 0 <= s.stage < n_stages:
                out[s.stage] += s.transfer_cycles
        return out

    def bits_by_kind(self) -> dict[str, int]:
        """Exact per-frame bit volume per kind (conservation checks)."""
        out = {k: 0 for k in STREAM_KINDS}
        for s in self.streams:
            out[s.kind] += s.bits_per_frame
        return out

    def summary(self) -> dict:
        return {
            "policy": self.policy,
            "capacity_bits_per_cycle": self.capacity_bits_per_cycle,
            "tick_cycles": self.tick_cycles,
            "n_streams": len(self.streams),
            "total_demand_rate": self.total_demand_rate,
            "total_granted_rate": self.total_granted_rate,
            "feasible": self.feasible,
            "utilization": self.utilization,
            "granted_gbps_by_kind": self.granted_gbps_by_kind(),
            "streams": [s.summary() for s in self.streams],
        }


class ChannelArbiter:
    """Registers a plan's off-chip streams and divides the channel.

    Registration order is deterministic (callers register weight-fetch
    streams stage-ascending, then spills in record order) so allocations
    — including fixed-priority tie-breaks — are reproducible.
    """

    def __init__(self, channel: OffChipChannel,
                 config: ChannelConfig | None = None) -> None:
        self.channel = channel
        self.config = config or ChannelConfig()
        self._demands: list[StreamDemand] = []

    def register(self, name: str, kind: str, *, stage: int,
                 bits_per_frame: int) -> StreamDemand:
        d = StreamDemand(name=name, kind=kind, stage=stage,
                         bits_per_frame=int(bits_per_frame))
        self._demands.append(d)
        return d

    @property
    def demands(self) -> list[StreamDemand]:
        return list(self._demands)

    def allocate(self, tick_cycles: float) -> ArbiterReport:
        """Divide the channel for one steady-state tick of ``tick_cycles``
        model cycles: each stream demands ``quantized_bits /
        tick_cycles`` bits/cycle, the policy grants rates summing to at
        most the channel's ``bits_per_cycle``."""
        if tick_cycles <= 0:
            raise ValueError(f"tick_cycles must be > 0, got {tick_cycles}")
        ch, cfg = self.channel, self.config
        q = [ch.quantized_bits(d.bits_per_frame) for d in self._demands]
        demand = [qi / tick_cycles for qi in q]
        weights = [cfg.kind_weight(d.kind) for d in self._demands]
        prio = {k: i for i, k in enumerate(PRIORITY_ORDER)}
        order = sorted(range(len(self._demands)),
                       key=lambda i: (prio.get(self._demands[i].kind,
                                               len(prio)), i))
        granted = _grant(cfg.policy, demand, weights, order,
                         ch.bits_per_cycle)
        allocs = [
            StreamAllocation(
                name=d.name, kind=d.kind, stage=d.stage,
                bits_per_frame=d.bits_per_frame, quantized_bits=q[i],
                bursts=ch.n_bursts(d.bits_per_frame),
                demand_rate=demand[i],
                granted_rate=min(granted[i], demand[i]),
                granted_gbps=(min(granted[i], demand[i])
                              * ch.cycles_per_s / 1e9))
            for i, d in enumerate(self._demands)]
        return ArbiterReport(policy=cfg.policy,
                             capacity_bits_per_cycle=ch.bits_per_cycle,
                             tick_cycles=tick_cycles, streams=allocs)


# =============================================================================
# The contended Eq. 5/6 extension
# =============================================================================

def contended_stage_latencies(base: list[float],
                              transfer: list[float]) -> list[float]:
    """``L_j^cont = max(L_j, X_j)``: the DMA FIFOs overlap transfer with
    compute, so a stage pays whichever is longer, never the sum."""
    if len(base) != len(transfer):
        raise ValueError(f"{len(base)} stage latencies vs "
                         f"{len(transfer)} transfer times")
    return [max(l, x) for l, x in zip(base, transfer)]


def contention_stall_cycles(base: list[float],
                            transfer: list[float]) -> list[float]:
    """Per-stage cycles the pipeline stalls on the channel per frame:
    the part of ``X_j`` compute cannot hide (0 when transfer fits)."""
    if len(base) != len(transfer):
        raise ValueError(f"{len(base)} stage latencies vs "
                         f"{len(transfer)} transfer times")
    return [max(0.0, x - l) for l, x in zip(base, transfer)]
