"""repro.memory — the shared off-chip channel subsystem.

Models the one resource every SMOF eviction stream competes for: the
off-chip port.  Four pieces:

``channel``
    :class:`OffChipChannel` — burst-granular bandwidth accounting in the
    Eq. 5/6 model-cycle unit; :class:`ChannelConfig` — the user-facing
    knobs on ``CompileSpec.channel``.
``arbiter``
    :class:`ChannelArbiter` — divides the channel between weight-fetch /
    activation-evict / activation-restore streams under round-robin,
    fixed-priority or weighted-fair policies, and the contended
    Eq. 5/6 extension (``L_j^cont = max(L_j, X_j)``).
``prefetch``
    double-buffered weight prefetch schedule with stage-start deadlines
    and deadline-miss accounting.
``model``
    :func:`build_memory_model` — assembles all of the above for one
    lowered plan into a :class:`MemoryModel` that rides on
    ``StreamReport.memory``.

Dependency-free (no JAX): property tests and the fuzz generator drive
it standalone.
"""
from .arbiter import (PRIORITY_ORDER, STREAM_KINDS, ArbiterReport,
                      ChannelArbiter, StreamAllocation, StreamDemand,
                      contended_stage_latencies, contention_stall_cycles)
from .channel import POLICIES, ChannelConfig, OffChipChannel
from .model import MemoryModel, build_memory_model
from .prefetch import PrefetchReport, PrefetchSlot, prefetch_schedule

__all__ = [
    "POLICIES", "ChannelConfig", "OffChipChannel",
    "STREAM_KINDS", "PRIORITY_ORDER", "StreamDemand", "StreamAllocation",
    "ArbiterReport", "ChannelArbiter",
    "contended_stage_latencies", "contention_stall_cycles",
    "PrefetchSlot", "PrefetchReport", "prefetch_schedule",
    "MemoryModel", "build_memory_model",
]
