"""MemoryModel: one plan's complete off-chip channel picture.

``build_memory_model`` assembles the subsystem for one lowered plan:

1. every spill record becomes an ``activation-evict`` stream at its
   producer stage and an ``activation-restore`` stream at its consumer
   stage (``bits_per_frame = SpillRecord.offchip_bits`` — the exact
   compile-time volume, so byte conservation against the
   ``StreamReport`` is bit-exact);
2. every stage with streamed weight bits registers one ``weight-fetch``
   stream;
3. the :class:`~repro.memory.arbiter.ChannelArbiter` divides the channel
   for one steady-state tick (``tick_cycles = max_j L_j``, the
   uncontended Eq. 6 frame time — the tick the pipeline actually runs
   at when the channel is not the bottleneck);
4. per-stage transfer times ``X_j`` extend Eq. 5/6 to the contended
   ``L_j^cont = max(L_j, X_j)``, with ``max(0, X_j - L_j)`` the
   contention-stall cycles compute cannot hide;
5. the weight-fetch grants feed the double-buffered
   :func:`~repro.memory.prefetch.prefetch_schedule`, whose deadline
   misses say which stage would stall on weights.

The resulting :class:`MemoryModel` travels on ``StreamReport.memory``
and is what ``obs.modelcheck.ContentionCheck``, the SLO layer's
per-stream budgets, autotune's feasibility pruning and the benchmark
columns all read.
"""
from __future__ import annotations

import dataclasses
import math

from .arbiter import (ArbiterReport, ChannelArbiter,
                      contended_stage_latencies, contention_stall_cycles)
from .channel import ChannelConfig, OffChipChannel
from .prefetch import PrefetchReport, prefetch_schedule

__all__ = ["MemoryModel", "build_memory_model"]


@dataclasses.dataclass
class MemoryModel:
    """The contended channel view of one plan (see module docstring)."""
    config: ChannelConfig
    channel: OffChipChannel
    arbitration: ArbiterReport
    prefetch: PrefetchReport
    base_latencies: list[float]          # L_j (Eq. 5/6 input, cycles)
    transfer_cycles: list[float]         # X_j per stage
    contended_latencies: list[float]     # max(L_j, X_j)
    stall_cycles: list[float]            # max(0, X_j - L_j)
    weight_bits_by_stage: dict[int, int]
    spill_evict_bits: int                # sum of evict stream volumes
    spill_restore_bits: int              # sum of restore stream volumes
    microbatches: int

    # -- the contended Eq. 5/6 ------------------------------------------------
    @property
    def tick_cycles(self) -> float:
        """The uncontended Eq. 6 tick the arbitration was solved for."""
        return self.arbitration.tick_cycles

    @property
    def eq5_cycles(self) -> float:
        return float(sum(self.base_latencies))

    @property
    def eq6_cycles(self) -> float:
        return float(max(self.base_latencies))

    @property
    def eq5_contended_cycles(self) -> float:
        return float(sum(self.contended_latencies))

    @property
    def eq6_contended_cycles(self) -> float:
        return float(max(self.contended_latencies))

    @property
    def contention_bound_stage(self) -> int:
        """The stage setting the contended Eq. 6 bound."""
        return max(range(len(self.contended_latencies)),
                   key=lambda j: self.contended_latencies[j])

    @property
    def total_stall_cycles(self) -> float:
        return float(sum(self.stall_cycles))

    def fps_bound_uncontended(self, s_per_cycle: float) -> float:
        """Eq. 6 throughput roofline at a seconds-per-cycle scale."""
        t = self.eq6_cycles * s_per_cycle
        return 1.0 / t if t > 0 else math.inf

    def fps_bound_contended(self, s_per_cycle: float) -> float:
        """Contended Eq. 6 roofline — <= the uncontended one, always."""
        t = self.eq6_contended_cycles * s_per_cycle
        return 1.0 / t if t > 0 else math.inf

    # -- downstream consumers -------------------------------------------------
    def budget_gbps_by_kind(self) -> dict[str, float]:
        """Per-kind granted bandwidth (the SLO per-stream budgets)."""
        return self.arbitration.granted_gbps_by_kind()

    def weight_rate_by_stage(self) -> dict[int, float]:
        """Granted weight-fetch rate per stage [bits/cycle]."""
        return _weight_rates(self.arbitration)

    def extra_queue_delay(self) -> dict[tuple[str, str], int]:
        """Per crossing edge, extra in-flight ticks its spill round-trip
        needs beyond one tick at the granted rates — the arbiter-derived
        crossing delay the queue capacity floors consume.  Capped at the
        microbatch count (a ring deeper than the stream is moot)."""
        per_edge: dict[tuple[str, str], float] = {}
        for s in self.arbitration.streams:
            if s.kind == "weight-fetch" or "->" not in s.name:
                continue
            edge = tuple(s.name.split(":", 1)[1].split("->", 1))
            per_edge[edge] = per_edge.get(edge, 0.0) + s.transfer_cycles
        out: dict[tuple[str, str], int] = {}
        for edge, cyc in per_edge.items():
            if not math.isfinite(cyc):
                out[edge] = self.microbatches
                continue
            extra = max(0, math.ceil(cyc / self.tick_cycles) - 1)
            out[edge] = min(extra, self.microbatches)
        return out

    def stream_table(self) -> list[dict]:
        """Flat per-stream rows (the examples' bandwidth table)."""
        return [{
            "name": s.name, "kind": s.kind, "stage": s.stage,
            "bits_per_frame": s.bits_per_frame, "bursts": s.bursts,
            "demand_gbps": s.demand_rate * self.channel.cycles_per_s / 1e9,
            "granted_gbps": s.granted_gbps,
            "satisfied": s.satisfied,
        } for s in self.arbitration.streams]

    def summary(self) -> dict:
        return {
            "policy": self.config.policy,
            "channel": self.channel.summary(),
            "arbitration": self.arbitration.summary(),
            "prefetch": self.prefetch.summary(),
            "transfer_cycles": list(self.transfer_cycles),
            "stall_cycles": list(self.stall_cycles),
            "eq6_cycles": self.eq6_cycles,
            "eq6_contended_cycles": self.eq6_contended_cycles,
            "eq5_contended_cycles": self.eq5_contended_cycles,
            "contention_bound_stage": self.contention_bound_stage,
            "feasible": self.arbitration.feasible,
            "spill_evict_bits": self.spill_evict_bits,
            "spill_restore_bits": self.spill_restore_bits,
            "streamed_weight_bits": sum(self.weight_bits_by_stage.values()),
            "prefetch_deadline_misses": self.prefetch.deadline_misses,
        }


def build_memory_model(*, spills, weight_bits_by_stage: dict[int, int],
                       stage_of: dict[str, int],
                       base_latencies: list[float],
                       gbps: float, freq_mhz: float,
                       config: ChannelConfig | None = None,
                       microbatches: int = 1) -> MemoryModel:
    """Assemble the channel/arbiter/prefetch model for one plan.

    spills
        ``SpillRecord``-likes (``src``/``dst``/``offchip_bits``); each
        contributes an evict stream at ``stage_of[src]`` and a restore
        stream at ``stage_of[dst]``.
    weight_bits_by_stage
        exact streamed weight bits per stage (see
        ``runtime.executor.analyze_plan``'s per-layer rounding).
    base_latencies
        the uncontended ``L_j`` in model cycles
        (``schedule.stage_latencies``); must be non-empty.
    """
    cfg = config or ChannelConfig()
    gbps = cfg.gbps if cfg.gbps is not None else gbps
    channel = OffChipChannel(gbps, freq_mhz=freq_mhz,
                             word_bits=cfg.word_bits)
    if not base_latencies:
        raise ValueError("need >= 1 stage latency")
    n_stages = len(base_latencies)
    tick_cycles = float(max(base_latencies))

    arb = ChannelArbiter(channel, cfg)
    for stage in sorted(weight_bits_by_stage):
        bits = int(weight_bits_by_stage[stage])
        if bits > 0:
            arb.register(f"weights:stage{stage}", "weight-fetch",
                         stage=stage, bits_per_frame=bits)
    evict_bits = restore_bits = 0
    for r in spills:
        bits = int(r.offchip_bits)
        arb.register(f"evict:{r.src}->{r.dst}", "activation-evict",
                     stage=stage_of[r.src], bits_per_frame=bits)
        arb.register(f"restore:{r.src}->{r.dst}", "activation-restore",
                     stage=stage_of[r.dst], bits_per_frame=bits)
        evict_bits += bits
        restore_bits += bits

    arbitration = arb.allocate(tick_cycles)
    transfer = arbitration.transfer_cycles_by_stage(n_stages)
    contended = contended_stage_latencies(list(base_latencies), transfer)
    stalls = contention_stall_cycles(list(base_latencies), transfer)
    pf = prefetch_schedule(
        {k: int(v) for k, v in weight_bits_by_stage.items()},
        _weight_rates(arbitration), tick_cycles=tick_cycles,
        microbatches=microbatches, channel=channel)
    return MemoryModel(
        config=cfg, channel=channel, arbitration=arbitration, prefetch=pf,
        base_latencies=list(base_latencies), transfer_cycles=transfer,
        contended_latencies=contended, stall_cycles=stalls,
        weight_bits_by_stage={int(k): int(v)
                              for k, v in weight_bits_by_stage.items()},
        spill_evict_bits=evict_bits, spill_restore_bits=restore_bits,
        microbatches=microbatches)


def _weight_rates(arbitration: ArbiterReport) -> dict[int, float]:
    out: dict[int, float] = {}
    for s in arbitration.streams:
        if s.kind == "weight-fetch":
            out[s.stage] = out.get(s.stage, 0.0) + s.granted_rate
    return out
