"""Differential conformance harness (fuzzing + cross-executor oracles).

SMOF's core claim is that off-chip eviction is *semantics-preserving*: a
plan that spills deep edges must compute the same function as the fully
on-chip pipeline, only with different resource/latency trade-offs.  The
hand-built graphs (UNet/YOLO/X3D) witness that claim on three topologies;
this package *manufactures* witnesses:

``gen``
    seeded random executable graphs over the lowerable op vocabulary
    (conv, dwconv, pool/global-pool, upsample, add/mul skips, SE blocks,
    concat feature banks) plus random/mutated :class:`ExecutionPlan`\\ s
    (stage splits, evict/unevict, fragmentation ratios, microbatches).
``oracle``
    differential oracles over one (graph, plan) case: reference ==
    staged == pipelined == served (exact where no BFP8 crossing,
    spill-bounded where there is), plan/artifact round-trips, ModelCheck
    and Eq. 1/5/6 invariants on every run.
``fuzz``
    the driver — ``python -m repro.testing.fuzz --budget N --seed S`` —
    which shrinks failing cases (unevict edges, merge stages, drop skip
    edges/layers) and writes replayable repro JSONs that
    ``tests/test_conformance.py`` re-executes.

See ``docs/TESTING.md`` for the oracle taxonomy and repro-file format.
"""
from .gen import (FuzzCase, GenConfig, mutate_plan, random_case,
                  random_exec_graph, random_plan)
from .oracle import (FAULTS, CaseReport, OracleViolation, check_case,
                     inject_fault)

__all__ = [
    "FuzzCase", "GenConfig", "random_case", "random_exec_graph",
    "random_plan", "mutate_plan",
    "CaseReport", "OracleViolation", "check_case", "inject_fault", "FAULTS",
]
