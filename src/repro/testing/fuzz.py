"""Conformance fuzz driver: generate -> check -> shrink -> write repro.

    python -m repro.testing.fuzz --budget 50 --seed 0

runs 50 deterministic cases (seeded graphs + plans, see ``gen``) through
every differential oracle (see ``oracle``).  On a violation the failing
case is *shrunk* — streams unevicted, stages merged, skip edges dropped,
shape-preserving layers spliced out, the microbatch reduced — keeping a
candidate only while the **same oracle** still fails, then written as a
replayable JSON repro under ``--out`` (default ``tests/repros/``, which
``tests/test_conformance.py`` re-executes automatically).

``--inject-fault`` deliberately breaks one mechanism (``oracle.FAULTS``)
to prove the harness catches, shrinks and persists a planted bug; the
recorded fault is replayed too, so a fault repro keeps failing until the
fault (or the harness hole it found) is addressed.

Repro file format (version 1)::

    {"kind": "smof-fuzz-repro", "version": 1,
     "label": "<seed>-<index>", "seed": <weight/input seed>,
     "oracle": "<oracle name>", "message": "<violation text>",
     "inject_fault": null | "<fault name>",
     "shrunk": {"from_vertices": N, "to_vertices": M, "runs": K},
     "case": {"graph": <Graph.to_json_dict>, "plan": <ExecutionPlan JSON>,
              "seed": ..., "label": ...}}
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import sys
import traceback
from typing import Iterator

from ..core.plan import PlanValidationError, StreamPlan
from .gen import (FuzzCase, GenConfig, case_from_json_dict,
                  case_to_json_dict, random_case)
from .oracle import FAULTS, CaseReport, OracleViolation, check_case, \
    inject_fault

__all__ = ["run_case", "shrink", "write_repro", "replay", "main",
           "REPRO_KIND", "REPRO_VERSION"]

REPRO_KIND = "smof-fuzz-repro"
REPRO_VERSION = 1


def run_case(case: FuzzCase, fault: str | None = None
             ) -> OracleViolation | None:
    """One case through every oracle; ``None`` when all pass.  Unexpected
    exceptions become an ``OracleViolation`` with oracle name ``crash`` —
    a lowering that dies on a valid generated case is a finding too."""
    try:
        with inject_fault(fault):
            check_case(case)
        return None
    except OracleViolation as v:
        return v
    except Exception as e:      # noqa: BLE001 - every crash is a finding
        tb = traceback.format_exc(limit=3).strip().splitlines()[-1]
        return OracleViolation("crash", f"{type(e).__name__}: {e} ({tb})")


# -----------------------------------------------------------------------------
# shrinking
# -----------------------------------------------------------------------------

def _copy(case: FuzzCase) -> FuzzCase:
    return case_from_json_dict(case_to_json_dict(case))


def _compress_stages(plan) -> None:
    used = sorted({lp.stage for lp in plan.layers.values()})
    remap = {s: i for i, s in enumerate(used)}
    for lp in plan.layers.values():
        lp.stage = remap[lp.stage]
    plan.n_stages = len(used)


def _shrink_candidates(case: FuzzCase) -> Iterator[FuzzCase]:
    """Simplified variants of ``case``, cheapest transformations first.
    Structurally invalid variants are silently skipped."""
    p, g = case.plan, case.graph
    # 1. unevict one stream (removes one eviction decision entirely)
    for i, s in enumerate(p.streams):
        if s.evicted:
            c = _copy(case)
            c.plan.streams[i].evicted = False
            c.plan.streams[i].codec = "none"
            yield c
    # 2. shallower stream: fewer microbatches
    if p.microbatch > 2:
        c = _copy(case)
        c.plan.microbatch = 2
        yield c
    # 3. merge each stage boundary
    for j in range(1, p.n_stages):
        c = _copy(case)
        for lp in c.plan.layers.values():
            if lp.stage >= j:
                lp.stage -= 1
        c.plan.n_stages -= 1
        yield c
    # 4. drop one input edge of a multi-input merge point
    for v in list(g.vertices()):
        if v.kind in ("add", "mul") and len(g.predecessors(v.name)) >= 2:
            for src in g.predecessors(v.name):
                try:
                    c = _copy(case)
                    c.graph.remove_edge(src, v.name)
                    c.graph.validate()
                    c.plan.streams = [s for s in c.plan.streams
                                      if (s.src, s.dst) != (src, v.name)]
                    c.plan.validate()
                except (ValueError, PlanValidationError):
                    continue
                yield c
    # 5. splice out one shape-preserving single-input layer
    for v in list(g.vertices()):
        spec = v.meta.get("exec", {})
        preserving = (v.kind in ("act", "dwconv")
                      or (v.kind == "conv"
                          and spec.get("cin") == spec.get("cout")))
        same_m = spec.get("m_out", spec.get("m")) == spec.get("m")
        if not (preserving and same_m
                and len(g.predecessors(v.name)) == 1):
            continue
        try:
            yield _drop_vertex(case, v.name)
        except (ValueError, PlanValidationError, KeyError):
            continue


def _drop_vertex(case: FuzzCase, name: str) -> FuzzCase:
    c = _copy(case)
    g, p = c.graph, c.plan
    pred = g.predecessors(name)[0]
    succs = g.successors(name)
    g.remove_vertex(name, reconnect=True)   # raises if not reconnectable
    g.validate()
    old = {(s.src, s.dst): s for s in p.streams}
    p.streams = [s for s in p.streams if name not in (s.src, s.dst)]
    for s2 in succs:                        # spliced edges keep their plan
        o = old.get((name, s2))
        if o is not None:
            p.streams.append(StreamPlan(pred, s2, o.evicted, o.codec))
    del p.layers[name]
    if name in p.topo_order:
        p.topo_order.remove(name)
    _compress_stages(p)
    p.validate()
    return c


def shrink(case: FuzzCase, violation: OracleViolation,
           fault: str | None = None, max_runs: int = 60
           ) -> tuple[FuzzCase, OracleViolation, int]:
    """Greedy shrink: accept a candidate only while the *same* oracle
    still fails (a candidate that passes, fails differently, or is
    structurally invalid is rejected).  Returns the smallest failing
    case, its violation, and how many oracle runs the search spent."""
    target = violation.oracle
    best, best_v = case, violation
    runs = 0
    improved = True
    while improved and runs < max_runs:
        improved = False
        for cand in _shrink_candidates(best):
            if runs >= max_runs:
                break
            runs += 1
            v = run_case(cand, fault)
            if v is not None and v.oracle == target:
                best, best_v, improved = cand, v, True
                break                        # restart from the smaller case
    return best, best_v, runs


# -----------------------------------------------------------------------------
# repro files
# -----------------------------------------------------------------------------

def write_repro(out_dir, case: FuzzCase, violation: OracleViolation, *,
                fault: str | None = None,
                shrink_stats: dict | None = None) -> pathlib.Path:
    out_dir = pathlib.Path(out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    payload = {
        "kind": REPRO_KIND,
        "version": REPRO_VERSION,
        "label": case.label,
        "seed": case.seed,
        "oracle": violation.oracle,
        "message": str(violation),
        "inject_fault": fault,
        "shrunk": shrink_stats or {},
        "case": case_to_json_dict(case),
    }
    stem = f"repro_{case.label}_{violation.oracle}"
    if fault:
        stem += f"_{fault}"
    path = out_dir / f"{stem}.json"
    path.write_text(json.dumps(payload, indent=1))
    return path


def load_repro(path) -> dict:
    d = json.loads(pathlib.Path(path).read_text())
    if d.get("kind") != REPRO_KIND:
        raise ValueError(f"{path}: not a {REPRO_KIND} file")
    if d.get("version", 0) > REPRO_VERSION:
        raise ValueError(f"{path}: repro version {d['version']} is newer "
                         f"than this harness (v{REPRO_VERSION})")
    return d


def replay(path) -> CaseReport:
    """Re-execute one repro file, honouring its recorded fault injection.
    Raises :class:`OracleViolation` while the bug (or planted fault)
    still reproduces; returns the passing :class:`CaseReport` once fixed."""
    d = load_repro(path)
    case = case_from_json_dict(d["case"])
    with inject_fault(d.get("inject_fault")):
        return check_case(case)


# -----------------------------------------------------------------------------
# CLI
# -----------------------------------------------------------------------------

def _build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.testing.fuzz",
        description="differential conformance fuzzer (see docs/TESTING.md)")
    ap.add_argument("--budget", type=int, default=50,
                    help="number of generated cases (default 50)")
    ap.add_argument("--seed", type=int, default=0,
                    help="population seed; (seed, index) fixes each case")
    ap.add_argument("--out", default="tests/repros",
                    help="directory for shrunk repro JSONs "
                         "(default tests/repros)")
    ap.add_argument("--inject-fault", choices=FAULTS, default=None,
                    help="plant a known fault; the run must then FAIL "
                         "(harness self-test)")
    ap.add_argument("--keep-going", action="store_true",
                    help="keep fuzzing after a failure instead of stopping")
    ap.add_argument("--max-shrink-runs", type=int, default=60,
                    help="oracle-run budget for shrinking one failure")
    # population-bounding knobs (smaller => faster cases, e.g. in tests)
    ap.add_argument("--min-blocks", type=int, default=None)
    ap.add_argument("--max-blocks", type=int, default=None)
    ap.add_argument("--max-stages", type=int, default=None)
    ap.add_argument("--max-microbatches", type=int, default=None)
    return ap


def _config_from_args(args) -> GenConfig:
    cfg = GenConfig()
    over = {k: getattr(args, k) for k in
            ("min_blocks", "max_blocks", "max_stages", "max_microbatches")
            if getattr(args, k) is not None}
    return dataclasses.replace(cfg, **over) if over else cfg


def main(argv=None) -> int:
    args = _build_parser().parse_args(argv)
    cfg = _config_from_args(args)
    failures = 0
    for i in range(args.budget):
        case = random_case(args.seed, i, cfg)
        v = run_case(case, args.inject_fault)
        if v is None:
            # cheap progress stats without re-running the oracles
            n_v = len(list(case.graph.vertices()))
            n_e = sum(1 for s in case.plan.streams if s.evicted)
            print(f"case {case.label}: ok ({n_v} vertices, "
                  f"{case.plan.n_stages} stages, B{case.plan.microbatch}, "
                  f"{n_e} evicted)")
            continue
        failures += 1
        n0 = len(list(case.graph.vertices()))
        print(f"case {case.label}: FAIL {v}")
        small, sv, runs = shrink(case, v, args.inject_fault,
                                 max_runs=args.max_shrink_runs)
        n1 = len(list(small.graph.vertices()))
        path = write_repro(
            args.out, small, sv, fault=args.inject_fault,
            shrink_stats={"from_vertices": n0, "to_vertices": n1,
                          "runs": runs})
        print(f"  shrunk {n0} -> {n1} vertices "
              f"({len(small.plan.streams)} streams, "
              f"{small.plan.n_stages} stages) in {runs} runs")
        print(f"  repro written: {path}")
        print(f"  replay: python -c \"from repro.testing.fuzz import "
              f"replay; replay('{path}')\"")
        if not args.keep_going:
            break
    verdict = "FAIL" if failures else "ok"
    print(f"fuzz: {verdict} — {failures} violation(s) in "
          f"{min(args.budget, i + 1) if args.budget else 0} case(s) "
          f"(seed {args.seed})")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
