"""Differential conformance oracles over one (graph, plan) case.

SMOF's correctness story is differential: the same function, computed by
four executors that stream it differently —

* ``reference`` — dense, un-evicted, un-fragmented (``reference_pipeline``);
* ``staged``    — the sequential Eq. 5 executor (``lower_plan``);
* ``pipelined`` — the 1F1B Eq. 6 streamer (``lower_plan_pipelined``);
* ``served``    — ``GraphStreamServer`` over the pipelined executor.

:func:`check_case` asserts the relations the paper's design implies:

``plan_roundtrip``      ``from_json(to_json(plan))`` is the same plan, the
                        re-serialisation is byte-identical, and no keys
                        were dropped.
``lossless_exact``      with every stream codec forced lossless, staged
                        *and* pipelined outputs are **bit-exact** vs the
                        reference — the semantics-preserving claim of
                        §III-A (eviction changes where data lives, not
                        what is computed).  Failures are localised to the
                        first diverging vertex via ``run_intermediates``.
``bfp8_bounded``        with the actual (possibly lossy) plan, staged
                        output is bit-exact when no BFP8 codec is in play
                        and finite + loosely error-bounded when one is.
``staged_vs_pipelined`` staged and 1F1B outputs are bit-exact per
                        microbatch under the *same* plan (same codec
                        composition on every edge).
``traced_parity``       the tick-by-tick traced run returns bit-exact
                        outputs vs the fused ``lax.scan``.
``modelcheck``          the traced run's :class:`ModelCheck` gates pass:
                        the walk matched ``T = B + S - 1`` / Eq. 6 steady
                        ticks and no Eq. 1-sized queue stalled or
                        overflowed.
``channel_model``       (cases with a drawn ``ChannelConfig``) the
                        ``repro.memory`` arbitration obeys its own model:
                        contended stage latencies dominate the base ones,
                        grants respect demands and channel capacity, and
                        per-kind arbitrated byte volumes equal the stream
                        report's spill/weight accounting bit-exactly.
``serve_vs_run``        the server returns bit-exact results per ticket,
                        including across a padded partial batch and (with
                        ``resident_limit``) after spilling results to the
                        host byte store.
``artifact_roundtrip``  ``Compiled.save`` -> ``Compiled.load`` reproduces
                        bit-exact outputs and an equal re-serialised plan.
``report_invariants``   spill accounting is self-consistent: BFP8 records
                        match the compile-time ``_bfp8_offchip_bits``
                        formula, lossless records are raw-volume, and the
                        stream report's schedule obeys ticks/Eq. 5/6.

:func:`inject_fault` deliberately breaks one mechanism (for harness
self-tests and the fuzz driver's ``--inject-fault``): the oracles must
catch every registered fault.
"""
from __future__ import annotations

import contextlib
import dataclasses
import tempfile
from pathlib import Path

import numpy as np

from .gen import FuzzCase

__all__ = ["OracleViolation", "CaseReport", "check_case", "inject_fault",
           "FAULTS"]


class OracleViolation(AssertionError):
    """One conformance oracle failed for one case."""

    def __init__(self, oracle: str, message: str):
        self.oracle = oracle
        super().__init__(f"[{oracle}] {message}")


@dataclasses.dataclass
class CaseReport:
    """What one passing case exercised (the fuzz driver's progress line)."""
    label: str
    n_vertices: int
    n_stages: int
    microbatches: int
    n_evicted: int
    n_lossy: int
    oracles: tuple[str, ...]

    def summary(self) -> str:
        return (f"{self.n_vertices}v/{self.n_stages}s/"
                f"B{self.microbatches}, {self.n_evicted} evicted "
                f"({self.n_lossy} lossy)")


def _eq(a, b) -> bool:
    return np.array_equal(np.asarray(a), np.asarray(b))


def _first_divergence(ref, other, x) -> str:
    """Name the first topo vertex where two executors' values differ."""
    try:
        va, vb = ref.run_intermediates(x), other.run_intermediates(x)
    except NotImplementedError:
        return "intermediates unavailable"
    for name, a in va.items():
        if name in vb and not _eq(a, vb[name]):
            return (f"first divergence at vertex {name!r} "
                    f"(max abs diff "
                    f"{float(np.max(np.abs(np.asarray(a) - np.asarray(vb[name])))):.3g})")
    return "no intermediate divergence found (outputs differ only)"


def _lossless_twin(plan):
    """The same plan with every stream codec forced lossless: eviction
    decisions survive, only the lossy compression is removed — exactly
    the plan under which SMOF's eviction must be semantics-preserving."""
    from ..core.plan import ExecutionPlan
    twin = ExecutionPlan.from_json(plan.to_json())
    for s in twin.streams:
        if s.codec == "bfp8":
            s.codec = "none"
    return twin


def check_case(case: FuzzCase, *, resident_limit: int = 2,
               rel_err_per_lossy: float = 0.25) -> CaseReport:
    """Run every oracle over ``case``; raises :class:`OracleViolation` on
    the first failure, returns a :class:`CaseReport` when all pass."""
    import jax.numpy as jnp

    import repro
    from ..runtime.executor import _bfp8_offchip_bits

    g, plan = case.graph, case.plan
    ran: list[str] = []

    # -- plan_roundtrip (before compiling: the pristine plan) ---------------
    from ..core.plan import ExecutionPlan
    s0 = plan.to_json()
    back = ExecutionPlan.from_json(s0)
    if back.dropped_keys:
        raise OracleViolation(
            "plan_roundtrip", f"round-trip dropped keys {back.dropped_keys}")
    if back != plan:
        raise OracleViolation("plan_roundtrip",
                              "from_json(to_json(plan)) != plan")
    if back.to_json() != s0:
        raise OracleViolation("plan_roundtrip",
                              "re-serialisation is not byte-identical")
    ran.append("plan_roundtrip")

    B = max(2, plan.microbatch)
    base = dict(model=g, device="u200", strategy="manual-plan",
                kernel_mode="reference", seed=case.seed)
    c_ref = repro.compile(repro.CompileSpec(mode="reference", **base))
    c_staged = repro.compile(repro.CompileSpec(mode="staged", plan=plan,
                                               **base))
    c_pipe = repro.compile(repro.CompileSpec(
        mode="pipelined", plan=plan, microbatches=B,
        placement="interleave", channel=case.channel, **base))

    m, c = case.input_shape
    rng = np.random.default_rng(case.seed)
    xs = jnp.asarray(rng.normal(size=(B, m, c)).astype(np.float32))

    ref_ys = [np.asarray(c_ref.run(xs[b])) for b in range(B)]
    staged_ys = [np.asarray(c_staged.run(xs[b])) for b in range(B)]
    pipe_ys = np.asarray(c_pipe.run(xs))

    # -- lossless_exact ------------------------------------------------------
    lossy = [s for s in plan.streams if s.evicted and s.codec == "bfp8"]
    twin = _lossless_twin(plan) if lossy else plan
    if lossy:
        c_tw_staged = repro.compile(repro.CompileSpec(
            mode="staged", plan=twin, **base))
        c_tw_pipe = repro.compile(repro.CompileSpec(
            mode="pipelined", plan=twin, microbatches=B,
            placement="interleave", channel=case.channel, **base))
        tw_staged_ys = [np.asarray(c_tw_staged.run(xs[b])) for b in range(B)]
        tw_pipe_ys = np.asarray(c_tw_pipe.run(xs))
    else:
        c_tw_staged = c_staged
        tw_staged_ys, tw_pipe_ys = staged_ys, pipe_ys
    for b in range(B):
        if not _eq(tw_staged_ys[b], ref_ys[b]):
            raise OracleViolation(
                "lossless_exact",
                f"staged (all-lossless plan) != reference on frame {b}: "
                + _first_divergence(c_ref.executor, c_tw_staged.executor,
                                    xs[b]))
        if not _eq(tw_pipe_ys[b], ref_ys[b]):
            raise OracleViolation(
                "lossless_exact",
                f"pipelined (all-lossless plan) != reference on frame {b}")
    ran.append("lossless_exact")

    # -- bfp8_bounded --------------------------------------------------------
    for b in range(B):
        y = staged_ys[b]
        if not lossy:
            if not _eq(y, ref_ys[b]):
                raise OracleViolation(
                    "bfp8_bounded",
                    f"no lossy codec in plan but staged != reference on "
                    f"frame {b}: "
                    + _first_divergence(c_ref.executor, c_staged.executor,
                                        xs[b]))
        else:
            if not np.all(np.isfinite(y)):
                raise OracleViolation(
                    "bfp8_bounded", f"non-finite staged output on frame {b} "
                    f"({len(lossy)} BFP8 stream(s))")
            err = float(np.linalg.norm(y - ref_ys[b]))
            bound = (rel_err_per_lossy * len(lossy)
                     * float(np.linalg.norm(ref_ys[b])) + 1e-3)
            if err > bound:
                raise OracleViolation(
                    "bfp8_bounded",
                    f"frame {b}: L2 error {err:.4g} exceeds bound "
                    f"{bound:.4g} ({len(lossy)} BFP8 stream(s))")
    ran.append("bfp8_bounded")

    # -- staged_vs_pipelined -------------------------------------------------
    for b in range(B):
        if not _eq(pipe_ys[b], staged_ys[b]):
            raise OracleViolation(
                "staged_vs_pipelined",
                f"1F1B stream output differs from staged on microbatch {b} "
                f"(same plan, same codecs: must be bit-exact)")
    ran.append("staged_vs_pipelined")

    # -- kernel_parity -------------------------------------------------------
    # cases drawn with kernel_mode="pallas": the staged executor under the
    # streaming_conv Pallas bodies (interpret mode on CPU, with the BFP8
    # boundary codec fused at evicted edges) must be bit-exact against the
    # staged reference dispatch per frame — the registry's two kernel paths
    # are the same function (tests/test_kernels.py locks the matrix; this
    # oracle locks it over the generated population).
    if case.kernel_mode == "pallas":
        c_pal = repro.compile(repro.CompileSpec(
            mode="staged", plan=plan, **{**base, "kernel_mode": "pallas"}))
        for b in range(B):
            y = np.asarray(c_pal.run(xs[b]))
            if not _eq(y, staged_ys[b]):
                raise OracleViolation(
                    "kernel_parity",
                    f"staged pallas != staged reference on frame {b}: "
                    + _first_divergence(c_staged.executor, c_pal.executor,
                                        xs[b]))
        ran.append("kernel_parity")

    # -- traced_parity + modelcheck ------------------------------------------
    ys_t, mc = c_pipe.executor.run_traced(xs, measure_stages=False)
    if not _eq(ys_t, pipe_ys):
        raise OracleViolation(
            "traced_parity", "tick-by-tick traced outputs differ from the "
            "fused lax.scan outputs")
    ran.append("traced_parity")
    bad = mc.violations()
    if bad:
        raise OracleViolation("modelcheck", "; ".join(bad))
    ran.append("modelcheck")

    # -- channel_model -------------------------------------------------------
    # model-domain invariants of the off-chip channel arbitration (no
    # measured-time claims: those are platform noise): contended stage
    # latencies dominate the base ones, grants never exceed demands or the
    # channel's capacity, and the per-kind arbitrated byte volumes equal
    # the spill/weight accounting of the stream report bit-exactly.
    if case.channel is not None:
        from ..obs.modelcheck import check_contention
        srep_pipe = c_pipe.executor.report
        if srep_pipe.memory is None:
            raise OracleViolation(
                "channel_model",
                "case has a ChannelConfig but the pipelined compile "
                "attached no MemoryModel to its StreamReport")
        cc = check_contention(srep_pipe)
        bad = cc.violations()
        if bad:
            raise OracleViolation("channel_model", "; ".join(bad))
        if cc.eq6_contended_cycles < cc.eq6_cycles - 1e-9:
            raise OracleViolation(
                "channel_model",
                f"contended Eq.6 ({cc.eq6_contended_cycles}) below "
                f"uncontended Eq.6 ({cc.eq6_cycles}): contention can only "
                "slow a stage down")
        ran.append("channel_model")

    # -- serve_vs_run --------------------------------------------------------
    srv = c_pipe.serve(resident_limit=resident_limit)
    frames = [np.asarray(xs[b]) for b in range(B)] + [np.asarray(xs[0])]
    tickets = [srv.submit(f) for f in frames]          # B+1: pads one batch
    srv.flush()
    want = staged_ys + [staged_ys[0]]
    for t, w in zip(tickets, want):
        got = srv.result(t)
        if not _eq(got, w):
            raise OracleViolation(
                "serve_vs_run",
                f"server result for ticket {t} differs from Compiled.run "
                f"(resident_limit={resident_limit})")
    ran.append("serve_vs_run")

    # -- artifact_roundtrip --------------------------------------------------
    with tempfile.TemporaryDirectory() as td:
        p = Path(td) / "case.smof.json"
        c_staged.save(p)
        loaded = repro.Compiled.load(p)
        if not _eq(np.asarray(loaded.run(xs[0])), staged_ys[0]):
            raise OracleViolation(
                "artifact_roundtrip",
                "loaded artifact's output differs from the saved compile "
                "(seeded params must reproduce bit-identically)")
        if loaded.plan.to_json() != c_staged.plan.to_json():
            raise OracleViolation(
                "artifact_roundtrip",
                "loaded artifact's plan re-serialises differently")
    ran.append("artifact_roundtrip")

    # -- report_invariants ---------------------------------------------------
    for r in c_staged.executor.report.spills:
        spec = g.vertex(r.src).meta["exec"]
        sm = spec.get("m_out", spec["m"])
        sc = spec["cout"]
        raw = sm * sc * g.edge(r.src, r.dst).word_bits
        if r.raw_bits != raw:
            raise OracleViolation(
                "report_invariants",
                f"spill {r.src}->{r.dst}: raw_bits {r.raw_bits} != "
                f"declared stripe volume {raw}")
        if r.codec == "bfp8" and r.reason == "evicted":
            want_bits = _bfp8_offchip_bits(sm, sc)
            if r.offchip_bits != want_bits or not r.exact:
                raise OracleViolation(
                    "report_invariants",
                    f"spill {r.src}->{r.dst}: BFP8 offchip_bits "
                    f"{r.offchip_bits} != compile-time formula {want_bits}")
        elif r.codec == "none" and r.offchip_bits != r.raw_bits:
            raise OracleViolation(
                "report_invariants",
                f"spill {r.src}->{r.dst}: uncompressed stream reports "
                f"offchip {r.offchip_bits} != raw {r.raw_bits}")
    srep = c_pipe.executor.report
    if srep.ticks != B + plan.n_stages - 1:
        raise OracleViolation(
            "report_invariants",
            f"stream report ticks {srep.ticks} != B + S - 1 = "
            f"{B + plan.n_stages - 1}")
    if srep.eq6_time > srep.eq5_time + 1e-9:
        raise OracleViolation(
            "report_invariants",
            f"Eq.6 steady frame time {srep.eq6_time} exceeds Eq.5 "
            f"sequential time {srep.eq5_time}")
    ran.append("report_invariants")

    return CaseReport(
        label=case.label, n_vertices=len(list(g.vertices())),
        n_stages=plan.n_stages, microbatches=B,
        n_evicted=sum(1 for s in plan.streams if s.evicted),
        n_lossy=len(lossy), oracles=tuple(ran))


# -----------------------------------------------------------------------------
# fault injection (harness self-test)
# -----------------------------------------------------------------------------

FAULTS = ("skip-bfp8-decode", "undersize-queues", "oversubscribe-channel",
          "skew-fused-quant")


@contextlib.contextmanager
def inject_fault(name: str | None):
    """Deliberately break one mechanism while compiling/running cases.

    ``skip-bfp8-decode``
        the staged executor's BFP8 spill round-trip becomes the identity —
        evicted BFP8 streams silently skip quantisation on the staged
        path while the 1F1B streamer still encodes/decodes its crossings,
        so ``staged_vs_pipelined`` (or ``bfp8_bounded``) must fire.
    ``undersize-queues``
        every inter-stage ring is sized to capacity 1, ignoring Eq. 1 —
        any crossing with pipeline delay > 1 then stalls or overflows and
        ``modelcheck`` must fire.
    ``oversubscribe-channel``
        the bandwidth arbiter grants every stream its full demand,
        ignoring the channel's capacity cap — on any case whose drawn
        channel is oversubscribed, total grants exceed ``bits_per_cycle``
        and ``modelcheck``/``channel_model`` must fire.
    ``skew-fused-quant``
        the fused egress quantiser of the streaming_conv Pallas kernels
        writes a one-off block exponent (doubling every dequantised
        value), while the standalone stripe codec stays correct — on any
        pallas-mode case whose fused egress actually fires,
        ``kernel_parity`` must catch the divergence.

    Used by the fuzz driver's ``--inject-fault`` flag and the harness
    self-tests: a conformance suite that cannot catch a planted bug is
    not measuring anything.
    """
    if not name:
        yield
        return
    if name == "skip-bfp8-decode":
        from ..runtime import executor as _ex
        orig = _ex._bfp8_roundtrip
        _ex._bfp8_roundtrip = lambda x, **kw: x
        try:
            yield
        finally:
            _ex._bfp8_roundtrip = orig
    elif name == "undersize-queues":
        from ..runtime.streamer import queues as _q
        orig = _q.queue_specs

        def undersized(*a, **kw):
            return {e: dataclasses.replace(s, capacity=1)
                    for e, s in orig(*a, **kw).items()}
        _q.queue_specs = undersized
        try:
            yield
        finally:
            _q.queue_specs = orig
    elif name == "skew-fused-quant":
        from ..kernels import streaming_conv as _sc
        orig = _sc._quant_vals

        def skewed(x, *, block):
            man, exp = orig(x, block=block)
            return man, exp + 1          # doubles every block's scale
        _sc._quant_vals = skewed
        try:
            yield
        finally:
            _sc._quant_vals = orig
    elif name == "oversubscribe-channel":
        from ..memory import arbiter as _arb
        orig = _arb._grant

        def uncapped(policy, demands, weights, order, capacity):
            return list(demands)        # every stream gets its demand
        _arb._grant = uncapped
        try:
            yield
        finally:
            _arb._grant = orig
    else:
        raise ValueError(f"unknown fault {name!r}; known: {FAULTS}")


def replay_json(payload: dict) -> CaseReport:
    """Re-execute one repro payload (see ``fuzz.write_repro``)."""
    from .gen import case_from_json_dict
    case = case_from_json_dict(payload["case"])
    with inject_fault(payload.get("inject_fault")):
        return check_case(case)
