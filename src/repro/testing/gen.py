"""Seeded random generator for executable graphs and execution plans.

The generator covers the full lowerable op vocabulary of the executable
runtime (``runtime/executor.apply_vertex``): 1x1 channel-mixing ``conv``,
depthwise temporal ``dwconv``, ``pool``/global-pool, ``upsample``,
``act``, residual ``add``, broadcast ``mul`` (squeeze-excitation), and
``concat`` — composed into the block patterns whose deep synchronisation
buffers SMOF's eviction attacks: residual/SE side branches and long
encoder->decoder / feature-bank skips.

Everything is driven by one ``random.Random`` instance, so a (seed, index)
pair fully determines a case; the fuzz driver and the committed repro files
both rely on that.
"""
from __future__ import annotations

import dataclasses
import json
import random

from ..core.builders import _XB, exec_input_shape
from ..core.graph import WEIGHTY, Graph
from ..core.plan import ExecutionPlan, LayerPlan, StreamPlan
from ..memory import POLICIES, ChannelConfig

__all__ = ["GenConfig", "FuzzCase", "random_exec_graph", "random_plan",
           "mutate_plan", "random_case", "case_to_json_dict",
           "case_from_json_dict"]


@dataclasses.dataclass(frozen=True)
class GenConfig:
    """Knobs bounding the generated case population.

    Positions are powers of two so pool/upsample chains always land on
    integral extents; channels need not be multiples of the BFP8 block
    (32) — odd widths exercise the codec's block padding path.
    """
    min_blocks: int = 3
    max_blocks: int = 8
    positions: tuple[int, ...] = (16, 32)
    max_positions: int = 64
    channels: tuple[int, ...] = (16, 32, 64)
    p_snapshot: float = 0.5        # block output becomes a skip candidate
    p_feature_bank: float = 0.8    # force one graph-spanning concat skip
    max_stages: int = 4
    p_evict_deep: float = 0.8      # eviction bias for deep/crossing streams
    p_evict: float = 0.25
    p_bfp8: float = 0.75
    frag_choices: tuple[float, ...] = (1.0, 1.0, 0.75, 0.5)
    min_microbatches: int = 2
    max_microbatches: int = 5
    max_mutations: int = 2
    # off-chip channel model draws (repro.memory): probability a case gets
    # a ChannelConfig at all, then policy/bandwidth/weight vocabularies.
    # The gbps menu deliberately includes starvation-grade bandwidths
    # (0.5/1.0) so oversubscribed channels appear within a smoke budget.
    p_channel: float = 0.5
    channel_policies: tuple[str, ...] = POLICIES
    channel_gbps: tuple[float, ...] = (0.5, 1.0, 8.0, 64.0)
    channel_weights: tuple[float, ...] = (0.5, 1.0, 2.0)
    #: probability a case draws kernel_mode="pallas" (interpret on CPU),
    #: arming the kernel_parity oracle against the reference dispatch
    p_pallas: float = 0.5


# -----------------------------------------------------------------------------
# graph generation
# -----------------------------------------------------------------------------

def random_exec_graph(rng: random.Random, cfg: GenConfig = GenConfig(),
                      name: str = "fuzz") -> Graph:
    """One random executable graph: a chain of blocks drawn from the op
    menu, with skip connections into ``add``/``mul``/``concat`` merge
    points and (usually) one long feature-bank skip spanning the whole
    body — the deepest buffer in the graph, like UNet's outermost skip."""
    g = Graph(name)
    b = _XB(g, word_bits=16, weight_bits=16)
    m = rng.choice(list(cfg.positions))
    c = rng.choice(list(cfg.channels))
    prev = b.xsimple(None, "input", c, m)
    # skip snapshots: (name, channels, positions) of earlier block outputs
    snaps: list[tuple[str, int, int]] = []
    bank: tuple[str, int, int] | None = None

    def menu() -> list[tuple[str, int]]:
        ops = [("conv", 3), ("act", 2), ("dwconv", 2), ("se", 1)]
        if m % 2 == 0 and m >= 4:
            ops.append(("pool", 2))
        if m * 2 <= cfg.max_positions:
            ops.append(("upsample", 1))
        if any(sc == c and sm == m and s != prev for s, sc, sm in snaps):
            ops.append(("add_skip", 2))
        if any(sm == m and s != prev for s, _, sm in snaps):
            ops.append(("concat_skip", 2))
        return ops

    n_blocks = rng.randint(cfg.min_blocks, cfg.max_blocks)
    for _ in range(n_blocks):
        ops = menu()
        kinds = [k for k, _ in ops]
        weights = [w for _, w in ops]
        kind = rng.choices(kinds, weights=weights, k=1)[0]
        if kind == "conv":
            cout = rng.choice(list(cfg.channels))
            prev = b.xconv(prev, c, cout, m)
            c = cout
        elif kind == "act":
            prev = b.xsimple(prev, "act", c, m)
        elif kind == "dwconv":
            prev = b.xdwconv(prev, c, m, taps=rng.choice((3, 5)))
        elif kind == "se":
            # squeeze-excitation: global pool -> bottleneck convs ->
            # broadcast mul; the side branch re-converges after the whole
            # excitation chain (a deep buffer on the trunk edge)
            se = b.xsimple(prev, "pool", c, m, m_out=1)
            se = b.xconv(se, c, 32, 1)
            se = b.xsimple(se, "act", 32, 1)
            se = b.xconv(se, 32, c, 1)
            prev = b.xsimple([prev, se], "mul", c, m)
        elif kind == "pool":
            prev = b.xsimple(prev, "pool", c, m, m_out=m // 2)
            m //= 2
        elif kind == "upsample":
            prev = b.xsimple(prev, "upsample", c, m, m_out=m * 2)
            m *= 2
        elif kind == "add_skip":
            skip = rng.choice([s for s, sc, sm in snaps
                               if sc == c and sm == m and s != prev])
            prev = b.xsimple([skip, prev], "add", c, m)
        elif kind == "concat_skip":
            skip, sc, _ = rng.choice([t for t in snaps
                                      if t[2] == m and t[0] != prev])
            prev = b.xsimple([skip, prev], "concat", sc + c, m)
            c += sc
        if rng.random() < cfg.p_snapshot:
            snaps.append((prev, c, m))
            if bank is None:
                bank = snaps[-1]

    # graph-spanning feature bank: the earliest snapshot skips the whole
    # body, pooled/upsampled to the final extent, fusing by concat
    if bank is not None and bank[0] != prev and rng.random() < cfg.p_feature_bank:
        bname, bc, bm = bank
        while bm > m:
            bname = b.xsimple(bname, "pool", bc, bm, m_out=bm // 2)
            bm //= 2
        while bm < m:
            bname = b.xsimple(bname, "upsample", bc, bm, m_out=bm * 2)
            bm *= 2
        if bname != prev:
            prev = b.xsimple([bname, prev], "concat", bc + c, m)
            c += bc
    prev = b.xconv(prev, c, rng.choice(list(cfg.channels)), m)
    b.xsimple(prev, "output", g.vertex(prev).meta["exec"]["cout"], m)
    g.validate()
    return g


# -----------------------------------------------------------------------------
# plan generation / mutation
# -----------------------------------------------------------------------------

def _edge_depth(topo: list[str], src: str, dst: str) -> int:
    pos = {n: i for i, n in enumerate(topo)}
    return pos[dst] - pos[src]


def random_plan(g: Graph, rng: random.Random,
                cfg: GenConfig = GenConfig()) -> ExecutionPlan:
    """A random valid plan for ``g``: contiguous topo-order stage cuts
    (stage bounds are then monotonic along every edge by construction),
    eviction biased towards deep and stage-crossing streams, random
    fragmentation on weighty layers, random microbatch count."""
    topo = g.topo()
    n_stages = rng.randint(1, min(cfg.max_stages, len(topo)))
    cuts = sorted(rng.sample(range(1, len(topo)), n_stages - 1))
    stage_of: dict[str, int] = {}
    s = 0
    for i, n in enumerate(topo):
        while s < len(cuts) and i >= cuts[s]:
            s += 1
        stage_of[n] = s
    layers = {
        n: LayerPlan(
            name=n, stage=stage_of[n],
            weight_static_fraction=(rng.choice(cfg.frag_choices)
                                    if g.vertex(n).kind in WEIGHTY else 1.0))
        for n in topo}
    streams = []
    for e in g.edges():
        deep = (_edge_depth(topo, e.src, e.dst) > 2
                or stage_of[e.src] != stage_of[e.dst])
        evicted = rng.random() < (cfg.p_evict_deep if deep else cfg.p_evict)
        codec = ("bfp8" if evicted and rng.random() < cfg.p_bfp8 else "none")
        streams.append(StreamPlan(e.src, e.dst, evicted=evicted, codec=codec))
    plan = ExecutionPlan(
        model=g.name, device="u200", n_stages=n_stages, layers=layers,
        streams=streams,
        microbatch=rng.randint(cfg.min_microbatches, cfg.max_microbatches),
        topo_order=topo)
    plan.validate()
    return plan


def _copy_plan(plan: ExecutionPlan) -> ExecutionPlan:
    return ExecutionPlan.from_json(plan.to_json())


def _stage_bounds(plan: ExecutionPlan) -> list[int] | None:
    """Per-layer stage ids along topo order, or None if not contiguous
    non-decreasing (mutations only operate on contiguous plans)."""
    stages = [plan.layers[n].stage for n in plan.ordered_layers()]
    if any(b < a for a, b in zip(stages, stages[1:])):
        return None
    return stages


def mutate_plan(g: Graph, plan: ExecutionPlan, rng: random.Random,
                cfg: GenConfig = GenConfig()) -> ExecutionPlan:
    """One random plan mutation: split/merge a stage, flip an eviction,
    change a codec/fragmentation fraction, or rescale the microbatch.
    Always returns a *valid* plan (falls back to a fresh random plan if
    the drawn move is inapplicable)."""
    p = _copy_plan(plan)
    order = p.ordered_layers()
    move = rng.choice(("split", "merge", "evict", "unevict", "frag",
                       "microbatch"))
    if move == "split":
        stages = _stage_bounds(p)
        if stages is not None and p.n_stages < cfg.max_stages:
            # cut one stage segment in two at a random internal boundary
            cands = [i for i in range(1, len(order))
                     if stages[i] == stages[i - 1]]
            if cands:
                cut = rng.choice(cands)
                for i in range(cut, len(order)):
                    p.layers[order[i]].stage += 1
                p.n_stages += 1
    elif move == "merge":
        if p.n_stages > 1:
            j = rng.randint(1, p.n_stages - 1)   # merge stage j into j-1
            for lp in p.layers.values():
                if lp.stage >= j:
                    lp.stage -= 1
            p.n_stages -= 1
    elif move == "evict":
        cands = [s for s in p.streams if not s.evicted]
        if cands:
            s = rng.choice(cands)
            s.evicted = True
            s.codec = "bfp8" if rng.random() < cfg.p_bfp8 else "none"
    elif move == "unevict":
        cands = [s for s in p.streams if s.evicted]
        if cands:
            s = rng.choice(cands)
            s.evicted, s.codec = False, "none"
    elif move == "frag":
        cands = [n for n in order if g.vertex(n).kind in WEIGHTY]
        if cands:
            p.layers[rng.choice(cands)].weight_static_fraction = \
                rng.choice(cfg.frag_choices)
    elif move == "microbatch":
        p.microbatch = rng.randint(cfg.min_microbatches,
                                   cfg.max_microbatches)
    p.validate()
    return p


# -----------------------------------------------------------------------------
# cases
# -----------------------------------------------------------------------------

@dataclasses.dataclass
class FuzzCase:
    """One conformance case: a graph, a plan for it, the seed that
    derives its weights and input frames, and (optionally) an off-chip
    channel model the pipelined compile arbitrates under."""
    graph: Graph
    plan: ExecutionPlan
    seed: int
    label: str = "case"
    channel: ChannelConfig | None = None
    #: kernel dispatch for the case's compiles ("reference" | "pallas");
    #: "pallas" additionally arms the kernel_parity oracle
    kernel_mode: str = "reference"

    @property
    def input_shape(self) -> tuple[int, int]:
        return exec_input_shape(self.graph)


def random_case(seed: int, index: int,
                cfg: GenConfig = GenConfig()) -> FuzzCase:
    """The fully deterministic case for (seed, index): graph, plan, and
    0..max_mutations plan mutations, all from one seeded stream."""
    rng = random.Random(f"smof-fuzz:{seed}:{index}")
    g = random_exec_graph(rng, cfg, name=f"fuzz_{seed}_{index}")
    plan = random_plan(g, rng, cfg)
    for _ in range(rng.randint(0, cfg.max_mutations)):
        plan = mutate_plan(g, plan, rng, cfg)
    # channel draw LAST: earlier draws are byte-identical to the
    # pre-channel generator, so old (seed, index) pairs still name the
    # same graph+plan and committed repro shrinks stay valid.
    channel = None
    if rng.random() < cfg.p_channel:
        channel = ChannelConfig(
            policy=rng.choice(list(cfg.channel_policies)),
            gbps=rng.choice(list(cfg.channel_gbps)),
            weight_fetch_weight=rng.choice(list(cfg.channel_weights)),
            evict_weight=rng.choice(list(cfg.channel_weights)),
            restore_weight=rng.choice(list(cfg.channel_weights)))
    # kernel_mode draw after the channel draw, same reasoning: every
    # earlier draw stays byte-identical to the pre-kernel-mode generator.
    kernel_mode = "pallas" if rng.random() < cfg.p_pallas else "reference"
    return FuzzCase(graph=g, plan=plan, seed=seed * 1000 + index,
                    label=f"{seed}-{index}", channel=channel,
                    kernel_mode=kernel_mode)


def case_to_json_dict(case: FuzzCase) -> dict:
    return {
        "graph": case.graph.to_json_dict(),
        "plan": json.loads(case.plan.to_json()),
        "seed": case.seed,
        "label": case.label,
        "channel": (case.channel.to_dict()
                    if case.channel is not None else None),
        "kernel_mode": case.kernel_mode,
    }


def case_from_json_dict(d: dict) -> FuzzCase:
    return FuzzCase(
        graph=Graph.from_json_dict(d["graph"]),
        plan=ExecutionPlan.from_json(json.dumps(d["plan"])),
        seed=int(d["seed"]),
        label=d.get("label", "case"),
        # pre-channel repro payloads have no "channel" key -> None
        channel=(ChannelConfig.from_dict(d["channel"])
                 if d.get("channel") else None),
        # pre-kernel-mode payloads replay on the reference dispatch
        kernel_mode=d.get("kernel_mode", "reference"),
    )
