"""Staged subgraph executor — the paper's §III-C reconfiguration on TPU.

An FPGA runs one subgraph's bitstream at a time and pays ``t_ri`` to load
the next; the TPU analogue keeps only one stage's weights resident and pays
the host->HBM weight-transfer time between stages.  Latency follows Eq. 5:

    t = sum_i (b * II_i + d_pi) / f + N * t_ri

Boundary activations between stages are the evicted streams: they leave the
device as BFP8 pages (core/compression) and come back for the next stage —
Eq. 2's bandwidth cost with the compile-time-known codec ratio.

Stages come from an :class:`ExecutionPlan` (the DSE output) or an explicit
group partition.  Weights for inactive stages live on host as numpy views.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compression import bfp8_decode, bfp8_encode
from repro.models import project_logits
from repro.models.config import ArchConfig
from repro.models.model import _embed, apply_norm


@dataclasses.dataclass
class StageTiming:
    stage: int
    compute_s: float
    reconfig_s: float
    boundary_bytes_raw: int
    boundary_bytes_sent: int


def split_group_stages(n_groups: int, n_stages: int) -> list[tuple[int, int]]:
    """Contiguous [start, end) group ranges, balanced."""
    n_stages = max(1, min(n_stages, n_groups))
    base, rem = divmod(n_groups, n_stages)
    out, s = [], 0
    for i in range(n_stages):
        e = s + base + (1 if i < rem else 0)
        out.append((s, e))
        s = e
    return out


class StagedExecutor:
    """Runs a model whose per-stage weights don't fit together on-device."""

    def __init__(self, cfg: ArchConfig, host_params: Any, *,
                 n_stages: int, compress_boundary: bool = True,
                 dtype=jnp.float32):
        self.cfg = cfg
        self.n_stages = n_stages
        self.compress = compress_boundary
        self.dtype = dtype
        self.stages = split_group_stages(cfg.n_groups, n_stages)
        # host-side parameter store (numpy; stands in for host DRAM)
        self.host_params = jax.tree.map(np.asarray, host_params)
        self.timings: list[StageTiming] = []

    # -- stage weight management ("reconfiguration") ---------------------------
    def _stage_params(self, stage: int) -> Any:
        """Slice this stage's group stack and move it to device (t_ri)."""
        s, e = self.stages[stage]
        sliced = jax.tree.map(lambda a: a[s:e], self.host_params["groups"])
        return jax.tree.map(jnp.asarray, sliced)

    def _boundary_roundtrip(self, x: jax.Array) -> tuple[jax.Array, int, int]:
        """Evict the inter-stage activation off-device and bring it back."""
        raw = np.asarray(x, np.float32)
        raw_bytes = raw.size * 2                       # bf16 stream words
        if not self.compress:
            return jnp.asarray(raw, x.dtype), raw_bytes, raw_bytes
        enc = bfp8_encode(raw)
        sent = enc.mantissas.size + enc.exponents.size
        back = bfp8_decode(enc).astype(np.float32)
        return jnp.asarray(back, x.dtype), raw_bytes, sent

    # -- execution ------------------------------------------------------------------
    def forward_logits(self, tokens: jax.Array, **extras) -> jax.Array:
        """Full forward over all stages with reconfiguration between them."""
        params = self.host_params
        x = _embed(jax.tree.map(jnp.asarray,
                                {"embed": params["embed"]}),
                   self.cfg, tokens,
                   extras.get("patch_embeds"))
        self.timings.clear()
        for i in range(self.n_stages):
            t0 = time.monotonic()
            gp = self._stage_params(i)                 # "bitstream load"
            t_rc = time.monotonic() - t0

            t1 = time.monotonic()
            x = self._run_groups(gp, x)
            t_cp = time.monotonic() - t1

            raw = sent = 0
            if i < self.n_stages - 1:
                x, raw, sent = self._boundary_roundtrip(x)
            self.timings.append(StageTiming(i, t_cp, t_rc, raw, sent))
        full = jax.tree.map(jnp.asarray,
                            {"final_norm": params["final_norm"],
                             "embed": params["embed"],
                             **({"lm_head": params["lm_head"]}
                                if "lm_head" in params else {})})
        x = apply_norm(self.cfg.norm, x, full["final_norm"])
        return project_logits(full, self.cfg, x)

    def _run_groups(self, group_params: Any, x: jax.Array) -> jax.Array:
        from repro.models.model import _apply_layer
        ng = jax.tree.leaves(group_params)[0].shape[0]
        pos = jnp.arange(x.shape[1])[None]
        gs = self.cfg.group_size

        def body(x, gp):
            for j in range(gs):
                x, _, _ = _apply_layer(gp[f"pos_{j}"], x, self.cfg, j,
                                       pos=pos, mode="full")
            return x, None

        x, _ = jax.lax.scan(body, x, group_params)
        return x

    # -- Eq. 5 accounting -------------------------------------------------------------
    def eq5_latency(self, batch: int) -> dict:
        comp = sum(t.compute_s for t in self.timings)
        reconf = sum(t.reconfig_s for t in self.timings)
        raw = sum(t.boundary_bytes_raw for t in self.timings)
        sent = sum(t.boundary_bytes_sent for t in self.timings)
        total = comp + reconf
        return {"n_stages": self.n_stages, "compute_s": comp,
                "reconfig_s": reconf, "total_s": total,
                "throughput_fps": batch / total if total else float("inf"),
                "boundary_raw_bytes": raw, "boundary_sent_bytes": sent,
                "boundary_compression": sent / raw if raw else 1.0}
