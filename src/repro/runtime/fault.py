"""Fault tolerance: checkpoint/restart, transient-failure retry, straggler
mitigation, and elastic rescaling.

Single-process simulation of the policies a 1000-node deployment needs —
the *control logic* is real (and unit-tested); only the failure injection
is synthetic:

* **checkpoint/restart** — periodic async checkpoints (checkpoint/store),
  deterministic data resume (data/pipeline is step-indexed), restore picks
  the newest intact checkpoint (a torn save is impossible by construction).
* **retry** — a failed step (device OOM, preempted worker, injected fault)
  is retried from the last good state up to ``max_retries``; repeated
  failure escalates to restore-from-checkpoint.
* **straggler mitigation** — per-step wall times feed a running median;
  a step slower than ``straggler_factor`` x median is logged and counted,
  and the policy hook decides (log | rebalance | skip). At scale the same
  hook triggers backup-task dispatch.
* **elastic rescaling** — on a device-count change, rebuild the mesh,
  recompute shardings, and restore the checkpoint into the new layout
  (CheckpointStore.restore(shardings=...)).
"""
from __future__ import annotations

import dataclasses
import time
from statistics import median
from typing import Any, Callable

from repro.checkpoint.store import CheckpointStore


@dataclasses.dataclass
class FaultConfig:
    checkpoint_every: int = 50
    max_retries: int = 2
    straggler_factor: float = 3.0
    straggler_policy: str = "log"          # log | skip


@dataclasses.dataclass
class StepRecord:
    step: int
    wall_s: float
    retries: int = 0
    straggler: bool = False


class FaultTolerantLoop:
    """Wraps (state, batch) -> state step functions with FT policies.

    With a ``metrics`` :class:`~repro.obs.metrics.MetricsRegistry`, every
    recovery event also lands in ``smof_fault_events_total{kind=...}``
    (retry / restore / rollback / checkpoint / straggler) and step wall
    times in ``smof_fault_step_seconds`` — so recovery behaviour is
    visible on the same scrape surface as the serving metrics, not only
    in the in-memory ``events`` list.
    """

    def __init__(self, step_fn: Callable[[Any, Any], Any],
                 store: CheckpointStore, cfg: FaultConfig | None = None,
                 fault_injector: Callable[[int], None] | None = None,
                 metrics=None):
        self.step_fn = step_fn
        self.store = store
        self.cfg = cfg or FaultConfig()
        self.fault_injector = fault_injector
        self.records: list[StepRecord] = []
        self.events: list[dict] = []
        self.metrics = metrics
        self._c_events = self._h_step = None
        if metrics is not None:
            self._c_events = metrics.counter(
                "smof_fault_events_total",
                "fault-tolerance events, by kind", ("kind",))
            self._h_step = metrics.histogram(
                "smof_fault_step_seconds", "per-step wall clock")

    def _event(self, kind: str, **payload) -> None:
        self.events.append({"kind": kind, **payload})
        if self._c_events is not None:
            self._c_events.labels(kind=kind).inc()

    # -- recovery ---------------------------------------------------------------
    def try_restore(self, template: Any, shardings: Any = None
                    ) -> tuple[Any, int]:
        """(state, next_step) from the newest checkpoint, or (template, 0)."""
        step = self.store.latest_step()
        if step is None:
            return template, 0
        state, extra = self.store.restore(template, step, shardings=shardings)
        self._event("restore", step=step)
        return state, int(extra.get("next_step", step + 1))

    # -- main loop ----------------------------------------------------------------
    def run(self, state: Any, batches: Callable[[int], Any], *,
            start_step: int, num_steps: int) -> Any:
        wall: list[float] = []
        step = start_step
        end = start_step + num_steps
        while step < end:
            batch = batches(step)
            t0 = time.monotonic()
            retries = 0
            while True:
                try:
                    if self.fault_injector is not None:
                        self.fault_injector(step)
                    new_state = self.step_fn(state, batch)
                    break
                except Exception as e:  # noqa: BLE001 — injected/transient
                    retries += 1
                    self._event("retry", step=step, error=str(e),
                                attempt=retries)
                    if retries > self.cfg.max_retries:
                        state, step = self._recover(state)
                        batch = batches(step)
                        retries = 0
            dt = time.monotonic() - t0
            is_straggler = (len(wall) >= 5
                            and dt > self.cfg.straggler_factor * median(wall))
            if is_straggler:
                self._event("straggler", step=step, wall_s=dt,
                            median_s=median(wall))
            wall.append(dt)
            if self._h_step is not None:
                self._h_step.observe(dt)
            self.records.append(StepRecord(step, dt, retries, is_straggler))
            state = new_state
            step += 1
            if step % self.cfg.checkpoint_every == 0:
                self.store.save_async(step, state, {"next_step": step})
                self._event("checkpoint", step=step)
        self.store.wait()
        return state

    def _recover(self, state: Any) -> tuple[Any, int]:
        """Exhausted retries: roll back to the newest checkpoint."""
        latest = self.store.latest_step()
        if latest is None:
            self._event("recover_failed_no_ckpt")
            raise RuntimeError("step keeps failing and no checkpoint exists")
        restored, extra = self.store.restore(state, latest)
        nxt = int(extra.get("next_step", latest + 1))
        self._event("rollback", to_step=nxt)
        return restored, nxt


def elastic_remesh(make_mesh: Callable[[], Any],
                   make_shardings: Callable[[Any], Any],
                   store: CheckpointStore, template: Any) -> tuple[Any, Any, int]:
    """Rebuild mesh + shardings for the CURRENT device population and
    restore the newest checkpoint into that layout."""
    mesh = make_mesh()
    shardings = make_shardings(mesh)
    step = store.latest_step()
    if step is None:
        return mesh, template, 0
    state, extra = store.restore(template, step, shardings=shardings)
    return mesh, state, int(extra.get("next_step", step + 1))
