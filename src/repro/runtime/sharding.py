"""Sharding rules: parameter / batch / cache PartitionSpecs.

Logical scheme on the (``pod``,) ``data``, ``model`` mesh:

* **FSDP** — parameter matrices shard their d_model-like axis over ``data``;
* **TP**   — head / hidden axes shard over ``model``;
* **EP**   — MoE expert axis shards over ``model`` when divisible (olmoe 64e,
  jamba 16e), otherwise experts stay together and TP falls back to d_ff
  (grok 8e on a 16-wide model axis);
* **DP**   — the batch shards over (``pod`` x) ``data``;
* **SP**   — when the batch is too small to shard (long_500k, B=1), the KV
  cache shards its *sequence* axis over ``data`` instead.

Every rule is divisibility-guarded: an axis that does not divide by its mesh
axis size is left unsharded rather than failing (e.g. whisper's vocab 51866).
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ArchConfig


def _axsize(mesh: Mesh, name: str) -> int:
    return mesh.shape.get(name, 1)


def dp_axes(mesh: Mesh):
    """The data-parallel mesh axes: ("pod","data") on multi-pod meshes."""
    return ("pod", "data") if "pod" in mesh.shape else ("data",)


def dp_size(mesh: Mesh) -> int:
    out = 1
    for a in dp_axes(mesh):
        out *= _axsize(mesh, a)
    return out


def _guard(shape: tuple, spec: list, mesh: Mesh) -> P:
    """Drop any sharding a dimension cannot honour."""
    out = []
    for dim, s in zip(shape, spec):
        if s is None:
            out.append(None)
            continue
        names = s if isinstance(s, tuple) else (s,)
        total = 1
        for n in names:
            total *= _axsize(mesh, n)
        out.append(s if dim % total == 0 and total > 1 else None)
    return P(*out)


def _param_spec(path: tuple, shape: tuple, cfg: ArchConfig, mesh: Mesh) -> P:
    names = [getattr(k, "key", str(k)) for k in path]
    name = names[-1]
    grouped = "groups" in names            # stacked (n_groups, ...) leading dim
    core = shape[1:] if grouped else shape

    def done(spec_core: list) -> P:
        spec = ([None] + spec_core) if grouped else spec_core
        return _guard(shape, spec, mesh)

    if name in ("embed", "lm_head"):
        return done(["model", None])
    # --- attention -----------------------------------------------------------
    if name in ("wq", "wk", "wv"):
        return done(["data", "model"])
    if name == "wo":
        return done(["model", "data"])
    # --- ffn / moe ------------------------------------------------------------
    if name == "router":
        return done(["data", None])
    if name in ("w_up", "w_gate", "w_down") and len(core) == 3:   # (E, d, f)
        E = core[0]
        if E % _axsize(mesh, "model") == 0:
            return done(["model", "data", None] if name != "w_down"
                        else ["model", None, "data"])
        return done([None, "data", "model"] if name != "w_down"
                    else [None, "model", "data"])
    if name in ("w_up", "w_gate"):
        return done(["data", "model"])
    if name == "w_down":
        return done(["model", "data"])
    # --- ssm / xlstm -----------------------------------------------------------
    if name == "in_proj":
        return done(["data", "model"])
    if name == "out_proj":
        return done(["model", "data"])
    if name in ("conv_w",):
        return done([None, "model"])
    if name == "x_proj":
        return done(["model", None])
    if name == "dt_proj":
        return done([None, "model"])
    if name in ("A_log",):
        return done(["model", None])
    if name in ("D", "wq_diag", "wk_diag"):
        return done(["model"])
    if name == "w_in":
        return done(["data", "model"])
    if name == "r":                         # (H, dh, 4dh)
        return done([None, None, "model"])
    # --- norms / biases / everything 1-D: replicate -----------------------------
    if len(core) <= 1:
        return done([None] * len(core))
    # generic 2-D fallback
    return done(["data", "model"] + [None] * (len(core) - 2))


def param_shardings(cfg: ArchConfig, params_shapes: Any, mesh: Mesh) -> Any:
    """NamedSharding pytree matching a params (shape) pytree."""
    def f(path, leaf):
        spec = _param_spec(path, leaf.shape, cfg, mesh)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(f, params_shapes)


def opt_state_shardings(cfg: ArchConfig, opt_shapes: Any, mesh: Mesh) -> Any:
    """Moments follow their parameter's sharding; scales drop the last axis."""
    def f(path, leaf):
        names = [getattr(k, "key", str(k)) for k in path]
        if names and names[-1] == "step":
            return NamedSharding(mesh, P())
        # strip the m/v level and any q/s quantisation leaf so the rule sees
        # the underlying parameter's path
        eff = tuple(k for k in path
                    if getattr(k, "key", str(k)) not in ("m", "v", "q", "s"))
        if names[-1] == "s":   # row scale: parameter spec minus the last axis
            fake = leaf.shape[:-1] + (mesh.size * 1024,)
            base = _param_spec(eff, fake, cfg, mesh)
            spec = _guard(leaf.shape, list(base)[:-1] + [None], mesh)
        else:
            spec = _param_spec(eff, leaf.shape, cfg, mesh)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(f, opt_shapes)


def batch_shardings(cfg: ArchConfig, batch: int, mesh: Mesh) -> dict:
    dp = dp_axes(mesh)
    dp = dp[0] if len(dp) == 1 else dp
    b_ok = batch % dp_size(mesh) == 0
    row = (dp,) if b_ok else (None,)
    return {
        "tokens": NamedSharding(mesh, P(*row, None)),
        "labels": NamedSharding(mesh, P(*row, None)),
        "enc_frames": NamedSharding(mesh, P(*row, None, None)),
        "patch_embeds": NamedSharding(mesh, P(*row, None, None)),
        "pos": NamedSharding(mesh, P(*row)),
    }


def cache_shardings(cfg: ArchConfig, batch: int, mesh: Mesh, cache_shapes) -> Any:
    """KV / state cache shardings; SP fallback when the batch won't shard."""
    dp = dp_axes(mesh)
    dp = dp[0] if len(dp) == 1 else dp
    b_ok = batch % dp_size(mesh) == 0

    def f(path, leaf):
        names = [getattr(k, "key", str(k)) for k in path]
        name = names[-1]
        nd = len(leaf.shape)
        spec: list = [None] * nd
        if name in ("k", "v", "xk", "xv") and nd == 5:
            # KV cache (ng, B, S, KH, D): batch over DP + *sequence over
            # model* — GSPMD turns decode attention into ring-attention-lite
            # (sharded partial scores + collective softmax), and a KH head
            # axis smaller than the model axis never forces a replica.
            if b_ok:
                spec[1] = dp
            spec[2] = "model" if b_ok else ("data", "model")
        elif b_ok:
            spec[1] = dp                                   # (ng, B, ...)
        elif name in ("h", "C") and nd >= 4:
            spec[2] = "model"                              # d_inner / heads
        return NamedSharding(mesh, _guard(leaf.shape, spec, mesh))

    return jax.tree_util.tree_map_with_path(f, cache_shapes)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
