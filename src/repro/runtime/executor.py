"""Executable lowering: DSE plan -> jittable JAX streaming pipeline.

This is the plan->execution bridge: ``core.dse`` decides *where* data lives
(Algorithm 1) and ``core.plan.ExecutionPlan`` records the decision vector;
this module makes those decisions actually happen on an accelerator:

* **evicted streams** (``StreamPlan.evicted``) round-trip through an
  off-chip spill buffer.  BFP8 streams are really quantised on the way out
  and dequantised on the way back in (``kernels/bfp8.py``), so the executed
  numerics carry the codec's error exactly as hardware would; RLE/Huffman
  are lossless, so their numerical effect is identity and only the traffic
  accounting changes.  On TPU the spill additionally hops through
  ``pinned_host`` memory via ``jax.device_put`` so the bytes truly leave
  HBM; elsewhere the hop is a no-op (the round-trip through the codec still
  executes).
* **fragmented weights** (``LayerPlan.weight_static_fraction < 1``)
  dispatch to ``kernels/streamed_matmul.py``: the static row-panel of the
  weight matrix is pinned in VMEM and the dynamic remainder streams from
  HBM block-by-block — the paper's Eq. 3/4 split, with the plan's ``1 - m``
  choosing the split point.
* **stage boundaries** (``LayerPlan.stage`` changes across an edge) hop
  off-chip uncompressed, modelling the sequential subgraph schedule of
  Eq. 5 where inter-partition streams always cross DDR.

Executable graphs come from ``core.builders.build_*_exec``: every vertex
carries ``meta["exec"] = {cin, cout, m[, m_out]}`` and activations flow as
``(positions, channels)`` f32 stripes.  Supported ops:

  ========== =====================================================
  kind       semantics
  ========== =====================================================
  input      identity (the graph input is fed here)
  conv       y = x @ W,  W: (cin, cout)    [1x1 channel mixing]
  matmul     same as conv
  deconv     same as conv (builders pair it with an upsample vertex)
  dwconv     depthwise temporal conv, W: (taps, c) — per-channel mix
             of ``taps`` adjacent positions ('same' padding); the
             3x1x1 temporal kernel of the X3D blocks
  act        relu
  pool       position-axis mean to m_out rows (m -> m_out; m_out=m/2
             is the classic halving pool, m_out=1 the SE global pool)
  upsample   repeat rows m_out/m times      (m -> m_out)
  add        elementwise sum of inputs
  mul        elementwise product of inputs; a (1, c) operand
             broadcasts over positions (SE excitation)
  concat     channel concatenation, predecessor order
  output     ravel-and-concatenate all inputs into one vector
  ========== =====================================================

The lowering also emits a :class:`SpillReport`: per evicted/boundary edge,
the raw and off-chip bit volumes.  For BFP8 the off-chip volume is computed
from the actual mantissa/exponent buffer sizes, so when the channel count
is a multiple of the block it is *bit-exact* against the DSE's
compile-time ``c_bar = (8 + 8/block)/word_bits`` (Eq. 2/4).
"""
from __future__ import annotations

import dataclasses
import functools
import math
import zlib
from typing import Callable

import jax
import jax.numpy as jnp

from ..core.graph import Graph
from ..core.plan import ExecutionPlan
from ..kernels import ref as kref
from ..kernels import streaming_conv as SC
from ..kernels.bfp8 import bfp8_dequant, bfp8_quant
from ..kernels.streamed_matmul import _round_up, streamed_matmul_padded

WEIGHT_KINDS = ("conv", "deconv", "matmul")
TEMPORAL_KINDS = ("dwconv",)
LOSSLESS_CODECS = ("none", "rle", "huffman")
BFP8_BLOCK = 32


# =============================================================================
# Spill accounting
# =============================================================================

@dataclasses.dataclass(frozen=True)
class SpillRecord:
    """Off-chip traffic of one spilled stream (per frame)."""
    src: str
    dst: str
    codec: str
    reason: str            # "evicted" | "stage_boundary"
    raw_bits: int          # words * word_bits before the codec
    offchip_bits: int      # bits actually crossing the off-chip boundary
    exact: bool            # True when offchip_bits is compile-time exact

    @property
    def ratio(self) -> float:
        return self.offchip_bits / max(self.raw_bits, 1)


@dataclasses.dataclass
class SpillReport:
    spills: list[SpillRecord]
    streamed_weight_bits: int     # dynamic-region weight traffic per frame
    static_weight_bits: int       # pinned on-chip (VMEM) weight residency

    @property
    def total_offchip_bits(self) -> int:
        return (sum(s.offchip_bits for s in self.spills)
                + self.streamed_weight_bits)

    def summary(self) -> dict:
        return {
            "n_spilled_edges": len(self.spills),
            "spill_offchip_bits": sum(s.offchip_bits for s in self.spills),
            "streamed_weight_bits": self.streamed_weight_bits,
            "static_weight_bits": self.static_weight_bits,
            "total_offchip_bits": self.total_offchip_bits,
        }


def _bfp8_offchip_bits(m: int, c: int, block: int = BFP8_BLOCK) -> int:
    """Mantissa + shared-exponent bits of a (m, c) stripe, after padding the
    channel axis to the codec block (same rounding as _bfp8_roundtrip)."""
    c_pad = _round_up(c, block)
    return m * c_pad * 8 + m * (c_pad // block) * 8


# =============================================================================
# Vertex semantics
# =============================================================================

def _exec_spec(g: Graph, name: str) -> dict:
    v = g.vertex(name)
    spec = v.meta.get("exec")
    if spec is None:
        raise ValueError(
            f"vertex {name!r} has no meta['exec'] — executable lowering "
            f"needs graphs built by core.builders.build_*_exec")
    return spec


def init_params(g: Graph, seed: int = 0,
                dtype=jnp.float32) -> dict[str, jax.Array]:
    """Deterministic per-vertex weights for every weighty executable op."""
    params: dict[str, jax.Array] = {}
    for v in g.vertices():
        if v.kind not in WEIGHT_KINDS and v.kind not in TEMPORAL_KINDS:
            continue
        spec = _exec_spec(g, v.name)
        key = jax.random.fold_in(jax.random.PRNGKey(seed),
                                 zlib.crc32(v.name.encode()))
        if v.kind in TEMPORAL_KINDS:
            taps = spec.get("taps", 3)
            params[v.name] = jax.random.normal(
                key, (taps, spec["cout"]), dtype) / math.sqrt(taps)
        else:
            scale = 1.0 / math.sqrt(spec["cin"])
            params[v.name] = scale * jax.random.normal(
                key, (spec["cin"], spec["cout"]), dtype)
    return params


def _pool(x: jax.Array, m_out: int) -> jax.Array:
    m, c = x.shape
    if m % m_out:
        raise ValueError(f"pool needs m_out | m, got {m} -> {m_out}")
    return x.reshape(m_out, m // m_out, c).mean(axis=1)


def _upsample(x: jax.Array, m_out: int) -> jax.Array:
    m = x.shape[0]
    if m_out % m:
        raise ValueError(f"upsample needs m | m_out, got {m} -> {m_out}")
    return jnp.repeat(x, m_out // m, axis=0)


def _dwconv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise temporal conv: per-channel mix of adjacent positions,
    'same' zero padding.  ``w`` is (taps, c)."""
    taps = w.shape[0]
    pad = taps // 2
    xp = jnp.pad(x, ((pad, taps - 1 - pad), (0, 0)))
    m = x.shape[0]
    return sum(w[k][None, :] * xp[k:k + m] for k in range(taps))


def bfp8_spill_encode(x: jax.Array, *, use_pallas: bool,
                      interpret: bool) -> tuple[jax.Array, jax.Array]:
    """Encode a (m, c) stripe to (mantissas, exponents), padding the channel
    axis to the codec block — the spill buffers that cross off-chip."""
    c = x.shape[1]
    xp = jnp.pad(x, ((0, 0), (0, _round_up(c, BFP8_BLOCK) - c)))
    if use_pallas:
        return bfp8_quant(xp, block=BFP8_BLOCK, interpret=interpret)
    return kref.bfp8_quant_ref(xp, block=BFP8_BLOCK)


def bfp8_spill_decode(payload: tuple[jax.Array, jax.Array], c: int, *,
                      use_pallas: bool, interpret: bool,
                      dtype=jnp.float32) -> jax.Array:
    """Decode spill buffers back to a (m, c) stripe (drops block padding)."""
    man, exp = payload
    if use_pallas:
        out = bfp8_dequant(man, exp, block=BFP8_BLOCK, dtype=dtype,
                           interpret=interpret)
    else:
        out = kref.bfp8_dequant_ref(man, exp, block=BFP8_BLOCK, dtype=dtype)
    return out[:, :c]


def _bfp8_roundtrip(x: jax.Array, *, use_pallas: bool,
                    interpret: bool) -> jax.Array:
    """Quantise->dequantise a (m, c) stripe through the BFP8 codec.

    Composed from the same encode/decode halves the pipelined streamer
    carries between stages, so the two executors' codec numerics are one
    implementation."""
    payload = bfp8_spill_encode(x, use_pallas=use_pallas, interpret=interpret)
    return bfp8_spill_decode(payload, x.shape[1], use_pallas=use_pallas,
                             interpret=interpret, dtype=x.dtype)


# =============================================================================
# Static plan analysis (shared by the sequential and pipelined executors)
# =============================================================================

@dataclasses.dataclass
class PlanAnalysis:
    """Everything ``lower_plan`` derives from (graph, plan) before tracing.

    Both executors (the sequential one below and the pipelined streamer in
    ``runtime/streamer``) build their traced functions from this one object,
    so spill routing, weight splits, and traffic accounting cannot drift
    between them.
    """
    topo: list[str]                               # deterministic vertex order
    out_shape: dict[str, tuple[int, int]]         # per-vertex (m, c)
    spills: list[SpillRecord]
    spill_fn: dict[tuple[str, str], Callable]     # per spilled edge numerics
    frac: dict[str, float]                        # weight_static_fraction
    stage_of: dict[str, int]                      # vertex -> stage index
    streamed_weight_bits: int
    static_weight_bits: int
    use_pallas: bool
    interpret: bool
    in_vertex: str
    in_shape: tuple[int, int]
    #: evicted edges carrying a BFP8 spill — the payload-routed set the
    #: pallas-mode executors encode once per producer / decode per consumer
    bfp8_edges: set = dataclasses.field(default_factory=set)
    #: plan-level Pallas tile sizes (0 = kernel default): row block and,
    #: for the conv family, out-channel block (docs/KERNELS.md)
    tile_bm: int = 0
    tile_bc: int = 0

    @property
    def n_stages(self) -> int:
        return max(self.stage_of.values(), default=0) + 1

    def report(self) -> SpillReport:
        return SpillReport(spills=list(self.spills),
                           streamed_weight_bits=self.streamed_weight_bits,
                           static_weight_bits=self.static_weight_bits)


def analyze_plan(g: Graph, plan: ExecutionPlan | None, *,
                 use_pallas: bool, interpret: bool) -> PlanAnalysis:
    """Static analysis: shapes, spill records/functions, weight traffic."""
    layers = plan.layers if plan is not None else {}
    stream_map = ({(s.src, s.dst): s for s in plan.streams}
                  if plan is not None else {})

    topo = g.topo()
    out_shape: dict[str, tuple[int, int]] = {}
    for name in topo:
        spec = _exec_spec(g, name)
        out_shape[name] = (spec.get("m_out", spec["m"]), spec["cout"])

    stage_of = {n: (layers[n].stage if n in layers else 0) for n in topo}

    spills: list[SpillRecord] = []
    spill_fn: dict[tuple[str, str], Callable] = {}
    bfp8_edges: set = set()
    for e in g.edges():
        u, w = e.src, e.dst
        s = stream_map.get((u, w))
        evicted = bool(s.evicted) if s is not None else False
        codec = s.codec if s is not None else "none"
        cross_stage = stage_of[u] != stage_of[w]
        if not (evicted or cross_stage):
            continue
        m, c = out_shape[u]
        raw_bits = m * c * e.word_bits
        if evicted and codec == "bfp8":
            off_bits, exact = _bfp8_offchip_bits(m, c), True
            fn = functools.partial(_bfp8_roundtrip, use_pallas=use_pallas,
                                   interpret=interpret)
            bfp8_edges.add((u, w))
        elif evicted and codec not in LOSSLESS_CODECS:
            raise ValueError(f"unsupported eviction codec {codec!r} "
                             f"on edge {(u, w)}")
        else:
            # lossless codecs: numerics are identity; traffic is the raw
            # volume (codec "none") — RLE/Huffman would shrink it by a
            # data-dependent ratio the DSE only estimates, so we report
            # the conservative raw volume and flag it non-exact.
            off_bits = raw_bits
            exact = codec == "none"
            fn = lambda x: x                                    # noqa: E731
        spills.append(SpillRecord(
            src=u, dst=w, codec=codec,
            reason="evicted" if evicted else "stage_boundary",
            raw_bits=raw_bits, offchip_bits=off_bits, exact=exact))
        spill_fn[(u, w)] = fn

    streamed_bits = static_bits = 0
    frac: dict[str, float] = {}
    for name in topo:
        v = g.vertex(name)
        if v.kind not in WEIGHT_KINDS and v.kind not in TEMPORAL_KINDS:
            continue
        lp = layers.get(name)
        f = lp.weight_static_fraction if lp is not None else 1.0
        frac[name] = f
        spec = _exec_spec(g, name)
        if v.kind in TEMPORAL_KINDS:
            wbits = spec.get("taps", 3) * spec["cout"] * v.weight_bits
        else:
            wbits = spec["cin"] * spec["cout"] * v.weight_bits
        static_bits += int(round(f * wbits))
        streamed_bits += int(round((1.0 - f) * wbits))

    in_vertex = next(n for n in topo if g.vertex(n).kind == "input")
    return PlanAnalysis(
        topo=topo, out_shape=out_shape, spills=spills, spill_fn=spill_fn,
        frac=frac, stage_of=stage_of, streamed_weight_bits=streamed_bits,
        static_weight_bits=static_bits, use_pallas=use_pallas,
        interpret=interpret, in_vertex=in_vertex,
        in_shape=out_shape[in_vertex], bfp8_edges=bfp8_edges,
        tile_bm=(plan.tile_bm if plan is not None else 0),
        tile_bc=(plan.tile_bc if plan is not None else 0))


def apply_vertex(v, ins: list[jax.Array], params: dict, x: jax.Array | None,
                 analysis: PlanAnalysis) -> jax.Array:
    """Execute one vertex's semantics — the single source of truth for what
    each op kind *does*, shared by both executors.

    Under the resolved ``kernel_mode="pallas"`` the conv/matmul/deconv,
    dwconv, pool and act bodies dispatch to the ``kernels/streaming_conv``
    Pallas kernels (bit-exact vs the reference bodies, every tile size);
    fragmented weight layers keep the ``streamed_matmul`` fragmentation
    kernel, whose codec stays unfused.  Data-movement and variadic kinds
    (upsample/add/mul/concat/output) run their reference bodies in every
    mode — the registry in ``kernels/ops.py`` records which is which.
    """
    an = analysis
    if v.kind == "input":
        assert x is not None, "input vertex fed without a graph input"
        return x
    if v.kind in WEIGHT_KINDS:
        h = ins[0]
        f = an.frac.get(v.name, 1.0)
        if f < 1.0 and an.use_pallas:
            return streamed_matmul_padded(h, params[v.name],
                                          static_fraction=f,
                                          interpret=an.interpret)
        if an.use_pallas:
            return SC.conv2d(h, params[v.name], bm=an.tile_bm,
                             bc=an.tile_bc,
                             interpret=an.interpret).astype(h.dtype)
        # reference mode (or fragmented-without-pallas): plain dot
        return jnp.dot(h, params[v.name],
                       preferred_element_type=jnp.float32).astype(h.dtype)
    if v.kind in TEMPORAL_KINDS:
        # the temporal split is not streamable through the matmul kernel;
        # a fragmented dwconv streams per the plan's traffic accounting but
        # executes the full (numerically identical) temporal mix.
        if an.use_pallas:
            return SC.dwconv(ins[0], params[v.name], bm=an.tile_bm,
                             interpret=an.interpret)
        return _dwconv(ins[0], params[v.name])
    if v.kind == "act":
        if an.use_pallas:
            return SC.act_relu(ins[0], bm=an.tile_bm, interpret=an.interpret)
        return jax.nn.relu(ins[0])
    if v.kind == "pool":
        if an.use_pallas:
            return SC.pool(ins[0], an.out_shape[v.name][0], bm=an.tile_bm,
                           interpret=an.interpret)
        return _pool(ins[0], an.out_shape[v.name][0])
    if v.kind == "upsample":
        return _upsample(ins[0], an.out_shape[v.name][0])
    if v.kind == "add":
        return functools.reduce(jnp.add, ins)
    if v.kind == "mul":
        return functools.reduce(jnp.multiply, ins)
    if v.kind == "concat":
        return jnp.concatenate(ins, axis=1)
    if v.kind == "output":
        return jnp.concatenate([i.ravel() for i in ins])
    raise ValueError(f"op kind {v.kind!r} has no executable lowering")


# =============================================================================
# Kernel-level vertex lowering: Pallas bodies + fused BFP8 boundary codec
# =============================================================================

#: kinds whose Pallas body can fuse the BFP8 boundary codec (mirrors
#: kernels.ops.fusable_kinds(); kept literal here so the executor does not
#: import the jitted wrapper layer)
FUSABLE_KINDS = ("conv", "deconv", "matmul", "dwconv", "pool", "act")


@dataclasses.dataclass(frozen=True)
class VertexLowering:
    """``_lower_vertex``'s decision record for one vertex under the
    resolved kernel mode."""
    fuse_in: tuple[str, str] | None  # bfp8 in-edge decoded inside the kernel
    fuse_out: bool                   # kernel also emits the spill payload
    needs_payload: bool              # some out-edge carries a bfp8 spill


def _lower_vertex(g: Graph, name: str, an: PlanAnalysis) -> VertexLowering:
    """Decide one vertex's kernel-level lowering: in pallas mode a fusable
    kind with an un-fragmented weight fuses a *single* BFP8-evicted input
    edge (ingress dequant inside the ``pallas_call``) and/or emits its
    output's spill payload from the same call (egress quant).  Multi-input
    consumers and fragmented weight layers fall back to the standalone
    ``bfp8_spill_decode``/``bfp8_spill_encode`` dispatches."""
    v = g.vertex(name)
    needs_payload = an.use_pallas and any(
        (name, s) in an.bfp8_edges for s in g.successors(name))
    fusable = (an.use_pallas and v.kind in FUSABLE_KINDS
               and not (v.kind in WEIGHT_KINDS
                        and an.frac.get(name, 1.0) < 1.0))
    fuse_in = None
    if fusable:
        in_edges = g.in_edges(name)
        if len(in_edges) == 1 and (in_edges[0].src, name) in an.bfp8_edges:
            fuse_in = (in_edges[0].src, name)
    return VertexLowering(fuse_in=fuse_in,
                          fuse_out=fusable and needs_payload,
                          needs_payload=needs_payload)


def apply_vertex_fused(v, ins, params, x, analysis: PlanAnalysis, *,
                       payload_in=None, want_payload: bool = False):
    """``apply_vertex`` with the fused BFP8 boundary codec.

    ``payload_in`` is the (mantissa, exponent) spill payload of the
    vertex's single input edge — dequantised per block *inside* the Pallas
    kernel; ``want_payload=True`` asks the same ``pallas_call`` to also
    quantise and emit the output's spill payload.  Returns
    ``(y, payload | None)``.  Callers consult :func:`_lower_vertex` for
    legality; with neither flag this is exactly ``apply_vertex``.
    """
    an = analysis
    if payload_in is None and not want_payload:
        return apply_vertex(v, ins, params, x, an), None
    assert an.use_pallas and v.kind in FUSABLE_KINDS, (v.kind, an.use_pallas)
    xin = ins[0] if payload_in is None else None
    kw = dict(payload=payload_in, encode=want_payload, block=BFP8_BLOCK,
              bm=an.tile_bm, interpret=an.interpret)
    if v.kind in WEIGHT_KINDS:
        out = SC.conv2d(xin, params[v.name], bc=an.tile_bc, **kw)
    elif v.kind in TEMPORAL_KINDS:
        out = SC.dwconv(xin, params[v.name], **kw)
    elif v.kind == "pool":
        out = SC.pool(xin, an.out_shape[v.name][0],
                      c=an.out_shape[v.name][1], **kw)
    else:                       # act
        out = SC.act_relu(xin, c=an.out_shape[v.name][1], **kw)
    return out if want_payload else (out, None)


def run_vertices(g: Graph, an: PlanAnalysis, names: list[str], params: dict,
                 x: jax.Array | None, external, hop):
    """The one per-vertex execution loop both executors trace.

    Runs ``names`` (a topo-ordered subset of the graph) with
    payload-routed BFP8 eviction: in pallas mode the producer of a
    BFP8-evicted edge encodes the spill once (fused into its kernel when
    :func:`_lower_vertex` allows) and every consumer decodes it (fused
    likewise, else via ``bfp8_spill_decode``); in reference mode every
    spilled edge round-trips through ``spill_fn`` — numerically the same
    composition either way, which is what the kernel conformance matrix
    locks.  ``external(edge)`` resolves in-edges whose producer is outside
    ``names`` (the pipelined streamer's decoded crossing reads); pass
    ``None`` for a whole-graph run.  Returns ``(values, payloads)``.
    """
    internal = set(names)
    values: dict[str, jax.Array] = {}
    payloads: dict[str, tuple] = {}
    for name in names:
        v = g.vertex(name)
        lv = _lower_vertex(g, name, an)
        ins, payload_in = [], None
        for e in g.in_edges(name):      # predecessor order = operand order
            edge = (e.src, name)
            if e.src not in internal:
                ins.append(external(edge))
                continue
            if an.use_pallas and edge in an.bfp8_edges:
                pay = jax.tree.map(hop, payloads[e.src])
                if lv.fuse_in == edge:
                    payload_in = pay
                    ins.append(None)
                else:
                    ins.append(bfp8_spill_decode(
                        pay, an.out_shape[e.src][1], use_pallas=True,
                        interpret=an.interpret))
            else:
                val = values[e.src]
                fn = an.spill_fn.get(edge)
                if fn is not None:
                    val = hop(fn(val))
                ins.append(val)
        y, pay = apply_vertex_fused(v, ins, params, x, an,
                                    payload_in=payload_in,
                                    want_payload=lv.fuse_out)
        values[name] = y
        if lv.needs_payload:
            payloads[name] = pay if pay is not None else bfp8_spill_encode(
                y, use_pallas=True, interpret=an.interpret)
    return values, payloads


# =============================================================================
# Lowering
# =============================================================================

@dataclasses.dataclass
class LoweredPipeline:
    """A jitted executable form of one ExecutionPlan.

    ``fn(params, x)`` runs the whole streaming pipeline; ``report`` is the
    static off-chip traffic accounting the lowering derived from the plan.
    """
    fn: Callable[[dict, jax.Array], jax.Array]
    params: dict[str, jax.Array]
    report: SpillReport
    plan: ExecutionPlan | None
    graph_name: str
    # oracle entry (repro.testing): un-jitted forward that returns every
    # vertex's output, for localising where two executors diverge
    values_fn: Callable[[dict, jax.Array], dict] | None = None

    def __call__(self, x: jax.Array) -> jax.Array:
        return self.fn(self.params, x)

    def run_intermediates(self, x: jax.Array) -> dict[str, jax.Array]:
        """Every vertex's output for one frame, in topo order.

        The conformance oracles (``repro.testing.oracle``) use this to name
        the *first* vertex where a plan's numerics leave the reference —
        far more actionable than "final outputs differ".  Un-jitted: this
        is a debugging path, not an execution path.
        """
        if self.values_fn is None:
            raise NotImplementedError("this pipeline was lowered without "
                                      "intermediate capture")
        return self.values_fn(self.params, x)

    def run_traced(self, x: jax.Array, recorder=None) -> jax.Array:
        """Run one frame, recording a ``frame`` span plus spill counters.

        The sequential executor has no tick structure, so the telemetry is
        one host-side wall-clock span per frame and one
        ``emit_spill_counters`` round-trip per :class:`SpillRecord` (every
        evicted edge crosses off-chip exactly once per frame here).  With
        ``recorder=None`` this is exactly ``self(x)``.
        """
        from ..obs.stream import emit_spill_counters
        from ..obs.trace import NULL_RECORDER

        rec = NULL_RECORDER if recorder is None else recorder
        with rec.span("frame", track="host",
                      args={"graph": self.graph_name}):
            y = self.fn(self.params, x)
            jax.block_until_ready(y)
        ts = rec.now()
        for r in self.report.spills:
            emit_spill_counters(rec, r, ts=ts)
        return y


def resolve_kernel_mode(kernel_mode: str,
                        interpret: bool | None) -> tuple[bool, bool]:
    """Kernel-dispatch policy shared by both executors: returns
    (use_pallas, interpret) for a requested mode on the current backend."""
    if kernel_mode not in ("auto", "pallas", "reference"):
        raise ValueError(f"unknown kernel_mode {kernel_mode!r}")
    on_tpu = jax.default_backend() == "tpu"
    use_pallas = kernel_mode == "pallas" or (kernel_mode == "auto" and on_tpu)
    if interpret is None:
        interpret = not on_tpu
    return use_pallas, interpret


def _make_offchip_hop() -> Callable[[jax.Array], jax.Array]:
    """Best-effort real off-chip placement: route the value through host
    memory when the backend exposes a host memory kind (TPU); identity
    elsewhere.  Called once at lowering time, not per trace."""
    try:
        from jax._src.sharding_impls import TransferToMemoryKind
        kinds = {m.kind for m in jax.devices()[0].addressable_memories()}
        if "pinned_host" in kinds and jax.default_backend() == "tpu":
            def hop(x: jax.Array) -> jax.Array:
                y = jax.device_put(x, TransferToMemoryKind("pinned_host"))
                return jax.device_put(y, TransferToMemoryKind("device"))
            return hop
    except Exception:       # pragma: no cover - jax-internal API moved
        pass
    return lambda x: x


def lower_plan(g: Graph, plan: ExecutionPlan | None = None, *,
               kernel_mode: str = "auto", seed: int = 0,
               interpret: bool | None = None) -> LoweredPipeline:
    """Lower ``plan`` over executable graph ``g`` to a jitted pipeline.

    plan=None lowers the dense reference: no eviction, no fragmentation,
    one stage — the numerical baseline every plan must match (lossless
    codecs) or approximate (BFP8).

    This is the low-level entry; the documented path is the compile façade
    (``repro.compile(CompileSpec(mode="staged"))``), which produces
    bit-identical executors and adds search, serving, and persistence.

    kernel_mode: "pallas" dispatches conv/dwconv/pool/act to the
    ``kernels/streaming_conv`` row-block kernels (with the BFP8 boundary
    codec fused at evicted edges), fragmented matmuls to
    ``streamed_matmul``, and the standalone codec to the bfp8 stripe
    kernels (interpret-mode off TPU); "reference" uses the pure-jnp
    oracles, "auto" picks pallas on TPU and reference elsewhere.  The two
    modes are bit-exact against each other (tests/test_kernels.py).
    """
    use_pallas, interpret = resolve_kernel_mode(kernel_mode, interpret)
    hop = _make_offchip_hop()
    an = analyze_plan(g, plan, use_pallas=use_pallas, interpret=interpret)

    # -- build the traced pipeline -------------------------------------------
    def forward_values(params: dict, x: jax.Array) -> dict[str, jax.Array]:
        if tuple(x.shape) != an.in_shape:
            # every op downstream is shape-agnostic on the position axis, so
            # a wrong-m input would execute silently while the SpillReport
            # described the declared shapes — refuse at trace time instead
            raise ValueError(
                f"input shape {tuple(x.shape)} does not match the graph's "
                f"input spec {an.in_shape} for {g.name!r}")
        values, _ = run_vertices(g, an, an.topo, params, x, None, hop)
        return values

    def forward(params: dict, x: jax.Array) -> jax.Array:
        return forward_values(params, x)[an.topo[-1]]

    return LoweredPipeline(fn=jax.jit(forward),
                           params=init_params(g, seed=seed),
                           report=an.report(), plan=plan, graph_name=g.name,
                           values_fn=forward_values)


def reference_pipeline(g: Graph, *, seed: int = 0) -> LoweredPipeline:
    """The dense, un-evicted, un-fragmented baseline pipeline."""
    return lower_plan(g, None, kernel_mode="reference", seed=seed)
