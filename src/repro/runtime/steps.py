"""Jitted train / prefill / decode step builders.

These are the units the dry-run lowers and the launcher executes.  All of
them take parameters (and caches) as explicit pytree arguments with
NamedShardings, so ``.lower()`` works on pure ShapeDtypeStructs — nothing is
allocated for the 40-cell x 2-mesh dry-run.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.models import (decode_step as _decode, forward, init_cache,
                          init_params, lm_loss, project_logits)
from repro.models.config import ArchConfig
from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state
from . import sharding as SH
from .hints import activation_hints


def _with_hints(fn, mesh):
    """Trace ``fn`` under activation-sharding hints (§Perf/H1)."""
    dp = SH.dp_axes(mesh)
    dp = dp[0] if len(dp) == 1 else dp

    @functools.wraps(fn)
    def wrapped(*args, **kw):
        with activation_hints(mesh, dp, "model"):
            return fn(*args, **kw)
    return wrapped


# -- abstract shapes (no allocation) -------------------------------------------

def abstract_params(cfg: ArchConfig, dtype=jnp.bfloat16):
    return jax.eval_shape(
        lambda k: init_params(k, cfg, dtype=dtype), jax.random.PRNGKey(0))


def abstract_opt_state(cfg: ArchConfig, opt_cfg: AdamWConfig,
                       dtype=jnp.bfloat16):
    p = abstract_params(cfg, dtype)
    return jax.eval_shape(lambda q: init_opt_state(q, opt_cfg), p)


def abstract_cache(cfg: ArchConfig, batch: int, s_max: int,
                   dtype=jnp.bfloat16):
    return jax.eval_shape(
        functools.partial(init_cache, cfg, batch, s_max, dtype=dtype))


# -- step functions -------------------------------------------------------------

def auto_microbatches(batch: int, mesh: Mesh, rows_per_device: int = 1) -> int:
    """Accumulation depth that keeps ~rows_per_device sequences live per
    device (bounds activation temps; the optimizer update stays one step)."""
    dp = SH.dp_size(mesh)
    mb = max(1, batch // (dp * rows_per_device))
    while batch % mb:
        mb -= 1
    return mb


def make_train_step(cfg: ArchConfig, mesh: Mesh, opt_cfg: AdamWConfig,
                    remat: str = "full", dtype=jnp.bfloat16,
                    microbatches: int | None = None, batch_size: int = 0):
    """Returns (jitted_fn, in_shardings, donate) for
    fn(params, opt_state, batch) -> (params, opt_state, metrics).

    Gradient accumulation: the global batch is split into ``microbatches``
    slices scanned sequentially; activation memory scales with the slice
    while the parameter update sees the full batch."""

    def loss_fn(p, mb):
        return lm_loss(p, cfg, mb["tokens"], mb["labels"],
                       enc_frames=mb.get("enc_frames"),
                       patch_embeds=mb.get("patch_embeds"),
                       remat=remat)

    # fp32 accumulation by default; bf16 when the optimizer states are
    # already int8-quantised (grok-class models, where the fp32 accumulator
    # alone is ~5 GB/device) — the same precision class as compressed
    # cross-pod gradient exchange.
    acc_dtype = jnp.bfloat16 if opt_cfg.quantize_states else jnp.float32
    grad_sh = SH.param_shardings(cfg, abstract_params(cfg, dtype), mesh)

    def step(params, opt_state, batch):
        B = batch["tokens"].shape[0]
        mbs = microbatches or auto_microbatches(B, mesh)
        if mbs <= 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            split = jax.tree.map(
                lambda x: x.reshape(mbs, B // mbs, *x.shape[1:]), batch)

            def mb_body(acc, mb):
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                acc = jax.tree.map(
                    lambda a, b: a + b.astype(acc_dtype), acc, g)
                # pin the accumulator to the parameter layout: FSDP grads
                # then reduce-SCATTER per microbatch instead of all-reduce
                # (§Perf/H4 — 1/dp the bytes on the data axis)
                acc = jax.tree.map(
                    lambda a, s: jax.lax.with_sharding_constraint(a, s),
                    acc, grad_sh)
                return acc, l

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dtype), params)
            grads, losses = jax.lax.scan(mb_body, zeros, split)
            grads = jax.tree.map(lambda g: g / mbs, grads)
            loss = losses.mean()
        new_p, new_opt, metrics = adamw_update(params, grads, opt_state, opt_cfg)
        metrics["loss"] = loss
        return new_p, new_opt, metrics

    step = _with_hints(step, mesh)
    p_sh = SH.param_shardings(cfg, abstract_params(cfg, dtype), mesh)
    o_sh = SH.opt_state_shardings(
        cfg, abstract_opt_state(cfg, opt_cfg, dtype), mesh)
    return step, (p_sh, o_sh), (0, 1)


def make_prefill_step(cfg: ArchConfig, mesh: Mesh, batch: int, seq: int,
                      dtype=jnp.bfloat16):
    """fn(params, cache, batch) -> (last_logits, cache)."""

    def step(params, cache, batch_in):
        x, new_cache, _ = forward(
            params, cfg, batch_in["tokens"], cache=cache,
            enc_frames=batch_in.get("enc_frames"),
            patch_embeds=batch_in.get("patch_embeds"))
        logits = project_logits(params, cfg, x[:, -1])
        return logits, new_cache

    step = _with_hints(step, mesh)
    p_sh = SH.param_shardings(cfg, abstract_params(cfg, dtype), mesh)
    c_sh = SH.cache_shardings(cfg, batch, mesh,
                              abstract_cache(cfg, batch, seq, dtype))
    return step, (p_sh, c_sh), (1,)


def make_decode_step(cfg: ArchConfig, mesh: Mesh, batch: int, s_max: int,
                     dtype=jnp.bfloat16):
    """fn(params, cache, token, pos) -> (logits, cache).  One new token
    against a KV/state cache of length s_max (the ``decode_*`` shapes)."""

    def step(params, cache, token, pos):
        return _decode(params, cfg, token, pos, cache)

    step = _with_hints(step, mesh)
    p_sh = SH.param_shardings(cfg, abstract_params(cfg, dtype), mesh)
    c_sh = SH.cache_shardings(cfg, batch, mesh,
                              abstract_cache(cfg, batch, s_max, dtype))
    return step, (p_sh, c_sh), (1,)


# -- input specs (the dry-run contract) ------------------------------------------

def input_specs(cfg: ArchConfig, shape, mesh: Mesh, *,
                opt_cfg: AdamWConfig | None = None,
                dtype=jnp.bfloat16) -> dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every input of the step that ``shape``
    lowers (train_step for ``train``, prefill/decode otherwise) — weak-type
    correct, sharded, no device allocation."""
    B, S = shape.global_batch, shape.seq_len
    bsh = SH.batch_shardings(cfg, B, mesh)
    i32 = jnp.int32

    def tok(b, s, sh):
        return jax.ShapeDtypeStruct((b, s), i32, sharding=sh)

    p_abs = abstract_params(cfg, dtype)
    p_sh = SH.param_shardings(cfg, p_abs, mesh)
    params = jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
        p_abs, p_sh)

    extras = {}
    if cfg.is_encdec:
        extras["enc_frames"] = jax.ShapeDtypeStruct(
            (B, cfg.enc_frames, cfg.d_model), dtype,
            sharding=bsh["enc_frames"])
    if cfg.vlm_patches and shape.kind != "decode":
        extras["patch_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.vlm_patches, cfg.d_model), dtype,
            sharding=bsh["patch_embeds"])

    if shape.kind == "train":
        o_abs = abstract_opt_state(cfg, opt_cfg or AdamWConfig(), dtype)
        o_sh = SH.opt_state_shardings(cfg, o_abs, mesh)
        opt = jax.tree.map(
            lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
            o_abs, o_sh)
        batch = {"tokens": tok(B, S, bsh["tokens"]),
                 "labels": tok(B, S, bsh["labels"]), **extras}
        return {"params": params, "opt_state": opt, "batch": batch}

    c_abs = abstract_cache(cfg, B, S, dtype)
    c_sh = SH.cache_shardings(cfg, B, mesh, c_abs)
    cache = jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
        c_abs, c_sh)

    if shape.kind == "prefill":
        batch = {"tokens": tok(B, S, bsh["tokens"]), **extras}
        return {"params": params, "cache": cache, "batch": batch}

    # decode: one new token with a cache of length S
    return {"params": params, "cache": cache,
            "token": tok(B, 1, bsh["tokens"]),
            "pos": jax.ShapeDtypeStruct((B,), i32, sharding=bsh["pos"])}
