"""Activation-sharding hints: mesh-aware constraints without mesh-aware
models.

The model zoo stays pure (no mesh imports); the step builders activate a
context during tracing, and layer code calls :func:`constrain` at the
points where GSPMD propagation is known to fail (q/k/v head axes through
the RoPE reshape chain, MoE expert/hidden axes).  Outside the context
``constrain`` is the identity, so smoke tests and the serving engine run
unchanged on one device.

This module exists because of a §Perf finding: without the head-axis
constraint, GSPMD replicates all attention computation across the entire
``model`` axis (16x flops on 32k prefill) — see EXPERIMENTS.md §Perf/H1.
"""
from __future__ import annotations

import contextlib
import contextvars

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_HINTS: contextvars.ContextVar = contextvars.ContextVar(
    "smof_sharding_hints", default=None)


@contextlib.contextmanager
def activation_hints(mesh, dp_axes, tp_axis: str = "model"):
    token = _HINTS.set({"mesh": mesh, "dp": dp_axes, "tp": tp_axis})
    try:
        yield
    finally:
        _HINTS.reset(token)


def active() -> bool:
    return _HINTS.get() is not None


def axis_size(kind: str) -> int:
    """Mesh extent of the "dp"/"tp" hint axes (1 when no context)."""
    h = _HINTS.get()
    if h is None:
        return 1
    axes = h[kind] if kind in ("dp", "tp") else None
    if axes is None:
        return 1
    tup = axes if isinstance(axes, tuple) else (axes,)
    total = 1
    for a in tup:
        total *= h["mesh"].shape.get(a, 1)
    return total


def constrain(x: jax.Array, *spec) -> jax.Array:
    """Apply a sharding constraint; spec entries: "dp" | "tp" | None.

    Divisibility-guarded: any axis that does not divide by its mesh axes is
    left unsharded instead of failing.
    """
    h = _HINTS.get()
    if h is None:
        return x
    mesh = h["mesh"]
    names = {"dp": h["dp"], "tp": h["tp"]}
    out = []
    for dim, s in zip(x.shape, spec):
        axes = names.get(s) if isinstance(s, str) else None
        if axes is None:
            out.append(None)
            continue
        tup = axes if isinstance(axes, tuple) else (axes,)
        total = 1
        for a in tup:
            total *= mesh.shape.get(a, 1)
        out.append(axes if total > 1 and dim % total == 0 else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*out)))
