"""The jitted pipelined executor: stages overlap over a microbatch stream.

``lower_plan_pipelined`` consumes the same ``core.plan.ExecutionPlan`` (and
the same per-vertex lowering, via ``runtime.executor.analyze_plan`` /
``apply_vertex``) as the sequential executor, but runs the plan's stages as
a software 1F1B pipeline over ``B`` microbatches:

* **single device** — one ``jax.lax.scan`` over ``T = B + S - 1`` ticks.
  The carry holds, per stage-crossing edge, a shift register of the
  *encoded* spill (BFP8 mantissas + shared exponents for ``bfp8`` streams,
  raw words otherwise): stage ``i`` pushes microbatch ``b``'s encoded spill
  while stage ``i+1`` decodes microbatch ``b-1`` from the other end — the
  paper's two DMA-burst FIFOs as a scan carry.  Every stage reads the
  previous tick's carry, so within a tick all stages are data-independent
  (XLA can fuse/overlap them) and the spill round-trip is off the critical
  path of its own microbatch.

* **devices >= stages** — a ``shard_map`` ring pipeline: each device owns
  one stage, crossing edges live in per-device transit slots that
  ``ppermute`` one hop per tick, so a spill produced on stage ``i`` arrives
  at stage ``k`` exactly ``k - i`` ticks later while both devices compute.

Numerics are identical to the sequential executor per microbatch: the same
codec functions run in the same composition (pad -> quantise -> dequantise
-> slice), only *when* they run changes.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Callable

import jax
import jax.numpy as jnp

from ...core.graph import Graph
from ...core.plan import ExecutionPlan, PlanValidationError
from ...core.resources import ALL_DEVICES
from ...kernels.streamed_matmul import _round_up
from ...memory import ChannelConfig, MemoryModel, build_memory_model
from ...obs.modelcheck import ModelCheck, check_stream
from ...obs.stream import StreamTracer
from ...obs.trace import NULL_RECORDER
from ..executor import (BFP8_BLOCK, TEMPORAL_KINDS, PlanAnalysis, SpillReport,
                        _exec_spec, _make_offchip_hop, analyze_plan,
                        bfp8_spill_decode, bfp8_spill_encode, init_params,
                        resolve_kernel_mode, run_vertices)
from . import queues as Q
from . import schedule as SCH


# =============================================================================
# StreamReport
# =============================================================================

@dataclasses.dataclass
class StreamReport(SpillReport):
    """SpillReport plus the pipeline's schedule/occupancy accounting.

    The spill records (and therefore all bit volumes) are the *same objects*
    the sequential executor would report for this plan — per microbatch,
    bit-exact — with the pipeline view stacked on top: per-stage occupancy
    and stall (bubble) counts, per-queue high-water marks, and the Eq. 5 vs
    Eq. 6 frame-time estimates from the stage latency model, so benchmarks
    can show which stage sets ``max_j(L_j)``.
    """
    n_stages: int = 1
    microbatches: int = 1
    ticks: int = 1
    placement: str = "interleave"
    stage_occupancy: list[float] = dataclasses.field(default_factory=list)
    stage_stalls: list[int] = dataclasses.field(default_factory=list)
    stage_latency: list[float] = dataclasses.field(default_factory=list)
    queue_stats: dict = dataclasses.field(default_factory=dict)
    #: the off-chip channel view (``repro.memory``) when the plan was
    #: lowered with a :class:`~repro.memory.ChannelConfig`; ``None`` keeps
    #: every contended property degrading to its uncontended twin.
    memory: MemoryModel | None = None

    @property
    def eq5_time(self) -> float:
        """Sequential frame time: sum of stage latencies (Eq. 5)."""
        return SCH.eq5_sequential_time(self.stage_latency)

    @property
    def eq6_time(self) -> float:
        """Pipelined steady-state frame time: slowest stage (Eq. 6)."""
        return SCH.eq6_pipeline_time(self.stage_latency)

    @property
    def bottleneck_stage(self) -> int:
        return max(range(len(self.stage_latency)),
                   key=lambda j: self.stage_latency[j])

    # -- contended (channel-arbitrated) views --------------------------------
    @property
    def stage_latency_contended(self) -> list[float]:
        """``max(L_j, X_j)`` per stage; ``stage_latency`` without a model."""
        if self.memory is None:
            return list(self.stage_latency)
        return list(self.memory.contended_latencies)

    @property
    def eq5_contended_time(self) -> float:
        return SCH.eq5_sequential_time(self.stage_latency_contended)

    @property
    def eq6_contended_time(self) -> float:
        return SCH.eq6_pipeline_time(self.stage_latency_contended)

    @property
    def contention_stall_cycles(self) -> list[float]:
        """Per-stage channel-stall cycles per frame (empty: no model)."""
        return [] if self.memory is None else list(self.memory.stall_cycles)

    @property
    def prefetch_deadline_misses(self) -> int:
        return 0 if self.memory is None else self.memory.prefetch.deadline_misses

    @property
    def channel_policy(self) -> str | None:
        return None if self.memory is None else self.memory.config.policy

    def summary(self) -> dict:
        out = super().summary()
        out.update({
            "n_stages": self.n_stages,
            "microbatches": self.microbatches,
            "ticks": self.ticks,
            "placement": self.placement,
            "stage_occupancy": self.stage_occupancy,
            "stage_stalls": self.stage_stalls,
            "eq5_time": self.eq5_time,
            "eq6_time": self.eq6_time,
            "bottleneck_stage": self.bottleneck_stage,
        })
        if self.memory is not None:
            out.update({
                "channel_policy": self.channel_policy,
                "eq5_contended_time": self.eq5_contended_time,
                "eq6_contended_time": self.eq6_contended_time,
                "contention_stall_cycles": self.contention_stall_cycles,
                "prefetch_deadline_misses": self.prefetch_deadline_misses,
                "memory": self.memory.summary(),
            })
        return out


# =============================================================================
# Encoded carry codecs (the queue payload)
# =============================================================================

def _codec_pair(codec: str, shape: tuple[int, int], *, use_pallas: bool,
                interpret: bool, dtype=jnp.float32):
    """(encode, decode, zero_template) for one crossing edge's payload.

    ``bfp8``: the carry holds the actual spill buffers (int8 mantissas +
    per-block int8 shared exponents), built from the *same* encode/decode
    halves the sequential executor composes into ``_bfp8_roundtrip`` — the
    two executors' codec numerics are one implementation.  Everything else
    carries raw words (lossless codecs shrink bits, not numbers).
    """
    m, c = shape
    if codec == "bfp8":
        enc = functools.partial(bfp8_spill_encode, use_pallas=use_pallas,
                                interpret=interpret)
        dec = functools.partial(bfp8_spill_decode, c=c, use_pallas=use_pallas,
                                interpret=interpret, dtype=dtype)
        c_pad = _round_up(c, BFP8_BLOCK)
        zero = (jnp.zeros((m, c_pad), jnp.int8),
                jnp.zeros((m, c_pad // BFP8_BLOCK), jnp.int8))
        return enc, dec, zero
    return (lambda x: x), (lambda p: p), jnp.zeros((m, c), dtype)


# =============================================================================
# Stage splitting
# =============================================================================

def _stage_names(an: PlanAnalysis) -> list[list[str]]:
    """Vertices per stage, in graph topological order (the deterministic
    schedule the streamer needs — plan.stage_layers agrees when the plan
    carries its topo_order)."""
    n = an.n_stages
    names: list[list[str]] = [[] for _ in range(n)]
    for v in an.topo:
        names[an.stage_of[v]].append(v)
    for j, ns in enumerate(names):
        if not ns:
            raise PlanValidationError(
                f"stage {j} is empty — plan stages must be "
                f"contiguous 0..{n - 1}")
    return names


def _crossing_edges(g: Graph, an: PlanAnalysis) -> list[tuple[str, str]]:
    out = []
    for e in g.edges():
        d = an.stage_of[e.dst] - an.stage_of[e.src]
        if d < 0:
            raise PlanValidationError(
                f"edge {(e.src, e.dst)} goes backwards across "
                f"stages ({an.stage_of[e.src]} -> "
                f"{an.stage_of[e.dst]})")
        if d > 0:
            out.append((e.src, e.dst))
    return out


def _make_stage_fns(g: Graph, an: PlanAnalysis, names: list[list[str]],
                    crossing: list[tuple[str, str]], hop, enc):
    """Per-stage callables with a uniform signature.

    ``fn_j(params, x, reads) -> (produced, y)`` where ``reads`` maps every
    crossing edge to its decoded value (stage ``j`` only touches the ones it
    consumes), ``produced`` maps every crossing edge to an encoded payload
    (zeros template for edges other stages produce — uniform pytrees keep
    ``lax.switch`` branches legal), and ``y`` is the graph output (zeros
    except on the last stage).
    """
    S = an.n_stages
    out_vertex = an.topo[-1]
    out_len = sum(an.out_shape[e.src][0] * an.out_shape[e.src][1]
                  for e in g.in_edges(out_vertex))
    produced_by = {e: an.stage_of[e[0]] for e in crossing}

    def make(j: int):
        mine = set(names[j])

        def fn(params, x, reads):
            # the same payload-routed vertex loop the sequential executor
            # traces (fused BFP8 codec in pallas mode, spill_fn round-trips
            # in reference mode); crossing reads arrive pre-decoded
            values, payloads = run_vertices(
                g, an, names[j], params, x, lambda edge: reads[edge], hop)
            produced = {}
            for e in crossing:
                if produced_by[e] == j:
                    # a pallas-mode producer already emitted this edge's
                    # spill payload (fused egress where _lower_vertex
                    # allowed) — bitwise what enc[e] would compute
                    pay = payloads.get(e[0]) if e in an.bfp8_edges else None
                    if pay is None:
                        pay = enc[e](values[e[0]])
                    produced[e] = jax.tree.map(hop, pay)
                else:
                    produced[e] = None       # filled with zeros by caller
            y = (values[out_vertex] if out_vertex in mine
                 else jnp.zeros((out_len,), jnp.float32))
            return produced, y
        return fn

    return [make(j) for j in range(S)], out_len


# =============================================================================
# Lowered streaming pipeline
# =============================================================================

@dataclasses.dataclass
class StreamingExecutor:
    """A jitted pipelined form of one ExecutionPlan.

    ``fn(params, xs)`` maps a ``(B, m, c)`` microbatch stream to ``(B, L)``
    outputs, bit-for-bit the outputs of running the sequential executor on
    each microbatch independently (modulo nothing: the same codecs run in
    the same composition).  ``stage_fns`` are the individually-jitted
    per-stage callables — the sequential decomposition the pipeline
    overlaps — used by :func:`measured_stage_latencies`.
    """
    fn: Callable[[dict, jax.Array], jax.Array]
    params: dict[str, jax.Array]
    report: StreamReport
    plan: ExecutionPlan | None
    graph_name: str
    n_stages: int
    microbatches: int
    placement: str
    stage_fns: list[Callable]
    _zero_reads: Callable[[], dict]
    _decoders: dict
    _crossing: list[tuple[str, str]]
    schedule: SCH.PipelineSchedule | None = None
    _tick_fn: Callable | None = None
    _carry0: Callable[[], dict] | None = None
    _queue_specs: dict = dataclasses.field(default_factory=dict)
    _stage_of: dict = dataclasses.field(default_factory=dict)
    _stream_shape: tuple = ()

    def __call__(self, xs: jax.Array) -> jax.Array:
        return self.fn(self.params, xs)

    def zero_reads(self) -> dict:
        """A zeros-filled decoded-reads template (for driving stage_fns)."""
        return self._zero_reads()

    def run_traced(self, xs: jax.Array, recorder=NULL_RECORDER, *,
                   measure_stages: bool = True, repeats: int = 3,
                   warmup: int = 1,
                   metrics=None) -> tuple[jax.Array, ModelCheck]:
        """Run the pipeline tick-by-tick, narrating each tick into a trace.

        Same jitted tick body as the fused ``lax.scan`` — the only change
        is *when* host control returns, so outputs are bit-for-bit ``fn``'s
        (asserted by the no-op parity test).  Per tick the host records the
        wall-clock interval, the :class:`~repro.obs.StreamTracer` emits the
        tick/stage spans and walks the bounded queues, and the spill
        counters account each crossing's off-chip bytes.  Returns the
        ``(B, L)`` outputs plus a :class:`~repro.obs.ModelCheck` comparing
        the walk (and, with ``measure_stages``, per-stage wall clock via
        :func:`measured_stage_latencies`) against Eq. 5/6 and Eq. 1.

        Instrumentation is host-side only, at tick boundaries: with the
        default ``NULL_RECORDER`` every hook is a no-op and the jitted
        computation is untouched.  With a ``metrics``
        :class:`~repro.obs.metrics.MetricsRegistry`, the run additionally
        feeds the scrape surface: per-phase ``smof_stream_ticks_total``,
        ``smof_stream_frames_total``, per-edge queue occupancy/stall
        metrics (via the rings) and ``smof_spill_bytes_total``.
        """
        import time

        if self._tick_fn is None:
            raise NotImplementedError(
                f"traced execution requires 'interleave' placement, "
                f"this executor is {self.placement!r}")
        if tuple(xs.shape) != self._stream_shape:
            raise ValueError(
                f"microbatch stream shape {tuple(xs.shape)} does not match "
                f"the lowered {self._stream_shape} for {self.graph_name!r}")
        sched = self.schedule
        queues = Q.build_queues(self._queue_specs, recorder, metrics)
        tracer = StreamTracer(recorder, sched, queues=queues,
                              stage_of=self._stage_of,
                              spill_records=self.report.spills)
        # compile warmup on a throwaway carry so tick 0's span measures the
        # tick, not XLA compilation
        warm = self._tick_fn(self.params, self._carry0(),
                             jnp.asarray(0, jnp.int32), xs)
        jax.block_until_ready(warm)

        mem = self.report.memory
        stalls = mem.stall_cycles if mem is not None else []
        carry = self._carry0()
        ys = []
        steady_durs: list[float] = []
        for t in range(sched.ticks):
            ts = recorder.now()
            t0 = time.perf_counter()
            carry, y = self._tick_fn(self.params, carry,
                                     jnp.asarray(t, jnp.int32), xs)
            jax.block_until_ready(y)
            jax.block_until_ready(carry)
            dur = time.perf_counter() - t0
            ys.append(y)
            tracer.tick(t, ts=ts, dur=dur)
            if sched.phase(t) == "steady":
                steady_durs.append(dur)
            # narrate where the channel model says compute waits on the
            # shared port this tick (stall > 0 for an active stage)
            for j in sched.active_stages(t):
                if j < len(stalls) and stalls[j] > 0:
                    recorder.instant(f"contention:stage{j}", ts,
                                     track=f"stage{j}")
        acct = tracer.finish()
        if metrics is not None:
            self._record_metrics(metrics, acct)

        stage_s = None
        if measure_stages:
            stage_s = measured_stage_latencies(
                self, xs[0], repeats=repeats, warmup=warmup)
        steady_s = None
        if steady_durs:
            steady_durs.sort()
            steady_s = steady_durs[len(steady_durs) // 2]
        mc = check_stream(self.report, stage_seconds=stage_s,
                          queue_stats=acct["queues"],
                          ticks_measured=acct["ticks_run"],
                          steady_measured=acct["phase_ticks"]["steady"],
                          steady_tick_seconds=steady_s)
        return jnp.stack(ys)[self.n_stages - 1:], mc

    def _record_metrics(self, metrics, acct: dict) -> None:
        """Feed one traced run's accounting into a MetricsRegistry.

        Queue occupancy/stall metrics update live inside the rings (they
        were built with the registry); what is left to record at run end
        are tick counts and the per-edge off-chip spill volume — each
        spill record moves ``offchip_bits`` once per microbatch, the same
        totals ``StreamTracer`` accumulates on the recorder.
        """
        ticks = metrics.counter(
            "smof_stream_ticks_total",
            "pipeline ticks walked, by 1F1B phase", ("phase",))
        for phase, n in acct["phase_ticks"].items():
            if n:
                ticks.labels(phase=phase).inc(n)
        metrics.counter(
            "smof_stream_frames_total",
            "microbatch frames retired by the pipelined streamer",
        ).inc(self.microbatches)
        spill = metrics.counter(
            "smof_spill_bytes_total",
            "off-chip spill traffic in bytes, by edge and direction",
            ("edge", "direction"))
        for r in self.report.spills:
            nbytes = (r.offchip_bits // 8) * self.microbatches
            if nbytes:
                edge = f"{r.src}->{r.dst}"
                spill.labels(edge=edge, direction="evict").inc(nbytes)
                spill.labels(edge=edge, direction="restore").inc(nbytes)
        mem = self.report.memory
        if mem is not None:
            stall = metrics.counter(
                "smof_contention_stall_cycles_total",
                "model cycles compute stalls on the shared off-chip "
                "channel, by stage", ("stage",))
            for j, c in enumerate(mem.stall_cycles):
                # one frame's stall per microbatch the stage processed
                if c > 0 and math.isfinite(c):
                    stall.labels(stage=str(j)).inc(c * self.microbatches)
            misses = mem.prefetch.deadline_misses
            if misses:
                metrics.counter(
                    "smof_prefetch_deadline_misses_total",
                    "weight prefetch slots that missed their stage-start "
                    "deadline").inc(misses)


def stage_weight_bits(g: Graph, an: PlanAnalysis) -> dict[int, int]:
    """Streamed weight bits per stage, mirroring ``analyze_plan``'s
    per-layer rounding exactly so the per-stage sums equal
    ``streamed_weight_bits`` bit-for-bit (the channel model's byte
    conservation depends on it)."""
    out = {j: 0 for j in range(an.n_stages)}
    for name, f in an.frac.items():
        v = g.vertex(name)
        spec = _exec_spec(g, name)
        if v.kind in TEMPORAL_KINDS:
            wbits = spec.get("taps", 3) * spec["cout"] * v.weight_bits
        else:
            wbits = spec["cin"] * spec["cout"] * v.weight_bits
        out[an.stage_of[name]] += int(round((1.0 - f) * wbits))
    return out


def _resolve_channel_device(channel: ChannelConfig,
                            device, plan: ExecutionPlan
                            ) -> tuple[float, float] | None:
    """(gbps, freq_mhz) for the channel model, or ``None`` when nothing
    prices the port.  Resolution order: the config's explicit override,
    then the ``device`` argument (a registry name or a ``Device``-like
    object), then the plan's recorded device name."""
    dev = None
    if isinstance(device, str):
        dev = ALL_DEVICES.get(device)
    elif device is not None:
        dev = device
    if dev is None:
        dev = ALL_DEVICES.get(plan.device)
    if dev is not None:
        gbps = channel.gbps if channel.gbps is not None else dev.offchip_gbps
        return gbps, dev.freq_mhz
    if channel.gbps is not None:
        return channel.gbps, 200.0      # Device's default clock
    return None


def lower_plan_pipelined(g: Graph, plan: ExecutionPlan, *,
                         microbatches: int | None = None,
                         kernel_mode: str = "auto", seed: int = 0,
                         interpret: bool | None = None,
                         placement: str = "auto",
                         channel: ChannelConfig | None = None,
                         device=None) -> StreamingExecutor:
    """Lower ``plan`` over ``g`` to a pipelined multi-microbatch executor.

    microbatches: length ``B`` of the input stream the jitted step is traced
    for (defaults to ``plan.microbatch``, floored at 1).
    placement: "interleave" (single-device scan), "shard_map" (one stage per
    device), or "auto" (shard_map when ``devices >= stages > 1``).
    channel: opt-in off-chip channel model (``repro.memory``): the plan's
    streams are arbitrated over the shared port, queue capacities absorb
    the arbiter-derived crossing delays, and the report carries the
    contended Eq. 5/6 bounds plus the prefetch deadline accounting.
    device: registry name or ``Device`` pricing the channel (defaults to
    ``plan.device``); without a resolvable device *and* no explicit gbps
    override the channel model is skipped.
    """
    use_pallas, interpret = resolve_kernel_mode(kernel_mode, interpret)
    B = int(microbatches if microbatches is not None
            else max(plan.microbatch, 1))
    if B < 1:
        raise ValueError(f"need >= 1 microbatch, got {B}")

    an = analyze_plan(g, plan, use_pallas=use_pallas, interpret=interpret)
    S = an.n_stages
    names = _stage_names(an)
    crossing = _crossing_edges(g, an)
    sched = SCH.build_schedule(S, B)
    hop = _make_offchip_hop()

    if placement not in ("auto", "interleave", "shard_map"):
        raise ValueError(f"unknown placement {placement!r}")
    if placement == "auto":
        placement = ("shard_map" if S > 1 and len(jax.devices()) >= S
                     else "interleave")
    if placement == "shard_map" and len(jax.devices()) < S:
        raise ValueError(f"shard_map placement needs >= {S} devices, "
                         f"have {len(jax.devices())}")

    stream_map = {(s.src, s.dst): s for s in plan.streams}
    codec_of = {e: (stream_map[e].codec
                    if e in stream_map and stream_map[e].evicted else "none")
                for e in crossing}
    enc: dict = {}
    dec: dict = {}
    zeros: dict = {}
    for e in crossing:
        enc[e], dec[e], zeros[e] = _codec_pair(
            codec_of[e], an.out_shape[e[0]], use_pallas=use_pallas,
            interpret=interpret)

    stage_fns, out_len = _make_stage_fns(g, an, names, crossing, hop, enc)
    delay = {e: an.stage_of[e[1]] - an.stage_of[e[0]] for e in crossing}

    def fill_zeros(produced: dict) -> dict:
        return {e: (zeros[e] if produced[e] is None else produced[e])
                for e in crossing}

    # -- single-device interleave: lax.scan over the tick axis ---------------
    # tick_body is shared between the fused scan (build_interleave) and the
    # per-tick traced loop (StreamingExecutor.run_traced): one definition,
    # so the traced run cannot drift numerically from the fast path.
    def make_carry0() -> dict:
        return {e: jax.tree.map(
            lambda z, d=delay[e]: jnp.zeros((d,) + z.shape, z.dtype),
            zeros[e]) for e in crossing}

    def tick_body(params, carry, t, xs):
        x_t = jax.lax.dynamic_index_in_dim(
            xs, jnp.clip(t, 0, B - 1), axis=0, keepdims=False)
        reads = {e: dec[e](jax.tree.map(lambda b: b[-1], carry[e]))
                 for e in crossing}
        produced: dict = {}
        y = jnp.zeros((out_len,), jnp.float32)
        for j in range(S):
            prod_j, y_j = stage_fns[j](params,
                                       x_t if j == 0 else None, reads)
            for e in crossing:
                if prod_j[e] is not None:
                    produced[e] = prod_j[e]
            if j == S - 1:
                y = y_j
        new_carry = {
            e: jax.tree.map(
                lambda buf, new: jnp.concatenate(
                    [new[None], buf[:-1]], axis=0),
                carry[e], produced[e])
            for e in crossing}
        return new_carry, y

    def build_interleave():
        def step(params, xs):
            _check_stream_shape(xs)
            _, ys = jax.lax.scan(lambda c, t: tick_body(params, c, t, xs),
                                 make_carry0(), jnp.arange(sched.ticks))
            return ys[S - 1:]
        return jax.jit(step)

    # -- multi-device ring: shard_map, one stage per device ------------------
    def build_shard_map():
        import numpy as np
        from jax.sharding import Mesh, PartitionSpec as P
        mesh = Mesh(np.array(jax.devices()[:S]), ("stage",))
        perm = [(i, (i + 1) % S) for i in range(S)]

        def body(params, xs):
            j = jax.lax.axis_index("stage")

            def tick(carry, t):
                x_t = jax.lax.dynamic_index_in_dim(
                    xs, jnp.clip(t, 0, B - 1), axis=0, keepdims=False)
                reads = {e: dec[e](jax.tree.map(lambda b: b[0], carry[e]))
                         for e in crossing}

                def branch(jj):
                    def f(params, x_t, reads):
                        prod, y = stage_fns[jj](
                            params, x_t if jj == 0 else None, reads)
                        return fill_zeros(prod), y
                    return f
                produced, y = jax.lax.switch(
                    j, [branch(jj) for jj in range(S)], params, x_t, reads)
                new_carry = {}
                for e in crossing:
                    i_prod = an.stage_of[e[0]]
                    slot = jax.tree.map(
                        lambda old, new: jnp.where(j == i_prod, new[None],
                                                   old),
                        carry[e], produced[e])
                    new_carry[e] = jax.tree.map(
                        lambda s: jax.lax.ppermute(s, "stage", perm), slot)
                return new_carry, y

            carry0 = {e: jax.tree.map(lambda z: z[None], zeros[e])
                      for e in crossing}
            _, ys = jax.lax.scan(tick, carry0, jnp.arange(sched.ticks))
            # only the last stage computed real outputs; share them
            ys = jnp.where(j == S - 1, ys, 0.0)
            return jax.lax.psum(ys, "stage")

        smap = _shard_map_compat(body, mesh, in_specs=(P(), P()),
                                 out_specs=P())

        def step(params, xs):
            _check_stream_shape(xs)
            ys = smap(params, xs)
            return ys[S - 1:]
        return jax.jit(step)

    def _check_stream_shape(xs):
        if tuple(xs.shape) != (B,) + an.in_shape:
            raise ValueError(
                f"microbatch stream shape {tuple(xs.shape)} does not match "
                f"the lowered ({B}, *{an.in_shape}) for {g.name!r}")

    fn = build_shard_map() if placement == "shard_map" else build_interleave()

    # -- report: schedule + bounded-queue accounting --------------------------
    lat = SCH.stage_latencies(g, plan)
    mem = None
    if channel is not None:
        priced = _resolve_channel_device(channel, device, plan)
        if priced is not None:
            gbps, freq_mhz = priced
            mem = build_memory_model(
                spills=an.spills,
                weight_bits_by_stage=stage_weight_bits(g, an),
                stage_of=an.stage_of, base_latencies=lat,
                gbps=gbps, freq_mhz=freq_mhz, config=channel,
                microbatches=B)
    specs = Q.queue_specs(
        g, an.stage_of, an.out_shape, codec_of,
        extra_delay=(mem.extra_queue_delay() if mem is not None else None))
    sim = SCH.simulate_schedule(
        sched, Q.build_queues(specs),
        producer_stage={e: an.stage_of[e[0]] for e in specs},
        consumer_stage={e: an.stage_of[e[1]] for e in specs})
    base = an.report()
    report = StreamReport(
        spills=base.spills, streamed_weight_bits=base.streamed_weight_bits,
        static_weight_bits=base.static_weight_bits,
        n_stages=S, microbatches=B, ticks=sched.ticks, placement=placement,
        stage_occupancy=sim["stage_occupancy"],
        stage_stalls=sim["stage_stalls"], stage_latency=lat,
        queue_stats={f"{u}->{w}": st
                     for (u, w), st in sim["queues"].items()},
        memory=mem)

    params = init_params(g, seed=seed)
    jitted_stage_fns = [jax.jit(functools.partial(_stage_call, f))
                        for f in stage_fns]

    def zero_reads():
        return {e: dec[e](zeros[e]) for e in crossing}

    return StreamingExecutor(
        fn=fn, params=params, report=report, plan=plan, graph_name=g.name,
        n_stages=S, microbatches=B, placement=placement,
        stage_fns=jitted_stage_fns, _zero_reads=zero_reads, _decoders=dec,
        _crossing=crossing, schedule=sched,
        _tick_fn=(jax.jit(tick_body) if placement == "interleave" else None),
        _carry0=make_carry0, _queue_specs=specs, _stage_of=dict(an.stage_of),
        _stream_shape=(B,) + an.in_shape)


def _stage_call(stage_fn, params, x, reads):
    """Uniform jit wrapper: drop the None placeholders so each stage's
    jitted signature only contains arrays."""
    prod, y = stage_fn(params, x, reads)
    return {e: p for e, p in prod.items() if p is not None}, y


def _shard_map_compat(f, mesh, *, in_specs, out_specs):
    if hasattr(jax, "shard_map"):                       # jax >= 0.7
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)


# =============================================================================
# Measured per-stage latencies (the Eq. 5/6 hook, wall-clock edition)
# =============================================================================

def measured_stage_latencies(sx: StreamingExecutor, x: jax.Array, *,
                             repeats: int = 5, warmup: int = 2
                             ) -> list[float]:
    """Wall-clock seconds per stage, dispatched stage-by-stage.

    This is what the *sequential* schedule pays per frame: each stage is a
    separate device dispatch fed through the decoded reads.  Feeding stage
    ``j+1`` with stage ``j``'s real outputs keeps shapes and codec work
    identical to the pipeline's steady state.  Plug the result into the
    Eq. 5/6 estimators to place measured pipeline throughput between the
    sequential sum and the slowest-stage bound.
    """
    import time

    reads = sx.zero_reads()
    lat: list[float] = []
    for j, fn in enumerate(sx.stage_fns):
        x_j = x if j == 0 else None

        def call():
            prod, y = fn(sx.params, x_j, reads)
            jax.block_until_ready((prod, y))
            return prod, y

        for _ in range(warmup):
            prod, _ = call()
        times = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            prod, _ = call()
            times.append(time.perf_counter() - t0)
        times.sort()
        lat.append(times[len(times) // 2])
        # thread this stage's real (decoded) outputs into the next reads
        for e, payload in prod.items():
            reads[e] = sx._decoders[e](payload)
    return lat
