"""Bounded inter-stage ring buffers for the spilled/encoded representation.

On hardware, an evicted stream crosses between pipeline stages through two
DMA-burst FIFOs of total depth ``d_b'`` (Eq. 1) plus an off-chip spill
region; the FIFOs are what lets the spill of microbatch ``b`` overlap with
compute on microbatch ``b`` instead of blocking the stage (the
memory-efficient dataflow queues of Petrica et al.).  Here each cross-stage
edge of a plan gets a :class:`RingBuffer` whose capacity *in microbatch
entries* derives from the same ``d_b'`` word budget — never below the two
DMA FIFOs' double buffer, and never below the edge's stage distance: a
``d``-stage crossing is executed as a depth-``d`` shift register in the
jitted scan carry, so any smaller ring would mis-model the buffer the
pipeline actually allocates.  The Python objects are used by
``schedule.simulate_schedule`` and ``obs.StreamTracer`` to account
occupancy and stalls, and (given a recorder) emit per-queue occupancy
counters and stall instants into the trace.
"""
from __future__ import annotations

import collections
import dataclasses
import math

from ...core.eviction import DMA_FIFO_DEPTH
from ...core.graph import Graph
from ...obs.trace import NULL_RECORDER


@dataclasses.dataclass(frozen=True)
class QueueSpec:
    """Sizing of one inter-stage queue.

    ``capacity_words`` is Eq. 1's ``d_b' = 2 * DMA_FIFO_DEPTH`` word budget;
    ``capacity`` is that budget expressed in whole microbatch entries,
    floored at 2 (the two DMA-burst FIFOs always double-buffer one entry in
    flight while the next is being encoded) and at ``delay`` (the executed
    shift-register depth for the crossing).
    """
    src: str
    dst: str
    words_per_entry: int          # one encoded microbatch stripe
    word_bits: int
    codec: str
    delay: int                    # consumer stage - producer stage (>= 1)
    capacity_words: float
    capacity: int

    @property
    def entry_bits(self) -> int:
        return self.words_per_entry * self.word_bits


class RingBuffer:
    """Bounded FIFO with occupancy high-water and stall accounting.

    ``push`` against a full ring and ``pop`` from an empty one are counted
    as stalls — the events that would backpressure (resp. starve) a
    hardware pipeline stage.  The push still lands (the accounting model
    must keep the schedule moving), so stall counts are diagnostics, not
    flow control; ``high_water`` saturates at ``capacity``, the most the
    modelled ring can physically hold.

    With a ``recorder``, every push/pop emits a ``queue:{name}:occupancy``
    counter sample and every stall a ``queue:{name}:push_stall`` /
    ``:pop_stall`` instant, timestamped by the caller's ``ts`` (the tick
    boundary) so the trace shows queue pressure against the stage spans.
    With a ``metrics`` registry, the same events additionally keep the
    per-edge ``smof_queue_occupancy`` gauge and
    ``smof_queue_{push,pop}_stalls_total`` counters current (the scrape
    view of the Eq. 1 invariant: stall totals should stay 0).
    """

    def __init__(self, capacity: int, *, name: str = "",
                 recorder=NULL_RECORDER, metrics=None) -> None:
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.name = name
        self.rec = recorder
        self._q: collections.deque = collections.deque()
        self.high_water = 0
        self.push_stalls = 0
        self.pop_stalls = 0
        self._m_occ = self._m_push = self._m_pop = None
        if metrics is not None:
            edge = name or "?"
            self._m_occ = metrics.gauge(
                "smof_queue_occupancy",
                "inter-stage ring occupancy (entries, Eq. 1-capped)",
                ("edge",)).labels(edge=edge)
            self._m_push = metrics.counter(
                "smof_queue_push_stalls_total",
                "pushes against a full inter-stage ring",
                ("edge",)).labels(edge=edge)
            self._m_pop = metrics.counter(
                "smof_queue_pop_stalls_total",
                "pops from an empty inter-stage ring",
                ("edge",)).labels(edge=edge)

    def __len__(self) -> int:
        return len(self._q)

    @property
    def occupancy(self) -> int:
        return len(self._q)

    def _emit(self, ts: float | None, stall: str | None = None) -> None:
        if self._m_occ is not None:
            self._m_occ.set(min(len(self._q), self.capacity))
            if stall == "push_stall":
                self._m_push.inc()
            elif stall == "pop_stall":
                self._m_pop.inc()
        if not self.rec.enabled:
            return
        self.rec.counter(f"queue:{self.name}:occupancy",
                         min(len(self._q), self.capacity), ts,
                         track="queues")
        if stall is not None:
            self.rec.instant(f"queue:{self.name}:{stall}", ts,
                             track="queues")

    def push(self, item, ts: float | None = None) -> bool:
        """Append; returns False (and counts a stall) if the ring was full."""
        ok = len(self._q) < self.capacity
        if not ok:
            self.push_stalls += 1
        self._q.append(item)
        self.high_water = max(self.high_water,
                              min(len(self._q), self.capacity))
        self._emit(ts, None if ok else "push_stall")
        return ok

    def pop(self, ts: float | None = None):
        if not self._q:
            self.pop_stalls += 1
            self._emit(ts, "pop_stall")
            return None
        item = self._q.popleft()
        self._emit(ts)
        return item

    def stats(self) -> dict:
        return {"capacity": self.capacity, "occupancy": len(self._q),
                "high_water": self.high_water,
                "push_stalls": self.push_stalls,
                "pop_stalls": self.pop_stalls}


def queue_specs(g: Graph, stage_of: dict[str, int],
                out_shape: dict[str, tuple[int, int]],
                codec_of: dict[tuple[str, str], str] | None = None,
                fifo_depth: float = DMA_FIFO_DEPTH,
                extra_delay: dict[tuple[str, str], int] | None = None
                ) -> dict[tuple[str, str], QueueSpec]:
    """One :class:`QueueSpec` per stage-crossing edge of the plan.

    ``extra_delay`` adds per-edge in-flight entries on top of the stage
    distance — the arbiter-derived crossing delay from
    ``repro.memory.MemoryModel.extra_queue_delay`` (a spill round-trip
    slower than one tick needs a deeper ring to keep the pipeline fed).
    """
    codec_of = codec_of or {}
    extra_delay = extra_delay or {}
    specs: dict[tuple[str, str], QueueSpec] = {}
    for e in g.edges():
        d = stage_of[e.dst] - stage_of[e.src]
        if d <= 0:
            continue
        m, c = out_shape[e.src]
        d_b_prime = 2.0 * fifo_depth                      # Eq. 1
        cap = max(2, d + extra_delay.get((e.src, e.dst), 0),
                  math.floor(d_b_prime / max(m * c, 1)))
        specs[(e.src, e.dst)] = QueueSpec(
            src=e.src, dst=e.dst, words_per_entry=m * c,
            word_bits=e.word_bits, codec=codec_of.get((e.src, e.dst), "none"),
            delay=d, capacity_words=d_b_prime, capacity=cap)
    return specs


def build_queues(specs: dict[tuple[str, str], QueueSpec],
                 recorder=NULL_RECORDER,
                 metrics=None) -> dict[tuple[str, str], RingBuffer]:
    return {e: RingBuffer(s.capacity, name=f"{s.src}->{s.dst}",
                          recorder=recorder, metrics=metrics)
            for e, s in specs.items()}
