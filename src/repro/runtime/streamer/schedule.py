"""1F1B fill/steady/drain schedule and the per-stage latency model.

A plan with ``S`` stages processing ``B`` microbatches runs for
``T = B + S - 1`` ticks: tick ``t`` has stage ``j`` working on microbatch
``b = t - j`` whenever ``0 <= b < B``.  The first ``S - 1`` ticks are the
*fill* region (downstream stages idle), the last ``S - 1`` are the *drain*
(upstream stages idle), and everything between is *steady state* where all
stages overlap — H2PIPE's regime, where throughput is set by the slowest
stage:

  Eq. 5 (sequential)   t_frame = sum_j L_j      -> fps = 1 / sum_j(L_j)
  Eq. 6 (pipelined)    t_frame = max_j L_j      -> fps = 1 / max_j(L_j)

``stage_latencies`` provides ``L_j``: analytically (the stage subgraph's
initiation interval in cycles, the same model the DSE scores partitions
with) or through a user hook — e.g. measured per-stage wall-clock from
``pipeline.measured_stage_latencies`` — so the report and benchmarks can
place the *executed* throughput between the two estimates.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

from ...core.graph import Graph
from ...core.pipeline import initiation_interval
from ...core.plan import ExecutionPlan
from ...obs.stream import StreamTracer
from ...obs.trace import NULL_RECORDER


@dataclasses.dataclass(frozen=True)
class StageTask:
    """One (tick, stage, microbatch) cell of the pipeline diagram."""
    tick: int
    stage: int
    microbatch: int


@dataclasses.dataclass(frozen=True)
class PipelineSchedule:
    n_stages: int
    n_microbatches: int

    def __post_init__(self) -> None:
        if self.n_stages < 1 or self.n_microbatches < 1:
            raise ValueError(
                f"need >= 1 stage and >= 1 microbatch, got "
                f"{self.n_stages} stages / {self.n_microbatches} microbatches")

    @property
    def ticks(self) -> int:
        return self.n_microbatches + self.n_stages - 1

    def microbatch_at(self, stage: int, tick: int) -> int | None:
        b = tick - stage
        return b if 0 <= b < self.n_microbatches else None

    def active_stages(self, tick: int) -> list[int]:
        return [j for j in range(self.n_stages)
                if self.microbatch_at(j, tick) is not None]

    def phase(self, tick: int) -> str:
        if tick < self.n_stages - 1:
            return "fill"
        if tick >= self.n_microbatches:
            return "drain"
        return "steady"

    # -- phase tick counts (the Eq. 6 regime is exactly the steady ticks) ----
    @property
    def fill_ticks(self) -> int:
        return min(self.n_stages - 1, self.ticks)

    @property
    def steady_ticks(self) -> int:
        return max(0, self.n_microbatches - self.n_stages + 1)

    @property
    def drain_ticks(self) -> int:
        return self.ticks - self.fill_ticks - self.steady_ticks

    def tasks(self) -> list[StageTask]:
        """All cells in tick order (stage-ascending within a tick)."""
        return [StageTask(t, j, self.microbatch_at(j, t))
                for t in range(self.ticks)
                for j in range(self.n_stages)
                if self.microbatch_at(j, t) is not None]

    # -- occupancy / stall accounting ---------------------------------------
    def stage_active_ticks(self, stage: int) -> int:
        return self.n_microbatches

    def stage_idle_ticks(self, stage: int) -> int:
        """Fill/drain bubbles seen by this stage (the 1F1B stall count)."""
        return self.ticks - self.n_microbatches

    def stage_occupancy(self, stage: int) -> float:
        return self.n_microbatches / self.ticks


def build_schedule(n_stages: int, n_microbatches: int) -> PipelineSchedule:
    return PipelineSchedule(n_stages=n_stages, n_microbatches=n_microbatches)


# =============================================================================
# Per-stage latency model (the hook Eq. 5/6 estimates are built from)
# =============================================================================

LatencyHook = Callable[[int, Graph], float]


def stage_latencies(g: Graph, plan: ExecutionPlan, *,
                    hook: LatencyHook | None = None) -> list[float]:
    """``L_j`` for every stage of ``plan`` over executable graph ``g``.

    Default model: the stage subgraph's initiation interval in cycles (the
    slowest vertex sets the stage's frame rate — the same model
    ``core.partition.subgraph_cost`` uses to score a partition).  ``hook``
    overrides it per stage: ``hook(stage_index, stage_subgraph) -> L_j`` in
    any consistent unit (cycles, seconds, ...).
    """
    n_stages = max((lp.stage for lp in plan.layers.values()), default=0) + 1
    out: list[float] = []
    for j in range(n_stages):
        names = plan.stage_layers(j)
        if not names:
            raise ValueError(f"stage {j} of plan {plan.model!r} is empty")
        sg = g.subgraph(names)
        out.append(hook(j, sg) if hook is not None
                   else initiation_interval(sg))
    return out


def eq5_sequential_time(latencies: Sequence[float]) -> float:
    """Eq. 5 frame time of the sequential schedule: the stage sum."""
    return float(sum(latencies))


def eq6_pipeline_time(latencies: Sequence[float]) -> float:
    """Eq. 6 steady-state frame time of the pipeline: the slowest stage."""
    return float(max(latencies))


def eq5_contended_time(latencies: Sequence[float],
                       transfer: Sequence[float]) -> float:
    """Eq. 5 over the contended stage latencies ``max(L_j, X_j)``, where
    ``X_j`` is stage ``j``'s off-chip transfer time from the channel
    arbiter (``repro.memory``).  >= the uncontended Eq. 5, always."""
    from ...memory import contended_stage_latencies
    return eq5_sequential_time(
        contended_stage_latencies(list(latencies), list(transfer)))


def eq6_contended_time(latencies: Sequence[float],
                       transfer: Sequence[float]) -> float:
    """Eq. 6 over the contended stage latencies — the steady-state frame
    time when the shared off-chip channel, not compute, may set the
    bottleneck.  >= the uncontended Eq. 6, always."""
    from ...memory import contended_stage_latencies
    return eq6_pipeline_time(
        contended_stage_latencies(list(latencies), list(transfer)))


def simulate_schedule(schedule: PipelineSchedule,
                      queues: dict[tuple[str, str], "RingBuffer"],
                      producer_stage: dict[tuple[str, str], int],
                      consumer_stage: dict[tuple[str, str], int],
                      recorder=NULL_RECORDER) -> dict:
    """Walk the schedule through the bounded inter-stage queues.

    Producers push one (encoded) microbatch entry per active tick, consumers
    pop one (consumers first: a pop at tick ``t`` reads the entry pushed
    ``delay`` ticks earlier, so within a tick the two ends of a queue act on
    different entries — double buffering); the ring buffers record occupancy
    high-water marks and stall events (push against a full queue / pop from
    an empty one).  The stats show where Eq. 6's bottleneck sits: a queue
    that rides its capacity is the spill FIFO that would backpressure the
    pipeline on hardware.  With a ``recorder``, the walk also emits the full
    model-time trace (tick/stage spans, queue counters) via
    :class:`~repro.obs.StreamTracer`.
    """
    stage_of: dict[str, int] = {}
    for (u, _w), s in producer_stage.items():
        stage_of[u] = s
    for (_u, w), s in consumer_stage.items():
        stage_of[w] = s
    tracer = StreamTracer(recorder, schedule, queues=queues,
                          stage_of=stage_of)
    for t in range(schedule.ticks):
        tracer.tick(t)
    per_queue = {e: q.stats() for e, q in queues.items()}
    return {
        "ticks": schedule.ticks,
        "stage_occupancy": [schedule.stage_occupancy(j)
                            for j in range(schedule.n_stages)],
        "stage_stalls": [schedule.stage_idle_ticks(j)
                         for j in range(schedule.n_stages)],
        "queues": per_queue,
    }
