"""Pipelined streaming executor — overlap partition stages, double-buffer
off-chip spills.

The sequential executor (``runtime/executor.py``) runs a plan's stages one
after another on one input at a time, so every evicted stream pays its full
off-chip round-trip on the critical path and the executed time tracks
Eq. 5's sequential sum.  This subsystem runs the *same*
``core.plan.ExecutionPlan`` as a coarse software pipeline over a stream of
microbatches — stage ``j`` processes microbatch ``b`` while stage ``j+1``
processes ``b-1`` — so steady-state throughput tracks Eq. 6's
``1/max_j(L_j)`` slowest-stage model instead.

The documented entry point is the compile façade —
``repro.compile(CompileSpec(mode="pipelined", ...))`` — which lowers
through :func:`lower_plan_pipelined` bit-identically; the names below
remain public for direct use.

Public API (everything re-exported here; the per-name contracts)
----------------------------------------------------------------

Lowering and execution (``pipeline.py``)
    ``lower_plan_pipelined(g, plan, *, microbatches, kernel_mode, seed,
    interpret, placement)``
        Lower a plan to a :class:`StreamingExecutor`.  Single device: one
        jitted ``lax.scan`` over ticks whose carry holds, per
        stage-crossing edge, the *encoded* spill (double-buffered BFP8
        payloads for ``bfp8`` streams).  ``placement="shard_map"`` places
        one stage per device with ``ppermute``-ring transit.
    ``StreamingExecutor``
        The lowered object: ``sx(xs)`` maps a ``(B, m, c)`` stream to
        ``(B, L)`` outputs, bit-for-bit what the sequential executor
        produces per microbatch; carries ``report``, the individually
        jitted ``stage_fns``, and ``zero_reads()`` for driving them.
        ``sx.run_traced(xs, recorder)`` runs the same jitted tick body
        tick-by-tick, emitting per-tick spans / queue counters / spill
        bytes into an ``repro.obs`` recorder and returning a
        :class:`~repro.obs.ModelCheck` (measured vs Eq. 5/6 and Eq. 1) —
        bit-exact against ``sx(xs)``, zero-cost with the null recorder.
    ``StreamReport``
        :class:`~repro.runtime.executor.SpillReport` plus the schedule
        view: per-stage occupancy/stalls/latency, queue high-water marks,
        ``eq5_time``/``eq6_time``/``bottleneck_stage``.
    ``measured_stage_latencies(sx, x, *, repeats, warmup)``
        Wall-clock ``L_j`` per stage in the dispatch regime the
        sequential schedule pays — the measured edition of the Eq. 5/6
        hook, and the autotuner's per-stage diagnostic.

Schedule and latency model (``schedule.py``)
    ``build_schedule(n_stages, n_microbatches)`` / ``PipelineSchedule``
        The 1F1B fill/steady/drain diagram: ``T = B + S - 1`` ticks,
        ``microbatch_at``/``active_stages``/``phase`` queries, per-stage
        occupancy and stall accounting.  ``StageTask`` is one
        (tick, stage, microbatch) cell.
    ``stage_latencies(g, plan, *, hook)``
        ``L_j`` per stage — analytic initiation interval by default
        (cycles, the DSE's own model), or any ``hook(j, subgraph)``
        override, e.g. measured seconds or the autotuner's
        ``calibrated_latency_hook(s_per_cycle)``.
    ``eq5_sequential_time(L)`` / ``eq6_pipeline_time(L)``
        The two frame-time estimators: stage sum vs slowest stage.
    ``eq5_contended_time(L, X)`` / ``eq6_contended_time(L, X)``
        The same estimators over the *contended* stage latencies
        ``max(L_j, X_j)``, with ``X_j`` the per-stage off-chip transfer
        time from the ``repro.memory`` channel arbiter.  Lowering with a
        ``channel=ChannelConfig(...)`` attaches the full
        :class:`~repro.memory.MemoryModel` to ``StreamReport.memory``;
        ``stage_weight_bits(g, an)`` is the exact per-stage streamed
        weight volume the model arbitrates.
    ``simulate_schedule(schedule, queues, producer_stage, consumer_stage)``
        Walk the schedule through the bounded rings for the report's
        occupancy/stall statistics.

Bounded inter-stage queues (``queues.py``)
    ``queue_specs(g, stage_of, out_shape, codec_of)`` / ``QueueSpec``
        One spec per stage-crossing edge; capacity in microbatch entries
        derives from Eq. 1's ``d_b' = 2·DMA_FIFO_DEPTH`` word budget,
        floored at the two DMA-burst FIFOs' double buffer and at the
        edge's stage distance (the executed shift-register depth).
    ``build_queues(specs, recorder)`` / ``RingBuffer``
        The Python-side rings with occupancy high-water and push/pop
        stall accounting (diagnostics, not flow control); with an
        ``repro.obs`` recorder each push/pop also emits occupancy
        counters and stall instants into the trace.
"""
from .pipeline import (StreamingExecutor, StreamReport, lower_plan_pipelined,
                       measured_stage_latencies, stage_weight_bits)
from .queues import QueueSpec, RingBuffer, build_queues, queue_specs
from .schedule import (PipelineSchedule, StageTask, build_schedule,
                       eq5_contended_time, eq5_sequential_time,
                       eq6_contended_time, eq6_pipeline_time,
                       simulate_schedule, stage_latencies)

__all__ = [n for n in dir() if not n.startswith("_")]
