"""Pipelined streaming executor — overlap partition stages, double-buffer
off-chip spills.

The sequential executor (``runtime/executor.py``) runs a plan's stages one
after another on one input at a time, so every evicted stream pays its full
off-chip round-trip on the critical path and the executed time tracks
Eq. 5's sequential sum.  This subsystem runs the *same*
``core.plan.ExecutionPlan`` as a coarse software pipeline over a stream of
microbatches — stage ``j`` processes microbatch ``b`` while stage ``j+1``
processes ``b-1`` — so steady-state throughput tracks Eq. 6's
``1/max_j(L_j)`` slowest-stage model instead.

Modules
-------
``schedule``   1F1B fill/steady/drain schedule + per-stage latency model
               hook (Eq. 5 vs Eq. 6 estimates, occupancy/stall accounting).
``queues``     bounded inter-stage ring buffers holding the spilled/encoded
               representation, capacity from Eq. 1's ``d_b'``.
``pipeline``   the jitted multi-microbatch step (``jax.lax.scan`` over a
               stage-state carry on one device; ``shard_map`` ring pipeline
               when devices >= stages) and the ``StreamReport``.
"""
from .pipeline import (StreamingExecutor, StreamReport, lower_plan_pipelined,
                       measured_stage_latencies)
from .queues import QueueSpec, RingBuffer, build_queues, queue_specs
from .schedule import (PipelineSchedule, StageTask, build_schedule,
                       eq5_sequential_time, eq6_pipeline_time,
                       simulate_schedule, stage_latencies)

__all__ = [n for n in dir() if not n.startswith("_")]
