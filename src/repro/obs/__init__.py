"""repro.obs — streaming telemetry: spans, counters, model-vs-measured.

Dependency-free layers so anything in the repo can import it:

* :mod:`repro.obs.trace` — recorder primitives.  :class:`NullRecorder`
  (the universal default: every hook is a no-op, zero cost when tracing
  is off), :class:`TraceRecorder` (in-memory spans/counters with a
  Chrome trace-event / Perfetto JSON exporter), ``validate_chrome_trace``
  (schema check for emitted files) and :class:`LatencyHistogram`
  (log-bucketed per-request latencies for serving).
* :mod:`repro.obs.stream` — :class:`StreamTracer`, the per-tick narrator
  for the pipelined streamer (tick/stage spans by 1F1B phase, queue
  occupancy through the bounded rings, spill byte counters), plus
  ``emit_spill_counters`` for the sequential executor's spill path.
* :mod:`repro.obs.modelcheck` — :class:`ModelCheck` via ``check_stream``:
  measured per-stage latencies, tick counts and queue depths vs the
  Eq. 5/6 predictions and Eq. 1 capacities.
* :mod:`repro.obs.metrics` — :class:`MetricsRegistry`: labeled
  counters/gauges/histograms with ``snapshot()``/``delta_since`` and
  Prometheus text exposition (``metrics_text`` + the strict
  ``parse_metrics_text`` round-trip gate).
* :mod:`repro.obs.slo` — :class:`SloEvaluator`: rolling-window
  pass/warn/breach scoring of fps vs the Eq. 6 roofline, p50/p99 latency
  targets, Eq. 1 stall ratio and spill bandwidth vs the device budget.
* :mod:`repro.obs.flight` — :class:`FlightRecorder`: a bounded ring of
  recent events that dumps a Chrome trace on an SLO breach or ModelCheck
  violation.

Configuration travels as :class:`ObsConfig` on ``CompileSpec`` and
round-trips through ``Compiled.save/load``.
"""
from .flight import FlightRecorder
from .metrics import (REGISTRY, Counter, Gauge, Histogram, MetricsRegistry,
                      escape_label_value, parse_metrics_text)
from .modelcheck import (ContentionCheck, ModelCheck, QueueDepthCheck,
                         StageLatencyCheck, check_contention, check_stream)
from .slo import BREACH, PASS, WARN, SloCheck, SloConfig, SloEvaluator, SloReport
from .stream import StreamTracer, emit_spill_counters
from .trace import (NULL_RECORDER, LatencyHistogram, NullRecorder, ObsConfig,
                    TraceRecorder, validate_chrome_trace)

__all__ = [
    "ObsConfig",
    "NullRecorder",
    "NULL_RECORDER",
    "TraceRecorder",
    "LatencyHistogram",
    "validate_chrome_trace",
    "StreamTracer",
    "emit_spill_counters",
    "ModelCheck",
    "StageLatencyCheck",
    "QueueDepthCheck",
    "ContentionCheck",
    "check_stream",
    "check_contention",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "REGISTRY",
    "escape_label_value",
    "parse_metrics_text",
    "SloConfig",
    "SloCheck",
    "SloReport",
    "SloEvaluator",
    "PASS",
    "WARN",
    "BREACH",
    "FlightRecorder",
]
