"""repro.obs — streaming telemetry: spans, counters, model-vs-measured.

Three layers, dependency-free so anything in the repo can import it:

* :mod:`repro.obs.trace` — recorder primitives.  :class:`NullRecorder`
  (the universal default: every hook is a no-op, zero cost when tracing
  is off), :class:`TraceRecorder` (in-memory spans/counters with a
  Chrome trace-event / Perfetto JSON exporter), ``validate_chrome_trace``
  (schema check for emitted files) and :class:`LatencyHistogram`
  (log-bucketed per-request latencies for serving).
* :mod:`repro.obs.stream` — :class:`StreamTracer`, the per-tick narrator
  for the pipelined streamer (tick/stage spans by 1F1B phase, queue
  occupancy through the bounded rings, spill byte counters), plus
  ``emit_spill_counters`` for the sequential executor's spill path.
* :mod:`repro.obs.modelcheck` — :class:`ModelCheck` via ``check_stream``:
  measured per-stage latencies, tick counts and queue depths vs the
  Eq. 5/6 predictions and Eq. 1 capacities.

Configuration travels as :class:`ObsConfig` on ``CompileSpec`` and
round-trips through ``Compiled.save/load``.
"""
from .modelcheck import (ModelCheck, QueueDepthCheck, StageLatencyCheck,
                         check_stream)
from .stream import StreamTracer, emit_spill_counters
from .trace import (NULL_RECORDER, LatencyHistogram, NullRecorder, ObsConfig,
                    TraceRecorder, validate_chrome_trace)

__all__ = [
    "ObsConfig",
    "NullRecorder",
    "NULL_RECORDER",
    "TraceRecorder",
    "LatencyHistogram",
    "validate_chrome_trace",
    "StreamTracer",
    "emit_spill_counters",
    "ModelCheck",
    "StageLatencyCheck",
    "QueueDepthCheck",
    "check_stream",
]
