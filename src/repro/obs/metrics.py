"""Serving-grade metrics: a labeled registry with Prometheus exposition.

PR 5's tracing layer answers "what happened during *this* run"; this
module answers "how healthy has the system been *over time*".  One
:class:`MetricsRegistry` holds labeled **counters** (monotone totals:
frames served, queue stalls, spill bytes), **gauges** (point-in-time
values: queue occupancy, best autotuned fps) and **histograms**
(distributions — reusing :class:`~repro.obs.trace.LatencyHistogram`'s
log2 buckets, so the serving front-ends' per-request latencies and the
registry view are one data structure).

Three read paths:

* :meth:`MetricsRegistry.snapshot` — a flat ``{sample_key: value}`` dict
  (sample keys are exposition-style, ``name{label="v"}``), cheap to diff;
* :meth:`MetricsRegistry.delta_since` — per-sample change vs an earlier
  snapshot (what the SLO evaluator and the autotuner's per-candidate
  accounting read);
* :meth:`MetricsRegistry.metrics_text` — Prometheus text exposition
  (``# HELP`` / ``# TYPE`` + samples, label values escaped per the spec),
  what ``GraphStreamServer.metrics_text()`` / ``ServingEngine
  .metrics_text()`` serve to a scraper.

:func:`parse_metrics_text` is the matching strict parser — the round-trip
gate the tests and the CI smoke validate the exposition through (label
escaping, histogram bucket cumulativity, ``le="+Inf"`` == ``_count``).

Registries are cheap objects: each serving engine owns one by default so
tests never cross-talk, and :data:`REGISTRY` is the process-wide default
for code that wants exactly one scrape surface per process.
"""
from __future__ import annotations

import re
import threading

from .trace import LatencyHistogram

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "REGISTRY",
    "escape_label_value", "parse_metrics_text",
]

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")


def escape_label_value(v: str) -> str:
    r"""Escape a label value per the Prometheus text format: backslash,
    double quote and newline become ``\\``, ``\"`` and ``\n``."""
    return (str(v).replace("\\", "\\\\").replace('"', '\\"')
            .replace("\n", "\\n"))


def _escape_help(v: str) -> str:
    return str(v).replace("\\", "\\\\").replace("\n", "\\n")


def _sample_key(name: str, labels: tuple[tuple[str, str], ...]) -> str:
    if not labels:
        return name
    inner = ",".join(f'{k}="{escape_label_value(v)}"' for k, v in labels)
    return f"{name}{{{inner}}}"


def _fmt(v: float) -> str:
    """Exposition value formatting: integers without the trailing ``.0``."""
    f = float(v)
    return str(int(f)) if f == int(f) and abs(f) < 1e15 else repr(f)


# =============================================================================
# Metric children (one per label combination)
# =============================================================================

class Counter:
    """A monotone total.  ``inc`` only — a counter that goes down is a bug
    (Prometheus rate() semantics depend on monotonicity)."""

    def __init__(self) -> None:
        self._value = 0.0

    @property
    def value(self) -> float:
        return self._value

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError(f"counters only go up, got inc({amount})")
        self._value += amount


class Gauge:
    """A point-in-time value: set/inc/dec freely."""

    def __init__(self) -> None:
        self._value = 0.0

    @property
    def value(self) -> float:
        return self._value

    def set(self, value: float) -> None:
        self._value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        self._value += amount

    def dec(self, amount: float = 1.0) -> None:
        self._value -= amount


class Histogram:
    """A distribution over :class:`LatencyHistogram`'s log2 buckets.

    ``hist`` is the underlying histogram object — the serving engines
    expose it directly as their legacy ``.latency`` attribute, so the
    registry and the front-end read the *same* counts.
    """

    def __init__(self, base: float = 1e-6, n_buckets: int = 32) -> None:
        self.hist = LatencyHistogram(base=base, n_buckets=n_buckets)

    @property
    def value(self) -> float:          # uniform child surface: the count
        return float(self.hist.n)

    def observe(self, value: float) -> None:
        self.hist.record(value)

    def quantile(self, q: float) -> float:
        return self.hist.quantile(q)

    def summary(self) -> dict:
        return self.hist.summary()


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


# =============================================================================
# Metric family: one name, one kind, N label combinations
# =============================================================================

class MetricFamily:
    """All children of one metric name.

    ``labels(**kv)`` resolves (creating on first use) the child for one
    label-value combination; a label-less family proxies ``inc`` / ``set``
    / ``observe`` / ``value`` straight to its single child.
    """

    def __init__(self, name: str, kind: str, help: str = "",
                 labelnames: tuple[str, ...] = (), **child_kw) -> None:
        if not _NAME_RE.match(name):
            raise ValueError(f"invalid metric name {name!r}")
        for ln in labelnames:
            if not _LABEL_RE.match(ln) or ln.startswith("__"):
                raise ValueError(f"invalid label name {ln!r}")
        if kind == "histogram" and "le" in labelnames:
            raise ValueError("'le' is reserved for histogram buckets")
        self.name = name
        self.kind = kind
        self.help = help
        self.labelnames = tuple(labelnames)
        self._child_kw = child_kw
        self._children: dict[tuple[str, ...], object] = {}

    def labels(self, **kv):
        if set(kv) != set(self.labelnames):
            raise ValueError(f"{self.name}: expected labels "
                             f"{self.labelnames}, got {tuple(sorted(kv))}")
        key = tuple(str(kv[ln]) for ln in self.labelnames)
        child = self._children.get(key)
        if child is None:
            child = self._children[key] = _KINDS[self.kind](**self._child_kw)
        return child

    def _default(self):
        if self.labelnames:
            raise ValueError(f"{self.name} is labeled {self.labelnames}; "
                             f"call .labels(...) first")
        return self.labels()

    # label-less convenience surface
    def inc(self, amount: float = 1.0) -> None:
        self._default().inc(amount)

    def dec(self, amount: float = 1.0) -> None:
        self._default().dec(amount)

    def set(self, value: float) -> None:
        self._default().set(value)

    def observe(self, value: float) -> None:
        self._default().observe(value)

    @property
    def value(self) -> float:
        return self._default().value

    def children(self) -> dict[tuple[str, ...], object]:
        return dict(self._children)

    # -- sample emission ------------------------------------------------------
    def samples(self) -> list[tuple[str, float]]:
        """Flat ``(sample_key, value)`` pairs for every child, exposition
        order (labels in first-use order, histogram buckets cumulative)."""
        out: list[tuple[str, float]] = []
        for key, child in self._children.items():
            labels = tuple(zip(self.labelnames, key))
            if self.kind == "histogram":
                h = child.hist
                cum = 0
                for edge, c in zip(h.edges, h.counts):
                    cum += c
                    out.append((_sample_key(
                        f"{self.name}_bucket",
                        labels + (("le", _fmt(edge)),)), float(cum)))
                out.append((_sample_key(f"{self.name}_bucket",
                                        labels + (("le", "+Inf"),)),
                            float(h.n)))
                out.append((_sample_key(f"{self.name}_sum", labels),
                            h.total_s))
                out.append((_sample_key(f"{self.name}_count", labels),
                            float(h.n)))
            else:
                out.append((_sample_key(self.name, labels), child.value))
        return out


# =============================================================================
# The registry
# =============================================================================

class MetricsRegistry:
    """Named metric families; the scrape/snapshot surface.

    Registration is idempotent — asking for an existing name returns the
    existing family (so instrumented code paths can declare their metrics
    at use sites) and re-registering with a different kind or label set is
    an error (two subsystems fighting over one name).
    """

    def __init__(self) -> None:
        self._families: dict[str, MetricFamily] = {}
        self._lock = threading.Lock()

    def _register(self, name: str, kind: str, help: str,
                  labelnames: tuple[str, ...], **child_kw) -> MetricFamily:
        with self._lock:
            fam = self._families.get(name)
            if fam is not None:
                if fam.kind != kind or fam.labelnames != tuple(labelnames):
                    raise ValueError(
                        f"metric {name!r} already registered as {fam.kind}"
                        f"{fam.labelnames}, cannot re-register as {kind}"
                        f"{tuple(labelnames)}")
                return fam
            fam = MetricFamily(name, kind, help, tuple(labelnames),
                               **child_kw)
            self._families[name] = fam
            return fam

    def counter(self, name: str, help: str = "",
                labelnames: tuple[str, ...] = ()) -> MetricFamily:
        return self._register(name, "counter", help, labelnames)

    def gauge(self, name: str, help: str = "",
              labelnames: tuple[str, ...] = ()) -> MetricFamily:
        return self._register(name, "gauge", help, labelnames)

    def histogram(self, name: str, help: str = "",
                  labelnames: tuple[str, ...] = (), *, base: float = 1e-6,
                  n_buckets: int = 32) -> MetricFamily:
        return self._register(name, "histogram", help, labelnames,
                              base=base, n_buckets=n_buckets)

    def get(self, name: str) -> MetricFamily:
        return self._families[name]

    def __contains__(self, name: str) -> bool:
        return name in self._families

    def families(self) -> list[MetricFamily]:
        return [self._families[n] for n in sorted(self._families)]

    # -- read paths -----------------------------------------------------------
    def snapshot(self) -> dict[str, float]:
        """Every sample as ``{exposition_sample_key: value}`` — counters and
        gauges one sample each, histograms their cumulative buckets plus
        ``_sum``/``_count``."""
        out: dict[str, float] = {}
        for fam in self.families():
            out.update(fam.samples())
        return out

    def delta_since(self, prev: dict[str, float]) -> dict[str, float]:
        """Per-sample change vs an earlier :meth:`snapshot` (new samples
        count from 0).  Zero-delta samples are dropped, so the result is
        exactly "what moved"."""
        now = self.snapshot()
        delta = {k: v - prev.get(k, 0.0) for k, v in now.items()}
        return {k: v for k, v in delta.items() if v != 0.0}

    def metrics_text(self) -> str:
        """Prometheus text exposition (version 0.0.4) of every family."""
        lines: list[str] = []
        for fam in self.families():
            if fam.help:
                lines.append(f"# HELP {fam.name} {_escape_help(fam.help)}")
            lines.append(f"# TYPE {fam.name} {fam.kind}")
            for key, value in fam.samples():
                lines.append(f"{key} {_fmt(value)}")
        return "\n".join(lines) + "\n"


REGISTRY = MetricsRegistry()
"""The process-wide default registry (engines default to their own)."""


# =============================================================================
# Strict exposition parser — the round-trip gate for tests + CI
# =============================================================================

_SUFFIXES = {"histogram": ("_bucket", "_sum", "_count")}


def _parse_labels(s: str, lineno: int) -> tuple[tuple[str, str], ...]:
    """Parse the ``k="v",...`` body of a label set, honouring escapes."""
    out: list[tuple[str, str]] = []
    i = 0
    while i < len(s):
        m = re.match(r'([a-zA-Z_][a-zA-Z0-9_]*)="', s[i:])
        if not m:
            raise ValueError(f"line {lineno}: bad label syntax at {s[i:]!r}")
        name = m.group(1)
        i += m.end()
        val: list[str] = []
        while i < len(s):                       # scan the quoted value
            ch = s[i]
            if ch == "\\":
                if i + 1 >= len(s):
                    raise ValueError(f"line {lineno}: dangling escape")
                nxt = s[i + 1]
                val.append({"\\": "\\", '"': '"', "n": "\n"}.get(nxt))
                if val[-1] is None:
                    raise ValueError(
                        f"line {lineno}: bad escape \\{nxt}")
                i += 2
            elif ch == '"':
                i += 1
                break
            else:
                val.append(ch)
                i += 1
        else:
            raise ValueError(f"line {lineno}: unterminated label value")
        out.append((name, "".join(val)))
        if i < len(s):
            if s[i] != ",":
                raise ValueError(f"line {lineno}: expected ',' between "
                                 f"labels, got {s[i]!r}")
            i += 1
    return tuple(out)


def _family_of(sample_name: str, types: dict[str, str]) -> str | None:
    if sample_name in types:
        return sample_name
    for fam, kind in types.items():
        if kind == "histogram" and sample_name in {
                fam + sfx for sfx in _SUFFIXES["histogram"]}:
            return fam
    return None


def parse_metrics_text(text: str) -> dict[str, dict]:
    """Parse (and validate) Prometheus text exposition.

    Returns ``{family: {"type", "help", "samples": {sample_key: value}}}``.
    Raises ``ValueError`` on: samples without a preceding ``# TYPE``,
    unknown types, duplicate sample keys, malformed label syntax/escapes,
    non-numeric values, histograms whose cumulative buckets decrease or
    whose ``le="+Inf"`` bucket disagrees with ``_count``.  This is the
    round-trip gate: ``parse_metrics_text(registry.metrics_text())`` must
    succeed for any registry state.
    """
    types: dict[str, str] = {}
    helps: dict[str, str] = {}
    samples: dict[str, dict[str, float]] = {}
    for lineno, raw in enumerate(text.splitlines(), 1):
        line = raw.strip()
        if not line:
            continue
        if line.startswith("# HELP "):
            parts = line[len("# HELP "):].split(" ", 1)
            helps[parts[0]] = parts[1] if len(parts) > 1 else ""
            continue
        if line.startswith("# TYPE "):
            parts = line[len("# TYPE "):].split()
            if len(parts) != 2:
                raise ValueError(f"line {lineno}: malformed TYPE line")
            name, kind = parts
            if kind not in ("counter", "gauge", "histogram", "summary",
                            "untyped"):
                raise ValueError(f"line {lineno}: unknown type {kind!r}")
            if name in types:
                raise ValueError(f"line {lineno}: duplicate TYPE for {name}")
            types[name] = kind
            samples.setdefault(name, {})
            continue
        if line.startswith("#"):
            continue                                    # plain comment
        m = re.match(r"^([a-zA-Z_:][a-zA-Z0-9_:]*)(?:\{(.*)\})?\s+(\S+)"
                     r"(?:\s+-?\d+)?$", line)
        if not m:
            raise ValueError(f"line {lineno}: malformed sample {line!r}")
        sample_name, label_body, value_s = m.groups()
        labels = _parse_labels(label_body, lineno) if label_body else ()
        try:
            value = float(value_s)
        except ValueError:
            raise ValueError(
                f"line {lineno}: non-numeric value {value_s!r}") from None
        fam = _family_of(sample_name, types)
        if fam is None:
            raise ValueError(f"line {lineno}: sample {sample_name!r} has no "
                             f"preceding # TYPE")
        key = _sample_key(sample_name, labels)
        if key in samples[fam]:
            raise ValueError(f"line {lineno}: duplicate sample {key!r}")
        samples[fam][key] = value

    out: dict[str, dict] = {}
    for fam, kind in types.items():
        out[fam] = {"type": kind, "help": helps.get(fam, ""),
                    "samples": samples[fam]}
        if kind == "histogram":
            _check_histogram(fam, samples[fam])
    return out


def _check_histogram(fam: str, fam_samples: dict[str, float]) -> None:
    """Cumulativity + ``+Inf``-equals-count per label combination."""
    series: dict[tuple, list[tuple[float, float]]] = {}
    counts: dict[tuple, float] = {}
    for key, value in fam_samples.items():
        m = re.match(r"^([a-zA-Z_:][a-zA-Z0-9_:]*)(?:\{(.*)\})?$", key)
        name, body = m.groups()
        labels = dict(_parse_labels(body, 0)) if body else {}
        if name == fam + "_bucket":
            le = labels.pop("le", None)
            if le is None:
                raise ValueError(f"{fam}: bucket sample without 'le'")
            group = tuple(sorted(labels.items()))
            edge = float("inf") if le == "+Inf" else float(le)
            series.setdefault(group, []).append((edge, value))
        elif name == fam + "_count":
            counts[tuple(sorted(labels.items()))] = value
    for group, buckets in series.items():
        buckets.sort(key=lambda p: p[0])
        prev = 0.0
        for edge, cum in buckets:
            if cum < prev:
                raise ValueError(
                    f"{fam}: bucket counts not cumulative at le={edge}")
            prev = cum
        if not buckets or buckets[-1][0] != float("inf"):
            raise ValueError(f"{fam}: histogram missing le=\"+Inf\" bucket")
        if group in counts and buckets[-1][1] != counts[group]:
            raise ValueError(f"{fam}: le=\"+Inf\" bucket "
                             f"({buckets[-1][1]}) != _count "
                             f"({counts[group]})")
