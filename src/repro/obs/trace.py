"""Span/counter tracing primitives and the Chrome trace-event exporter.

The whole observability layer funnels through one small ``Recorder``
contract: a *null* implementation (:data:`NULL_RECORDER`) whose every
method is a no-op — the default everywhere, so instrumented code paths
cost nothing when tracing is off — and :class:`TraceRecorder`, which
accumulates **spans** (named intervals on named tracks), **instants**
(point events, e.g. a queue stall), and **counters** (named running
series, e.g. per-edge spill bytes) and exports them in the Chrome
trace-event JSON format that ``chrome://tracing`` and Perfetto
(https://ui.perfetto.dev) open directly.

Design rules (the ISSUE 6 contract):

* recording never touches jitted computations — callers instrument at
  host-side boundaries (tick loops, flush calls, candidate evaluations),
  so outputs are bit-identical with tracing on or off;
* the clock is injectable (``TraceRecorder(clock=...)``), so tests drive
  the whole layer with a deterministic stub and golden traces are exact;
* timestamps are kept in seconds internally and converted to the Chrome
  format's microseconds only at export.

See ``docs/OBSERVABILITY.md`` for the span/counter taxonomy emitted by
the streamer, the serving engine, and the autotuner.
"""
from __future__ import annotations

import bisect
import dataclasses
import json
import pathlib
import time
from contextlib import contextmanager
from typing import Any, Callable

__all__ = [
    "ObsConfig", "NullRecorder", "TraceRecorder", "NULL_RECORDER",
    "LatencyHistogram", "validate_chrome_trace",
]


@dataclasses.dataclass(frozen=True)
class ObsConfig:
    """The observability knobs a :class:`~repro.api.CompileSpec` carries.

    ``enabled`` turns host-side tracing on (``Compiled.trace`` and the
    autotune loop allocate a :class:`TraceRecorder`); ``trace_path`` is
    where the Chrome trace JSON lands when set.  ``slo`` carries the
    :class:`~repro.obs.slo.SloConfig` targets the serving layer scores
    against; ``flight_capacity`` > 0 makes ``Compiled.trace`` record into
    a bounded :class:`~repro.obs.flight.FlightRecorder` ring that dumps
    to ``flight_path`` on a ModelCheck violation.  The config round-trips
    through ``Compiled.save``/``load`` (see ``to_dict``/``from_dict``).
    """
    enabled: bool = False
    trace_path: str | None = None
    slo: Any = None                   # repro.obs.slo.SloConfig | None
    flight_capacity: int = 0          # > 0 enables the flight recorder
    flight_path: str | None = None

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)  # SloConfig nests as a plain dict

    @classmethod
    def from_dict(cls, d: dict) -> "ObsConfig":
        # forward-compat: a newer writer's extra keys are ignored, same
        # policy as ExecutionPlan.from_json
        known = {f.name for f in dataclasses.fields(cls)}
        kw = {k: v for k, v in d.items() if k in known}
        if isinstance(kw.get("slo"), dict):
            from .slo import SloConfig
            kw["slo"] = SloConfig.from_dict(kw["slo"])
        return cls(**kw)


class NullRecorder:
    """The no-op recorder: every hook is a pass-through.

    This is the default recorder everywhere instrumentation is threaded,
    so with tracing disabled the instrumented paths do no bookkeeping,
    allocate nothing per event, and cannot perturb numerics.
    """

    enabled = False

    def now(self) -> float:
        return 0.0

    @contextmanager
    def span(self, name: str, *, track: str = "host", cat: str | None = None,
             args: dict | None = None):
        yield {}

    def add_span(self, name: str, ts: float, dur: float, *,
                 track: str = "host", cat: str | None = None,
                 args: dict | None = None) -> None:
        pass

    def instant(self, name: str, ts: float | None = None, *,
                track: str = "host", cat: str | None = None,
                args: dict | None = None) -> None:
        pass

    def counter(self, name: str, value: float, ts: float | None = None, *,
                track: str = "counters") -> None:
        pass

    def incr(self, name: str, delta: float = 1, ts: float | None = None, *,
             track: str = "counters") -> None:
        pass

    @property
    def totals(self) -> dict:
        return {}


NULL_RECORDER = NullRecorder()


class TraceRecorder(NullRecorder):
    """Accumulates spans/instants/counters; exports Chrome trace JSON.

    Tracks (the ``track`` argument) become Chrome *threads* under one
    process, named via metadata events, so Perfetto shows one lane per
    pipeline stage / queue / subsystem.  ``clock`` defaults to
    ``time.perf_counter``; inject a stub for deterministic traces.
    """

    enabled = True

    def __init__(self, clock: Callable[[], float] | None = None) -> None:
        self._clock = clock or time.perf_counter
        self._t0 = self._clock()
        self._events: list[dict] = []     # raw events, seconds-domain ts
        self._tracks: dict[str, int] = {}
        self._totals: dict[str, float] = {}

    # -- clock ----------------------------------------------------------------
    def now(self) -> float:
        """Seconds since the recorder was created (recorder-relative)."""
        return self._clock() - self._t0

    def _tid(self, track: str) -> int:
        return self._tracks.setdefault(track, len(self._tracks))

    # -- spans ----------------------------------------------------------------
    @contextmanager
    def span(self, name: str, *, track: str = "host", cat: str | None = None,
             args: dict | None = None):
        """Measure a host-side interval; yields a mutable args dict so the
        body can attach results (e.g. a measured fps) before the span
        closes."""
        span_args = dict(args or {})
        t0 = self.now()
        try:
            yield span_args
        finally:
            self.add_span(name, t0, self.now() - t0, track=track, cat=cat,
                          args=span_args)

    def add_span(self, name: str, ts: float, dur: float, *,
                 track: str = "host", cat: str | None = None,
                 args: dict | None = None) -> None:
        """Record an explicitly-timed interval (``ts``/``dur`` seconds)."""
        self._events.append({"ph": "X", "name": name, "ts": ts,
                             "dur": max(dur, 0.0), "tid": self._tid(track),
                             "cat": cat, "args": args})

    def instant(self, name: str, ts: float | None = None, *,
                track: str = "host", cat: str | None = None,
                args: dict | None = None) -> None:
        self._events.append({"ph": "i", "name": name,
                             "ts": self.now() if ts is None else ts,
                             "tid": self._tid(track), "cat": cat,
                             "args": args})

    # -- counters -------------------------------------------------------------
    def counter(self, name: str, value: float, ts: float | None = None, *,
                track: str = "counters") -> None:
        """Set the current value of a counter series (absolute)."""
        self._totals[name] = value
        self._events.append({"ph": "C", "name": name,
                             "ts": self.now() if ts is None else ts,
                             "tid": self._tid(track),
                             "args": {name.rsplit(":", 1)[-1]: value}})

    def incr(self, name: str, delta: float = 1, ts: float | None = None, *,
             track: str = "counters") -> None:
        """Bump a running counter and record the new running total."""
        self.counter(name, self._totals.get(name, 0) + delta, ts,
                     track=track)

    @property
    def totals(self) -> dict:
        """Final value per counter series (tests read conservation here)."""
        return dict(self._totals)

    # -- queries (tests and ModelCheck read these) ----------------------------
    def spans(self, track: str | None = None,
              cat: str | None = None) -> list[dict]:
        """Recorded spans in timestamp order, optionally filtered."""
        tid = self._tracks.get(track) if track is not None else None
        out = [e for e in self._events if e["ph"] == "X"
               and (tid is None or e["tid"] == tid)
               and (cat is None or e["cat"] == cat)]
        return sorted(out, key=lambda e: (e["ts"], e["tid"]))

    def track_name(self, tid: int) -> str:
        for name, t in self._tracks.items():
            if t == tid:
                return name
        raise KeyError(tid)

    # -- Chrome trace-event export --------------------------------------------
    def chrome_trace(self) -> dict:
        """The trace in Chrome trace-event JSON object form.

        Load it at ``chrome://tracing`` or https://ui.perfetto.dev.  All
        events live in one process (pid 0); tracks are threads with
        ``thread_name`` metadata; timestamps are microseconds.
        """
        events: list[dict] = [{
            "ph": "M", "name": "process_name", "pid": 0, "tid": 0,
            "args": {"name": "repro.obs"},
        }]
        for track, tid in sorted(self._tracks.items(), key=lambda kv: kv[1]):
            events.append({"ph": "M", "name": "thread_name", "pid": 0,
                           "tid": tid, "args": {"name": track}})
            events.append({"ph": "M", "name": "thread_sort_index", "pid": 0,
                           "tid": tid, "args": {"sort_index": tid}})
        for e in self._events:
            out = {"ph": e["ph"], "name": e["name"], "pid": 0,
                   "tid": e["tid"], "ts": e["ts"] * 1e6}
            if e["ph"] == "X":
                out["dur"] = e["dur"] * 1e6
            if e.get("cat"):
                out["cat"] = e["cat"]
            if e["ph"] == "i":
                out["s"] = "t"                      # thread-scoped instant
            if e.get("args"):
                out["args"] = e["args"]
            elif e["ph"] == "C":
                out["args"] = {}
            events.append(out)
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def save(self, path) -> pathlib.Path:
        path = pathlib.Path(path)
        path.write_text(json.dumps(self.chrome_trace(), indent=1))
        return path


# =============================================================================
# Schema validation (tests + the CI smoke both go through this)
# =============================================================================

_PHASES = {"X", "i", "C", "M"}


def validate_chrome_trace(data: Any) -> dict:
    """Validate a Chrome trace-event JSON object; raise ``ValueError`` on
    the first violation.  Returns summary stats (event/span/counter/track
    counts) so callers can assert on trace shape."""
    if not isinstance(data, dict) or "traceEvents" not in data:
        raise ValueError("trace must be an object with a 'traceEvents' list")
    events = data["traceEvents"]
    if not isinstance(events, list) or not events:
        raise ValueError("'traceEvents' must be a non-empty list")
    stats = {"events": len(events), "spans": 0, "instants": 0,
             "counters": 0, "metadata": 0, "tracks": set()}
    for i, e in enumerate(events):
        if not isinstance(e, dict):
            raise ValueError(f"event {i} is not an object")
        ph = e.get("ph")
        if ph not in _PHASES:
            raise ValueError(f"event {i}: unknown phase {ph!r}")
        if not isinstance(e.get("name"), str) or not e["name"]:
            raise ValueError(f"event {i}: missing/empty 'name'")
        if not isinstance(e.get("pid"), int) or not isinstance(
                e.get("tid"), int):
            raise ValueError(f"event {i}: 'pid'/'tid' must be integers")
        stats["tracks"].add((e["pid"], e["tid"]))
        if ph == "M":
            stats["metadata"] += 1
            continue
        ts = e.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            raise ValueError(f"event {i}: 'ts' must be a non-negative number")
        if ph == "X":
            stats["spans"] += 1
            dur = e.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                raise ValueError(
                    f"event {i}: complete event needs non-negative 'dur'")
        elif ph == "C":
            stats["counters"] += 1
            args = e.get("args")
            if not isinstance(args, dict) or not all(
                    isinstance(v, (int, float)) for v in args.values()):
                raise ValueError(
                    f"event {i}: counter 'args' must map to numbers")
        else:
            stats["instants"] += 1
    stats["tracks"] = len(stats["tracks"])
    return stats


# =============================================================================
# Per-request latency histogram (the serving engines' front-end metric)
# =============================================================================

class LatencyHistogram:
    """Log2-bucketed latency histogram: cheap to record, stable to report.

    Buckets double from ``base`` seconds (default 1 µs); everything above
    the last edge lands in the overflow bucket.  Quantiles are read from
    the bucket upper edges, so they are conservative (<= one bucket off),
    then clamped into ``[min_s, max_s]`` so an estimate never lies
    outside the recorded range.
    """

    def __init__(self, base: float = 1e-6, n_buckets: int = 32) -> None:
        self.edges = [base * (2.0 ** i) for i in range(n_buckets)]
        self.counts = [0] * (n_buckets + 1)
        self.n = 0
        self.total_s = 0.0
        self.min_s = 0.0
        self.max_s = 0.0

    def record(self, seconds: float) -> None:
        self.counts[bisect.bisect_left(self.edges, seconds)] += 1
        self.min_s = seconds if not self.n else min(self.min_s, seconds)
        self.n += 1
        self.total_s += seconds
        self.max_s = max(self.max_s, seconds)

    def quantile(self, q: float) -> float:
        """Upper-edge estimate of the ``q`` quantile (0 < q <= 1)."""
        if not self.n:
            return 0.0
        need = q * self.n
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= need and c:
                edge = self.edges[i] if i < len(self.edges) else self.max_s
                return min(max(edge, self.min_s), self.max_s)
        return self.max_s

    def summary(self) -> dict:
        return {
            "count": self.n,
            "mean_s": self.total_s / self.n if self.n else 0.0,
            "p50_s": self.quantile(0.50),
            "p95_s": self.quantile(0.95),
            "p99_s": self.quantile(0.99),
            "min_s": self.min_s,
            "max_s": self.max_s,
        }
