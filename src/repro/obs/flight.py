"""Flight recorder: a bounded ring of recent events, dumped on trouble.

A full :class:`~repro.obs.trace.TraceRecorder` grows without bound, so a
long-lived serving process can't leave one on.  The
:class:`FlightRecorder` is the black-box variant: the same recorder
contract (it *is* a ``TraceRecorder``, so the streamer/engine
instrumentation threads through unchanged), but the event buffer is a
``deque(maxlen=capacity)`` — old ticks fall off the back, memory stays
bounded, and at any moment the ring holds the most recent window of
pipeline activity.

It dumps that window as a normal Chrome trace (valid under
:func:`~repro.obs.trace.validate_chrome_trace`) when something goes
wrong:

* :meth:`on_slo_report` — wired into ``SloEvaluator.on_breach``; dumps
  when a report's verdict is ``breach``;
* :meth:`on_model_check` — dumps when a
  :class:`~repro.obs.modelcheck.ModelCheck` comes back ``ok is False``;
* :meth:`dump` — manual, for operator-initiated snapshots.

Each dump appends an ``instant`` event named ``flight:dump`` carrying
the trigger reason, so the trigger point is visible on the timeline.
Successive dumps overwrite ``path`` (the latest incident wins);
``dumps`` keeps the history of (path, reason) for tests and logs.
"""
from __future__ import annotations

import collections
import pathlib
from typing import Callable

from .trace import TraceRecorder

__all__ = ["FlightRecorder"]

DEFAULT_CAPACITY = 4096


class FlightRecorder(TraceRecorder):
    """A :class:`TraceRecorder` whose event buffer is a bounded ring.

    capacity
        how many raw events (spans/instants/counter updates) to retain;
        the ring keeps the newest.
    path
        default dump destination; :meth:`dump` may override per call.
    clock
        injectable, as on :class:`TraceRecorder` — tests use a stub.
    """

    def __init__(self, capacity: int = DEFAULT_CAPACITY, *,
                 path=None, clock: Callable[[], float] | None = None) -> None:
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        super().__init__(clock=clock)
        # TraceRecorder appends to / iterates self._events; a maxlen deque
        # keeps that contract while evicting the oldest events.
        self._events = collections.deque(self._events, maxlen=capacity)
        self.capacity = capacity
        self.path = pathlib.Path(path) if path is not None else None
        self.dumps: list[tuple[pathlib.Path, str]] = []

    # -- dumping --------------------------------------------------------------
    def dump(self, path=None, *, reason: str = "manual") -> pathlib.Path:
        """Write the current ring as a Chrome trace; returns the path."""
        target = pathlib.Path(path) if path is not None else self.path
        if target is None:
            raise ValueError("no dump path: pass one or set FlightRecorder("
                             "path=...)")
        self.instant("flight:dump", track="flight", cat="dump",
                     args={"reason": reason, "events": len(self._events)})
        out = self.save(target)
        self.dumps.append((out, reason))
        return out

    # -- triggers -------------------------------------------------------------
    def on_slo_report(self, report) -> pathlib.Path | None:
        """``SloEvaluator.on_breach`` hook: dump when the verdict breaches.

        Accepts any object with ``ok``/``verdict`` (an ``SloReport``).
        """
        if getattr(report, "ok", True):
            return None
        names = ",".join(c.objective for c in report.breaches())
        return self.dump(reason=f"slo_breach:{names or report.verdict}")

    def on_model_check(self, check) -> pathlib.Path | None:
        """Dump when a ``ModelCheck`` fails its structural gates."""
        if getattr(check, "ok", True):
            return None
        why = []
        if not getattr(check, "ticks_ok", True):
            why.append("ticks")
        if not getattr(check, "queues_ok", True):
            why.append("queues")
        return self.dump(reason=f"model_check:{'+'.join(why) or 'failed'}")
