"""SLO evaluation: rolling-window health scored against the paper's models.

SMOF's claims are quantitative — Eq. 6 says steady-state throughput is
``1 / max_j(L_j)``, Eq. 1 says a well-sized inter-stage ring never
stalls, and the device sheet says how much off-chip bandwidth exists to
spill into.  A production front-end should therefore be able to say *how
far from those bounds it is running*, continuously.  The
:class:`SloEvaluator` keeps a rolling window of serving observations and
scores four objectives, each emitting a ``pass`` / ``warn`` / ``breach``
verdict:

``fps``
    measured frames/s as a fraction of the **Eq. 6 roofline**
    (``roofline_fps``, e.g. the calibrated ``1 / (eq6_cycles *
    s_per_cycle)`` of the served plan).  Below ``fps_fraction_warn`` of
    the roofline is a warn; below ``fps_fraction_breach`` a breach.
``latency_p50`` / ``latency_p99``
    request-latency quantiles (from any object with a ``quantile(q)``,
    e.g. the serving engines' :class:`~repro.obs.trace.LatencyHistogram`)
    against configurable absolute targets.
``stall_ratio``
    queue stalls per queue operation over the window — Eq. 1 sizing says
    this should be 0; a rising ratio is the spill FIFO backpressuring.
``spill_bw``
    off-chip spill bandwidth (Gbit/s over the window) as a fraction of
    the device's ``bw_gbps`` (``Device.offchip_gbps``) — riding the DMA
    budget is exactly the regime the paper's Eq. 2 trades against.
``spill_bw_evict`` / ``spill_bw_restore``
    the same objective split by direction, each scored against its *own*
    budget — by default half the device number, or, when the plan was
    compiled with a channel config, the per-kind effective bandwidth the
    ``repro.memory`` arbiter actually granted that direction
    (``stream_budgets``).  The combined ``spill_bw`` check stays for
    backward compat; the split is what catches one-sided saturation
    (e.g. a restore-heavy skip connection) that the sum hides.

Objectives without data or targets are skipped, not failed.  A breach
fires every ``on_breach`` callback with the :class:`SloReport` — the
:class:`~repro.obs.flight.FlightRecorder` hooks in there to dump the
recent event ring for post-mortem.
"""
from __future__ import annotations

import collections
import dataclasses

__all__ = ["SloConfig", "SloCheck", "SloReport", "SloEvaluator",
           "PASS", "WARN", "BREACH"]

PASS, WARN, BREACH = "pass", "warn", "breach"
_SEVERITY = {PASS: 0, WARN: 1, BREACH: 2}


@dataclasses.dataclass(frozen=True)
class SloConfig:
    """Targets for the four objectives; ``None`` disables a latency check.

    Travels on ``CompileSpec.obs.slo`` and round-trips through
    ``Compiled.save``/``load`` (same forward-compat policy as
    :class:`~repro.obs.trace.ObsConfig`: unknown keys from a newer writer
    are ignored).
    """
    window: int = 64                      # rolling observations kept
    fps_fraction_warn: float = 0.5        # measured/roofline below -> warn
    fps_fraction_breach: float = 0.25     # measured/roofline below -> breach
    p50_target_s: float | None = None
    p99_target_s: float | None = None
    latency_warn_fraction: float = 0.8    # warn band: > fraction * target
    stall_ratio_warn: float = 0.01        # stalls per queue op
    stall_ratio_breach: float = 0.10
    spill_bw_fraction_warn: float = 0.5   # spill Gbps / device bw_gbps
    spill_bw_fraction_breach: float = 1.0

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "SloConfig":
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in known})


@dataclasses.dataclass(frozen=True)
class SloCheck:
    """One objective's verdict over the current window."""
    objective: str
    measured: float
    target: float            # the breach threshold the verdict gates on
    verdict: str
    detail: str = ""

    def summary(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class SloReport:
    checks: list[SloCheck]
    window: dict             # aggregate measured stats the checks read

    @property
    def verdict(self) -> str:
        worst = max((_SEVERITY[c.verdict] for c in self.checks), default=0)
        return {v: k for k, v in _SEVERITY.items()}[worst]

    @property
    def ok(self) -> bool:
        return self.verdict != BREACH

    def breaches(self) -> list[SloCheck]:
        return [c for c in self.checks if c.verdict == BREACH]

    def summary(self) -> dict:
        return {"verdict": self.verdict, "ok": self.ok,
                "window": dict(self.window),
                "checks": [c.summary() for c in self.checks]}


@dataclasses.dataclass(frozen=True)
class _Sample:
    frames: float
    seconds: float
    stalls: float
    queue_ops: float
    spill_bytes: float        # combined (kept for backward compat)
    evict_bytes: float
    restore_bytes: float


class SloEvaluator:
    """Rolling-window SLO scoring for one serving front-end.

    roofline_fps
        the Eq. 6 bound to score throughput against (``None``: fps
        objective skipped).
    bw_gbps
        the device's off-chip bandwidth budget
        (:attr:`~repro.core.resources.Device.offchip_gbps`; ``None``:
        spill objective skipped).
    latency
        any ``quantile(q) -> seconds`` provider — typically the serving
        engine's :class:`~repro.obs.trace.LatencyHistogram`.
    stream_budgets
        per-direction Gbit/s budgets for the split spill objectives,
        keyed by the arbiter's stream kinds (``activation-evict`` /
        ``activation-restore``) — e.g.
        ``MemoryModel.budget_gbps_by_kind()``.  Without them each
        direction defaults to half of ``bw_gbps``.
    """

    def __init__(self, cfg: SloConfig | None = None, *,
                 roofline_fps: float | None = None,
                 bw_gbps: float | None = None,
                 latency=None,
                 stream_budgets: dict[str, float] | None = None) -> None:
        self.cfg = cfg or SloConfig()
        self.roofline_fps = roofline_fps
        self.bw_gbps = bw_gbps
        self.latency = latency
        self.stream_budgets = dict(stream_budgets or {})
        self.on_breach: list = []         # callbacks: f(report) -> None
        self._samples: collections.deque[_Sample] = collections.deque(
            maxlen=max(self.cfg.window, 1))
        self.last_report: SloReport | None = None

    # -- intake ---------------------------------------------------------------
    def observe(self, *, frames: float, seconds: float, stalls: float = 0.0,
                queue_ops: float = 0.0, spill_bytes: float = 0.0,
                evict_bytes: float | None = None,
                restore_bytes: float | None = None) -> None:
        """Record one window sample (e.g. one served stream): ``frames``
        delivered over ``seconds`` of wall clock, with the queue/spill
        traffic that run generated.  ``evict_bytes``/``restore_bytes``
        split the spill traffic by direction; callers that only know the
        combined number get an even split (the pipelined spill story moves
        every evicted bit out once and back once, so halves are exact
        there)."""
        if seconds < 0 or frames < 0:
            raise ValueError(f"negative observation ({frames=}, {seconds=})")
        if evict_bytes is None and restore_bytes is None:
            evict_bytes = restore_bytes = spill_bytes / 2.0
        else:
            evict_bytes = evict_bytes or 0.0
            restore_bytes = restore_bytes or 0.0
            spill_bytes = max(spill_bytes, evict_bytes + restore_bytes)
        self._samples.append(_Sample(frames, seconds, stalls, queue_ops,
                                     spill_bytes, evict_bytes,
                                     restore_bytes))

    # -- window aggregates ----------------------------------------------------
    def _window(self) -> dict:
        frames = sum(s.frames for s in self._samples)
        seconds = sum(s.seconds for s in self._samples)
        stalls = sum(s.stalls for s in self._samples)
        ops = sum(s.queue_ops for s in self._samples)
        spill_bytes = sum(s.spill_bytes for s in self._samples)
        evict_bytes = sum(s.evict_bytes for s in self._samples)
        restore_bytes = sum(s.restore_bytes for s in self._samples)

        def gbps(nbytes: float) -> float:
            return (nbytes * 8 / 1e9) / seconds if seconds > 0 else 0.0

        return {
            "samples": len(self._samples),
            "frames": frames,
            "seconds": seconds,
            "fps": frames / seconds if seconds > 0 else 0.0,
            "stalls": stalls,
            "queue_ops": ops,
            "stall_ratio": stalls / ops if ops > 0 else 0.0,
            "spill_bytes": spill_bytes,
            "spill_gbps": gbps(spill_bytes),
            "evict_bytes": evict_bytes,
            "evict_gbps": gbps(evict_bytes),
            "restore_bytes": restore_bytes,
            "restore_gbps": gbps(restore_bytes),
        }

    # -- scoring --------------------------------------------------------------
    @staticmethod
    def _band(value: float, warn: float, breach: float, *,
              low_is_bad: bool) -> str:
        """Three-way verdict; ``low_is_bad`` flips the comparison sense."""
        if low_is_bad:
            if value < breach:
                return BREACH
            return WARN if value < warn else PASS
        if value > breach:
            return BREACH
        return WARN if value > warn else PASS

    def evaluate(self) -> SloReport:
        """Score every configured objective over the current window and
        fire ``on_breach`` callbacks if the overall verdict is a breach."""
        cfg = self.cfg
        win = self._window()
        checks: list[SloCheck] = []

        if self.roofline_fps and win["seconds"] > 0:
            frac = win["fps"] / self.roofline_fps
            checks.append(SloCheck(
                "fps", measured=win["fps"],
                target=cfg.fps_fraction_breach * self.roofline_fps,
                verdict=self._band(frac, cfg.fps_fraction_warn,
                                   cfg.fps_fraction_breach, low_is_bad=True),
                detail=f"{frac:.3f} of the Eq. 6 roofline "
                       f"({self.roofline_fps:.4g} fps)"))

        if self.latency is not None:
            for name, q, target in (("latency_p50", 0.50, cfg.p50_target_s),
                                    ("latency_p99", 0.99, cfg.p99_target_s)):
                if target is None:
                    continue
                measured = self.latency.quantile(q)
                checks.append(SloCheck(
                    name, measured=measured, target=target,
                    verdict=self._band(
                        measured, cfg.latency_warn_fraction * target,
                        target, low_is_bad=False),
                    detail=f"target {target:.4g}s"))

        if win["queue_ops"] > 0:
            checks.append(SloCheck(
                "stall_ratio", measured=win["stall_ratio"],
                target=cfg.stall_ratio_breach,
                verdict=self._band(win["stall_ratio"], cfg.stall_ratio_warn,
                                   cfg.stall_ratio_breach, low_is_bad=False),
                detail=f"{win['stalls']:.0f} stalls / "
                       f"{win['queue_ops']:.0f} queue ops (Eq. 1 says 0)"))

        if self.bw_gbps and win["seconds"] > 0:
            frac = win["spill_gbps"] / self.bw_gbps
            checks.append(SloCheck(
                "spill_bw", measured=win["spill_gbps"],
                target=cfg.spill_bw_fraction_breach * self.bw_gbps,
                verdict=self._band(frac, cfg.spill_bw_fraction_warn,
                                   cfg.spill_bw_fraction_breach,
                                   low_is_bad=False),
                detail=f"{frac:.3f} of the device's "
                       f"{self.bw_gbps:.4g} Gbps off-chip budget"))
            for name, kind, key in (
                    ("spill_bw_evict", "activation-evict", "evict_gbps"),
                    ("spill_bw_restore", "activation-restore",
                     "restore_gbps")):
                budget = self.stream_budgets.get(kind, self.bw_gbps / 2.0)
                if budget <= 0:
                    continue
                frac = win[key] / budget
                src = ("arbiter-granted" if kind in self.stream_budgets
                       else "half-device")
                checks.append(SloCheck(
                    name, measured=win[key],
                    target=cfg.spill_bw_fraction_breach * budget,
                    verdict=self._band(frac, cfg.spill_bw_fraction_warn,
                                       cfg.spill_bw_fraction_breach,
                                       low_is_bad=False),
                    detail=f"{frac:.3f} of the {src} "
                           f"{budget:.4g} Gbps budget"))

        report = SloReport(checks=checks, window=win)
        self.last_report = report
        if not report.ok:
            for cb in self.on_breach:
                cb(report)
        return report
