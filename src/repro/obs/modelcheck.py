"""ModelCheck: the paper's analytical models vs the measured pipeline.

SMOF's argument is analytical — Eq. 1 sizes the inter-stage buffers,
Eq. 5/6 predict frame time from per-stage latencies — and the telemetry
layer exists so those claims are *checkable* against a real run.  One
:class:`ModelCheck` compares, for one pipelined executor:

* **stage latencies** — the analytic per-stage ``L_j`` (initiation
  interval in cycles, the model the DSE ranks partitions with) against
  measured per-stage wall clock.  The two live in different units, so
  the check fits one through-origin scale ``s_per_cycle`` (exactly the
  autotuner's calibration regression) and reports the per-stage residual
  error — a stage whose measured share deviates is where the model is
  wrong;
* **schedule** — the measured tick count and steady-state tick count
  against the 1F1B diagram's ``T = B + S - 1`` / ``B - S + 1`` (the
  Eq. 6 regime is exactly the steady ticks);
* **queue depths** — each bounded ring's occupancy high-water mark and
  stall counts against its Eq. 1 capacity; a queue that stalls or rides
  its cap is mis-sized (the spill FIFO that would backpressure real
  hardware).

``check_stream`` builds one from a ``StreamReport``-like object plus the
measurements a traced run produced; ``Compiled.report()`` surfaces the
summary once ``Compiled.trace()`` has run.
"""
from __future__ import annotations

import dataclasses
import math

__all__ = ["StageLatencyCheck", "QueueDepthCheck", "ContentionCheck",
           "ModelCheck", "check_stream", "check_contention"]

_EPS = 1e-9


@dataclasses.dataclass(frozen=True)
class StageLatencyCheck:
    """One stage's predicted-vs-measured latency residual."""
    stage: int
    predicted_cycles: float        # analytic L_j (Eq. 5/6 input)
    measured_s: float | None       # per-stage wall clock (None: not measured)
    fitted_s: float | None         # predicted_cycles * s_per_cycle
    rel_err: float | None          # (measured - fitted) / fitted

    def summary(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class QueueDepthCheck:
    """One inter-stage ring vs its Eq. 1 capacity."""
    edge: str
    capacity: int
    high_water: int
    push_stalls: int
    pop_stalls: int

    @property
    def ok(self) -> bool:
        return (self.high_water <= self.capacity
                and self.push_stalls == 0 and self.pop_stalls == 0)

    def summary(self) -> dict:
        return dataclasses.asdict(self) | {"ok": self.ok}


@dataclasses.dataclass(frozen=True)
class ContentionCheck:
    """The off-chip channel model (``repro.memory``) vs one plan's run.

    Gated invariants (deterministic, unit-free — they must hold whatever
    the host wall clock does):

    * ordering — every contended stage latency ``max(L_j, X_j)`` is >= its
      uncontended ``L_j``, hence contended Eq. 6 >= uncontended Eq. 6;
    * capacity — the arbiter's grants sum to at most the channel's
      bits-per-cycle budget, and no stream got more than it asked for
      (the invariant the planted ``oversubscribe-channel`` fault breaks);
    * conservation — the arbiter's per-kind bit totals equal the
      ``StreamReport``'s spill volumes and streamed weight bits exactly
      (integer equality, no tolerance).

    The measured chain (steady tick seconds <= contended bound <=
    uncontended bound at the fitted ``s_per_cycle`` scale) is *reported*
    — a fused CPU tick can legitimately beat the per-stage-dispatch
    model — and gated only by callers that control their measurement
    (the acceptance tests drive it with stub clocks).
    """
    eq6_cycles: float
    eq6_contended_cycles: float
    latency_ordering_ok: bool          # max(L, X) >= L pointwise
    capacity_ok: bool                  # sum(granted) <= capacity
    grants_bounded_ok: bool            # granted <= demand per stream
    evict_bits_ok: bool                # arbiter evict bits == report spills
    restore_bits_ok: bool
    weight_bits_ok: bool
    feasible: bool                     # total demand fits the channel
    stall_cycles_total: float
    prefetch_deadline_misses: int
    steady_tick_seconds: float | None  # measured (None: no traced run)
    eq6_seconds: float | None          # uncontended bound at s_per_cycle
    eq6_contended_seconds: float | None

    @property
    def bits_conserved(self) -> bool:
        return (self.evict_bits_ok and self.restore_bits_ok
                and self.weight_bits_ok)

    @property
    def measured_within_bounds(self) -> bool | None:
        """The throughput chain ``measured fps <= contended-Eq.6 fps <=
        uncontended-Eq.6 fps``, stated on frame time: the measured steady
        tick must take at least the contended bound's seconds (which are
        >= the uncontended bound's by the latency ordering).  ``None``
        without a measurement or a fitted scale."""
        if self.steady_tick_seconds is None or self.eq6_seconds is None:
            return None
        bound = self.eq6_contended_seconds
        if not math.isfinite(bound):
            # a starved stream (fixed-priority oversubscription) predicts
            # 0 fps — nothing finite to compare against
            return None
        return self.steady_tick_seconds >= bound * (1.0 - 1e-6) - _EPS

    @property
    def ok(self) -> bool:
        return (self.latency_ordering_ok and self.capacity_ok
                and self.grants_bounded_ok and self.bits_conserved)

    def violations(self) -> list[str]:
        out: list[str] = []
        if not self.latency_ordering_ok:
            out.append("contention: contended stage latency below the "
                       "uncontended L_j (max(L,X) ordering broken)")
        if not self.capacity_ok:
            out.append("contention: arbiter grants exceed channel "
                       "capacity (oversubscribed off-chip port)")
        if not self.grants_bounded_ok:
            out.append("contention: a stream was granted more bandwidth "
                       "than it demanded")
        if not self.evict_bits_ok:
            out.append("contention: evict stream bits != report spill "
                       "volume (byte conservation broken)")
        if not self.restore_bits_ok:
            out.append("contention: restore stream bits != report spill "
                       "volume (byte conservation broken)")
        if not self.weight_bits_ok:
            out.append("contention: weight-fetch stream bits != report "
                       "streamed_weight_bits (byte conservation broken)")
        return out

    def summary(self) -> dict:
        return dataclasses.asdict(self) | {
            "ok": self.ok,
            "bits_conserved": self.bits_conserved,
            "measured_within_bounds": self.measured_within_bounds,
        }


def check_contention(report, *, s_per_cycle: float = 0.0,
                     steady_tick_seconds: float | None = None
                     ) -> ContentionCheck | None:
    """Build the :class:`ContentionCheck` for a ``StreamReport`` carrying a
    ``repro.memory.MemoryModel`` (``None`` when the plan was lowered
    without a channel config)."""
    mem = getattr(report, "memory", None)
    if mem is None:
        return None
    arb = mem.arbitration
    ordering = all(c >= l - _EPS for l, c in zip(mem.base_latencies,
                                                 mem.contended_latencies))
    capacity = (arb.total_granted_rate
                <= arb.capacity_bits_per_cycle * (1.0 + _EPS) + _EPS)
    bounded = all(s.granted_rate <= s.demand_rate + _EPS
                  for s in arb.streams)
    bits = arb.bits_by_kind()
    spill_bits = sum(int(r.offchip_bits) for r in report.spills)
    evict_ok = bits["activation-evict"] == spill_bits
    restore_ok = bits["activation-restore"] == spill_bits
    weight_ok = bits["weight-fetch"] == int(report.streamed_weight_bits)
    eq6_s = eq6c_s = None
    if s_per_cycle > 0:
        eq6_s = mem.eq6_cycles * s_per_cycle
        eq6c_s = (mem.eq6_contended_cycles * s_per_cycle
                  if math.isfinite(mem.eq6_contended_cycles) else math.inf)
    return ContentionCheck(
        eq6_cycles=mem.eq6_cycles,
        eq6_contended_cycles=mem.eq6_contended_cycles,
        latency_ordering_ok=ordering, capacity_ok=capacity,
        grants_bounded_ok=bounded, evict_bits_ok=evict_ok,
        restore_bits_ok=restore_ok, weight_bits_ok=weight_ok,
        feasible=arb.feasible,
        stall_cycles_total=mem.total_stall_cycles,
        prefetch_deadline_misses=mem.prefetch.deadline_misses,
        steady_tick_seconds=steady_tick_seconds,
        eq6_seconds=eq6_s, eq6_contended_seconds=eq6c_s)


@dataclasses.dataclass
class ModelCheck:
    """Measured-vs-model report for one pipelined run."""
    stages: list[StageLatencyCheck]
    queues: list[QueueDepthCheck]
    s_per_cycle: float             # fitted measured-seconds per analytic cycle
    ticks_predicted: int           # T = B + S - 1
    ticks_measured: int | None
    steady_predicted: int          # B - S + 1 (the Eq. 6 regime)
    steady_measured: int | None
    contention: ContentionCheck | None = None

    @property
    def ticks_ok(self) -> bool:
        return (self.ticks_measured is None
                or (self.ticks_measured == self.ticks_predicted
                    and self.steady_measured == self.steady_predicted))

    @property
    def queues_ok(self) -> bool:
        return all(q.ok for q in self.queues)

    @property
    def bottleneck_predicted(self) -> int:
        return max(range(len(self.stages)),
                   key=lambda j: self.stages[j].predicted_cycles)

    @property
    def bottleneck_measured(self) -> int | None:
        if any(s.measured_s is None for s in self.stages):
            return None
        return max(range(len(self.stages)),
                   key=lambda j: self.stages[j].measured_s)

    @property
    def bottleneck_agree(self) -> bool | None:
        m = self.bottleneck_measured
        return None if m is None else m == self.bottleneck_predicted

    @property
    def max_stage_rel_err(self) -> float | None:
        errs = [abs(s.rel_err) for s in self.stages if s.rel_err is not None]
        return max(errs) if errs else None

    @property
    def contention_ok(self) -> bool:
        """Channel-model invariants (vacuously true without a model)."""
        return self.contention is None or self.contention.ok

    @property
    def ok(self) -> bool:
        """Schedule walked as predicted, no queue is mis-sized, and the
        channel model (when present) holds its deterministic invariants.

        Stage-latency residuals are reported, not gated — wall clock on a
        shared host is noisy, and the residual's job is attribution."""
        return self.ticks_ok and self.queues_ok and self.contention_ok

    def violations(self) -> list[str]:
        """Every failed gated invariant, named — the conformance oracles
        (``repro.testing.oracle``) attach these to their failure reports so
        a fuzzed plan that breaks Eq. 1 / the 1F1B tick count says *which*
        queue or count broke, not just ``ok=False``."""
        out: list[str] = []
        if self.ticks_measured is not None:
            if self.ticks_measured != self.ticks_predicted:
                out.append(f"ticks: measured {self.ticks_measured} != "
                           f"predicted B+S-1 = {self.ticks_predicted}")
            if self.steady_measured != self.steady_predicted:
                out.append(f"steady ticks: measured {self.steady_measured} "
                           f"!= predicted B-S+1 = {self.steady_predicted}")
        for q in self.queues:
            if q.high_water > q.capacity:
                out.append(f"queue {q.edge}: high water {q.high_water} "
                           f"exceeds Eq.1 capacity {q.capacity}")
            if q.push_stalls or q.pop_stalls:
                out.append(f"queue {q.edge}: {q.push_stalls} push / "
                           f"{q.pop_stalls} pop stalls (Eq.1-sized rings "
                           f"must never stall)")
        if self.contention is not None:
            out.extend(self.contention.violations())
        return out

    def summary(self) -> dict:
        return {
            "ok": self.ok,
            "ticks_ok": self.ticks_ok,
            "queues_ok": self.queues_ok,
            "s_per_cycle": self.s_per_cycle,
            "ticks": {"predicted": self.ticks_predicted,
                      "measured": self.ticks_measured,
                      "steady_predicted": self.steady_predicted,
                      "steady_measured": self.steady_measured},
            "bottleneck": {"predicted": self.bottleneck_predicted,
                           "measured": self.bottleneck_measured,
                           "agree": self.bottleneck_agree},
            "max_stage_rel_err": self.max_stage_rel_err,
            "stages": [s.summary() for s in self.stages],
            "queues": [q.summary() for q in self.queues],
            "contention": (self.contention.summary()
                           if self.contention is not None else None),
        }


def check_stream(report, *, stage_seconds=None, queue_stats=None,
                 ticks_measured=None, steady_measured=None,
                 steady_tick_seconds=None) -> ModelCheck:
    """Build a :class:`ModelCheck` for one pipelined executor.

    report
        a ``StreamReport``-like: ``stage_latency`` (analytic cycles),
        ``n_stages``, ``microbatches``, ``ticks``, ``queue_stats``.
    stage_seconds
        measured per-stage wall clock (``measured_stage_latencies``), or
        ``None`` — latency checks then carry predictions only.
    queue_stats
        ``{edge: {capacity, high_water, push_stalls, pop_stalls}}`` from
        a traced run; defaults to the report's lowering-time simulation.
    ticks_measured / steady_measured
        tick counts a traced run actually walked (``None``: not run).
    steady_tick_seconds
        one measured steady-phase tick's wall clock (median), feeding the
        :class:`ContentionCheck`'s measured-vs-bound throughput chain.
    """
    pred = list(report.stage_latency)
    meas = list(stage_seconds) if stage_seconds is not None else None
    if meas is not None and len(meas) != len(pred):
        raise ValueError(f"{len(meas)} measured stages vs "
                         f"{len(pred)} predicted")
    # through-origin least squares: the calibration regression of
    # optim.autotune, one run's worth
    s_per_cycle = 0.0
    if meas is not None:
        denom = sum(p * p for p in pred)
        s_per_cycle = (sum(p * m for p, m in zip(pred, meas)) / denom
                       if denom else 0.0)
    stages = []
    for j, p in enumerate(pred):
        m = meas[j] if meas is not None else None
        fitted = p * s_per_cycle if meas is not None else None
        err = ((m - fitted) / fitted
               if fitted else None)
        stages.append(StageLatencyCheck(stage=j, predicted_cycles=p,
                                        measured_s=m, fitted_s=fitted,
                                        rel_err=err))
    qs = queue_stats if queue_stats is not None else report.queue_stats
    queues = [QueueDepthCheck(edge=e, capacity=st["capacity"],
                              high_water=st["high_water"],
                              push_stalls=st["push_stalls"],
                              pop_stalls=st["pop_stalls"])
              for e, st in sorted(qs.items())]
    S, B = report.n_stages, report.microbatches
    contention = check_contention(report, s_per_cycle=s_per_cycle,
                                  steady_tick_seconds=steady_tick_seconds)
    return ModelCheck(
        stages=stages, queues=queues, s_per_cycle=s_per_cycle,
        ticks_predicted=B + S - 1, ticks_measured=ticks_measured,
        steady_predicted=max(0, B - S + 1), steady_measured=steady_measured,
        contention=contention)
