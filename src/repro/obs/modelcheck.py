"""ModelCheck: the paper's analytical models vs the measured pipeline.

SMOF's argument is analytical — Eq. 1 sizes the inter-stage buffers,
Eq. 5/6 predict frame time from per-stage latencies — and the telemetry
layer exists so those claims are *checkable* against a real run.  One
:class:`ModelCheck` compares, for one pipelined executor:

* **stage latencies** — the analytic per-stage ``L_j`` (initiation
  interval in cycles, the model the DSE ranks partitions with) against
  measured per-stage wall clock.  The two live in different units, so
  the check fits one through-origin scale ``s_per_cycle`` (exactly the
  autotuner's calibration regression) and reports the per-stage residual
  error — a stage whose measured share deviates is where the model is
  wrong;
* **schedule** — the measured tick count and steady-state tick count
  against the 1F1B diagram's ``T = B + S - 1`` / ``B - S + 1`` (the
  Eq. 6 regime is exactly the steady ticks);
* **queue depths** — each bounded ring's occupancy high-water mark and
  stall counts against its Eq. 1 capacity; a queue that stalls or rides
  its cap is mis-sized (the spill FIFO that would backpressure real
  hardware).

``check_stream`` builds one from a ``StreamReport``-like object plus the
measurements a traced run produced; ``Compiled.report()`` surfaces the
summary once ``Compiled.trace()`` has run.
"""
from __future__ import annotations

import dataclasses

__all__ = ["StageLatencyCheck", "QueueDepthCheck", "ModelCheck",
           "check_stream"]


@dataclasses.dataclass(frozen=True)
class StageLatencyCheck:
    """One stage's predicted-vs-measured latency residual."""
    stage: int
    predicted_cycles: float        # analytic L_j (Eq. 5/6 input)
    measured_s: float | None       # per-stage wall clock (None: not measured)
    fitted_s: float | None         # predicted_cycles * s_per_cycle
    rel_err: float | None          # (measured - fitted) / fitted

    def summary(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class QueueDepthCheck:
    """One inter-stage ring vs its Eq. 1 capacity."""
    edge: str
    capacity: int
    high_water: int
    push_stalls: int
    pop_stalls: int

    @property
    def ok(self) -> bool:
        return (self.high_water <= self.capacity
                and self.push_stalls == 0 and self.pop_stalls == 0)

    def summary(self) -> dict:
        return dataclasses.asdict(self) | {"ok": self.ok}


@dataclasses.dataclass
class ModelCheck:
    """Measured-vs-model report for one pipelined run."""
    stages: list[StageLatencyCheck]
    queues: list[QueueDepthCheck]
    s_per_cycle: float             # fitted measured-seconds per analytic cycle
    ticks_predicted: int           # T = B + S - 1
    ticks_measured: int | None
    steady_predicted: int          # B - S + 1 (the Eq. 6 regime)
    steady_measured: int | None

    @property
    def ticks_ok(self) -> bool:
        return (self.ticks_measured is None
                or (self.ticks_measured == self.ticks_predicted
                    and self.steady_measured == self.steady_predicted))

    @property
    def queues_ok(self) -> bool:
        return all(q.ok for q in self.queues)

    @property
    def bottleneck_predicted(self) -> int:
        return max(range(len(self.stages)),
                   key=lambda j: self.stages[j].predicted_cycles)

    @property
    def bottleneck_measured(self) -> int | None:
        if any(s.measured_s is None for s in self.stages):
            return None
        return max(range(len(self.stages)),
                   key=lambda j: self.stages[j].measured_s)

    @property
    def bottleneck_agree(self) -> bool | None:
        m = self.bottleneck_measured
        return None if m is None else m == self.bottleneck_predicted

    @property
    def max_stage_rel_err(self) -> float | None:
        errs = [abs(s.rel_err) for s in self.stages if s.rel_err is not None]
        return max(errs) if errs else None

    @property
    def ok(self) -> bool:
        """Schedule walked as predicted and no queue is mis-sized.

        Stage-latency residuals are reported, not gated — wall clock on a
        shared host is noisy, and the residual's job is attribution."""
        return self.ticks_ok and self.queues_ok

    def violations(self) -> list[str]:
        """Every failed gated invariant, named — the conformance oracles
        (``repro.testing.oracle``) attach these to their failure reports so
        a fuzzed plan that breaks Eq. 1 / the 1F1B tick count says *which*
        queue or count broke, not just ``ok=False``."""
        out: list[str] = []
        if self.ticks_measured is not None:
            if self.ticks_measured != self.ticks_predicted:
                out.append(f"ticks: measured {self.ticks_measured} != "
                           f"predicted B+S-1 = {self.ticks_predicted}")
            if self.steady_measured != self.steady_predicted:
                out.append(f"steady ticks: measured {self.steady_measured} "
                           f"!= predicted B-S+1 = {self.steady_predicted}")
        for q in self.queues:
            if q.high_water > q.capacity:
                out.append(f"queue {q.edge}: high water {q.high_water} "
                           f"exceeds Eq.1 capacity {q.capacity}")
            if q.push_stalls or q.pop_stalls:
                out.append(f"queue {q.edge}: {q.push_stalls} push / "
                           f"{q.pop_stalls} pop stalls (Eq.1-sized rings "
                           f"must never stall)")
        return out

    def summary(self) -> dict:
        return {
            "ok": self.ok,
            "ticks_ok": self.ticks_ok,
            "queues_ok": self.queues_ok,
            "s_per_cycle": self.s_per_cycle,
            "ticks": {"predicted": self.ticks_predicted,
                      "measured": self.ticks_measured,
                      "steady_predicted": self.steady_predicted,
                      "steady_measured": self.steady_measured},
            "bottleneck": {"predicted": self.bottleneck_predicted,
                           "measured": self.bottleneck_measured,
                           "agree": self.bottleneck_agree},
            "max_stage_rel_err": self.max_stage_rel_err,
            "stages": [s.summary() for s in self.stages],
            "queues": [q.summary() for q in self.queues],
        }


def check_stream(report, *, stage_seconds=None, queue_stats=None,
                 ticks_measured=None, steady_measured=None) -> ModelCheck:
    """Build a :class:`ModelCheck` for one pipelined executor.

    report
        a ``StreamReport``-like: ``stage_latency`` (analytic cycles),
        ``n_stages``, ``microbatches``, ``ticks``, ``queue_stats``.
    stage_seconds
        measured per-stage wall clock (``measured_stage_latencies``), or
        ``None`` — latency checks then carry predictions only.
    queue_stats
        ``{edge: {capacity, high_water, push_stalls, pop_stalls}}`` from
        a traced run; defaults to the report's lowering-time simulation.
    ticks_measured / steady_measured
        tick counts a traced run actually walked (``None``: not run).
    """
    pred = list(report.stage_latency)
    meas = list(stage_seconds) if stage_seconds is not None else None
    if meas is not None and len(meas) != len(pred):
        raise ValueError(f"{len(meas)} measured stages vs "
                         f"{len(pred)} predicted")
    # through-origin least squares: the calibration regression of
    # optim.autotune, one run's worth
    s_per_cycle = 0.0
    if meas is not None:
        denom = sum(p * p for p in pred)
        s_per_cycle = (sum(p * m for p, m in zip(pred, meas)) / denom
                       if denom else 0.0)
    stages = []
    for j, p in enumerate(pred):
        m = meas[j] if meas is not None else None
        fitted = p * s_per_cycle if meas is not None else None
        err = ((m - fitted) / fitted
               if fitted else None)
        stages.append(StageLatencyCheck(stage=j, predicted_cycles=p,
                                        measured_s=m, fitted_s=fitted,
                                        rel_err=err))
    qs = queue_stats if queue_stats is not None else report.queue_stats
    queues = [QueueDepthCheck(edge=e, capacity=st["capacity"],
                              high_water=st["high_water"],
                              push_stalls=st["push_stalls"],
                              pop_stalls=st["pop_stalls"])
              for e, st in sorted(qs.items())]
    S, B = report.n_stages, report.microbatches
    return ModelCheck(
        stages=stages, queues=queues, s_per_cycle=s_per_cycle,
        ticks_predicted=B + S - 1, ticks_measured=ticks_measured,
        steady_predicted=max(0, B - S + 1), steady_measured=steady_measured)
