"""Per-tick telemetry for the pipelined streamer: the StreamTracer.

One :class:`StreamTracer` narrates one pipelined run — executed
(``StreamingExecutor.run_traced`` walks real jitted ticks and feeds wall
clock in) or simulated (``schedule.simulate_schedule`` walks the 1F1B
diagram in model time, one unit per tick).  Per tick it emits:

* a **tick span** on the ``pipeline`` track, categorised by the 1F1B
  phase (``fill`` / ``steady`` / ``drain``);
* one **stage span** per active stage on its own ``stage{j}`` track,
  named by the microbatch it processes — stages overlap within a tick,
  so stage spans share the tick's interval (that overlap *is* the
  pipeline diagram);
* **queue accounting** through the bounded rings (consumers pop before
  producers push, the double-buffer ordering of
  ``schedule.simulate_schedule``) with per-queue occupancy counters and
  stall instants;
* **spill counters** per crossing/evicted edge: bytes evicted where the
  producer stage runs, bytes restored where the consumer stage runs,
  plus BFP8 encode/decode counts — so ``bytes evicted == bytes
  restored`` per edge over any complete run is an emitted, testable
  invariant.

This module is deliberately dependency-free (duck-typed schedule, queues
and spill records), so property tests can drive it over randomly
generated plans without touching JAX.
"""
from __future__ import annotations

from .trace import NULL_RECORDER

__all__ = ["StreamTracer", "emit_spill_counters"]


def emit_spill_counters(recorder, record, *, ts: float | None = None,
                        evict: bool = True, restore: bool = True) -> None:
    """Count one microbatch's off-chip round-trip on one spilled edge.

    The executor's spill path is jitted, so counting happens here at the
    host-side boundary from the static :class:`SpillRecord` accounting —
    ``offchip_bits`` is what actually crosses (bit-exact for BFP8).  Both
    executors call this: the sequential one per frame, the streamer's
    tracer at producer/consumer ticks.
    """
    if not recorder.enabled:
        return
    edge = f"{record.src}->{record.dst}"
    nbytes = record.offchip_bits // 8
    if evict:
        recorder.incr(f"spill:{edge}:bytes_evicted", nbytes, ts)
        if record.codec == "bfp8":
            recorder.incr(f"bfp8:{edge}:encodes", 1, ts)
    if restore:
        recorder.incr(f"spill:{edge}:bytes_restored", nbytes, ts)
        if record.codec == "bfp8":
            recorder.incr(f"bfp8:{edge}:decodes", 1, ts)


class StreamTracer:
    """Drives span/counter emission for one pipelined run, tick by tick.

    Parameters are duck-typed on purpose:

    schedule
        a ``PipelineSchedule``: ``ticks``, ``phase(t)``,
        ``microbatch_at(stage, t)``, ``n_stages``, ``steady_ticks``.
    queues
        ``{(src, dst): RingBuffer}`` bounded rings (may be ``{}``); the
        tracer pops/pushes them per the schedule and emits occupancy
        counters plus stall instants.
    stage_of
        vertex -> stage map; resolves each queue edge's producer/consumer
        stage and attributes spill records to ticks.
    spill_records
        iterable of ``SpillRecord``-likes (``src``/``dst``/``codec``/
        ``offchip_bits``); cross-stage records count eviction at the
        producer's tick and restore at the consumer's, same-stage evicted
        records count both where their stage runs.
    """

    def __init__(self, recorder=NULL_RECORDER, schedule=None, *,
                 queues=None, stage_of=None, spill_records=(),
                 track_prefix: str = ""):
        if schedule is None:
            raise ValueError("StreamTracer needs a schedule")
        self.rec = recorder
        self.sched = schedule
        self.queues = dict(queues or {})
        self.stage_of = dict(stage_of or {})
        self.records = list(spill_records)
        self.prefix = track_prefix
        self.ticks_run = 0
        self.phase_counts = {"fill": 0, "steady": 0, "drain": 0}
        for (u, w) in self.queues:
            if u not in self.stage_of or w not in self.stage_of:
                raise ValueError(f"queue edge {(u, w)} missing from stage_of")

    # -- per-tick emission ----------------------------------------------------
    def tick(self, t: int, ts: float | None = None,
             dur: float = 1.0) -> None:
        """Account tick ``t``: spans, queue movement, spill counters.

        ``ts``/``dur`` are the tick's host wall-clock interval when the
        run is executed; simulation callers omit them and get model time
        (one unit per tick).
        """
        if ts is None:
            ts = float(t)
        phase = self.sched.phase(t)
        self.ticks_run += 1
        self.phase_counts[phase] += 1
        rec = self.rec
        end = ts + dur
        if rec.enabled:
            rec.add_span("tick", ts, dur, track=self.prefix + "pipeline",
                         cat=phase, args={"tick": t, "phase": phase})
            for j in self.sched.active_stages(t):
                b = self.sched.microbatch_at(j, t)
                rec.add_span(f"mb{b}", ts, dur,
                             track=self.prefix + f"stage{j}", cat=phase,
                             args={"tick": t, "stage": j, "microbatch": b})

        # queues: consumers pop first, then producers push — within a tick
        # the two ends act on different entries (the double buffer).  The
        # rings own their occupancy/stall emission (queues.py hooks).
        for (u, w), q in self.queues.items():
            if self.sched.microbatch_at(self.stage_of[w], t) is not None:
                q.pop(ts=end)
        for (u, w), q in self.queues.items():
            b = self.sched.microbatch_at(self.stage_of[u], t)
            if b is not None:
                q.push(b, ts=end)

        # spill traffic: evict at the producer's tick, restore at the
        # consumer's (same tick for same-stage evictions)
        for r in self.records:
            p, c = self.stage_of[r.src], self.stage_of[r.dst]
            emit_spill_counters(
                rec, r, ts=end,
                evict=self.sched.microbatch_at(p, t) is not None,
                restore=self.sched.microbatch_at(c, t) is not None)

    def run_model(self) -> dict:
        """Walk every tick in model time (no execution) and finish."""
        for t in range(self.sched.ticks):
            self.tick(t)
        return self.finish()

    def finish(self) -> dict:
        """Final accounting: per-queue stats, phase tick counts, totals."""
        return {
            "ticks_run": self.ticks_run,
            "phase_ticks": dict(self.phase_counts),
            "queues": {f"{u}->{w}": q.stats()
                       for (u, w), q in self.queues.items()},
            "counter_totals": self.rec.totals,
        }
