"""The one SMOF compile façade: ``CompileSpec`` -> ``Compiled`` artifact.

SMOF's pitch is a *toolflow*: one entry point takes a CNN graph plus a
device and emits a deployable streaming design with off-chip eviction
decisions baked in.  This module is that entry point for the whole repo —
the single seam where model resolution (``core.builders.get_model``),
plan search (``core.dse.run_dse`` / ``optim.autotune``), lowering
(``runtime.executor.lower_plan`` / ``runtime.streamer
.lower_plan_pipelined``), serving (``serving.engine.GraphStreamServer``)
and artifact persistence meet.  The low-level functions stay public, but
every driver in this repo (benchmarks, examples, serving, the autotune
CLI) goes through here:

    import repro

    compiled = repro.compile(repro.CompileSpec(
        model="unet_exec", device="u200", mode="pipelined"))
    y = compiled.run(x)                    # execute one frame / stream
    print(compiled.report())               # unified traffic + schedule view
    compiled.save("unet.smof.json")        # versioned plan artifact
    srv = compiled.serve()                 # batched streaming front-end

    again = repro.Compiled.load("unet.smof.json")   # fresh process OK:
    again.run(x)                           # bit-identical (seeded params)

Spec knobs -> subsystems
------------------------
``strategy``  "dse" (Algorithm 1, the default), "autotune" (closed-loop
              measured search, ``optim/autotune.py``), or "manual-plan"
              (caller supplies ``spec.plan``).
``mode``      "reference" (dense baseline, no plan), "staged" (sequential
              executor, the Eq. 5 regime), "pipelined" (1F1B streamer,
              the Eq. 6 regime).
``kernel_mode`` / ``use_pallas`` / ``interpret``
              kernel dispatch policy (``use_pallas`` is the boolean
              shorthand: True -> "pallas", False -> "reference").
``microbatches`` stream depth B the pipelined executor is traced for
              (an ``autotune_cfg`` overrides it with the depth the search
              measured at).
``dse`` / ``autotune_cfg`` / ``seed``
              search configuration; ``seed`` also fixes the deterministic
              per-vertex weights, which is what makes saved artifacts
              reproduce bit-identically in a fresh process.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import pathlib
from typing import Any

from .core.builders import (EXEC_MODELS, PAPER_MODELS, exec_input_shape,
                            get_model)
from .core.dse import DSEConfig, run_dse
from .core.graph import Graph
from .core.plan import ExecutionPlan, PLAN_SCHEMA_VERSION, plan_from_dse
from .core.resources import ALL_DEVICES, Device, get_device
from .memory import POLICIES, ChannelConfig
from .obs.metrics import MetricsRegistry
from .obs.trace import NULL_RECORDER, ObsConfig, TraceRecorder

MODES = ("reference", "staged", "pipelined")
STRATEGIES = ("dse", "autotune", "manual-plan")

ARTIFACT_KIND = "smof-compiled"
ARTIFACT_SCHEMA_VERSION = 1

# The default executable-path DSE configuration: eviction + fragmentation
# friendly settings at 16-bit stream words (matches the autotuner's seed).
_DEFAULT_DSE = DSEConfig(batch=1, codecs=("none", "bfp8"), word_bits=16,
                         cut_kinds=("pool", "conv"))


@dataclasses.dataclass
class CompileSpec:
    """Everything the toolflow needs to go graph + device -> executable.

    ``model`` is a registry name (``EXEC_MODELS`` / ``PAPER_MODELS``) or an
    already-built :class:`~repro.core.graph.Graph`; ``device`` a registry
    name (``ALL_DEVICES``) or a :class:`~repro.core.resources.Device`.
    """
    model: str | Graph
    device: str | Device = "u200"
    strategy: str = "dse"              # dse | autotune | manual-plan
    mode: str = "staged"               # reference | staged | pipelined
    kernel_mode: str = "auto"          # auto | pallas | reference
    microbatches: int = 8              # pipelined stream depth B
    use_pallas: bool | None = None     # bool shorthand over kernel_mode
    autotune_cfg: Any = None           # optim.autotune.AutotuneConfig
    seed: int = 0                      # weight init + search RNG
    plan: ExecutionPlan | None = None  # strategy="manual-plan" input
    dse: DSEConfig | None = None       # strategy="dse" knobs
    interpret: bool | None = None      # Pallas interpret-mode override
    placement: str = "auto"            # pipelined: interleave | shard_map
    obs: ObsConfig = dataclasses.field(default_factory=ObsConfig)
    #: opt-in off-chip channel model (``repro.memory``): arbitration
    #: policy + optional gbps override; pipelined lowerings then carry the
    #: contended Eq. 5/6 bounds and prefetch deadline accounting.
    channel: ChannelConfig | None = None

    def resolved_kernel_mode(self) -> str:
        if self.use_pallas is None:
            return self.kernel_mode
        return "pallas" if self.use_pallas else "reference"

    def validate(self) -> None:
        if self.mode not in MODES:
            raise ValueError(f"unknown mode {self.mode!r}; pick one of "
                             f"{MODES}")
        if self.strategy not in STRATEGIES:
            raise ValueError(f"unknown strategy {self.strategy!r}; pick one "
                             f"of {STRATEGIES}")
        if (self.strategy == "manual-plan" and self.plan is None
                and self.mode != "reference"):
            raise ValueError('strategy="manual-plan" needs spec.plan '
                             '(mode="reference" is the plan-free baseline)')
        if self.microbatches < 1:
            raise ValueError(f"need >= 1 microbatch, got {self.microbatches}")


def _resolve_graph(spec: CompileSpec) -> Graph:
    if isinstance(spec.model, Graph):
        return spec.model
    return get_model(spec.model)()


def _resolve_device(spec: CompileSpec) -> Device:
    if isinstance(spec.device, Device):
        return spec.device
    return get_device(spec.device)


def _device_name(spec: CompileSpec, plan: ExecutionPlan | None) -> str:
    if isinstance(spec.device, Device):
        return spec.device.name
    if spec.strategy == "manual-plan" and plan is not None and plan.device:
        return plan.device          # the artifact's own record wins
    return spec.device


def _autotune_digest(result) -> str:
    """Stable short digest of the search trajectory (provenance stamp)."""
    payload = json.dumps(result.trajectory_rows(), sort_keys=True)
    return hashlib.sha256(payload.encode()).hexdigest()[:16]


def build_plan(spec: CompileSpec, graph: Graph | None = None, *,
               metrics: MetricsRegistry | None = None
               ) -> tuple[ExecutionPlan | None, Any]:
    """Resolve the spec's decision vector: ``(plan, autotune_result)``.

    This is the search half of :func:`compile` — usable on its own for
    paper-scale (cost-model-only) graphs that cannot be lowered.  Returns
    ``(None, None)`` for ``mode="reference"`` (the dense baseline ignores
    any plan) and ``autotune_result=None`` unless ``strategy="autotune"``.

    The returned plan carries provenance: strategy, device name, and — for
    autotuned plans — the calibration ``s_per_cycle`` plus a digest of the
    measured trajectory.
    """
    spec.validate()
    g = graph if graph is not None else _resolve_graph(spec)
    if spec.mode == "reference":
        return None, None

    autotune_result = None
    cfg = None
    if spec.strategy == "manual-plan":
        plan = spec.plan
        if plan is not None:
            plan.validate()       # typed PlanValidationError, not a crash
                                  # deep inside the lowering
    elif spec.strategy == "autotune":
        from .optim.autotune import AutotuneConfig, autotune
        cfg = spec.autotune_cfg or AutotuneConfig(
            microbatches=spec.microbatches,
            kernel_mode=spec.resolved_kernel_mode(), seed=spec.seed)
        rec = TraceRecorder() if spec.obs.enabled else NULL_RECORDER
        autotune_result = autotune(g, _resolve_device(spec), cfg,
                                   recorder=rec, metrics=metrics)
        plan = autotune_result.best_plan
    else:                                     # "dse": Algorithm 1
        dev = _resolve_device(spec)
        res = run_dse(g, dev, spec.dse or _DEFAULT_DSE)
        plan = plan_from_dse(g.name, dev.name, res,
                             microbatch=spec.microbatches)

    prov = {"compiled_by": "repro.api.compile",
            "strategy": spec.strategy,
            "device": _device_name(spec, plan),
            "seed": spec.seed}
    if autotune_result is not None:
        prov.update({
            "s_per_cycle": autotune_result.calibration.s_per_cycle,
            "autotune_digest": _autotune_digest(autotune_result),
            "autotune_candidates": len(autotune_result.trajectory),
            # the search's own knobs — a caller-supplied cfg may differ
            # from the spec's, and provenance records what actually ran
            "autotune_seed": cfg.seed,
            "autotune_kernel_mode": cfg.kernel_mode,
            "baseline_fps": autotune_result.baseline_fps,
            "best_fps": autotune_result.best_fps,
        })
    for k, v in prov.items():
        plan.provenance.setdefault(k, v)
    return plan, autotune_result


def compile(spec: CompileSpec) -> "Compiled":
    """The toolflow entry point: resolve, search, lower — one call.

    Resolves the graph through the model registry, produces an
    :class:`~repro.core.plan.ExecutionPlan` per ``spec.strategy``, lowers
    it per ``spec.mode``, and returns a :class:`Compiled` artifact that can
    run, serve, report, and persist itself.  Numerics are bit-identical to
    calling the underlying ``lower_plan`` / ``lower_plan_pipelined``
    directly with the same plan and seed.
    """
    spec.validate()
    g = _resolve_graph(spec)
    # one registry per artifact: the autotune search, traced runs and any
    # server built from this compile all land on the same scrape surface
    registry = MetricsRegistry()
    plan, autotune_result = build_plan(spec, g, metrics=registry)
    km = spec.resolved_kernel_mode()

    if spec.mode == "reference":
        from .runtime.executor import reference_pipeline
        executor = reference_pipeline(g, seed=spec.seed)
    elif spec.mode == "staged":
        from .runtime.executor import lower_plan
        executor = lower_plan(g, plan, kernel_mode=km, seed=spec.seed,
                              interpret=spec.interpret)
    else:                                     # "pipelined"
        from .runtime.streamer import lower_plan_pipelined
        B = spec.microbatches
        if autotune_result is not None:       # serve at the measured depth
            B = autotune_result.microbatches
        try:
            dev = _resolve_device(spec)
        except (KeyError, ValueError):
            dev = None
        executor = lower_plan_pipelined(
            g, plan, microbatches=B, kernel_mode=km, seed=spec.seed,
            interpret=spec.interpret, placement=spec.placement,
            channel=spec.channel, device=dev)

    return Compiled(spec=spec, graph=g, device=_device_name(spec, plan),
                    plan=plan, executor=executor,
                    autotune_result=autotune_result, registry=registry)


@dataclasses.dataclass
class Compiled:
    """A deployable compiled design: executor + plan + provenance.

    ``run(x)`` executes (staged/reference: one ``(m, c)`` frame ->
    ``(L,)``; pipelined: a ``(B, m, c)`` stream -> ``(B, L)``, or a single
    frame, broadcast through the pipeline, -> ``(L,)``).  ``serve()``
    wraps the pipelined executor in a :class:`GraphStreamServer`;
    ``report()`` unifies the Spill/Stream/Calibration reports; ``save`` /
    ``load`` round-trip a versioned plan artifact that reproduces
    bit-identically in a fresh process (weights are seeded).
    """
    spec: CompileSpec
    graph: Graph
    device: str
    plan: ExecutionPlan | None
    executor: Any                    # LoweredPipeline | StreamingExecutor
    autotune_result: Any = None      # optim.autotune.AutotuneResult
    model_check: Any = None          # obs.ModelCheck, set by trace()
    recorder: Any = None             # obs.TraceRecorder, set by trace()
    # one scrape surface per artifact: trace() and serve() both feed it
    registry: MetricsRegistry = dataclasses.field(
        default_factory=MetricsRegistry)

    @property
    def model(self) -> str:
        return self.graph.name

    @property
    def mode(self) -> str:
        return self.spec.mode

    @property
    def strategy(self) -> str:
        """Where the plan's decisions came from.  Reads the plan's own
        provenance when present, so a loaded artifact (whose spec strategy
        is necessarily "manual-plan" — decisions are baked in) still
        reports and re-saves the strategy that produced it."""
        if self.plan is not None and "strategy" in self.plan.provenance:
            return self.plan.provenance["strategy"]
        return self.spec.strategy

    def __call__(self, x):
        return self.run(x)

    def run(self, x):
        import jax.numpy as jnp
        x = jnp.asarray(x)
        if self.mode == "pipelined" and x.ndim == 2:
            # single-frame convenience: broadcast through the stream,
            # every slot computes the same frame — return one output
            B = self.executor.microbatches
            return self.executor(jnp.broadcast_to(x, (B,) + x.shape))[0]
        return self.executor(x)

    def input_shape(self) -> tuple[int, int]:
        return exec_input_shape(self.graph)

    # -- unified reporting ----------------------------------------------------
    def report(self) -> dict:
        """One dict over all report families the toolflow produced:
        SpillReport (staged) / StreamReport (pipelined) summaries under
        ``traffic``, plan provenance, and — when the autotuner ran — its
        summary incl. the CalibrationReport."""
        out = {
            "model": self.model,
            "device": self.device,
            "mode": self.mode,
            "strategy": self.strategy,
            "kernel_mode": self.spec.resolved_kernel_mode(),
            "schema_version": (self.plan.schema_version if self.plan
                               else PLAN_SCHEMA_VERSION),
            "n_stages": self.plan.n_stages if self.plan else 1,
            "traffic": self.executor.report.summary(),
        }
        if self.plan is not None:
            out["provenance"] = dict(self.plan.provenance)
        if self.autotune_result is not None:
            out["autotune"] = self.autotune_result.summary()
        if self.model_check is not None:
            out["model_check"] = self.model_check.summary()
        return out

    def metrics(self) -> dict:
        """The artifact's metrics snapshot (``{sample_key: value}``).

        Every traced run (:meth:`trace`) and every server built by
        :meth:`serve` feeds the artifact's one
        :class:`~repro.obs.metrics.MetricsRegistry`, so this is the whole
        design's scrape surface; :meth:`metrics_text` is the Prometheus
        exposition of the same registry.
        """
        return self.registry.snapshot()

    def metrics_text(self) -> str:
        """Prometheus text exposition of :meth:`metrics`."""
        return self.registry.metrics_text()

    # -- tracing --------------------------------------------------------------
    def trace(self, x=None, *, path=None, recorder=None):
        """Execute once with telemetry on; returns ``(outputs, ModelCheck)``.

        Pipelined designs run tick-by-tick through
        ``StreamingExecutor.run_traced`` — per-tick wall-clock spans, queue
        counters, spill bytes — and yield a full
        :class:`~repro.obs.ModelCheck` (measured vs Eq. 5/6 latencies,
        Eq. 1 queue bounds), which subsequent :meth:`report` calls include.
        Staged/reference designs record one frame span plus spill counters
        and yield ``model_check=None``.

        ``x=None`` synthesizes a seeded input stream; ``path`` (default:
        ``spec.obs.trace_path``) writes the Chrome trace-event JSON —
        open it in Perfetto / ``chrome://tracing``.

        With ``spec.obs.flight_capacity > 0`` the default recorder is a
        bounded :class:`~repro.obs.flight.FlightRecorder` ring instead,
        which auto-dumps to ``spec.obs.flight_path`` if the run's
        ModelCheck comes back violated.
        """
        import jax.numpy as jnp
        import numpy as np

        if recorder is not None:
            rec = recorder
        elif self.spec.obs.flight_capacity > 0:
            from .obs.flight import FlightRecorder
            rec = FlightRecorder(self.spec.obs.flight_capacity,
                                 path=self.spec.obs.flight_path)
        else:
            rec = TraceRecorder()
        m, c = self.input_shape()
        if x is None:
            rng = np.random.default_rng(self.spec.seed)
            x = jnp.asarray(rng.normal(size=(m, c)).astype(np.float32))
        else:
            x = jnp.asarray(x)
        mc = None
        if self.mode == "pipelined":
            if x.ndim == 2:
                B = self.executor.microbatches
                x = jnp.broadcast_to(x, (B,) + x.shape)
            y, mc = self.executor.run_traced(x, rec, metrics=self.registry)
        else:
            y = self.executor.run_traced(x, rec)
        self.model_check = mc
        self.recorder = rec
        if (mc is not None and getattr(rec, "path", None) is not None
                and hasattr(rec, "on_model_check")):
            rec.on_model_check(mc)       # flight ring: dump on violation
        path = path if path is not None else self.spec.obs.trace_path
        if path is not None and rec.enabled:
            rec.save(path)
        return y, mc

    # -- serving --------------------------------------------------------------
    def serve(self, *, resident_limit: int = 0, **kw):
        """Batched streaming front-end around this design.

        Reuses the pipelined executor when this artifact is already
        pipelined and no overrides are given; otherwise re-lowers the same
        plan pipelined with ``kw`` applied as :class:`CompileSpec`
        overrides (e.g. ``microbatches=16``).  Unless overridden, the
        stream depth follows the current executor's (so an autotuned
        artifact keeps serving at the depth the search measured at).
        ``resident_limit`` bounds the flushed-but-unclaimed results kept
        resident by the server (oldest spill to an exact host byte store).

        The server shares this artifact's metrics registry (one scrape
        surface, read via :meth:`metrics` / ``server.metrics_text()``).
        When ``spec.obs.slo`` is set, a rolling-window
        :class:`~repro.obs.slo.SloEvaluator` is attached — roofline from
        the plan's calibrated provenance, spill bandwidth budget from the
        device sheet — and, with ``spec.obs.flight_capacity > 0``, an SLO
        breach dumps a :class:`~repro.obs.flight.FlightRecorder` ring to
        ``spec.obs.flight_path``."""
        from .serving.engine import GraphStreamServer
        if self.mode != "pipelined" and self.plan is None:
            raise ValueError(
                'mode="reference" compiles are plan-free and cannot be '
                'served; compile with mode="staged"/"pipelined" (any '
                "strategy) to get a servable plan")
        if self.mode == "pipelined" and not kw:
            sx = self.executor
        else:
            kw.setdefault("microbatches",
                          getattr(self.executor, "microbatches",
                                  self.spec.microbatches))
            sx = compile(dataclasses.replace(
                self.spec, mode="pipelined", strategy="manual-plan",
                plan=self.plan, **kw)).executor
        srv = GraphStreamServer(executor=sx, metrics=self.registry,
                                resident_limit=resident_limit)
        srv.autotune_result = self.autotune_result
        if self.spec.obs.slo is not None:
            try:
                bw = _resolve_device(self.spec).offchip_gbps
            except (KeyError, ValueError):
                bw = None
            evaluator = srv.enable_slo(self.spec.obs.slo, bw_gbps=bw)
            if (self.spec.obs.flight_capacity > 0
                    and self.spec.obs.flight_path is not None):
                from .obs.flight import FlightRecorder
                flight = FlightRecorder(self.spec.obs.flight_capacity,
                                        path=self.spec.obs.flight_path)
                evaluator.on_breach.append(flight.on_slo_report)
                srv.flight = flight
        return srv

    # -- persistence ----------------------------------------------------------
    def save(self, path) -> pathlib.Path:
        """Write the versioned compile artifact (JSON): the plan with its
        provenance, the graph structure (so custom-built graphs reload
        exactly, without the model registry), plus every spec knob ``load``
        needs to re-lower it."""
        path = pathlib.Path(path)
        B = (self.executor.microbatches if self.mode == "pipelined"
             else self.spec.microbatches)
        payload = {
            "artifact": ARTIFACT_KIND,
            "artifact_schema_version": ARTIFACT_SCHEMA_VERSION,
            "plan_schema_version": (self.plan.schema_version if self.plan
                                    else PLAN_SCHEMA_VERSION),
            "model": self.model,
            "device": self.device,
            "mode": self.mode,
            "strategy": self.strategy,   # decision origin: save/load-stable
            "kernel_mode": self.spec.resolved_kernel_mode(),
            # the resolved Pallas interpret override (None = decide per
            # backend via kernels.ops.resolve_interpret): saved so the
            # artifact replays with the kernel path it was compiled with
            "interpret": self.spec.interpret,
            "microbatches": B,
            "seed": self.spec.seed,
            "placement": self.spec.placement,
            "obs": self.spec.obs.to_dict(),
            "channel": (self.spec.channel.to_dict()
                        if self.spec.channel is not None else None),
            "graph": self.graph.to_json_dict(),
            "plan": (json.loads(self.plan.to_json())
                     if self.plan is not None else None),
        }
        path.write_text(json.dumps(payload, indent=1))
        return path

    @staticmethod
    def load(path) -> "Compiled":
        """Reconstruct a saved artifact and re-lower it.

        The artifact bakes the searched decisions in, so loading never
        re-runs DSE or the autotuner (``strategy`` becomes "manual-plan")
        and rebuilds the graph from the embedded structural dump; with the
        stored seed the reconstructed executor is bit-identical — including
        in a fresh process."""
        d = json.loads(pathlib.Path(path).read_text())
        if d.get("artifact") != ARTIFACT_KIND:
            raise ValueError(f"{path}: not a {ARTIFACT_KIND} artifact")
        if d.get("artifact_schema_version", 0) > ARTIFACT_SCHEMA_VERSION:
            raise ValueError(
                f"{path}: artifact schema v{d['artifact_schema_version']} is "
                f"newer than this toolflow (v{ARTIFACT_SCHEMA_VERSION})")
        plan = (ExecutionPlan.from_json(json.dumps(d["plan"]))
                if d.get("plan") is not None else None)
        model = (Graph.from_json_dict(d["graph"]) if d.get("graph")
                 else d["model"])
        spec = CompileSpec(
            model=model, device=d["device"], strategy="manual-plan",
            mode=d["mode"], kernel_mode=d["kernel_mode"],
            microbatches=d["microbatches"], seed=d["seed"],
            interpret=d.get("interpret"),
            placement=d.get("placement", "auto"), plan=plan,
            obs=ObsConfig.from_dict(d.get("obs", {})),
            channel=(ChannelConfig.from_dict(d["channel"])
                     if d.get("channel") else None))
        return compile(spec)


# =============================================================================
# Shared CLI surface (examples / benchmark / autotune entry points)
# =============================================================================

def add_compile_args(ap, *, default_model: str | None = "unet_exec",
                     default_device: str = "u200",
                     default_mode: str = "staged",
                     models: dict | None = None,
                     modes: tuple[str, ...] = MODES):
    """Attach the canonical ``--model/--device/--mode`` flags to ``ap``.

    Choices come from the registries (``EXEC_MODELS`` + ``PAPER_MODELS``
    by default, or the narrower ``models`` dict), never from hand-kept
    lists — a new registered builder is immediately reachable from every
    CLI that uses this helper.  ``modes`` narrows the ``--mode`` choices
    for CLIs where some modes make no sense (e.g. the plan-free
    "reference" mode in the autotune CLI)."""
    names = sorted(models if models is not None
                   else {**EXEC_MODELS, **PAPER_MODELS})
    ap.add_argument("--model", default=default_model, choices=names,
                    help=f"model registry name (default: {default_model})")
    ap.add_argument("--device", default=default_device,
                    choices=sorted(ALL_DEVICES),
                    help=f"device registry name (default: {default_device})")
    ap.add_argument("--mode", default=default_mode, choices=list(modes),
                    help=f"execution mode (default: {default_mode})")
    ap.add_argument("--kernel-mode", default="auto",
                    choices=("auto", "pallas", "reference"),
                    help="kernel dispatch: pallas = streaming_conv bodies "
                         "with the fused BFP8 boundary codec (interpret "
                         "mode off TPU), reference = pure-jnp oracles, "
                         "auto = pallas on TPU only (default)")
    ap.add_argument("--channel", default=None, choices=list(POLICIES),
                    help="model the shared off-chip channel with this "
                         "arbitration policy (default: off)")
    ap.add_argument("--channel-gbps", default=None, type=float,
                    help="override the device's off-chip bandwidth for "
                         "the channel model (implies --channel "
                         "round-robin when --channel is not given)")
    return ap


def spec_from_args(args, **overrides) -> CompileSpec:
    """Build a :class:`CompileSpec` from ``add_compile_args`` output."""
    kw: dict[str, Any] = {"model": args.model, "device": args.device,
                          "mode": args.mode}
    if getattr(args, "kernel_mode", None) is not None:
        kw["kernel_mode"] = args.kernel_mode
    policy = getattr(args, "channel", None)
    gbps = getattr(args, "channel_gbps", None)
    if policy is not None or gbps is not None:
        kw["channel"] = ChannelConfig(policy=policy or "round-robin",
                                      gbps=gbps)
    kw.update(overrides)
    return CompileSpec(**kw)
