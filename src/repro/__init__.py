"""SMOF reproduction — streaming CNNs with smart off-chip eviction.

The public toolflow surface is the compile façade (``repro.api``):

    import repro

    compiled = repro.compile(repro.CompileSpec(
        model="unet_exec", device="u200", mode="pipelined"))
    y = compiled.run(x)

Subpackages (``repro.core``, ``repro.runtime``, ``repro.optim``, ...)
remain importable directly for low-level use; the façade names below are
resolved lazily (PEP 562) so ``import repro.core`` does not drag in the
executor/serving stack.
"""

_API_NAMES = ("CompileSpec", "Compiled", "compile", "build_plan",
              "add_compile_args", "spec_from_args", "MODES", "STRATEGIES")

# telemetry surface (repro.obs), same lazy resolution
_OBS_NAMES = ("ObsConfig", "TraceRecorder", "NullRecorder", "ModelCheck",
              "ContentionCheck", "LatencyHistogram", "validate_chrome_trace",
              "MetricsRegistry", "parse_metrics_text",
              "SloConfig", "SloEvaluator", "FlightRecorder")

# off-chip channel surface (repro.memory), same lazy resolution
_MEMORY_NAMES = ("ChannelConfig", "MemoryModel")

__all__ = list(_API_NAMES) + list(_OBS_NAMES) + list(_MEMORY_NAMES)


def __getattr__(name):
    if name in _API_NAMES:
        from . import api
        return getattr(api, name)
    if name in _OBS_NAMES:
        from . import obs
        return getattr(obs, name)
    if name in _MEMORY_NAMES:
        from . import memory
        return getattr(memory, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_API_NAMES) | set(_OBS_NAMES)
                  | set(_MEMORY_NAMES))
