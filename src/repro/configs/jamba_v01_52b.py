"""jamba-v0.1-52b — 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536,
MoE 16 experts top-2; Mamba+attention 1:7 interleave, MoE every other
layer.  [arXiv:2403.19887; hf]"""
from repro.models.config import ArchConfig, MoECfg

CONFIG = ArchConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=65536, head_dim=128,
    moe=MoECfg(n_experts=16, top_k=2, every_k_layers=2),
    pattern=("mamba", "mamba", "mamba", "mamba",
             "attn", "mamba", "mamba", "mamba"),
    act="swiglu", norm="rmsnorm", rope="none",   # jamba: no rope in attn
    d_state=16, d_conv=4, ssm_expand=2,
)
