"""whisper-large-v3 — enc-dec, 32L d_model=1280 20H (kv=20) d_ff=5120
vocab=51866; conv frontend is a STUB (input_specs provides precomputed
frame embeddings).  [arXiv:2212.04356; unverified]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-large-v3", family="audio",
    n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20,
    d_ff=5120, vocab=51866, head_dim=64,
    encoder_layers=32, enc_frames=1500,
    act="gelu", norm="layernorm", rope="none",   # whisper uses learned pos
)
