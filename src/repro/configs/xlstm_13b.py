"""xlstm-1.3b — 48L d_model=2048 4H (kv=4) d_ff=0 vocab=50304;
sLSTM + mLSTM blocks (xLSTM[7:1] interleave, no separate FFN — the mLSTM
block carries a 2x inner expansion).  [arXiv:2405.04517; unverified]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50304, head_dim=512,
    pattern=("mlstm",) * 7 + ("slstm",),
    act="gelu", norm="layernorm", rope="none", ssm_expand=2,
)
