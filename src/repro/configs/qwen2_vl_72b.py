"""qwen2-vl-72b — 80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064;
M-RoPE, dynamic resolution (vision frontend is a STUB: input_specs provides
precomputed patch embeddings).  [arXiv:2409.12191; hf]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-72b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=29568, vocab=152064, head_dim=128,
    rope="mrope", mrope_sections=(16, 24, 24),
    act="swiglu", norm="rmsnorm", vlm_patches=1024,
)
