"""Assigned architecture configs (``--arch <id>``).

Each module exports ``CONFIG`` (the exact published configuration) and the
registry below maps arch ids to them.  ``SHAPES`` defines the assigned
input-shape set shared by all LM-family architectures.
"""
from __future__ import annotations

import dataclasses

from repro.models.config import ArchConfig

from . import (glm4_9b, granite_8b, grok_1_314b, jamba_v01_52b, olmoe_1b_7b,
               phi4_mini_38b, qwen2_vl_72b, whisper_large_v3, xlstm_13b,
               yi_6b)

ARCHS: dict[str, ArchConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (grok_1_314b, olmoe_1b_7b, whisper_large_v3, glm4_9b, yi_6b,
              phi4_mini_38b, granite_8b, xlstm_13b, jamba_v01_52b,
              qwen2_vl_72b)
}


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # train | prefill | decode


SHAPES: dict[str, Shape] = {
    "train_4k": Shape("train_4k", 4096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32768, 128, "decode"),
    "long_500k": Shape("long_500k", 524288, 1, "decode"),
}


def get_arch(name: str) -> ArchConfig:
    try:
        return ARCHS[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}") from None


def cell_applicable(arch: ArchConfig, shape: Shape) -> tuple[bool, str]:
    """Whether an (arch x shape) dry-run cell applies, with the reason.

    ``long_500k`` needs sub-quadratic attention: only SSM/hybrid families run
    it (DESIGN.md §Arch-applicability); pure full-attention archs skip.
    """
    if shape.name == "long_500k" and not arch.is_subquadratic:
        return False, "long_500k skipped: pure full-attention arch (O(L^2))"
    return True, ""
