"""Token data pipeline: deterministic, shard-aware, resumable.

Every batch is a pure function of (seed, step), so a restarted job resumes
mid-epoch with no data-order drift — the property the fault-tolerance layer
(runtime/fault.py) relies on.  Sources: a synthetic Zipf stream (default),
or a memory-mapped token file.  A background prefetch thread keeps
``prefetch`` batches ready so host-side generation overlaps device compute.
"""
from __future__ import annotations

import dataclasses
import pathlib
import queue
import threading
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    source: str = "synthetic"           # synthetic | file
    path: str | None = None
    zipf_a: float = 1.2
    prefetch: int = 2


class TokenPipeline:
    """Deterministic batches of (tokens, labels), step-indexed."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self._tokens = None
        if cfg.source == "file":
            assert cfg.path, "file source needs a path"
            self._tokens = np.memmap(pathlib.Path(cfg.path), dtype=np.int32,
                                     mode="r")

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        """The batch for ``step`` — pure, so restart-safe."""
        c = self.cfg
        n = c.global_batch * (c.seq_len + 1)
        if self._tokens is not None:
            start = (step * n) % max(len(self._tokens) - n, 1)
            flat = np.asarray(self._tokens[start:start + n], np.int32)
        else:
            rng = np.random.default_rng((c.seed, step))
            flat = rng.zipf(c.zipf_a, size=n).astype(np.int32) % c.vocab
        flat = flat.reshape(c.global_batch, c.seq_len + 1)
        return {"tokens": flat[:, :-1].copy(), "labels": flat[:, 1:].copy()}

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        return self.iter_from(0)

    def iter_from(self, start_step: int) -> Iterator[dict[str, np.ndarray]]:
        """Prefetching iterator resuming at ``start_step``."""
        q: queue.Queue = queue.Queue(maxsize=self.cfg.prefetch)
        stop = threading.Event()

        def worker():
            step = start_step
            while not stop.is_set():
                try:
                    q.put(self.batch_at(step), timeout=0.2)
                    step += 1
                except queue.Full:
                    continue

        t = threading.Thread(target=worker, daemon=True)
        t.start()
        try:
            while True:
                yield q.get()
        finally:
            stop.set()


def write_token_file(path: str, tokens: np.ndarray) -> None:
    np.asarray(tokens, np.int32).tofile(path)
